#!/usr/bin/env python3
"""Diff two BENCH_JSON trajectories (advisory perf report for CI).

Usage: bench_diff.py PREV.json CURR.json [--key throughput_eps]

Each file holds one JSON object per line with a "bench" name plus numeric
fields (see rust/benches/harness.rs::json_line).  Lines are joined on the
bench name; for every bench present in both runs the chosen metric's
relative change is printed, with the batch-native serving sweep
(`e2e_serving/batch_sweep/...`) broken out first — that's the trajectory
the batched-execution work is measured by.

Exit code is always 0: shared-runner perf is noisy, so this report is
advisory and must never fail the job.
"""

import json
import sys


def load(path):
    out = {}
    try:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                name = rec.get("bench")
                if isinstance(name, str):
                    # last occurrence wins (benches may append reruns)
                    out[name] = rec
    except OSError as e:
        print(f"(bench_diff: cannot read {path}: {e})")
    return out


def metric(rec, key):
    v = rec.get(key)
    return v if isinstance(v, (int, float)) else None


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 0
    prev_path, curr_path = argv[1], argv[2]
    key = "throughput_eps"
    if "--key" in argv:
        key_at = argv.index("--key") + 1
        if key_at < len(argv):
            key = argv[key_at]
        else:
            print("(bench_diff: --key given without a value; using throughput_eps)")
    prev, curr = load(prev_path), load(curr_path)
    if not prev and not curr:
        print(f"(bench_diff: nothing to compare — prev={len(prev)} curr={len(curr)} lines)")
        return 0

    shared = sorted(set(prev) & set(curr))
    sweeps = [n for n in shared if "/batch_sweep/" in n]
    others = [n for n in shared if "/batch_sweep/" not in n]

    def pick_key(rec, wanted, fallback):
        # --key, then the timing fallback, then the first numeric field
        # (sorted for determinism) so metric-only lines — e.g. the
        # mixed-vs-uniform resource totals, which carry dsp/ff/lut/bram18
        # and no mean_ns — still show up in the value diff
        for k in (wanted, fallback):
            if metric(rec, k) is not None:
                return k
        for k in sorted(rec):
            if k != "bench" and metric(rec, k) is not None:
                return k
        return None

    def report(names, title, fallback_key):
        rows = []
        for n in names:
            k = pick_key(curr[n], key, fallback_key)
            if k is None:
                continue
            a, b = metric(prev[n], k), metric(curr[n], k)
            if a is None or b is None or a == 0:
                continue
            rows.append((n, k, a, b, (b - a) / abs(a) * 100.0))
        if not rows:
            return
        print(f"\n== {title} ==")
        for n, k, a, b, pct in rows:
            arrow = "+" if pct >= 0 else ""
            print(f"  {n:<60} {k}: {a:,.0f} -> {b:,.0f}  ({arrow}{pct:.1f}%)")

    report(sweeps, "batch-native serving sweep vs previous run", "mean_ns")
    report(others, "other benches vs previous run", "mean_ns")
    # added/removed bench keys are lifecycle events, not errors: a rename
    # shows up as one "gone" plus one "new" and must never break the
    # (always-advisory) diff
    dropped = sorted(set(prev) - set(curr))
    added = sorted(set(curr) - set(prev))
    if dropped:
        names = ", ".join(dropped[:10]) + (" ..." if len(dropped) > 10 else "")
        print(f"\n(benches gone since last run: {names})")
    if added:
        names = ", ".join(added[:10]) + (" ..." if len(added) > 10 else "")
        print(f"(new benches this run: {names})")
    print(
        f"\n(bench_diff summary: {len(shared)} shared, "
        f"{len(added)} new, {len(dropped)} gone)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
