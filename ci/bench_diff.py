#!/usr/bin/env python3
"""Diff two BENCH_JSON trajectories (perf report for CI).

Usage: bench_diff.py PREV.json CURR.json [--key throughput_eps]
                     [--fail-on-regression PCT]

Each file holds one JSON object per line with a "bench" name plus numeric
fields (see rust/benches/harness.rs::json_line).  Lines are joined on the
bench name; for every bench present in both runs the chosen metric's
relative change is printed, with the batch-native serving sweep
(`e2e_serving/batch_sweep/...`) broken out first — that's the trajectory
the batched-execution work is measured by.

By default the report is advisory and always exits 0: shared-runner perf
is noisy.  With `--fail-on-regression PCT` the diff additionally scans
every *latency-keyed* metric shared by both runs — fields ending in
`_ns` or `_cycles`, or containing `latency` — and exits nonzero if any
grew by more than PCT percent.  Latency keys are where lower is strictly
better (wall-clock percentiles, modeled FPGA cycles), so a guarded
increase is a real regression rather than a rebalanced trade-off.
*Speedup-keyed* metrics — fields ending in `speedup_x` or containing
`speedup` — gate in the opposite direction: they are ratios where higher
is better (integer path vs f64 reference, compiled plan vs per-call
lift), so a DROP of more than PCT percent exits nonzero.
*Throughput-keyed* metrics — fields ending in `_sps` or containing
`throughput` — gate the same way as speedups: rates where higher is
better (events/s, sustained samples/s), so a DROP of more than PCT
percent exits nonzero.

Under `--fail-on-regression`, a latency, speedup or throughput series
that was tracked in the previous run and is missing from the current
one — the whole bench gone, or just the field — is also a hard error: a
gating lane must not go silently green because the regressed series
stopped being emitted.  Renames and removals in advisory mode remain
lifecycle notes, not errors.

With `--plans`, PREV and CURR are instead `repro lint-plan --json`
verifier reports (one JSON object per line keyed "plan", carrying
"errors"/"warnings" counts and a "diagnostics" array).  The diff is
always gating in this mode: any plan that was clean (errors == 0) in the
previous run and carries verifier ERRORs now exits 1, printing the
gained ERROR diagnostics.  Added and removed plans are lifecycle notes,
exactly like bench renames.
"""

import json
import sys

LATENCY_SUFFIXES = ("_ns", "_cycles")


def load(path):
    out = {}
    try:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                name = rec.get("bench")
                if isinstance(name, str):
                    # last occurrence wins (benches may append reruns)
                    out[name] = rec
    except OSError as e:
        print(f"(bench_diff: cannot read {path}: {e})")
    return out


def load_plans(path):
    """Like load(), but joined on the verifier report's "plan" key."""
    out = {}
    try:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                name = rec.get("plan")
                if isinstance(name, str):
                    # last occurrence wins (lint-plan appends reruns)
                    out[name] = rec
    except OSError as e:
        print(f"(bench_diff: cannot read {path}: {e})")
    return out


def error_count(rec):
    v = rec.get("errors")
    return v if isinstance(v, int) and not isinstance(v, bool) else None


def plan_verdict_regressions(prev, curr):
    """(plan, curr_errors, error_diags) for every plan that was clean in
    the previous run and carries verifier ERRORs in the current one."""
    rows = []
    for name in sorted(set(prev) & set(curr)):
        a, b = error_count(prev[name]), error_count(curr[name])
        if a is None or b is None:
            continue
        if a == 0 and b > 0:
            diags = [
                d
                for d in curr[name].get("diagnostics", [])
                if isinstance(d, dict) and d.get("severity") == "error"
            ]
            rows.append((name, b, diags))
    return rows


def plans_main(prev_path, curr_path):
    prev, curr = load_plans(prev_path), load_plans(curr_path)
    if not prev and not curr:
        print(f"(bench_diff: nothing to compare — prev={len(prev)} curr={len(curr)} plans)")
        return 0
    shared = sorted(set(prev) & set(curr))
    if shared:
        print("== plan verification verdicts vs previous run ==")
        for name in shared:
            a, b = error_count(prev[name]), error_count(curr[name])
            print(f"  {name:<50} errors: {a} -> {b}")
    dropped = sorted(set(prev) - set(curr))
    added = sorted(set(curr) - set(prev))
    if dropped:
        print(f"(plans gone since last run: {', '.join(dropped)})")
    if added:
        print(f"(new plans this run: {', '.join(added)})")
    regressions = plan_verdict_regressions(prev, curr)
    if regressions:
        print("\n== previously-clean plans now carrying verifier ERRORs (gating) ==")
        for name, n_errors, diags in regressions:
            print(f"  {name}: {n_errors} error(s)")
            for d in diags:
                site = d.get("site", "?")
                msg = d.get("message", "?")
                print(f"    site '{site}': {msg}")
        return 1
    print("(no previously-clean plan gained verifier errors)")
    return 0


def metric(rec, key):
    v = rec.get(key)
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) else None


def is_latency_key(key):
    return key.endswith(LATENCY_SUFFIXES) or "latency" in key


def is_speedup_key(key):
    return key.endswith("speedup_x") or "speedup" in key


def is_throughput_key(key):
    return key.endswith("_sps") or "throughput" in key


def latency_regressions(prev, curr, shared, threshold_pct):
    """(bench, key, prev, curr, pct) for every latency-keyed metric that
    grew past the threshold."""
    rows = []
    for name in shared:
        keys = set(prev[name]) & set(curr[name])
        for key in sorted(keys):
            if key == "bench" or not is_latency_key(key):
                continue
            a, b = metric(prev[name], key), metric(curr[name], key)
            if a is None or b is None or a <= 0:
                continue
            pct = (b - a) / a * 100.0
            if pct > threshold_pct:
                rows.append((name, key, a, b, pct))
    return rows


def speedup_regressions(prev, curr, shared, threshold_pct):
    """(bench, key, prev, curr, pct) for every speedup-keyed metric that
    DROPPED past the threshold (speedups are higher-is-better ratios, so
    the gate is the mirror image of the latency one)."""
    rows = []
    for name in shared:
        keys = set(prev[name]) & set(curr[name])
        for key in sorted(keys):
            if key == "bench" or not is_speedup_key(key):
                continue
            a, b = metric(prev[name], key), metric(curr[name], key)
            if a is None or b is None or a <= 0:
                continue
            pct = (a - b) / a * 100.0
            if pct > threshold_pct:
                rows.append((name, key, a, b, pct))
    return rows


def throughput_regressions(prev, curr, shared, threshold_pct):
    """(bench, key, prev, curr, pct) for every throughput-keyed metric
    that DROPPED past the threshold — like speedups, throughputs are
    higher-is-better rates, so the gate mirrors the latency one."""
    rows = []
    for name in shared:
        keys = set(prev[name]) & set(curr[name])
        for key in sorted(keys):
            if key == "bench" or not is_throughput_key(key):
                continue
            a, b = metric(prev[name], key), metric(curr[name], key)
            if a is None or b is None or a <= 0:
                continue
            pct = (a - b) / a * 100.0
            if pct > threshold_pct:
                rows.append((name, key, a, b, pct))
    return rows


def vanished_latency_series(prev, curr):
    """(bench, key) for every latency series the previous run tracked
    that the current run no longer emits — either the bench vanished
    entirely or the record lost its latency field."""
    rows = []
    for name in sorted(prev):
        for key in sorted(prev[name]):
            if key == "bench" or not is_latency_key(key):
                continue
            if metric(prev[name], key) is None:
                continue
            if name not in curr or metric(curr.get(name, {}), key) is None:
                rows.append((name, key))
    return rows


def vanished_speedup_series(prev, curr):
    """Speedup twin of vanished_latency_series: a tracked speedup ratio
    the current run stopped emitting is a hard error under the gate."""
    rows = []
    for name in sorted(prev):
        for key in sorted(prev[name]):
            if key == "bench" or not is_speedup_key(key):
                continue
            if metric(prev[name], key) is None:
                continue
            if name not in curr or metric(curr.get(name, {}), key) is None:
                rows.append((name, key))
    return rows


def vanished_throughput_series(prev, curr):
    """Throughput twin of vanished_latency_series: a tracked rate the
    current run stopped emitting is a hard error under the gate."""
    rows = []
    for name in sorted(prev):
        for key in sorted(prev[name]):
            if key == "bench" or not is_throughput_key(key):
                continue
            if metric(prev[name], key) is None:
                continue
            if name not in curr or metric(curr.get(name, {}), key) is None:
                rows.append((name, key))
    return rows


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 0
    prev_path, curr_path = argv[1], argv[2]
    if "--plans" in argv:
        return plans_main(prev_path, curr_path)
    key = "throughput_eps"
    if "--key" in argv:
        key_at = argv.index("--key") + 1
        if key_at < len(argv):
            key = argv[key_at]
        else:
            print("(bench_diff: --key given without a value; using throughput_eps)")
    fail_pct = None
    if "--fail-on-regression" in argv:
        at = argv.index("--fail-on-regression") + 1
        if at < len(argv):
            try:
                fail_pct = float(argv[at])
            except ValueError:
                print(
                    f"(bench_diff: --fail-on-regression '{argv[at]}' is not a number; "
                    "staying advisory)"
                )
        else:
            print(
                "(bench_diff: --fail-on-regression given without a value; "
                "staying advisory)"
            )
    prev, curr = load(prev_path), load(curr_path)
    if not prev and not curr:
        print(f"(bench_diff: nothing to compare — prev={len(prev)} curr={len(curr)} lines)")
        return 0

    shared = sorted(set(prev) & set(curr))
    sweeps = [n for n in shared if "/batch_sweep/" in n]
    others = [n for n in shared if "/batch_sweep/" not in n]

    def pick_key(rec, wanted, fallback):
        # --key, then the timing fallback, then the first numeric field
        # (sorted for determinism) so metric-only lines — e.g. the
        # mixed-vs-uniform resource totals, which carry dsp/ff/lut/bram18
        # and no mean_ns — still show up in the value diff
        for k in (wanted, fallback):
            if metric(rec, k) is not None:
                return k
        for k in sorted(rec):
            if k != "bench" and metric(rec, k) is not None:
                return k
        return None

    def report(names, title, fallback_key):
        rows = []
        for n in names:
            k = pick_key(curr[n], key, fallback_key)
            if k is None:
                continue
            a, b = metric(prev[n], k), metric(curr[n], k)
            if a is None or b is None or a == 0:
                continue
            rows.append((n, k, a, b, (b - a) / abs(a) * 100.0))
        if not rows:
            return
        print(f"\n== {title} ==")
        for n, k, a, b, pct in rows:
            arrow = "+" if pct >= 0 else ""
            print(f"  {n:<60} {k}: {a:,.0f} -> {b:,.0f}  ({arrow}{pct:.1f}%)")

    report(sweeps, "batch-native serving sweep vs previous run", "mean_ns")
    report(others, "other benches vs previous run", "mean_ns")
    # added/removed bench keys are lifecycle events, not errors: a rename
    # shows up as one "gone" plus one "new" and must never break the
    # (advisory-by-default) diff
    dropped = sorted(set(prev) - set(curr))
    added = sorted(set(curr) - set(prev))
    if dropped:
        names = ", ".join(dropped[:10]) + (" ..." if len(dropped) > 10 else "")
        print(f"\n(benches gone since last run: {names})")
    if added:
        names = ", ".join(added[:10]) + (" ..." if len(added) > 10 else "")
        print(f"(new benches this run: {names})")
    print(
        f"\n(bench_diff summary: {len(shared)} shared, "
        f"{len(added)} new, {len(dropped)} gone)"
    )
    if fail_pct is not None:
        failed = False
        regressions = latency_regressions(prev, curr, shared, fail_pct)
        if regressions:
            print(f"\n== latency regressions past {fail_pct:g}% (gating) ==")
            for n, k, a, b, pct in regressions:
                print(f"  {n:<60} {k}: {a:,.0f} -> {b:,.0f}  (+{pct:.1f}%)")
            failed = True
        slower = speedup_regressions(prev, curr, shared, fail_pct)
        if slower:
            print(f"\n== speedup drops past {fail_pct:g}% (gating) ==")
            for n, k, a, b, pct in slower:
                print(f"  {n:<60} {k}: {a:.2f}x -> {b:.2f}x  (-{pct:.1f}%)")
            failed = True
        slower_rates = throughput_regressions(prev, curr, shared, fail_pct)
        if slower_rates:
            print(f"\n== throughput drops past {fail_pct:g}% (gating) ==")
            for n, k, a, b, pct in slower_rates:
                print(f"  {n:<60} {k}: {a:,.0f} -> {b:,.0f}  (-{pct:.1f}%)")
            failed = True
        vanished = vanished_latency_series(prev, curr)
        if vanished:
            print("\n== latency series missing from the current run (gating) ==")
            for n, k in vanished:
                print(f"  {n:<60} {k}: tracked last run, not emitted now")
            failed = True
        vanished_speedups = vanished_speedup_series(prev, curr)
        if vanished_speedups:
            print("\n== speedup series missing from the current run (gating) ==")
            for n, k in vanished_speedups:
                print(f"  {n:<60} {k}: tracked last run, not emitted now")
            failed = True
        vanished_rates = vanished_throughput_series(prev, curr)
        if vanished_rates:
            print("\n== throughput series missing from the current run (gating) ==")
            for n, k in vanished_rates:
                print(f"  {n:<60} {k}: tracked last run, not emitted now")
            failed = True
        if failed:
            return 1
        print(
            f"(no latency-, speedup- or throughput-keyed metric regressed "
            f"past {fail_pct:g}%)"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
