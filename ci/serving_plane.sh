#!/usr/bin/env bash
# Gating CI lane for the network serving plane: a release `repro serve`
# on loopback, driven by `repro send` with a bursty flood and a
# mid-stream hot plan swap, observed only through the scrapeable
# Prometheus endpoint.  Asserts:
#
#   * the endpoint serves valid exposition text while the server runs,
#   * zero events shed and zero dropped across the flood AND the swap,
#   * exactly one completed plan swap,
#   * the autoscaler left the floor (>= 2 shards) under the flood and
#     stayed inside the 1..4 band,
#   * the scraped counters agree with the server's own final report.
#
# Env: BIN (default rust/target/release/repro), SERVE_ADDR, METRICS_ADDR.
set -euo pipefail

BIN=${BIN:-rust/target/release/repro}
SERVE_ADDR=${SERVE_ADDR:-127.0.0.1:17071}
METRICS_ADDR=${METRICS_ADDR:-127.0.0.1:17091}
EVENTS=4000
SWAP_AT=2000

work=$(mktemp -d)
SERVE_PID=""
cleanup() {
    [ -n "$SERVE_PID" ] && kill "$SERVE_PID" 2>/dev/null || true
    rm -rf "$work"
}
trap cleanup EXIT

metric() {
    # first sample whose name{labels} matches exactly (prometheus text
    # puts the value in field 2); empty if the scrape or metric is absent
    curl -sf "http://$METRICS_ADDR/metrics" \
        | awk -v m="$1" '$1 == m { print $2; exit }'
}

"$BIN" serve --backend hls --models engine --listen "$SERVE_ADDR" \
    --metrics-addr "$METRICS_ADDR" --autoscale 1..4 --ring 4096 \
    >"$work/serve.log" 2>&1 &
SERVE_PID=$!

echo "== waiting for the metrics endpoint"
up=""
for _ in $(seq 1 150); do
    if curl -sf "http://$METRICS_ADDR/metrics" >/dev/null 2>&1; then
        up=1
        break
    fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "FAIL: server exited before coming up"
        cat "$work/serve.log"
        exit 1
    fi
    sleep 0.2
done
[ -n "$up" ] || { echo "FAIL: metrics endpoint never came up"; cat "$work/serve.log"; exit 1; }

echo "== scrape 1: exposition sanity"
curl -s "http://$METRICS_ADDR/metrics" >"$work/scrape1.txt"
grep -q '^# TYPE repro_event_latency_ns histogram$' "$work/scrape1.txt"
grep -q '^# TYPE repro_events_shed_total counter$' "$work/scrape1.txt"
grep -q 'repro_shards{model="engine"} 1$' "$work/scrape1.txt"

# an unpaced flood (rate 0) far outruns inference, so the queue depth
# must cross the scale-up threshold; 4000 events < ring 4096 bounds the
# worst-case backlog below capacity, so ANY shed is a real bug
echo "block0.ffn1 ap_fixed<18,8>" >"$work/swap.plan"
echo "== driving $EVENTS events with a hot swap at $SWAP_AT"
"$BIN" send --to "$SERVE_ADDR" --model engine --events "$EVENTS" \
    --rate 0 --burst 64 --seed 7 \
    --swap-at "$SWAP_AT" --precision-plan "$work/swap.plan"

echo "== scrape 2 (mid-drain), then poll until everything is scored"
mid=$(metric 'repro_events_scored_total{model="engine"}')
echo "   mid-drain scored=$mid"
scored=""
for _ in $(seq 1 600); do
    scored=$(metric 'repro_events_scored_total{model="engine"}')
    [ "${scored:-0}" = "$EVENTS" ] && break
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "FAIL: server died mid-drain"
        cat "$work/serve.log"
        exit 1
    fi
    sleep 0.5
done
[ "${scored:-0}" = "$EVENTS" ] || {
    echo "FAIL: scored $scored of $EVENTS"
    curl -s "http://$METRICS_ADDR/metrics"
    exit 1
}
[ "${mid:-0}" -le "$scored" ] || { echo "FAIL: counter went backwards"; exit 1; }

echo "== final scrape: zero-loss + swap + autoscale assertions"
accepted=$(metric 'repro_events_accepted_total{model="engine"}')
shed=$(metric 'repro_events_shed_total{model="engine"}')
dropped=$(metric 'repro_events_dropped_total{model="engine"}')
swaps=$(metric 'repro_plan_swaps_total{model="engine"}')
ups=$(metric 'repro_scale_ups_total{model="engine"}')
shards=$(metric 'repro_shards{model="engine"}')
hist_count=$(metric 'repro_event_latency_ns_count{model="engine"}')
echo "   accepted=$accepted shed=$shed dropped=$dropped swaps=$swaps" \
     "scale_ups=$ups shards=$shards hist_count=$hist_count"
[ "$accepted" = "$EVENTS" ] || { echo "FAIL: accepted != $EVENTS"; exit 1; }
[ "$shed" = "0" ] || { echo "FAIL: events shed under a sub-capacity flood"; exit 1; }
[ "$dropped" = "0" ] || { echo "FAIL: events dropped across the hot swap"; exit 1; }
[ "$swaps" = "1" ] || { echo "FAIL: expected exactly 1 completed plan swap"; exit 1; }
[ "$hist_count" = "$EVENTS" ] || { echo "FAIL: latency histogram disagrees with scored"; exit 1; }
[ "${ups:-0}" -ge 1 ] || { echo "FAIL: autoscaler never scaled up under the flood"; exit 1; }
[ "$shards" -ge 2 ] && [ "$shards" -le 4 ] || { echo "FAIL: width $shards outside 2..4"; exit 1; }

echo "== shutdown and scrape-vs-report agreement"
"$BIN" send --to "$SERVE_ADDR" --shutdown
wait "$SERVE_PID"
SERVE_PID=""
cat "$work/serve.log"
rep_accepted=$(grep -o 'accepted=[0-9]*' "$work/serve.log" | head -1 | cut -d= -f2)
rep_shed=$(grep -o 'shed=[0-9]*' "$work/serve.log" | head -1 | cut -d= -f2)
rep_dropped=$(grep -o 'dropped=[0-9]*' "$work/serve.log" | head -1 | cut -d= -f2)
[ "$rep_accepted" = "$accepted" ] || { echo "FAIL: report accepted=$rep_accepted vs scraped $accepted"; exit 1; }
[ "$rep_shed" = "$shed" ] || { echo "FAIL: report shed=$rep_shed vs scraped $shed"; exit 1; }
[ "$rep_dropped" = "$dropped" ] || { echo "FAIL: report dropped=$rep_dropped vs scraped $dropped"; exit 1; }

echo "OK: $EVENTS events, 0 shed, 0 dropped, 1 hot swap, width $shards"
