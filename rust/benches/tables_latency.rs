//! Bench: regenerates Tables II, III, IV (paper vs measured) and times
//! the synthesis pass itself.  `cargo bench --bench tables_latency`.

mod harness;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::experiments::{artifacts_ready, latency_tables, load_checkpoints};
use hls4ml_transformer::hls::{FixedTransformer, ParallelismPlan, QuantConfig, ReuseFactor};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo;

fn main() {
    harness::section("E3: Tables II-IV — latency/interval/clock vs reuse factor");
    for m in zoo() {
        let weights = if artifacts_ready(&artifacts_dir(), &m.config.name) {
            load_checkpoints(&artifacts_dir(), &m.config).unwrap().0
        } else {
            eprintln!("(synthetic weights for {})", m.config.name);
            synthetic_weights(&m.config, 1)
        };
        println!("\n{}", latency_tables::render(&m.config, &weights));

        // paper-vs-measured deltas, summarized
        let rows = latency_tables::measure(&m.config, &weights);
        let worst = rows
            .iter()
            .map(|(p, r)| {
                (r.latency_cycles as f64 / p.latency_cycles as f64 - 1.0).abs()
            })
            .fold(0.0f64, f64::max);
        println!("worst |latency delta| vs paper: {:.1}%", worst * 100.0);
    }

    harness::section("synthesis pass cost (per design point)");
    for m in zoo() {
        let w = synthetic_weights(&m.config, 2);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let par = ParallelismPlan::uniform(m.config.num_blocks, ReuseFactor(2));
        harness::bench(&format!("synthesize {}", m.config.name), || {
            harness::black_box(t.synthesize(&par));
        });
    }
}
