//! Bench: E6 — end-to-end trigger serving across backends and batch
//! policies.  Reports throughput + latency percentiles per configuration
//! (the testbed analogue of the paper's headline "<2 µs @ R1" claim,
//! which for the FPGA itself is modeled by Tables II-IV).
//! `cargo bench --bench e2e_serving`.

mod harness;

use std::time::Duration;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::coordinator::{
    net, serve_net, BackendKind, BatchPolicy, Frame, NetEvent, NetServeOptions, PipelineConfig,
    ServerConfig, SourceMode, StreamSource, TriggerServer, WeightsSource,
};
use hls4ml_transformer::data::{generator_for, StrainConfig};
use hls4ml_transformer::experiments::artifacts_ready;
use hls4ml_transformer::hls::{FixedTransformer, ParallelismPlan, QuantConfig, ReuseFactor};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo_model;
use hls4ml_transformer::quant::{pareto_explore, EvalSet, ParetoConfig};
use hls4ml_transformer::stream::{analyze, StreamParams};

fn run(model: &'static str, backend: BackendKind, batch: usize, events: u64) {
    let have_artifacts = artifacts_ready(&artifacts_dir(), model);
    if backend == BackendKind::Pjrt && !have_artifacts {
        println!("  SKIP {model}/{backend:?}: artifacts missing");
        return;
    }
    let cfg = ServerConfig {
        pipelines: vec![PipelineConfig {
            batch: BatchPolicy { max_batch: batch, max_wait: Duration::from_micros(200) },
            weights: if have_artifacts {
                WeightsSource::Artifacts
            } else {
                WeightsSource::Synthetic(7)
            },
            ..PipelineConfig::new(model, backend)
        }],
        events_per_source: events,
        rate_per_source: 0,
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    };
    match TriggerServer::run(&cfg) {
        Ok(report) => {
            let s = &report.per_model[model];
            println!(
                "  {model:7} {backend:6?} batch<={batch}  {:>9.0} ev/s  fill {:4.1}  lat {}{}",
                report.throughput_eps(),
                s.mean_batch_fill(),
                s.latency.summary(),
                s.online_auc().map(|a| format!("  auc={a:.3}")).unwrap_or_default(),
            );
            harness::json_line(
                &format!("e2e_serving/{model}/{backend:?}/b{batch}"),
                &[
                    ("throughput_eps", report.throughput_eps()),
                    ("mean_ns", s.latency.mean_ns()),
                    ("p50_ns", s.latency.quantile_ns(0.50) as f64),
                    ("p99_ns", s.latency.quantile_ns(0.99) as f64),
                    ("accepted", s.accepted as f64),
                    ("shed", s.shed as f64),
                    ("dropped", s.dropped as f64),
                ],
            );
        }
        Err(e) => println!("  {model}/{backend:?} FAILED: {e:#}"),
    }
}

/// Batch-size sweep: one model per backend at batch caps 1/2/4/8/16.
/// Float and HLS now execute batch-native (weight-stationary kernels +
/// scratch arena; see `nn`'s batched execution model), so throughput
/// should climb with the cap instead of being flat — this sweep is the
/// measurement behind that claim, and its `BENCH_JSON` lines
/// (`e2e_serving/batch_sweep/...`) are what CI archives and diffs
/// against the previous run.
fn batch_sweep() {
    harness::section("batch-native sweep: engine, batch cap 1/2/4/8/16 per backend");
    println!("(HLS batched output is bitwise identical to per-event — see hls::transformer tests)");
    for (backend, events) in [
        (BackendKind::Float, 8_000u64),
        (BackendKind::Hls, 400),
        (BackendKind::Pjrt, 2_000),
    ] {
        if backend == BackendKind::Pjrt && !artifacts_ready(&artifacts_dir(), "engine") {
            println!("  SKIP engine/Pjrt batch sweep: artifacts missing");
            continue;
        }
        let mut base_eps = 0.0f64;
        for batch in [1usize, 2, 4, 8, 16] {
            let cfg = ServerConfig {
                pipelines: vec![PipelineConfig {
                    batch: BatchPolicy { max_batch: batch, max_wait: Duration::from_micros(200) },
                    weights: if backend == BackendKind::Pjrt {
                        WeightsSource::Artifacts
                    } else {
                        WeightsSource::Synthetic(7)
                    },
                    ..PipelineConfig::new("engine", backend)
                }],
                events_per_source: events,
                rate_per_source: 0,
                artifacts_dir: artifacts_dir(),
                ..Default::default()
            };
            match TriggerServer::run(&cfg) {
                Ok(report) => {
                    let s = &report.per_model["engine"];
                    let eps = report.throughput_eps();
                    if batch == 1 {
                        base_eps = eps;
                    }
                    let speedup = if base_eps > 0.0 { eps / base_eps } else { f64::NAN };
                    println!(
                        "  {backend:6?} batch<={batch:<2} {eps:>9.0} ev/s  x{speedup:.2} vs b1  fill {:4.1}  lat {}",
                        s.mean_batch_fill(),
                        s.latency.summary(),
                    );
                    harness::json_line(
                        &format!("e2e_serving/batch_sweep/engine/{backend:?}/b{batch}"),
                        &[
                            ("batch", batch as f64),
                            ("throughput_eps", eps),
                            ("speedup_vs_b1", speedup),
                            ("mean_fill", s.mean_batch_fill()),
                            ("mean_ns", s.latency.mean_ns()),
                            ("p99_ns", s.latency.quantile_ns(0.99) as f64),
                            ("shed", s.shed as f64),
                            ("dropped", s.dropped as f64),
                        ],
                    );
                }
                Err(e) => println!("  {backend:?} batch<={batch} FAILED: {e:#}"),
            }
        }
    }
}

/// Pool-scaling sweep: the same model and offered load served by worker
/// pools of width 1/2/4/8.  At saturating offered load a 4-wide pool
/// should deliver >= 2x the single-replica throughput on a multi-core
/// host (the PR's acceptance bar).
fn replica_sweep() {
    harness::section("replica scaling: engine/Float pool width 1/2/4/8 at saturating load");
    println!("(one max-rate source; speedup is vs the replicas=1 row)");
    let mut base_eps = 0.0f64;
    for replicas in [1usize, 2, 4, 8] {
        let cfg = ServerConfig {
            pipelines: vec![PipelineConfig {
                replicas,
                weights: WeightsSource::Synthetic(7),
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_micros(200),
                },
                ..PipelineConfig::new("engine", BackendKind::Float)
            }],
            events_per_source: 12_000,
            rate_per_source: 0,
            artifacts_dir: artifacts_dir(),
            ..Default::default()
        };
        match TriggerServer::run(&cfg) {
            Ok(report) => {
                let s = &report.per_model["engine"];
                let eps = report.throughput_eps();
                if replicas == 1 {
                    base_eps = eps;
                }
                // NAN when the r1 baseline failed: json_num serializes it
                // as null, which keeps the archived trajectory honest
                let speedup = if base_eps > 0.0 { eps / base_eps } else { f64::NAN };
                println!(
                    "  replicas={replicas}  {eps:>9.0} ev/s  x{speedup:.2} vs r1  shed={}  lat {}",
                    s.shed,
                    s.latency.summary(),
                );
                harness::json_line(
                    &format!("e2e_serving/replica_sweep/engine/float/r{replicas}"),
                    &[
                        ("replicas", replicas as f64),
                        ("throughput_eps", eps),
                        ("speedup_vs_r1", speedup),
                        ("mean_ns", s.latency.mean_ns()),
                        ("p99_ns", s.latency.quantile_ns(0.99) as f64),
                        ("shed", s.shed as f64),
                        ("dropped", s.dropped as f64),
                    ],
                );
            }
            Err(e) => println!("  replicas={replicas} FAILED: {e:#}"),
        }
    }
}

/// Reuse-plan sweep: the *modeled* FPGA design point (latency / interval
/// / resources from the schedule-derived `synthesize`) for the engine
/// model under uniform reuse R ∈ {1,2,4,8} plus the Pareto-found mixed
/// plan.  Each row is one `BENCH_JSON` line
/// (`e2e_serving/reuse_plan_sweep/...`), so the per-site-parallelism
/// trajectory is archived and diffed by CI alongside the serving
/// throughput numbers — `latency_cycles` here is exactly the quantity
/// `ci/bench_diff.py --fail-on-regression` guards.
fn reuse_plan_sweep() {
    harness::section("reuse-plan sweep: engine modeled design, uniform R 1/2/4/8 + pareto mix");
    let m = zoo_model("engine").expect("zoo model");
    let w = synthetic_weights(&m.config, 7);
    let quant = QuantConfig::new(6, 8);
    let t = FixedTransformer::new(m.config.clone(), &w, quant);
    let emit = |tag: &str, rep: &hls4ml_transformer::hls::SynthesisReport| {
        println!(
            "  {tag:<12} lat {:>5} cyc  II {:>4} cyc  {:>7.3} us  DSP {:>6} FF {:>8}",
            rep.latency_cycles,
            rep.interval_cycles,
            rep.latency_us,
            rep.total.dsp,
            rep.total.ff,
        );
        harness::json_line(
            &format!("e2e_serving/reuse_plan_sweep/engine/{tag}"),
            &[
                ("latency_cycles", rep.latency_cycles as f64),
                ("interval_cycles", rep.interval_cycles as f64),
                ("latency_us", rep.latency_us),
                ("dsp", rep.total.dsp as f64),
                ("ff", rep.total.ff as f64),
                ("bram18", rep.total.bram18 as f64),
            ],
        );
    };
    for r in [1u32, 2, 4, 8] {
        let par = ParallelismPlan::uniform(m.config.num_blocks, ReuseFactor(r));
        emit(&format!("uniform_r{r}"), &t.synthesize(&par));
    }
    // the joint explorer's dominating mixed plan (deterministic greedy
    // phase; tiny eval set — reuse moves never re-score it anyway)
    let eval = EvalSet::synthetic(&m.config, &w, 12, 11);
    let pcfg = ParetoConfig { anneal_iters: 16, ..ParetoConfig::default() };
    let res = pareto_explore(&m.config, &w, &eval, quant, &pcfg);
    match res.mixed_dominator() {
        Some(dom) => {
            let rep = FixedTransformer::with_plan(m.config.clone(), &w, dom.precision.clone())
                .synthesize(&dom.parallelism);
            emit("pareto_mixed", &rep);
            println!("    (mixed plan: {})", dom.parallelism.summary());
        }
        None => println!("  (no mixed-reuse dominator found this run)"),
    }
}

/// Continuous-stream sweep: hop ∈ {S/4, S/2, S} × {Float, Hls} on the
/// engine model with analytic detector weights.  The first workload
/// where sustained throughput is set by *overlap reuse* rather than
/// batch size: halving the hop doubles the windows the backend must
/// score for the same strain seconds, so samples/s falls while
/// windows/s holds.  Each row is one BENCH_JSON line
/// (`e2e_serving/stream_sweep/...`) carrying sustained throughput, p99
/// trigger latency and detection efficiency — archived and diffed by
/// the existing CI bench job.
fn stream_sweep() {
    harness::section("stream sweep: engine strain stream, hop S/4 | S/2 | S per backend");
    println!("(detector weights; efficiency = injected chirps recovered by clustered triggers)");
    let cfg = zoo_model("engine").expect("zoo model").config;
    let s = cfg.seq_len;
    for (backend, samples) in [(BackendKind::Float, 120_000u64), (BackendKind::Hls, 12_000)] {
        for hop in [s / 4, s / 2, s] {
            let server = ServerConfig {
                pipelines: vec![PipelineConfig {
                    weights: WeightsSource::Detector,
                    ring_capacity: 16_384,
                    source: SourceMode::Stream(StreamSource {
                        samples,
                        hop,
                        strain: StrainConfig::new(0xA11CE, cfg.input_size, s),
                        reuse: true,
                    }),
                    ..PipelineConfig::new("engine", backend)
                }],
                events_per_source: 0,
                rate_per_source: 0,
                artifacts_dir: artifacts_dir(),
                ..Default::default()
            };
            match TriggerServer::run(&server) {
                Ok(report) => {
                    let st = &report.per_model["engine"];
                    let truth = report
                        .stream_truth
                        .get("engine")
                        .map(|v| v.as_slice())
                        .unwrap_or(&[]);
                    let sr = analyze(
                        st.windows.clone(),
                        truth,
                        &StreamParams::for_windows(s as u64),
                    );
                    let wall = report.wall.as_secs_f64().max(1e-9);
                    let sps = samples as f64 / wall;
                    let wps = st.windows.len() as f64 / wall;
                    println!(
                        "  {backend:6?} hop {hop:>3}  {sps:>9.0} samples/s  {wps:>7.0} win/s  \
                         eff {:>5.1}%  {}/{} inj  fa {}  trig p99 {:.1}us",
                        100.0 * sr.efficiency(),
                        sr.found,
                        sr.injections,
                        sr.false_alarms,
                        sr.trigger_latency.quantile_ns(0.99) as f64 / 1000.0,
                    );
                    harness::json_line(
                        &format!("e2e_serving/stream_sweep/engine/{backend:?}/hop{hop}"),
                        &[
                            ("hop", hop as f64),
                            ("sustained_sps", sps),
                            ("windows_per_s", wps),
                            ("windows", st.windows.len() as f64),
                            ("shed", st.shed as f64),
                            ("dropped", st.dropped as f64),
                            ("efficiency", sr.efficiency()),
                            ("injections", sr.injections as f64),
                            ("found", sr.found as f64),
                            ("false_alarms", sr.false_alarms as f64),
                            ("trigger_p99_ns", sr.trigger_latency.quantile_ns(0.99) as f64),
                            ("window_p99_ns", st.latency.quantile_ns(0.99) as f64),
                        ],
                    );
                }
                Err(e) => println!("  {backend:?} hop {hop} FAILED: {e:#}"),
            }
        }
    }
}

/// Cross-window reuse sweep: the same strain stream served with the
/// incremental window cache on vs the naive full recompute, hop ∈
/// {S/4, S/2, S} per backend.  Reuse never changes the scores (bitwise,
/// pinned by `stream_integration`), only the work per window: at hop h
/// the per-row prefix reuses (S-h)/S of its MACs and the block-0 raw
/// score block ((S-h)/S)^2 of its dot products, so the speedup should
/// grow as the hop shrinks and collapse to ~1x at hop = S (no overlap).
/// Each hop is one BENCH_JSON line (`e2e_serving/stream_reuse/...`)
/// carrying both sustained throughputs plus `reuse_speedup_x` — the
/// measured series behind EXPERIMENTS.md E13 and, on the engine/Hls
/// hop-S/4 point, the `STREAM_ASSERT_REUSE_SPEEDUP` hotpath CI gate.
fn stream_reuse_sweep() {
    harness::section("stream reuse sweep: incremental vs full recompute, hop S/4 | S/2 | S");
    println!("(same stream both ways; scores are bitwise identical — only the work differs)");
    let cfg = zoo_model("engine").expect("zoo model").config;
    let s = cfg.seq_len;
    let run = |backend: BackendKind, samples: u64, hop: usize, reuse: bool| -> Option<f64> {
        let server = ServerConfig {
            pipelines: vec![PipelineConfig {
                weights: WeightsSource::Detector,
                ring_capacity: 16_384,
                source: SourceMode::Stream(StreamSource {
                    samples,
                    hop,
                    strain: StrainConfig::new(0xCAFE, cfg.input_size, s),
                    reuse,
                }),
                ..PipelineConfig::new("engine", backend)
            }],
            events_per_source: 0,
            rate_per_source: 0,
            artifacts_dir: artifacts_dir(),
            ..Default::default()
        };
        match TriggerServer::run(&server) {
            Ok(report) => {
                let wall = report.wall.as_secs_f64().max(1e-9);
                Some(samples as f64 / wall)
            }
            Err(e) => {
                println!("  {backend:?} hop {hop} reuse={reuse} FAILED: {e:#}");
                None
            }
        }
    };
    for (backend, samples) in [(BackendKind::Float, 120_000u64), (BackendKind::Hls, 12_000)] {
        for hop in [s / 4, s / 2, s] {
            let (Some(inc), Some(full)) =
                (run(backend, samples, hop, true), run(backend, samples, hop, false))
            else {
                continue;
            };
            let speedup = inc / full;
            println!(
                "  {backend:6?} hop {hop:>3}  incremental {inc:>9.0} samples/s  \
                 full {full:>9.0} samples/s  x{speedup:.2}",
            );
            harness::json_line(
                &format!("e2e_serving/stream_reuse/engine/{backend:?}/hop{hop}"),
                &[
                    ("hop", hop as f64),
                    ("incremental_sps", inc),
                    ("full_sps", full),
                    ("reuse_speedup_x", speedup),
                ],
            );
        }
    }
}

/// Network serving plane over loopback: the same engine/Float pipeline
/// fed through the length-prefixed TCP framing (`repro serve --listen`)
/// instead of an in-process source.  Measures the sustained wire-to-score
/// rate of one connection -> dispatcher -> pool path; the BENCH_JSON row
/// (`e2e_serving/net_loopback/...`) archives it next to the in-process
/// numbers so framing+dispatch overhead stays visible as a series.
fn net_loopback() {
    harness::section("network serving plane: engine/Float over loopback TCP framing");
    let events = 20_000u64;
    let cfg = ServerConfig {
        pipelines: vec![PipelineConfig {
            weights: WeightsSource::Synthetic(7),
            ring_capacity: 8192,
            ..PipelineConfig::new("engine", BackendKind::Float)
        }],
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_net(&cfg, listener, NetServeOptions { metrics: None, autoscale: None })
    });
    let mut conn = std::net::TcpStream::connect(addr).expect("connect loopback");
    conn.set_nodelay(true).ok();
    let mut gen = generator_for("engine", 7).expect("zoo generator");
    let t0 = std::time::Instant::now();
    for i in 0..events {
        let e = gen.next_event();
        net::write_frame(
            &mut conn,
            &Frame::Event(NetEvent {
                id: i,
                model: "engine".into(),
                x: e.x,
                label: Some(e.label),
                stream_pos: None,
            }),
        )
        .expect("write frame");
    }
    let send_wall = t0.elapsed().as_secs_f64().max(1e-9);
    net::write_frame(&mut conn, &Frame::Shutdown).expect("write shutdown");
    drop(conn);
    let report = server.join().expect("server thread").expect("server report");
    let s = &report.per_model["engine"];
    let eps = report.throughput_eps();
    println!(
        "  wire rate {:>9.0} ev/s  scored {eps:>9.0} ev/s  accepted={} shed={} dropped={}  lat {}",
        events as f64 / send_wall,
        s.accepted,
        s.shed,
        s.dropped,
        s.latency.summary(),
    );
    harness::json_line(
        "e2e_serving/net_loopback/engine/Float",
        &[
            ("events", events as f64),
            ("wire_eps", events as f64 / send_wall),
            ("throughput_eps", eps),
            ("accepted", s.accepted as f64),
            ("shed", s.shed as f64),
            ("dropped", s.dropped as f64),
            ("mean_ns", s.latency.mean_ns()),
            ("p99_ns", s.latency.quantile_ns(0.99) as f64),
        ],
    );
}

fn main() {
    harness::section("E6: end-to-end trigger serving (throughput / latency)");
    println!("(sources run at max rate; latency includes queueing + batching)");

    for model in ["engine", "btag", "gw"] {
        run(model, BackendKind::Float, 1, 4000);
        run(model, BackendKind::Float, 8, 4000);
        run(model, BackendKind::Hls, 8, 300);
        run(model, BackendKind::Pjrt, 1, 1500);
        run(model, BackendKind::Pjrt, 8, 3000);
        println!();
    }

    batch_sweep();

    replica_sweep();

    reuse_plan_sweep();

    stream_sweep();

    stream_reuse_sweep();

    net_loopback();

    harness::section("multi-model concurrent serving (all three pipelines)");
    let cfg = ServerConfig {
        pipelines: ["engine", "btag", "gw"]
            .into_iter()
            .map(|m| {
                let have = artifacts_ready(&artifacts_dir(), m);
                PipelineConfig {
                    weights: if have {
                        WeightsSource::Artifacts
                    } else {
                        WeightsSource::Synthetic(3)
                    },
                    ..PipelineConfig::new(m, BackendKind::Float)
                }
            })
            .collect(),
        events_per_source: 2000,
        rate_per_source: 0,
        artifacts_dir: artifacts_dir(),
        ..Default::default()
    };
    let report = TriggerServer::run(&cfg).unwrap();
    print!("{report}");
}
