//! Bench: E6 — end-to-end trigger serving across backends and batch
//! policies.  Reports throughput + latency percentiles per configuration
//! (the testbed analogue of the paper's headline "<2 µs @ R1" claim,
//! which for the FPGA itself is modeled by Tables II-IV).
//! `cargo bench --bench e2e_serving`.

mod harness;

use std::time::Duration;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::coordinator::{
    BackendKind, BatchPolicy, PipelineConfig, ServerConfig, TriggerServer, WeightsSource,
};
use hls4ml_transformer::experiments::artifacts_ready;

fn run(model: &'static str, backend: BackendKind, batch: usize, events: u64) {
    let have_artifacts = artifacts_ready(&artifacts_dir(), model);
    if backend == BackendKind::Pjrt && !have_artifacts {
        println!("  SKIP {model}/{backend:?}: artifacts missing");
        return;
    }
    let cfg = ServerConfig {
        pipelines: vec![PipelineConfig {
            batch: BatchPolicy { max_batch: batch, max_wait: Duration::from_micros(200) },
            weights: if have_artifacts {
                WeightsSource::Artifacts
            } else {
                WeightsSource::Synthetic(7)
            },
            ..PipelineConfig::new(model, backend)
        }],
        events_per_source: events,
        rate_per_source: 0,
        artifacts_dir: artifacts_dir(),
    };
    match TriggerServer::run(&cfg) {
        Ok(report) => {
            let s = &report.per_model[model];
            println!(
                "  {model:7} {backend:6?} batch<={batch}  {:>9.0} ev/s  fill {:4.1}  lat {}{}",
                report.throughput_eps(),
                s.mean_batch_fill(),
                s.latency.summary(),
                s.online_auc().map(|a| format!("  auc={a:.3}")).unwrap_or_default(),
            );
        }
        Err(e) => println!("  {model}/{backend:?} FAILED: {e:#}"),
    }
}

fn main() {
    harness::section("E6: end-to-end trigger serving (throughput / latency)");
    println!("(sources run at max rate; latency includes queueing + batching)");

    for model in ["engine", "btag", "gw"] {
        run(model, BackendKind::Float, 1, 4000);
        run(model, BackendKind::Float, 8, 4000);
        run(model, BackendKind::Hls, 8, 300);
        run(model, BackendKind::Pjrt, 1, 1500);
        run(model, BackendKind::Pjrt, 8, 3000);
        println!();
    }

    harness::section("multi-model concurrent serving (all three pipelines)");
    let cfg = ServerConfig {
        pipelines: ["engine", "btag", "gw"]
            .into_iter()
            .map(|m| {
                let have = artifacts_ready(&artifacts_dir(), m);
                PipelineConfig {
                    weights: if have {
                        WeightsSource::Artifacts
                    } else {
                        WeightsSource::Synthetic(3)
                    },
                    ..PipelineConfig::new(m, BackendKind::Float)
                }
            })
            .collect(),
        events_per_source: 2000,
        rate_per_source: 0,
        artifacts_dir: artifacts_dir(),
    };
    let report = TriggerServer::run(&cfg).unwrap();
    print!("{report}");
}
