//! Bench: regenerates Figures 9, 10, 11 (AUC ratio vs fractional bits,
//! PTQ + QAT, integer widths 6..10) over the exported eval tensors.
//! Requires `make artifacts`.  `cargo bench --bench figures_auc`.
//!
//! Environment knobs: REPRO_AUC_EVENTS (default 192),
//! REPRO_AUC_FULL=1 for the paper's full 5x10 integer/fraction grid.

mod harness;

use std::time::Instant;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::experiments::{artifacts_ready, auc_figures, load_checkpoints};
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::quant::EvalSet;

fn main() {
    let dir = artifacts_dir();
    let events: usize = std::env::var("REPRO_AUC_EVENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);
    let full = std::env::var("REPRO_AUC_FULL").map(|v| v == "1").unwrap_or(false);
    let ints: Vec<u32> = if full { vec![6, 7, 8, 9, 10] } else { vec![6, 8, 10] };
    let fracs: Vec<u32> = (2..=11).collect();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    harness::section("E2: Figures 9-11 — AUC ratio vs fractional bits");
    for m in zoo() {
        if !artifacts_ready(&dir, &m.config.name) {
            println!("SKIP {}: artifacts missing (run `make artifacts`)", m.config.name);
            continue;
        }
        let (ptq, qat) = load_checkpoints(&dir, &m.config).unwrap();
        let eval = EvalSet::load(&dir, &m.config).unwrap().truncate(events);
        let t0 = Instant::now();
        let results =
            auc_figures::run_figure(&m.config, &ptq, &qat, &eval, &ints, &fracs, threads);
        let wall = t0.elapsed();
        println!("\n{}", auc_figures::render(&m.config, &results, &fracs));
        println!(
            "({} design points x {} events in {:.2}s, {} threads)",
            results.len(),
            eval.len(),
            wall.as_secs_f64(),
            threads
        );

        // acceptance shape: curves converge to ratio ~1 at high precision
        for qat_flag in [false, true] {
            let ok = auc_figures::converges_to_one(&results, qat_flag, ints[0]);
            println!(
                "  trend: {} {}-int curve converges to 1.0: {}",
                if qat_flag { "QAT" } else { "PTQ" },
                ints[0],
                if ok { "OK" } else { "VIOLATED" }
            );
        }
        // fidelity improves with precision
        let err_at = |f: u32| {
            results
                .iter()
                .find(|r| !r.point.qat && r.point.integer_bits == ints[0] && r.point.frac_bits == f)
                .unwrap()
                .mean_abs_err
        };
        println!(
            "  mean |p_fixed - p_float|: frac2 {:.4} -> frac11 {:.4}",
            err_at(2),
            err_at(11)
        );
    }
}
