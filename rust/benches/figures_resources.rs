//! Bench: regenerates Figures 12, 13, 14 (resource usage vs reuse factor
//! x precision) and verifies the paper's §VI-B trends hold numerically.
//! `cargo bench --bench figures_resources`.

mod harness;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::experiments::{artifacts_ready, load_checkpoints, resource_figures};
use hls4ml_transformer::hls::resources::VU13P;
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo;

fn main() {
    harness::section("E4: Figures 12-14 — DSP/FF/LUT/BRAM vs reuse x precision");
    let fracs: Vec<u32> = (2..=11).collect();
    for m in zoo() {
        let weights = if artifacts_ready(&artifacts_dir(), &m.config.name) {
            load_checkpoints(&artifacts_dir(), &m.config).unwrap().0
        } else {
            synthetic_weights(&m.config, 1)
        };
        let pts = resource_figures::sweep(&m.config, &weights, 6, &[1, 2, 4], &fracs);
        println!("\n{}", resource_figures::render(&m.config, &pts, &fracs));

        // the §VI-B narrative, checked numerically
        let at = |r: u32, f: u32| {
            pts.iter().find(|p| p.reuse == r && p.frac_bits == f).unwrap().resources
        };
        let checks = [
            ("FF linear-ish in precision", at(1, 11).ff > at(1, 2).ff),
            ("LUT linear-ish in precision", at(1, 11).lut > at(1, 2).lut),
            ("DSP flat below port width", at(1, 2).dsp == at(1, 11).dsp),
            ("DSP shrinks with reuse", at(4, 8).dsp < at(1, 8).dsp),
            ("FF shrinks with reuse", at(4, 8).ff < at(1, 8).ff),
            ("BRAM grows with reuse", at(4, 8).bram18 >= at(1, 8).bram18),
            ("fits VU13P at R1", at(1, 8).fits(&VU13P)),
        ];
        for (name, ok) in checks {
            println!("  trend: {name:<32} {}", if ok { "OK" } else { "VIOLATED" });
            assert!(ok, "{}: trend violated: {name}", m.config.name);
        }
    }

    harness::section("resource sweep cost");
    let m = &zoo()[2];
    let w = synthetic_weights(&m.config, 2);
    harness::bench("gw full 3x10 resource sweep", || {
        harness::black_box(resource_figures::sweep(&m.config, &w, 6, &[1, 2, 4], &fracs));
    });
}
