//! Bench: regenerates Figures 12, 13, 14 (resource usage vs reuse factor
//! x precision) and verifies the paper's §VI-B trends hold numerically.
//! `cargo bench --bench figures_resources`.

mod harness;

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::experiments::{artifacts_ready, load_checkpoints, resource_figures};
use hls4ml_transformer::hls::resources::{Resources, VU13P};
use hls4ml_transformer::hls::{
    calibrate_plan, FixedTransformer, ParallelismPlan, QuantConfig, ReuseFactor,
};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::quant::{bit_shave_search, EvalSet};
use hls4ml_transformer::testutil::Gen;

fn main() {
    harness::section("E4: Figures 12-14 — DSP/FF/LUT/BRAM vs reuse x precision");
    let fracs: Vec<u32> = (2..=11).collect();
    for m in zoo() {
        let weights = if artifacts_ready(&artifacts_dir(), &m.config.name) {
            load_checkpoints(&artifacts_dir(), &m.config).unwrap().0
        } else {
            synthetic_weights(&m.config, 1)
        };
        let pts = resource_figures::sweep(&m.config, &weights, 6, &[1, 2, 4], &fracs);
        println!("\n{}", resource_figures::render(&m.config, &pts, &fracs));

        // the §VI-B narrative, checked numerically
        let at = |r: u32, f: u32| {
            pts.iter().find(|p| p.reuse == r && p.frac_bits == f).unwrap().resources
        };
        let checks = [
            ("FF linear-ish in precision", at(1, 11).ff > at(1, 2).ff),
            ("LUT linear-ish in precision", at(1, 11).lut > at(1, 2).lut),
            ("DSP flat below port width", at(1, 2).dsp == at(1, 11).dsp),
            ("DSP shrinks with reuse", at(4, 8).dsp < at(1, 8).dsp),
            ("FF shrinks with reuse", at(4, 8).ff < at(1, 8).ff),
            ("BRAM grows with reuse", at(4, 8).bram18 >= at(1, 8).bram18),
            ("fits VU13P at R1", at(1, 8).fits(&VU13P)),
        ];
        for (name, ok) in checks {
            println!("  trend: {name:<32} {}", if ok { "OK" } else { "VIOLATED" });
            assert!(ok, "{}: trend violated: {name}", m.config.name);
        }
    }

    // mixed-vs-uniform plan resource totals (VU13P), one BENCH_JSON line
    // per (model, plan kind) — the per-layer-precision perf trajectory
    harness::section("E7: mixed-precision plans vs uniform (VU13P totals)");
    let uniform = QuantConfig::new(6, 12); // width 18: above the DSP port
    let emit = |model: &str, tag: &str, r: &Resources| {
        harness::json_line(
            &format!("figures_resources/mixed_vs_uniform/{model}/{tag}"),
            &[
                ("dsp", r.dsp as f64),
                ("ff", r.ff as f64),
                ("lut", r.lut as f64),
                ("bram18", r.bram18 as f64),
                ("fits_vu13p", r.fits(&VU13P) as u64 as f64),
            ],
        );
    };
    for m in zoo() {
        let w = synthetic_weights(&m.config, 7);
        let par1 = ParallelismPlan::uniform(m.config.num_blocks, ReuseFactor(1));
        let uni_total = FixedTransformer::new(m.config.clone(), &w, uniform)
            .synthesize(&par1)
            .total;
        emit(&m.config.name, "uniform", &uni_total);
        // calibrated plan: per-site integer bits from profiled ranges
        let mut g = Gen::new(29);
        let events: Vec<Mat> = (0..6)
            .map(|_| {
                Mat::from_vec(
                    m.config.seq_len,
                    m.config.input_size,
                    g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
                )
            })
            .collect();
        let cal = calibrate_plan(&m.config, &w, &events, uniform.data.frac());
        let cal_total = FixedTransformer::with_plan(m.config.clone(), &w, cal)
            .synthesize(&par1)
            .total;
        emit(&m.config.name, "calibrated", &cal_total);
        println!(
            "  {:8} uniform DSP {} FF {} | calibrated DSP {} FF {}",
            m.config.name, uni_total.dsp, uni_total.ff, cal_total.dsp, cal_total.ff
        );
    }
    // the full greedy bit-shave on the engine model (kept to one model:
    // each shave attempt scores the whole eval set)
    {
        let m = &zoo()[0];
        let w = synthetic_weights(&m.config, 7);
        let eval = EvalSet::synthetic(&m.config, &w, 16, 11);
        let par1 = ParallelismPlan::uniform(m.config.num_blocks, ReuseFactor(1));
        let res = bit_shave_search(
            &m.config, &w, &eval, uniform, 0.99, 2, &par1,
        );
        emit(&m.config.name, "bit_shaved", &res.plan_resources);
        harness::json_line(
            &format!("figures_resources/mixed_vs_uniform/{}/savings", m.config.name),
            &[
                (
                    "dsp_plus_ff_saved",
                    (res.uniform_resources.dsp + res.uniform_resources.ff) as f64
                        - (res.plan_resources.dsp + res.plan_resources.ff) as f64,
                ),
                ("bits_shaved", res.bits_shaved as f64),
                ("auc_ratio", res.plan_score.auc_ratio),
                ("points_scored", res.points_scored as f64),
            ],
        );
        println!(
            "  engine bit-shaved: DSP {} FF {} ({} bits shaved, auc_ratio {:.4})",
            res.plan_resources.dsp,
            res.plan_resources.ff,
            res.bits_shaved,
            res.plan_score.auc_ratio
        );
        assert!(
            res.plan_resources.dsp + res.plan_resources.ff
                <= res.uniform_resources.dsp + res.uniform_resources.ff,
            "bit shaving must never cost resources"
        );
    }

    harness::section("resource sweep cost");
    let m = &zoo()[2];
    let w = synthetic_weights(&m.config, 2);
    harness::bench("gw full 3x10 resource sweep", || {
        harness::black_box(resource_figures::sweep(&m.config, &w, 6, &[1, 2, 4], &fracs));
    });
}
