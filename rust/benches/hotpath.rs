//! Bench: micro-benchmarks of every hot path the perf pass optimizes
//! (EXPERIMENTS.md §Perf).  `cargo bench --bench hotpath`.

mod harness;

use hls4ml_transformer::coordinator::spsc;
use hls4ml_transformer::fixed::{FixedSpec, LutKind, LutTable};
use hls4ml_transformer::hls::{dense, layernorm, mha, softmax, FixedTransformer, QuantConfig};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::nn::FloatTransformer;
use hls4ml_transformer::testutil::Gen;

fn main() {
    let data = FixedSpec::new(16, 6);
    let accum = data.accum();
    let roms = hls4ml_transformer::fixed::lut::Roms::new();
    let mut g = Gen::new(1);

    harness::section("fixed-point primitives");
    {
        let xs: Vec<f32> = g.normal_vec(1024, 2.0);
        let mut buf = xs.clone();
        harness::bench("quantize_slice 1024", || {
            buf.copy_from_slice(&xs);
            data.quantize_slice(&mut buf);
            harness::black_box(&buf);
        });
        let lut = LutTable::new(LutKind::Exp);
        harness::bench("exp LUT lookup x1024", || {
            let mut acc = 0.0f32;
            for &x in &xs {
                acc += lut.lookup(x);
            }
            harness::black_box(acc);
        });
    }

    harness::section("hls layer kernels (gw-sized: S=100, d=32)");
    {
        let x = Mat::from_vec(100, 32, g.normal_vec(3200, 1.0));
        let w = Mat::from_vec(32, 32, g.normal_vec(1024, 0.3)).map(|v| data.quantize(v));
        let b: Vec<f32> = g.normal_vec(32, 0.1);
        harness::bench("dense_fixed 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed(
                &x, &w, &b,
                hls4ml_transformer::nn::layers::Activation::Relu,
                data, accum,
            ));
        });
        let mut row = g.normal_vec(100, 1.0);
        harness::bench("softmax_fixed_row k=100", || {
            let mut r = row.clone();
            softmax::softmax_fixed_row(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        harness::bench("softmax_fixed_legacy k=100 (O(k^2) ablation)", || {
            let mut r = row.clone();
            softmax::softmax_fixed_legacy(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        let gamma = vec![1.0f32; 100];
        let beta = vec![0.0f32; 100];
        harness::bench("layernorm_fixed_row k=100", || {
            layernorm::layernorm_fixed_row(&mut row, &gamma, &beta, &roms, data, accum);
            harness::black_box(&row);
        });
        let zoo_gw = &zoo()[2];
        let wts = synthetic_weights(&zoo_gw.config, 5);
        harness::bench("mha_fixed gw block (S=100,h=2,k=2)", || {
            harness::black_box(mha::mha_fixed(&x, &wts.blocks[0].mha, &roms, data, accum));
        });
    }

    harness::section("full-model inference (single event)");
    for m in zoo() {
        let w = synthetic_weights(&m.config, 9);
        let x = Mat::from_vec(
            m.config.seq_len,
            m.config.input_size,
            g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
        );
        let fx = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        harness::bench(&format!("hls-sim forward {}", m.config.name), || {
            harness::black_box(fx.forward(&x));
        });
        let fl = FloatTransformer::new(m.config.clone(), w);
        harness::bench(&format!("float forward {}", m.config.name), || {
            harness::black_box(fl.forward(&x));
        });
    }

    harness::section("coordinator primitives");
    {
        let (p, c) = spsc::ring::<u64>(1024);
        harness::bench("spsc push+pop", || {
            p.try_push(42).unwrap();
            harness::black_box(c.try_pop());
        });
    }
}
