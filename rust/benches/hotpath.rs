//! Bench: micro-benchmarks of every hot path the perf pass optimizes
//! (EXPERIMENTS.md §Perf, E10).  `cargo bench --bench hotpath`.
//!
//! The "integer hot path vs f64 reference" sections time the
//! integer-mantissa kernels against the retained f64 reference — same
//! output bits, different arithmetic — via the `hls::hotpath` switch
//! (safe here: a bench `main` is single-threaded).  When the
//! `HOTPATH_ASSERT_SPEEDUP` env var is set (e.g. `2.0`), the run fails
//! unless the full-model integer path beats the reference by at least
//! that factor on the widest zoo model — CI's absolute floor alongside
//! the relative `ci/bench_diff.py` gate.
//!
//! The "compiled plan vs per-call lift" section times the same integer
//! kernels through the build-once `CompiledModel` artifact against the
//! lift-on-every-call dispatchers (same bits either way), and
//! `HOTPATH_ASSERT_COMPILED_SPEEDUP` gates the batch-1 full-model
//! speedup on gw the same way.

mod harness;

use hls4ml_transformer::coordinator::spsc;
use hls4ml_transformer::fixed::{FixedSpec, LutKind, LutTable};
use hls4ml_transformer::hls::{
    dense, hotpath, layernorm, mha, pooling, softmax, CompiledDense, FixedTransformer,
    QuantConfig,
};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::nn::FloatTransformer;
use hls4ml_transformer::testutil::Gen;

fn main() {
    let data = FixedSpec::new(16, 6);
    let accum = data.accum();
    let roms = hls4ml_transformer::fixed::lut::Roms::new();
    let mut g = Gen::new(1);

    harness::section("fixed-point primitives");
    {
        let xs: Vec<f32> = g.normal_vec(1024, 2.0);
        let mut buf = xs.clone();
        harness::bench("quantize_slice 1024", || {
            buf.copy_from_slice(&xs);
            data.quantize_slice(&mut buf);
            harness::black_box(&buf);
        });
        let lut = LutTable::new(LutKind::Exp);
        harness::bench("exp LUT lookup x1024", || {
            let mut acc = 0.0f32;
            for &x in &xs {
                acc += lut.lookup(x);
            }
            harness::black_box(acc);
        });
    }

    harness::section("hls layer kernels (gw-sized: S=100, d=32)");
    {
        let x = Mat::from_vec(100, 32, g.normal_vec(3200, 1.0));
        let w = Mat::from_vec(32, 32, g.normal_vec(1024, 0.3)).map(|v| data.quantize(v));
        let b: Vec<f32> = g.normal_vec(32, 0.1);
        harness::bench("dense_fixed 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed(
                &x, &w, &b,
                hls4ml_transformer::nn::layers::Activation::Relu,
                data, accum,
            ));
        });
        let mut row = g.normal_vec(100, 1.0);
        harness::bench("softmax_fixed_row k=100", || {
            let mut r = row.clone();
            softmax::softmax_fixed_row(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        harness::bench("softmax_fixed_legacy k=100 (O(k^2) ablation)", || {
            let mut r = row.clone();
            softmax::softmax_fixed_legacy(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        let gamma = vec![1.0f32; 100];
        let beta = vec![0.0f32; 100];
        harness::bench("layernorm_fixed_row k=100", || {
            layernorm::layernorm_fixed_row(&mut row, &gamma, &beta, &roms, data, accum);
            harness::black_box(&row);
        });
        let zoo_gw = &zoo()[2];
        let wts = synthetic_weights(&zoo_gw.config, 5);
        harness::bench("mha_fixed gw block (S=100,h=2,k=2)", || {
            harness::black_box(mha::mha_fixed(&x, &wts.blocks[0].mha, &roms, data, accum));
        });
    }

    harness::section("integer hot path vs f64 reference (per kernel)");
    {
        // on-grid inputs: what the transformer delivers at every site,
        // and the only regime where the two paths are comparable work
        let x = Mat::from_vec(100, 32, g.normal_vec(3200, 1.0)).map(|v| data.quantize(v));
        let w = Mat::from_vec(32, 32, g.normal_vec(1024, 0.3)).map(|v| data.quantize(v));
        let b: Vec<f32> =
            g.normal_vec(32, 0.1).iter().map(|&v| data.quantize(v)).collect();
        let act = hls4ml_transformer::nn::layers::Activation::Relu;
        harness::bench("dense_fixed_int 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed_int(&x, &w, &b, act, data, accum));
        });
        harness::bench("dense_fixed_ref 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed_ref(&x, &w, &b, act, data, accum));
        });
        let row0: Vec<f32> =
            g.normal_vec(100, 1.0).iter().map(|&v| data.quantize(v)).collect();
        harness::bench("softmax_fixed_row_int k=100", || {
            let mut r = row0.clone();
            softmax::softmax_fixed_row_int(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        harness::bench("softmax_fixed_row_ref k=100", || {
            let mut r = row0.clone();
            softmax::softmax_fixed_row_ref(&mut r, &roms, data, accum);
            harness::black_box(&r);
        });
        let gamma = vec![1.0f32; 100];
        let beta = vec![0.0f32; 100];
        harness::bench("layernorm_fixed_row_int k=100", || {
            let mut r = row0.clone();
            layernorm::layernorm_fixed_row_int(&mut r, &gamma, &beta, &roms, data, accum);
            harness::black_box(&r);
        });
        harness::bench("layernorm_fixed_row_ref k=100", || {
            let mut r = row0.clone();
            layernorm::layernorm_fixed_row_ref(&mut r, &gamma, &beta, &roms, data, accum);
            harness::black_box(&r);
        });
        let mut pooled = vec![0.0f32; 32];
        harness::bench("pool_int_core 100x32", || {
            pooling::pool_int_core(x.data(), &mut pooled, 100, 32, data, accum);
            harness::black_box(&pooled);
        });
        harness::bench("pool_ref 100x32", || {
            harness::black_box(pooling::global_average_pool_fixed_ref(&x, data, accum));
        });
    }

    harness::section("full-model inference (single event)");
    // the absolute gate: integer path vs f64 reference on the widest
    // zoo model (gw: S=100, the largest MAC volume), asserted when
    // HOTPATH_ASSERT_SPEEDUP is set
    let mut gated_speedup: Option<f64> = None;
    for m in zoo() {
        let w = synthetic_weights(&m.config, 9);
        let x = Mat::from_vec(
            m.config.seq_len,
            m.config.input_size,
            g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
        );
        let fx = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        hotpath::force_f64_reference(false);
        let int_stats = harness::bench(&format!("hls-sim forward {}", m.config.name), || {
            harness::black_box(fx.forward(&x));
        });
        hotpath::force_f64_reference(true);
        let ref_stats =
            harness::bench(&format!("hls-sim forward {} (f64 reference)", m.config.name), || {
                harness::black_box(fx.forward(&x));
            });
        hotpath::force_f64_reference(cfg!(feature = "f64-reference"));
        let speedup = ref_stats.mean_ns / int_stats.mean_ns;
        println!("    -> integer hot path speedup {speedup:.2}x");
        harness::json_line(
            &format!("hotpath speedup {}", m.config.name),
            &[("speedup_x", speedup)],
        );
        if m.config.name == "gw" {
            gated_speedup = Some(speedup);
        }
        let fl = FloatTransformer::new(m.config.clone(), w);
        harness::bench(&format!("float forward {}", m.config.name), || {
            harness::black_box(fl.forward(&x));
        });
    }
    if let Ok(floor) = std::env::var("HOTPATH_ASSERT_SPEEDUP") {
        let floor: f64 = floor.parse().expect("HOTPATH_ASSERT_SPEEDUP must be a number");
        let got = gated_speedup.expect("gw model must be in the zoo");
        if got < floor {
            eprintln!(
                "FAIL: integer hot path speedup {got:.2}x on gw is below the \
                 required {floor:.2}x floor"
            );
            std::process::exit(1);
        }
        println!("    hotpath speedup gate passed: {got:.2}x >= {floor:.2}x");
    }

    harness::section("compiled plan vs per-call lift");
    // both sides run the same integer kernels and return the same bits;
    // the compiled side reads the artifact's pre-lifted mantissa tiles
    // while the per-call side re-quantizes weights and re-lifts them on
    // every call.  When HOTPATH_ASSERT_COMPILED_SPEEDUP is set, the run
    // fails unless the compiled full-model path beats per-call lift by
    // at least that factor at batch 1 on the widest zoo model (gw).
    hotpath::force_f64_reference(false);
    {
        let w = Mat::from_vec(32, 32, g.normal_vec(1024, 0.3)).map(|v| data.quantize(v));
        let b: Vec<f32> =
            g.normal_vec(32, 0.1).iter().map(|&v| data.quantize(v)).collect();
        let x = Mat::from_vec(100, 32, g.normal_vec(3200, 1.0)).map(|v| data.quantize(v));
        let act = hls4ml_transformer::nn::layers::Activation::Relu;
        let site = CompiledDense::build(&w, &b, QuantConfig::new(6, 10));
        let pre = harness::bench("dense_fixed_compiled 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed_compiled(&x, &w, &site, act));
        });
        let per = harness::bench("dense_fixed (per-call lift) 100x32 @ 32x32", || {
            harness::black_box(dense::dense_fixed(&x, &w, &b, act, data, accum));
        });
        harness::json_line(
            "hotpath compiled dense",
            &[("speedup_x", per.mean_ns / pre.mean_ns)],
        );
    }
    let mut gated_compiled: Option<f64> = None;
    for m in zoo() {
        let w = synthetic_weights(&m.config, 9);
        let fx = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        let events: Vec<Mat> = (0..8)
            .map(|_| {
                Mat::from_vec(
                    m.config.seq_len,
                    m.config.input_size,
                    g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
                )
            })
            .collect();
        let x = &events[0];
        let c1 = harness::bench(&format!("forward compiled {}", m.config.name), || {
            harness::black_box(fx.forward(x));
        });
        let p1 =
            harness::bench(&format!("forward per-call lift {}", m.config.name), || {
                harness::black_box(fx.forward_percall(x));
            });
        let refs: Vec<&Mat> = events.iter().collect();
        let c8 = harness::bench(
            &format!("forward_batch(8) compiled {}", m.config.name),
            || {
                harness::black_box(fx.forward_batch(&refs));
            },
        );
        let p8 = harness::bench(
            &format!("forward_batch(8) per-call lift {}", m.config.name),
            || {
                harness::black_box(fx.forward_batch_percall(&refs));
            },
        );
        let b1 = p1.mean_ns / c1.mean_ns;
        let b8 = p8.mean_ns / c8.mean_ns;
        println!("    -> compiled-plan speedup {b1:.2}x (batch 1), {b8:.2}x (batch 8)");
        harness::json_line(
            &format!("hotpath compiled {}", m.config.name),
            &[("speedup_x", b1), ("batch8_speedup_x", b8)],
        );
        if m.config.name == "gw" {
            gated_compiled = Some(b1);
        }
    }
    hotpath::force_f64_reference(cfg!(feature = "f64-reference"));
    {
        let pool = hotpath::tls_pool_stats();
        harness::json_line(
            "hotpath tls pool",
            &[
                ("high_water_ints", pool.high_water_ints as f64),
                ("shrinks", pool.shrinks as f64),
            ],
        );
    }
    if let Ok(floor) = std::env::var("HOTPATH_ASSERT_COMPILED_SPEEDUP") {
        let floor: f64 =
            floor.parse().expect("HOTPATH_ASSERT_COMPILED_SPEEDUP must be a number");
        let got = gated_compiled.expect("gw model must be in the zoo");
        if got < floor {
            eprintln!(
                "FAIL: compiled-plan speedup {got:.2}x on gw (batch 1) is below \
                 the required {floor:.2}x floor"
            );
            std::process::exit(1);
        }
        println!("    compiled speedup gate passed: {got:.2}x >= {floor:.2}x");
    }

    harness::section("incremental window reuse vs full recompute (engine, hop S/4)");
    // consecutive stream windows at hop h share S-h token rows;
    // `forward_incremental` reuses their embed/Q/K/V rows and the
    // block-0 raw score block while `forward` recomputes everything.
    // Same output bits either way (pinned by hls::transformer tests) —
    // only the work differs.  When STREAM_ASSERT_REUSE_SPEEDUP is set
    // (e.g. `1.2`), the run fails unless the incremental path sustains
    // at least that speedup over full recompute at hop S/4.
    {
        let m = zoo()
            .into_iter()
            .find(|m| m.config.name == "engine")
            .expect("engine model must be in the zoo");
        let w = synthetic_weights(&m.config, 9);
        let fx = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        let (s, d) = (m.config.seq_len, m.config.input_size);
        let hop = (s / 4).max(1);
        let n_windows = 64usize;
        let buf: Vec<f32> = g.normal_vec((s + hop * n_windows) * d, 1.0);
        let windows: Vec<(u64, Mat)> = (0..n_windows)
            .map(|i| {
                let start = i * hop;
                (start as u64, Mat::from_vec(s, d, buf[start * d..(start + s) * d].to_vec()))
            })
            .collect();
        // the cache persists across bench iterations: each pass replays
        // the stream from pos 0, so exactly one window per pass is cold
        let mut cache = fx.window_cache();
        let inc = harness::bench("stream x64 windows incremental (hop S/4)", || {
            for (pos, x) in &windows {
                harness::black_box(fx.forward_incremental(x, *pos, &mut cache));
            }
        });
        let full = harness::bench("stream x64 windows full recompute (hop S/4)", || {
            for (_, x) in &windows {
                harness::black_box(fx.forward(x));
            }
        });
        let speedup = full.mean_ns / inc.mean_ns;
        println!("    -> incremental reuse speedup {speedup:.2}x at hop {hop}");
        harness::json_line(
            "hotpath stream reuse engine",
            &[("hop", hop as f64), ("reuse_speedup_x", speedup)],
        );
        if let Ok(floor) = std::env::var("STREAM_ASSERT_REUSE_SPEEDUP") {
            let floor: f64 =
                floor.parse().expect("STREAM_ASSERT_REUSE_SPEEDUP must be a number");
            if speedup < floor {
                eprintln!(
                    "FAIL: incremental stream reuse speedup {speedup:.2}x on engine \
                     (hop S/4) is below the required {floor:.2}x floor"
                );
                std::process::exit(1);
            }
            println!("    stream reuse gate passed: {speedup:.2}x >= {floor:.2}x");
        }
    }

    harness::section("coordinator primitives");
    {
        let (p, c) = spsc::ring::<u64>(1024);
        harness::bench("spsc push+pop", || {
            p.try_push(42).unwrap();
            harness::black_box(c.try_pop());
        });
    }
}
