//! Shared bench harness — the criterion stand-in for this offline
//! environment (criterion is not in the vendored crate set).
//!
//! Auto-calibrates iteration counts to a target wall time, reports
//! mean / p50 / p99 per iteration, and provides the table printers the
//! per-paper-artifact benches share.  Used via `mod harness;` from each
//! `harness = false` bench target.
//!
//! Machine-readable output: when the `BENCH_JSON` env var names a file,
//! every bench result is also appended there as one JSON line (see
//! [`json_line`]), so CI runs can archive perf trajectories as
//! `BENCH_*.json` artifacts and diff them across commits.

#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Benchmark a closure: warm up, calibrate, then sample.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_with_target(name, Duration::from_millis(400), &mut f)
}

/// Benchmark with an explicit sampling budget.
pub fn bench_with_target<F: FnMut()>(
    name: &str,
    budget: Duration,
    f: &mut F,
) -> BenchStats {
    // warmup + calibration: find an iteration count that takes ~1ms
    let mut batch = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(1) || batch >= 1 << 20 {
            break;
        }
        batch *= 2;
    }
    // sample batches until the budget is spent
    let mut samples_ns: Vec<f64> = Vec::new();
    let mut total_iters = 0u64;
    let t_start = Instant::now();
    while t_start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let per_iter = t0.elapsed().as_nanos() as f64 / batch as f64;
        samples_ns.push(per_iter);
        total_iters += batch;
        if samples_ns.len() > 10_000 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.total_cmp(b));
    let q = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
    let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        p50_ns: q(0.5),
        p99_ns: q(0.99),
        min_ns: samples_ns[0],
    };
    println!("{}", format_stats(&stats));
    json_line(
        &stats.name,
        &[
            ("mean_ns", stats.mean_ns),
            ("p50_ns", stats.p50_ns),
            ("p99_ns", stats.p99_ns),
            ("min_ns", stats.min_ns),
            ("iters", stats.iters as f64),
        ],
    );
    stats
}

/// Append one machine-readable JSON line (`{"bench":...,"k":v,...}`) to
/// the file named by `BENCH_JSON`, if set.  No-op otherwise, so human
/// runs stay clean.  Delegates to the library's shared writer
/// ([`hls4ml_transformer::benchjson::emit`]) so the benches and the CLI
/// (`repro pareto`) land in the same perf-trajectory format.
pub fn json_line(bench: &str, fields: &[(&str, f64)]) {
    hls4ml_transformer::benchjson::emit(bench, fields);
}

pub fn format_stats(s: &BenchStats) -> String {
    format!(
        "  {:<44} {:>12} /iter  p50 {:>12}  p99 {:>12}  ({} iters)",
        s.name,
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p99_ns),
        s.iters
    )
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
