//! Network serving plane e2e over loopback TCP (artifact-free): a
//! `serve_net` server fed through the framed wire path must
//!
//! * scale up under overload inside the `--autoscale 1..4` band,
//!   observably via scraped per-shard queue depths / shard gauges,
//! * complete a hot plan swap mid-stream with zero shed and zero
//!   dropped events,
//! * score post-swap events bitwise identically to a cold engine built
//!   on the new plan (the swap is a real plan change, not a restart
//!   approximation), and
//! * expose Prometheus text whose counters agree with the final
//!   `ServerReport`.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use hls4ml_transformer::coordinator::{
    net, serve_net, AutoscaleConfig, Backend, BackendKind, Frame, NetEvent, NetServeOptions,
    PipelineConfig, PlanSwap, ServerConfig, WeightsSource,
};
use hls4ml_transformer::hls::{
    FixedTransformer, ParallelismPlan, PrecisionPlan, QuantConfig, ReuseFactor,
};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::zoo_model;
use hls4ml_transformer::nn::tensor::Mat;

const SWAP_PRECISION: &str = "block0.ffn1 ap_fixed<18,8>";
const WEIGHTS_SEED: u64 = 1;

/// Deterministic event matrix for id `i` — the same bytes the reference
/// engine recomputes locally for the bitwise comparison.
fn event_mat(i: u64, seq_len: usize, input_size: usize) -> Mat {
    let data: Vec<f32> = (0..seq_len * input_size)
        .map(|k| ((i as usize * 31 + k * 7) % 97) as f32 / 97.0 - 0.5)
        .collect();
    Mat::from_vec(seq_len, input_size, data)
}

/// One GET /metrics scrape (the server closes the connection after the
/// response, so read-to-end terminates).
fn scrape(addr: std::net::SocketAddr) -> String {
    let mut conn = TcpStream::connect(addr).expect("connect metrics");
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .expect("send scrape");
    let mut buf = String::new();
    conn.read_to_string(&mut buf).expect("read scrape");
    let (head, body) = buf.split_once("\r\n\r\n").expect("http response");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(
        head.contains("text/plain; version=0.0.4"),
        "exposition content type: {head}"
    );
    body.to_string()
}

/// Value of the first sample line whose name+labels start with `prefix`.
fn metric(body: &str, prefix: &str) -> Option<f64> {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

fn poll_metric(
    addr: std::net::SocketAddr,
    prefix: &str,
    pred: impl Fn(f64) -> bool,
    what: &str,
) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let body = scrape(addr);
        if metric(&body, prefix).is_some_and(&pred) {
            return body;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {what}; last value {:?}\n{body}",
            metric(&body, prefix)
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn loopback_autoscale_hot_swap_zero_drop_bitwise() {
    let mcfg = zoo_model("engine").unwrap().config;
    let (sl, is) = (mcfg.seq_len, mcfg.input_size);
    let pre = 400u64; // un-paced flood: drives the queue-depth scale-up
    let post = 200u64; // stream_pos-tagged: pinned bitwise against the new plan

    let cfg = ServerConfig {
        pipelines: vec![PipelineConfig {
            weights: WeightsSource::Synthetic(WEIGHTS_SEED),
            ring_capacity: 4096,
            ..PipelineConfig::new("engine", BackendKind::Hls)
        }],
        artifacts_dir: std::path::PathBuf::from("."),
        ..Default::default()
    };
    let ingest = TcpListener::bind("127.0.0.1:0").unwrap();
    let metrics = TcpListener::bind("127.0.0.1:0").unwrap();
    let ingest_addr = ingest.local_addr().unwrap();
    let metrics_addr = metrics.local_addr().unwrap();
    // a touchy band: any queue past ~8 events triggers growth, and the
    // calm threshold is unreachable so the width is monotone during the
    // test (scale-down mechanics are pinned by the pool unit tests)
    let autoscale = AutoscaleConfig {
        interval: Duration::from_millis(2),
        up_fill: 0.002,
        calm_ticks: u32::MAX,
        ..AutoscaleConfig::band(1, 4)
    };
    let server = std::thread::spawn(move || {
        serve_net(
            &cfg,
            ingest,
            NetServeOptions { metrics: Some(metrics), autoscale: Some(autoscale) },
        )
    });

    let mut conn = TcpStream::connect(ingest_addr).expect("connect ingest");
    conn.set_nodelay(true).ok();
    for i in 0..pre {
        net::write_frame(
            &mut conn,
            &Frame::Event(NetEvent {
                id: i,
                model: "engine".into(),
                x: event_mat(i, sl, is),
                label: Some((i % 2) as u8),
                stream_pos: None,
            }),
        )
        .expect("send pre-swap event");
    }
    // overload observable from outside: the scraped shard gauge must
    // leave 1 while the flood is queued (HLS inference is far slower
    // than loopback framing)
    let body = poll_metric(
        metrics_addr,
        "repro_shards{model=\"engine\"}",
        |v| v >= 2.0,
        "autoscale growth past one shard",
    );
    assert!(
        body.contains("repro_shard_queue_depth{model=\"engine\",shard="),
        "per-shard queue depths exported:\n{body}"
    );

    // hot swap mid-stream, same connection, strictly after the flood
    net::write_frame(
        &mut conn,
        &Frame::Swap(PlanSwap {
            model: "engine".into(),
            precision: Some(SWAP_PRECISION.into()),
            reuse: None,
        }),
    )
    .expect("send swap");
    for i in 0..post {
        let id = pre + i;
        net::write_frame(
            &mut conn,
            &Frame::Event(NetEvent {
                id,
                model: "engine".into(),
                x: event_mat(id, sl, is),
                label: None,
                stream_pos: Some(id),
            }),
        )
        .expect("send post-swap event");
    }

    // quiesce, then check scrape-vs-report agreement on live counters
    let sent = pre + post;
    let body = poll_metric(
        metrics_addr,
        "repro_events_scored_total{model=\"engine\"}",
        |v| v >= sent as f64,
        "all events scored",
    );
    assert_eq!(
        metric(&body, "repro_events_accepted_total{model=\"engine\"}"),
        Some(sent as f64)
    );
    assert_eq!(metric(&body, "repro_events_shed_total{model=\"engine\"}"), Some(0.0));
    assert_eq!(
        metric(&body, "repro_events_dropped_total{model=\"engine\"}"),
        Some(0.0)
    );
    assert_eq!(
        metric(&body, "repro_plan_swaps_total{model=\"engine\"}"),
        Some(1.0),
        "the mid-stream swap completed:\n{body}"
    );
    assert!(body.contains("# TYPE repro_event_latency_ns histogram"));
    assert_eq!(
        metric(&body, "repro_event_latency_ns_count{model=\"engine\"}"),
        Some(sent as f64),
        "histogram count agrees with the scored total"
    );
    let shards = metric(&body, "repro_shards{model=\"engine\"}").unwrap();
    assert!((2.0..=4.0).contains(&shards), "width stayed in band: {shards}");

    net::write_frame(&mut conn, &Frame::Shutdown).expect("send shutdown");
    drop(conn);
    let report = server.join().expect("server thread").expect("server report");
    let s = &report.per_model["engine"];
    assert_eq!(s.accepted, sent, "every framed event scored exactly once");
    assert_eq!(s.shed, 0, "zero-drop hot swap: nothing shed");
    assert_eq!(s.dropped, 0, "zero-drop hot swap: nothing dropped");
    assert_eq!(s.latency.count(), sent);
    // the modeled design point followed the swap
    let modeled = report.modeled_designs.get("engine").expect("hls design");
    assert!(
        modeled.plan.summary().contains("mixed"),
        "post-swap plan is the mixed one: {}",
        modeled.plan.summary()
    );

    // bitwise pin: every post-swap score equals a cold engine built
    // directly on the new plan (i.e. the swap == a restart, minus the
    // downtime and the drops)
    let weights = synthetic_weights(&mcfg, WEIGHTS_SEED);
    let mut plan = PrecisionPlan::uniform(mcfg.num_blocks, QuantConfig::new(6, 10));
    plan.apply_overrides(SWAP_PRECISION).unwrap();
    let cold = Backend::from_hls_engine(
        FixedTransformer::with_plan(mcfg.clone(), &weights, plan),
        ParallelismPlan::uniform(mcfg.num_blocks, ReuseFactor(1)),
    );
    assert_eq!(s.windows.len(), post as usize, "every stream_pos event recorded");
    let mut seen = std::collections::HashSet::new();
    for w in &s.windows {
        assert!(seen.insert(w.pos), "pos {} scored twice", w.pos);
        assert!((pre..pre + post).contains(&w.pos), "pos {} out of range", w.pos);
        let x = event_mat(w.pos, sl, is);
        let want = cold.score(&cold.infer(&[&x]).unwrap()[0]);
        assert_eq!(
            w.score.to_bits(),
            want.to_bits(),
            "pos {}: served {} vs cold restart {}",
            w.pos,
            w.score,
            want
        );
    }
}

#[test]
fn torn_connection_does_not_kill_the_server() {
    // one producer dies mid-frame; the plane must keep serving others
    // and still shut down cleanly with exact accounting for what landed
    let mcfg = zoo_model("engine").unwrap().config;
    let (sl, is) = (mcfg.seq_len, mcfg.input_size);
    let cfg = ServerConfig {
        pipelines: vec![PipelineConfig {
            weights: WeightsSource::Synthetic(WEIGHTS_SEED),
            ..PipelineConfig::new("engine", BackendKind::Float)
        }],
        artifacts_dir: std::path::PathBuf::from("."),
        ..Default::default()
    };
    let ingest = TcpListener::bind("127.0.0.1:0").unwrap();
    let metrics = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = ingest.local_addr().unwrap();
    let metrics_addr = metrics.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_net(&cfg, ingest, NetServeOptions { metrics: Some(metrics), autoscale: None })
    });

    // victim: two whole events, then half a length prefix, then gone
    let mut victim = TcpStream::connect(addr).unwrap();
    for i in 0..2u64 {
        net::write_frame(
            &mut victim,
            &Frame::Event(NetEvent {
                id: i,
                model: "engine".into(),
                x: event_mat(i, sl, is),
                label: None,
                stream_pos: None,
            }),
        )
        .unwrap();
    }
    victim.write_all(&[0xFF, 0x00]).unwrap();
    drop(victim);
    // both whole victim events must land before the survivor can race a
    // shutdown past them (frame order holds per connection, not across)
    poll_metric(
        metrics_addr,
        "repro_events_accepted_total{model=\"engine\"}",
        |v| v >= 2.0,
        "victim's whole frames accepted",
    );

    // survivor: a full stream plus the shutdown
    let mut conn = TcpStream::connect(addr).unwrap();
    for i in 100..140u64 {
        net::write_frame(
            &mut conn,
            &Frame::Event(NetEvent {
                id: i,
                model: "engine".into(),
                x: event_mat(i, sl, is),
                label: Some((i % 2) as u8),
                stream_pos: None,
            }),
        )
        .unwrap();
    }
    net::write_frame(&mut conn, &Frame::Shutdown).unwrap();
    drop(conn);

    let report = server.join().unwrap().expect("server survives torn frames");
    let s = &report.per_model["engine"];
    assert_eq!(s.accepted, 42, "2 whole victim events + 40 survivor events");
    assert_eq!(s.lost(), 0);
}
