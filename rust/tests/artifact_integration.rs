//! Integration tests against the real AOT artifacts.
//!
//! These are the cross-layer correctness contract of the whole system:
//! Python (L1/L2) exported tables, quantization vectors, weights, eval
//! tensors and HLO graphs; here the Rust side (L3) must agree with every
//! one of them.  All tests skip gracefully when `make artifacts` hasn't
//! run (CI bootstrapping), but the Makefile `test` target always builds
//! artifacts first.

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::fixed::lut::{LutKind, LutTable};
use hls4ml_transformer::fixed::FixedSpec;
use hls4ml_transformer::models::weights::Weights;
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::models::NnwFile;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::nn::FloatTransformer;
use hls4ml_transformer::quant::EvalSet;

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn lut_tables_bit_identical_to_python() {
    let Some(dir) = artifacts_or_skip() else { return };
    let file = NnwFile::load(dir.join("tables.nnw")).unwrap();
    for kind in [LutKind::Exp, LutKind::Inv, LutKind::InvSqrt] {
        let ours = LutTable::new(kind);
        let theirs = file.require(kind.name()).unwrap();
        assert_eq!(ours.len(), theirs.data.len(), "{:?} size", kind);
        for (i, (a, b)) in ours.rom().iter().zip(&theirs.data).enumerate() {
            assert_eq!(a, b, "{:?}[{i}]: rust {a} vs python {b}", kind);
        }
    }
}

#[test]
fn quantizer_bit_identical_to_python() {
    let Some(dir) = artifacts_or_skip() else { return };
    let file = NnwFile::load(dir.join("quantvec.nnw")).unwrap();
    let xs = &file.require("x").unwrap().data;
    for (w, i) in [(8u32, 3u32), (12, 4), (16, 6), (10, 10), (18, 8), (6, 2)] {
        let spec = FixedSpec::new(w, i);
        let expected = &file.require(&format!("q_{w}_{i}")).unwrap().data;
        for (n, (&x, &want)) in xs.iter().zip(expected).enumerate() {
            let got = spec.quantize(x);
            assert_eq!(got, want, "{spec} on x[{n}]={x}: rust {got} vs python {want}");
        }
    }
}

#[test]
fn weights_load_and_match_param_counts() {
    let Some(dir) = artifacts_or_skip() else { return };
    for m in zoo() {
        for qat in [false, true] {
            let file = NnwFile::load(dir.join(m.weights_file(qat))).unwrap();
            let w = Weights::from_nnw(&m.config, &file).unwrap();
            assert_eq!(w.param_count(), m.config.param_count(), "{}", m.config.name);
        }
    }
}

#[test]
fn rust_float_forward_matches_jax_exact_logits() {
    // The strongest cross-layer test: the Rust float transformer must
    // reproduce jax's logits_exact on the exported eval events.
    let Some(dir) = artifacts_or_skip() else { return };
    for m in zoo() {
        let cfg = &m.config;
        let weights = Weights::from_nnw(
            cfg,
            &NnwFile::load(dir.join(m.weights_file(false))).unwrap(),
        )
        .unwrap();
        let eval_file = NnwFile::load(dir.join(m.eval_file())).unwrap();
        let x = eval_file.require("x").unwrap();
        let expected = eval_file.require("logits_exact").unwrap();
        let t = FloatTransformer::new(cfg.clone(), weights);
        let n = 64.min(x.shape[0]);
        let w = cfg.seq_len * cfg.input_size;
        let mut worst = 0.0f32;
        for i in 0..n {
            let ev = Mat::from_vec(
                cfg.seq_len,
                cfg.input_size,
                x.data[i * w..(i + 1) * w].to_vec(),
            );
            let logits = t.forward(&ev);
            for (j, &l) in logits.iter().enumerate() {
                let want = expected.data[i * cfg.output_size + j];
                worst = worst.max((l - want).abs());
            }
        }
        assert!(
            worst < 2e-3,
            "{}: rust float vs jax exact worst |dlogit| = {worst}",
            cfg.name
        );
    }
}

#[test]
fn eval_set_loads_for_all_models() {
    let Some(dir) = artifacts_or_skip() else { return };
    for m in zoo() {
        let eval = EvalSet::load(&dir, &m.config).unwrap();
        assert!(eval.len() >= 128, "{}: eval too small", m.config.name);
        assert_eq!(eval.float_probs[0].len(), m.config.output_size);
        // labels from both classes present
        assert!(eval.labels.iter().any(|&l| l == 0));
        assert!(eval.labels.iter().any(|&l| l == 1));
    }
}

#[test]
fn float_model_auc_matches_manifest_regime() {
    // E5: the trained float models must show the separability recorded
    // in the manifest (and the manifest must show strong models).
    let Some(dir) = artifacts_or_skip() else { return };
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    for m in zoo() {
        let line = manifest
            .lines()
            .find(|l| l.contains(&format!("model={}", m.config.name)))
            .expect("manifest line");
        let auc: f64 = line
            .split_whitespace()
            .find_map(|t| t.strip_prefix("ptq_auc=").map(|v| v.parse().unwrap()))
            .unwrap();
        assert!(auc > 0.8, "{}: manifest float AUC {auc} too weak", m.config.name);
    }
}
