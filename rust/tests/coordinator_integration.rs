//! Coordinator integration: multi-pipeline serving with failure
//! injection (overload shedding, slow consumers, shape validation) —
//! artifact-free (synthetic weights) so it always runs.

use hls4ml_transformer::coordinator::{
    BackendKind, BatchPolicy, PipelineConfig, Router, ServerConfig, Submit, TriggerEvent,
    TriggerServer, WeightsSource,
};
use hls4ml_transformer::coordinator::spsc;
use hls4ml_transformer::nn::tensor::Mat;
use std::path::PathBuf;
use std::time::Duration;

fn pipeline(model: &'static str, backend: BackendKind) -> PipelineConfig {
    PipelineConfig {
        weights: WeightsSource::Synthetic(9),
        ..PipelineConfig::new(model, backend)
    }
}

#[test]
fn three_pipelines_serve_concurrently() {
    let cfg = ServerConfig {
        pipelines: vec![
            pipeline("engine", BackendKind::Float),
            pipeline("btag", BackendKind::Float),
            pipeline("gw", BackendKind::Float),
        ],
        events_per_source: 400,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    };
    let report = TriggerServer::run(&cfg).unwrap();
    assert_eq!(report.per_model.len(), 3);
    for (m, s) in &report.per_model {
        assert_eq!(s.accepted + s.lost(), 400, "{m}");
        assert!(s.latency.count() == s.accepted);
        assert!(s.batches >= s.accepted / 8, "{m}: batches sane");
    }
    // debug builds run the float model ~10x slower on this 1-core host
    let floor = if cfg!(debug_assertions) { 10.0 } else { 100.0 };
    assert!(report.throughput_eps() > floor, "{}", report.throughput_eps());
}

#[test]
fn paced_sources_keep_latency_low() {
    // at a modest rate the queue never builds, so p99 stays far below
    // the unpaced run's
    let run = |rate: u64| {
        let cfg = ServerConfig {
            pipelines: vec![pipeline("engine", BackendKind::Float)],
            events_per_source: 400,
            rate_per_source: rate,
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        };
        TriggerServer::run(&cfg).unwrap()
    };
    // debug inference is 10-20x slower; pace well below debug capacity
    // still never builds and the bound tests queueing, not compute
    let (rate, bound_ns) = if cfg!(debug_assertions) {
        (25, 200_000_000.0)
    } else {
        (2000, 20_000_000.0)
    };
    let paced = run(rate);
    let s = &paced.per_model["engine"];
    assert_eq!(s.lost(), 0, "paced source must not shed or drop");
    // the queue never builds at this rate: latency stays in the
    // sub-batch-window regime (generous bound — the test binary runs
    // its cases concurrently, so wall-clock noise is real)
    assert!(
        s.latency.mean_ns() < bound_ns,
        "paced mean latency {} ns",
        s.latency.mean_ns()
    );
}

#[test]
fn overload_sheds_and_recovers() {
    // tiny ring + expensive backend: the source must shed rather than
    // stall, and every accepted event must still be scored exactly once
    let mut pc = pipeline("gw", BackendKind::Hls);
    pc.ring_capacity = 2;
    pc.batch = BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(50) };
    let cfg = ServerConfig {
        pipelines: vec![pc],
        events_per_source: 200,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    };
    let report = TriggerServer::run(&cfg).unwrap();
    let s = &report.per_model["gw"];
    assert_eq!(s.accepted + s.shed, 200);
    assert!(s.shed > 0, "expected shedding");
    assert_eq!(s.dropped, 0, "backpressure sheds at the source, never drops");
    assert_eq!(s.latency.count(), s.accepted);
}

#[test]
fn router_validates_before_queueing() {
    let (tx, _rx) = spsc::ring::<TriggerEvent>(8);
    let mut router = Router::new();
    router.add_route("engine", vec![tx], 50, 1);
    assert_eq!(
        router.submit(TriggerEvent::new(0, "engine", Mat::zeros(50, 1), None)),
        Submit::Accepted
    );
    assert_eq!(
        router.submit(TriggerEvent::new(0, "engine", Mat::zeros(10, 1), None)),
        Submit::BadShape
    );
    assert_eq!(
        router.submit(TriggerEvent::new(0, "muon", Mat::zeros(50, 1), None)),
        Submit::UnknownModel
    );
}

#[test]
fn unknown_model_in_config_is_an_error() {
    let cfg = ServerConfig {
        pipelines: vec![pipeline("nonexistent", BackendKind::Float)],
        events_per_source: 1,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    };
    // zoo lookup fails before any thread spawns
    assert!(std::panic::catch_unwind(|| TriggerServer::run(&cfg)).is_err()
        || TriggerServer::run(&cfg).is_err());
}

#[test]
fn four_replica_pool_scores_every_event_exactly_once() {
    // 4-replica pool, synthetic weights, ample rings: every event must
    // be scored exactly once (no drops, no duplicates), and the shard
    // stats must sum to the per-model totals
    let n = 600u64;
    let mut pc = pipeline("engine", BackendKind::Float);
    pc.replicas = 4;
    let cfg = ServerConfig {
        pipelines: vec![pc],
        events_per_source: n,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    };
    let report = TriggerServer::run(&cfg).unwrap();
    let s = &report.per_model["engine"];
    // no loss: per-shard rings (1024 each) dwarf the event count
    assert_eq!(s.lost(), 0);
    // no loss, no duplication: exactly n scored, exactly n latencies,
    // exactly n labeled scores (the synthetic source labels everything)
    assert_eq!(s.accepted, n);
    assert_eq!(s.latency.count(), n);
    assert_eq!(s.scored_labels.len(), n as usize);
    assert_eq!(s.scored_pos.len(), n as usize);
    // shard accounting closes over the model totals
    assert_eq!(s.shards.len(), 4);
    assert_eq!(s.shards.iter().map(|sh| sh.accepted).sum::<u64>(), s.accepted);
    assert_eq!(s.shards.iter().map(|sh| sh.batches).sum::<u64>(), s.batches);
    assert_eq!(
        s.shards.iter().map(|sh| sh.batch_fill_sum).sum::<u64>(),
        s.batch_fill_sum
    );
    assert_eq!(
        s.shards.iter().map(|sh| sh.latency.count()).sum::<u64>(),
        s.latency.count()
    );
}

#[test]
fn replica_count_does_not_change_scores() {
    // the same deterministic event stream through pools of width 1 and 4
    // must produce the identical online AUC: the score *set* is
    // identical and the rank statistic is order-independent
    let run = |replicas: usize| {
        let mut pc = pipeline("engine", BackendKind::Float);
        pc.replicas = replicas;
        let cfg = ServerConfig {
            pipelines: vec![pc],
            events_per_source: 300,
            rate_per_source: 0,
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        };
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.lost(), 0, "run must not shed for the comparison to hold");
        s.online_auc().unwrap()
    };
    let single = run(1);
    let pooled = run(4);
    assert!(
        (single - pooled).abs() < 1e-12,
        "replicas=1 auc {single} vs replicas=4 auc {pooled}"
    );
}

#[test]
fn sharded_overload_sheds_only_when_all_shards_full() {
    // tiny rings + expensive backend: the pool must shed under overload,
    // and the exactly-once accounting must still close
    let mut pc = pipeline("gw", BackendKind::Hls);
    pc.replicas = 2;
    pc.ring_capacity = 2;
    pc.batch = BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(50) };
    let cfg = ServerConfig {
        pipelines: vec![pc],
        events_per_source: 200,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    };
    let report = TriggerServer::run(&cfg).unwrap();
    let s = &report.per_model["gw"];
    assert_eq!(s.accepted + s.shed, 200);
    assert!(s.shed > 0, "expected shedding");
    assert_eq!(s.dropped, 0, "backpressure sheds at the source, never drops");
    assert_eq!(s.latency.count(), s.accepted);
    assert_eq!(s.shards.len(), 2);
    assert_eq!(s.shards.iter().map(|sh| sh.accepted).sum::<u64>(), s.accepted);
}

#[test]
fn soak_multi_replica_bursty_arrivals_exactly_once() {
    // The soak bar: a 3-replica pool under bursty randomized arrivals
    // (compound-Poisson pacing: burst sizes uniform in [1, 2*burst),
    // exponential inter-burst gaps) must lose nothing, score every
    // event exactly once, and the per-shard accounting must reconcile
    // with the injected count to the event.
    let n = 3_000u64;
    let mut pc = pipeline("engine", BackendKind::Float);
    pc.replicas = 3;
    let cfg = ServerConfig {
        pipelines: vec![pc],
        events_per_source: n,
        // mean rate well inside float capacity; bursts of ~24 slam the
        // rings but sit far below the 1024/shard capacity, so any drop
        // is a real routing bug, not designed shedding
        rate_per_source: 30_000,
        burst_per_source: 24,
        artifacts_dir: PathBuf::from("."),
    };
    let report = TriggerServer::run(&cfg).unwrap();
    let s = &report.per_model["engine"];
    // zero loss on either side of the rings
    assert_eq!(s.lost(), 0, "bursty load within capacity must not shed or drop");
    // exactly-once scoring: n accepted, n latencies, n labeled scores
    assert_eq!(s.accepted, n);
    assert_eq!(s.latency.count(), n);
    assert_eq!(s.scored_labels.len(), n as usize);
    assert_eq!(s.scored_pos.len(), n as usize);
    // ShardStats totals reconcile with the injected count
    assert_eq!(s.shards.len(), 3);
    assert_eq!(s.shards.iter().map(|sh| sh.accepted).sum::<u64>(), n);
    assert_eq!(s.shards.iter().map(|sh| sh.latency.count()).sum::<u64>(), n);
    assert_eq!(s.shards.iter().map(|sh| sh.batches).sum::<u64>(), s.batches);
    assert_eq!(
        s.shards.iter().map(|sh| sh.batch_fill_sum).sum::<u64>(),
        s.batch_fill_sum
    );
    assert_eq!(s.batch_fill_sum, n, "every accepted event sits in exactly one batch");
    // bursts really did interleave work across the pool
    assert!(
        s.shards.iter().filter(|sh| sh.accepted > 0).count() >= 2,
        "bursty round-robin must exercise multiple shards"
    );
}

#[test]
fn hls_and_float_backends_rank_events_consistently() {
    // same events through both backends: online AUCs must be close
    let run = |backend| {
        let cfg = ServerConfig {
            pipelines: vec![pipeline("engine", backend)],
            events_per_source: 150,
            rate_per_source: 0,
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        };
        TriggerServer::run(&cfg).unwrap().per_model["engine"]
            .online_auc()
            .unwrap()
    };
    let a = run(BackendKind::Float);
    let b = run(BackendKind::Hls);
    assert!((a - b).abs() < 0.15, "float {a} vs hls {b}");
}
