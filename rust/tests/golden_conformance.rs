//! Golden-vector conformance suite (ISSUE 5 satellite): the committed
//! fixtures under `tests/golden/` pin the float forward's logits and
//! the HLS forward's probabilities **bitwise**, per zoo model ×
//! {uniform, mixed} precision plan.  Any cross-PR drift of either
//! forward fails here, naming the case and the first differing value.
//!
//! Sealing flow (see `testutil::golden` for the rationale):
//! * committed fixtures always carry the sealed *inputs* (integer-only
//!   RNG, platform-independent bit patterns);
//! * output lines reading `unsealed` are rewritten in place with the
//!   computed bit patterns on the first run — commit the sealed file;
//! * sealed output lines are compared bitwise and must match exactly.
//!
//! No network, no generation step: `cargo test` + the committed corpus.

use hls4ml_transformer::testutil::golden::{bits_of, compute, corpus, parse, render};
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn diff_bits(case: &str, what: &str, got: &[u32], want: &[u32]) {
    assert_eq!(
        got.len(),
        want.len(),
        "{case}: {what} length {} != sealed {}",
        got.len(),
        want.len()
    );
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g, w,
            "{case}: {what}[{i}] drifted: got {:08x} ({}), sealed {:08x} ({})\n\
             The bit-exact contract of the forward changed — if this is an\n\
             intentional numeric change, regenerate the corpus by deleting the\n\
             output lines (or the files) under tests/golden/ and committing\n\
             the re-sealed fixtures with an explanation.",
            g,
            f32::from_bits(*g),
            w,
            f32::from_bits(*w),
        );
    }
}

#[test]
fn golden_corpus_is_bitwise_stable() {
    let dir = golden_dir();
    let mut sealed_now = Vec::new();
    for case in corpus() {
        let name = case.file_name();
        let path = dir.join(&name);
        let v = compute(&case);
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!(
                "{name}: committed golden fixture missing ({e}); the corpus in \
                 tests/golden/ is part of the repository — restore it or re-seal \
                 by committing the output of testutil::golden::render"
            )
        });
        let f = parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(f.model, case.model, "{name}: fixture/corpus model mismatch");
        assert_eq!(f.plan, case.plan.tag(), "{name}: fixture/corpus plan mismatch");
        assert_eq!(f.input_seed, case.input_seed, "{name}: input seed drifted");
        assert_eq!(f.weights_seed, case.weights_seed, "{name}: weights seed drifted");
        // the committed inputs must be exactly what the corpus definition
        // regenerates — guards the generator itself
        diff_bits(&name, "input", &bits_of(v.input.data()), &f.input_bits);
        match (&f.float_logits_bits, &f.fixed_probs_bits) {
            (Some(fl), Some(fx)) => {
                diff_bits(&name, "float-logits", &bits_of(&v.float_logits), fl);
                diff_bits(&name, "fixed-probs", &bits_of(&v.fixed_probs), fx);
            }
            _ => {
                // first run on this corpus revision: seal in place
                std::fs::write(&path, render(&v, true))
                    .unwrap_or_else(|e| panic!("{name}: sealing failed: {e}"));
                sealed_now.push(name);
            }
        }
    }
    if !sealed_now.is_empty() {
        eprintln!(
            "golden conformance: sealed output bit patterns into {} fixture(s): \
             {:?}\nCommit the updated files under rust/tests/golden/ so later \
             PRs are held to these exact bits.",
            sealed_now.len(),
            sealed_now
        );
    }
}

#[test]
fn sealed_fixture_detects_a_single_flipped_bit() {
    // the guard must actually guard: take the real computed vector,
    // seal it, flip one mantissa bit, and the comparison must fail
    let case = &corpus()[0];
    let v = compute(case);
    let sealed = render(&v, true);
    let f = parse(&sealed).unwrap();
    let mut bad = f.fixed_probs_bits.clone().unwrap();
    bad[0] ^= 1;
    let name = case.file_name();
    let res = std::panic::catch_unwind(|| {
        diff_bits(&name, "fixed-probs", &bits_of(&v.fixed_probs), &bad)
    });
    assert!(res.is_err(), "a one-bit drift must fail the conformance suite");
}
