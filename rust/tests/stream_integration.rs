//! Continuous-stream ingestion, end to end (the ISSUE 5 acceptance bar):
//!
//! * streamed inference through the coordinator is **bitwise identical**
//!   to per-window `forward` on naive re-slices of the same stream;
//! * `repro stream`'s pipeline recovers >= 95% of injected chirps at
//!   hop S/2 on a zoo model (engine + analytic detector weights);
//! * trigger latency and sustained-throughput numbers come out sane.
//!
//! Artifact-free: the strain stream, detector weights and windowizer are
//! all deterministic in their seeds.

use std::path::PathBuf;

use hls4ml_transformer::coordinator::{
    Backend, BackendKind, PipelineConfig, ServerConfig, SourceMode, StreamSource,
    TriggerServer, WeightsSource,
};
use hls4ml_transformer::data::gw::{StrainConfig, StrainStream};
use hls4ml_transformer::hls::{ParallelismPlan, PrecisionPlan, QuantConfig, ReuseFactor};
use hls4ml_transformer::models::weights::detector_weights;
use hls4ml_transformer::models::zoo_model;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::stream::{analyze, StreamParams};

fn stream_server_cfg(
    backend: BackendKind,
    samples: u64,
    hop: usize,
    seed: u64,
    replicas: usize,
) -> ServerConfig {
    let seq_len = zoo_model("engine").unwrap().config.seq_len;
    ServerConfig {
        pipelines: vec![PipelineConfig {
            weights: WeightsSource::Detector,
            ring_capacity: 8192,
            replicas,
            source: SourceMode::Stream(StreamSource {
                samples,
                hop,
                strain: StrainConfig::new(seed, 1, seq_len),
                reuse: true,
            }),
            ..PipelineConfig::new("engine", backend)
        }],
        events_per_source: 0,
        rate_per_source: 0,
        artifacts_dir: PathBuf::from("."),
        ..Default::default()
    }
}

/// Re-create the exact windows the server's source thread produced:
/// same strain seed, same windowizer.
fn naive_windows(samples: u64, hop: usize, seed: u64) -> Vec<(u64, Mat)> {
    let cfg = zoo_model("engine").unwrap().config;
    let mut strain = StrainStream::new(StrainConfig::new(seed, 1, cfg.seq_len));
    let all = strain.collect(samples as usize);
    let mut out = Vec::new();
    let mut start = 0usize;
    while start + cfg.seq_len <= samples as usize {
        let mut data = Vec::with_capacity(cfg.seq_len);
        for t in start..start + cfg.seq_len {
            data.push(all.at(t, 0));
        }
        out.push((start as u64, Mat::from_vec(cfg.seq_len, 1, data)));
        start += hop;
    }
    out
}

fn backend_for(kind: BackendKind) -> Backend {
    let cfg = zoo_model("engine").unwrap().config;
    let w = detector_weights(&cfg);
    let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
    let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
    Backend::build(kind, &cfg, &w, &plan, &par, None, std::path::Path::new(".")).unwrap()
}

/// Streamed-through-the-coordinator scores must equal direct per-window
/// scoring of the naively re-sliced stream, bitwise, float and HLS.
/// Cross-window reuse is on (the default), so this pins the incremental
/// path to the naive recompute at the integration level too.
#[test]
fn streamed_scores_bitwise_match_naive_reslice_per_backend() {
    for (backend, samples, hop) in
        [(BackendKind::Float, 20_000u64, 37usize), (BackendKind::Hls, 3_000, 50)]
    {
        let seed = 0xB17E;
        let report =
            TriggerServer::run(&stream_server_cfg(backend, samples, hop, seed, 1)).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.lost(), 0, "{backend:?}: ring must absorb the whole stream");
        assert!(
            s.reuse.windows_incremental > 0,
            "{backend:?}: hop {hop} < S must engage incremental reuse"
        );
        let mut got: Vec<(u64, f32)> = s.windows.iter().map(|w| (w.pos, w.score)).collect();
        got.sort_unstable_by_key(|(p, _)| *p);
        let want = naive_windows(samples, hop, seed);
        assert_eq!(got.len(), want.len(), "{backend:?}: window count");
        let b = backend_for(backend);
        for ((gp, gs), (wp, wx)) in got.iter().zip(&want) {
            assert_eq!(gp, wp, "{backend:?}: window start");
            let probs = b.infer(&[wx]).unwrap();
            let direct = b.score(&probs[0]);
            assert_eq!(
                *gs, direct,
                "{backend:?}: window at {gp} drifted from the naive re-slice"
            );
        }
    }
}

/// The headline acceptance: >= 95% of injected chirps recovered at hop
/// S/2, with nonzero trigger-latency percentiles.  Parameters match the
/// `repro stream` defaults (threshold 3, amp 5-9, mean gap 1000).
#[test]
fn stream_recovers_95_percent_of_injections_at_hop_s_over_2() {
    let cfg = zoo_model("engine").unwrap().config;
    let (samples, hop) = (50_000u64, cfg.seq_len / 2);
    let report =
        TriggerServer::run(&stream_server_cfg(BackendKind::Float, samples, hop, 0xA11CE, 1))
            .unwrap();
    let s = &report.per_model["engine"];
    assert_eq!(s.lost(), 0);
    let truth = &report.stream_truth["engine"];
    let sr = analyze(
        s.windows.clone(),
        truth,
        &StreamParams::for_windows(cfg.seq_len as u64),
    );
    assert!(
        sr.injections >= 10,
        "50k samples at ~1.3k spacing must inject >= 10 chirps, got {}",
        sr.injections
    );
    assert!(
        sr.efficiency() >= 0.95,
        "recovered {}/{} injections ({:.1}%) — below the 95% bar\n{sr}",
        sr.found,
        sr.injections,
        100.0 * sr.efficiency()
    );
    // every trigger carries a real latency; percentiles are usable
    assert!(sr.trigger_latency.count() as usize == sr.triggers.len());
    assert!(sr.trigger_latency.quantile_ns(0.99) > 0);
    assert!(sr.trigger_latency.quantile_ns(0.5) <= sr.trigger_latency.quantile_ns(0.99));
    // false alarms stay a small fraction of the trigger count (the z
    // threshold is 3: a few background excursions are expected)
    assert!(
        sr.false_alarms <= sr.triggers.len() / 2,
        "{} false alarms of {} triggers",
        sr.false_alarms,
        sr.triggers.len()
    );
}

/// A sharded pool changes completion order, never the trigger verdicts:
/// same stream through 1 and 3 replicas must yield identical analyzer
/// results (scores are bitwise stable, the analyzer sorts).
#[test]
fn sharded_stream_pool_reproduces_single_replica_triggers() {
    let cfg = zoo_model("engine").unwrap().config;
    let run = |replicas: usize| {
        let report = TriggerServer::run(&stream_server_cfg(
            BackendKind::Float,
            20_000,
            cfg.seq_len / 2,
            0x5EED,
            replicas,
        ))
        .unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.lost(), 0);
        let truth = &report.stream_truth["engine"];
        analyze(
            s.windows.clone(),
            truth,
            &StreamParams::for_windows(cfg.seq_len as u64),
        )
    };
    let single = run(1);
    let pooled = run(3);
    assert_eq!(single.windows, pooled.windows);
    assert_eq!(single.injections, pooled.injections);
    assert_eq!(single.found, pooled.found);
    assert_eq!(single.false_alarms, pooled.false_alarms);
    let peaks = |r: &hls4ml_transformer::stream::StreamReport| {
        r.triggers.iter().map(|t| (t.peak_pos, t.onset, t.windows)).collect::<Vec<_>>()
    };
    assert_eq!(peaks(&single), peaks(&pooled), "identical de-duplicated triggers");
}
