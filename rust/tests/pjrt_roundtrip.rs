//! PJRT round-trip: load the AOT HLO-text artifacts, execute them on the
//! CPU client, and compare against the jax-recorded LUT-path logits —
//! the production serving path end to end.
//!
//! Compiled only with `--features pjrt`: without the vendored `xla`
//! crate the runtime is a stub and there is nothing to round-trip.
#![cfg(feature = "pjrt")]

use hls4ml_transformer::artifacts_dir;
use hls4ml_transformer::models::zoo::zoo;
use hls4ml_transformer::models::NnwFile;
use hls4ml_transformer::nn::tensor::Mat;
use hls4ml_transformer::runtime::Runtime;

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_executes_all_models_and_matches_jax() {
    let Some(dir) = artifacts_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    for m in zoo() {
        let cfg = &m.config;
        let eval = NnwFile::load(dir.join(m.eval_file())).unwrap();
        let x = eval.require("x").unwrap();
        let expected = eval.require("logits_lut").unwrap();
        let w = cfg.seq_len * cfg.input_size;

        for batch in [1usize, 8] {
            let exe = rt
                .load_hlo(
                    dir.join(m.hlo_file(batch)),
                    (batch, cfg.seq_len, cfg.input_size),
                    cfg.output_size,
                )
                .unwrap();
            let events: Vec<Mat> = (0..batch)
                .map(|i| {
                    Mat::from_vec(
                        cfg.seq_len,
                        cfg.input_size,
                        x.data[i * w..(i + 1) * w].to_vec(),
                    )
                })
                .collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let logits = exe.run_events(&refs).unwrap();
            // statistical gate (same as aot.py's): tight in the median,
            // ROM bin-edge flips allowed in the tail — the PJRT graph is
            // the *pallas* path while logits_lut records the oracle path
            let mut rels: Vec<f32> = Vec::new();
            for (i, l) in logits.iter().enumerate() {
                for (j, &v) in l.iter().enumerate() {
                    let want = expected.data[i * cfg.output_size + j];
                    rels.push((v - want).abs() / want.abs().max(1.0));
                }
            }
            rels.sort_by(|a, b| a.total_cmp(b));
            let median = rels[rels.len() / 2];
            let max = *rels.last().unwrap();
            assert!(median < 5e-3, "{} b{batch}: median rel {median}", cfg.name);
            assert!(max < 0.1, "{} b{batch}: max rel {max}", cfg.name);
        }
    }
}

#[test]
fn pjrt_batch_padding_works() {
    // fewer events than the compiled batch: tail is zero-padded and only
    // real events are returned
    let Some(dir) = artifacts_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let m = &zoo()[0];
    let cfg = &m.config;
    let exe = rt
        .load_hlo(dir.join(m.hlo_file(8)), (8, cfg.seq_len, cfg.input_size), cfg.output_size)
        .unwrap();
    let ev = Mat::zeros(cfg.seq_len, cfg.input_size);
    let out = exe.run_events(&[&ev, &ev, &ev]).unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].len(), cfg.output_size);
    // identical inputs -> identical outputs
    assert_eq!(out[0], out[1]);
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let Some(dir) = artifacts_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let m = &zoo()[0];
    let cfg = &m.config;
    let exe = rt
        .load_hlo(dir.join(m.hlo_file(1)), (1, cfg.seq_len, cfg.input_size), cfg.output_size)
        .unwrap();
    // wrong flat size
    assert!(exe.run(&[0.0; 7]).is_err());
    // wrong event shape
    let bad = Mat::zeros(3, 3);
    assert!(exe.run_events(&[&bad]).is_err());
    // batch overflow
    let ok = Mat::zeros(cfg.seq_len, cfg.input_size);
    assert!(exe.run_events(&[&ok, &ok]).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let rt = Runtime::cpu().unwrap();
    let err = rt.load_hlo("/nonexistent/model.hlo.txt", (1, 2, 3), 4);
    assert!(err.is_err());
}
