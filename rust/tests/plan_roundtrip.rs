//! Property tests for the shared plan-file skeleton (`hls/planfile.rs`)
//! through its two public grammars: `PrecisionPlan` (`site ap_fixed<W,I>`)
//! and `ParallelismPlan` (`site R`).
//!
//! * parse -> format -> parse identity for random valid plans (any block
//!   count, any site values, with and without explicit accumulators);
//! * error paths — unknown site, malformed `ap_fixed`, duplicate line —
//!   are ONE line and name the offending entry with its line number.

use hls4ml_transformer::fixed::FixedSpec;
use hls4ml_transformer::hls::{ParallelismPlan, PrecisionPlan, QuantConfig, ReuseFactor};
use hls4ml_transformer::testutil::{Gen, Prop};

fn random_precision_plan(g: &mut Gen) -> PrecisionPlan {
    let blocks = g.usize_in(0, 5);
    let mut plan = PrecisionPlan::uniform(blocks, QuantConfig::new(6, 10));
    for site in plan.site_names() {
        if g.bool() {
            plan.set_data(&site, g.fixed_spec_max_width(24)).unwrap();
        } else {
            // explicit (non-derived) accumulator exercises the second
            // token of the grammar
            let data = g.fixed_spec_max_width(20);
            let accum = FixedSpec::new(
                30 + (g.usize_in(0, 10) as u32),
                10 + (g.usize_in(0, 5) as u32),
            );
            plan.set(&site, QuantConfig { data, accum }).unwrap();
        }
    }
    plan
}

#[test]
fn prop_precision_plan_parse_format_parse_identity() {
    Prop::new("precision plan serialize round-trip").runs(200).check(|g| {
        let plan = random_precision_plan(g);
        let text = plan.serialize();
        // parse onto an unrelated base: every site must be overwritten
        let mut rt = PrecisionPlan::uniform(plan.num_blocks(), QuantConfig::new(4, 4));
        rt.apply_overrides(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        assert_eq!(rt, plan, "parse(format(plan)) != plan for:\n{text}");
        // format is a fixpoint: format(parse(format(plan))) == format(plan)
        assert_eq!(rt.serialize(), text);
    });
}

#[test]
fn prop_parallelism_plan_parse_format_parse_identity() {
    Prop::new("parallelism plan serialize round-trip").runs(200).check(|g| {
        let blocks = g.usize_in(0, 5);
        let mut plan = ParallelismPlan::uniform(blocks, ReuseFactor(1));
        for site in plan.site_names() {
            let r = [1u32, 2, 3, 4, 8, 16, 64, 1024][g.usize_in(0, 8)];
            plan.set(&site, ReuseFactor(r)).unwrap();
        }
        let text = plan.serialize();
        let mut rt = ParallelismPlan::uniform(blocks, ReuseFactor(7));
        rt.apply_overrides(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        assert_eq!(rt, plan, "parse(format(plan)) != plan for:\n{text}");
        assert_eq!(rt.serialize(), text);
    });
}

/// Every error is one line, carries the 1-based line number, and names
/// the offending entry.
fn assert_one_line_error(err: &str, line: usize, needle: &str) {
    assert!(!err.contains('\n'), "one line: {err}");
    assert!(err.contains(&format!("line {line}")), "line number: {err}");
    assert!(err.contains(needle), "names '{needle}': {err}");
}

#[test]
fn precision_error_paths_name_the_bad_entry() {
    let base = || PrecisionPlan::uniform(2, QuantConfig::new(6, 10));
    // unknown site (second line, so numbering is visible)
    let err = base()
        .apply_overrides("embed ap_fixed<12,4>\nblock7.ln1 ap_fixed<8,3>\n")
        .unwrap_err();
    assert_one_line_error(&err, 2, "block7.ln1");
    // malformed ap_fixed
    let err = base().apply_overrides("embed ap_fixd<8,3>\n").unwrap_err();
    assert_one_line_error(&err, 1, "ap_fixd<8,3>");
    // structurally valid but inconsistent widths
    let err = base().apply_overrides("embed ap_fixed<3,9>\n").unwrap_err();
    assert_one_line_error(&err, 1, "ap_fixed<3,9>");
    // duplicate line
    let err = base()
        .apply_overrides("embed ap_fixed<12,4>\npool ap_fixed<8,3>\nembed ap_fixed<10,4>\n")
        .unwrap_err();
    assert_one_line_error(&err, 3, "duplicate assignment for site 'embed'");
    assert!(err.contains("first assigned at line 1"), "{err}");
}

#[test]
fn parallelism_error_paths_name_the_bad_entry() {
    let base = || ParallelismPlan::uniform(2, ReuseFactor(1));
    let err = base().apply_overrides("pool R2\nblock9.ffn1 4\n").unwrap_err();
    assert_one_line_error(&err, 2, "block9.ffn1");
    let err = base().apply_overrides("pool R0\n").unwrap_err();
    assert_one_line_error(&err, 1, "out of range");
    // softmax is a precision-only site: the reuse grammar rejects it
    let err = base().apply_overrides("softmax 4\n").unwrap_err();
    assert_one_line_error(&err, 1, "softmax");
    let err = base().apply_overrides("pool R2\n\n# c\npool 4\n").unwrap_err();
    assert_one_line_error(&err, 4, "duplicate assignment for site 'pool'");
}

#[test]
fn prop_duplicate_of_any_random_site_is_rejected_by_both_grammars() {
    Prop::new("duplicate site rejected").runs(100).check(|g| {
        let blocks = g.usize_in(1, 4);
        let plan = PrecisionPlan::uniform(blocks, QuantConfig::new(6, 10));
        let sites = plan.site_names();
        let site = &sites[g.usize_in(0, sites.len())];
        let text = format!("{site} ap_fixed<12,4>\n{site} ap_fixed<10,3>\n");
        let err = plan.clone().apply_overrides(&text).unwrap_err();
        assert_one_line_error(&err, 2, &format!("'{site}'"));
        // the reuse grammar shares the skeleton (minus softmax)
        if site != "softmax" {
            let mut par = ParallelismPlan::uniform(blocks, ReuseFactor(1));
            let err = par
                .apply_overrides(&format!("{site} 2\n{site} 4\n"))
                .unwrap_err();
            assert_one_line_error(&err, 2, &format!("'{site}'"));
        }
    });
}
