//! Log-bucketed latency histogram for the serving path — allocation-free
//! on the record path (fixed bucket array), p50/p99 by interpolation.

/// Latency histogram over nanosecond samples.
///
/// Buckets are log2-spaced from 64 ns to ~1.1 s; recording is O(1) with
/// no allocation (the coordinator records on its hot path).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; 48],
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: [0; 48],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        // bucket i covers [64 * 2^(i/2 rounding), ...): use leading_zeros
        let b = 64 - (ns.max(1)).leading_zeros() as usize;
        b.saturating_sub(6).min(47)
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min_ns }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile (bucket upper-edge interpolation).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                // bucket i spans [2^(i+5), 2^(i+6)) ns (approx; bucket 0
                // absorbs everything below); clamp into observed range
                return (1u64 << (i + 6)).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Merge another histogram in (for multi-worker aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us min={:.1}us max={:.1}us",
            self.count,
            self.mean_ns() / 1000.0,
            self.quantile_ns(0.50) as f64 / 1000.0,
            self.quantile_ns(0.99) as f64 / 1000.0,
            self.min_ns() as f64 / 1000.0,
            self.max_ns as f64 / 1000.0,
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prop;

    #[test]
    fn records_and_counts() {
        let mut h = LatencyHistogram::new();
        for ns in [100, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 100_000);
        assert!((h.mean_ns() - 20_300.0).abs() < 1.0);
    }

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(i * 1000);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99, "{p50} vs {p99}");
        assert!(p50 >= 64, "sane lower bound");
    }

    #[test]
    fn empty_histogram_is_calm() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1000);
        b.record(2000);
        b.record(3000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_ns(), 3000);
    }

    #[test]
    fn prop_quantile_within_minmax_envelope() {
        Prop::new("quantile envelope").runs(200).check(|g| {
            let mut h = LatencyHistogram::new();
            let n = g.usize_in(1, 200);
            for _ in 0..n {
                h.record(g.usize_in(100, 10_000_000) as u64);
            }
            let p50 = h.quantile_ns(0.5);
            // quantile is a bucket edge: allow one bucket (2x) slack
            assert!(p50 >= h.min_ns() / 2, "p50 {p50} min {}", h.min_ns());
            assert!(p50 <= h.max_ns() * 2, "p50 {p50} max {}", h.max_ns());
        });
    }
}
