//! Log-bucketed latency histogram for the serving path — allocation-free
//! on the record path (fixed bucket array), p50/p99 by bucket-edge
//! lookup.  The bucket layout is exported verbatim by the metrics
//! endpoint (`coordinator::metrics_http`), so the edges here ARE the
//! Prometheus `le` labels a scrape aggregator sees.

/// Latency histogram over nanosecond samples.
///
/// Buckets are log2-spaced from 64 ns to ~4.5e15 ns; recording is O(1)
/// with no allocation (the coordinator records on its hot path).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; Self::NUM_BUCKETS],
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl LatencyHistogram {
    /// Number of log2 buckets (fixed; part of the exposition format).
    pub const NUM_BUCKETS: usize = 48;

    pub fn new() -> Self {
        Self {
            buckets: [0; Self::NUM_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        // log2 spacing off the sample's bit width: bucket 0 absorbs
        // [0, 64); bucket i in 1..=46 covers [2^(i+5), 2^(i+6)); the
        // last bucket (47) absorbs everything from 2^52 ns up
        let b = 64 - (ns.max(1)).leading_zeros() as usize;
        b.saturating_sub(6).min(Self::NUM_BUCKETS - 1)
    }

    /// Inclusive upper edge of bucket `i` in nanoseconds; `None` for the
    /// open-ended last bucket (the Prometheus `+Inf` bucket).  Every
    /// sample `ns` satisfies `ns <= bucket_upper_edge_ns(bucket_of(ns))`.
    pub fn bucket_upper_edge_ns(i: usize) -> Option<u64> {
        assert!(i < Self::NUM_BUCKETS, "bucket index {i} out of range");
        if i == Self::NUM_BUCKETS - 1 {
            None
        } else {
            Some((1u64 << (i + 6)) - 1)
        }
    }

    /// Per-bucket sample counts (non-cumulative), for exposition.
    pub fn bucket_counts(&self) -> &[u64; Self::NUM_BUCKETS] {
        &self.buckets
    }

    /// Total of all recorded samples in nanoseconds (exposition `_sum`).
    pub fn sum_ns(&self) -> u128 {
        self.sum_ns
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min_ns }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile: the *inclusive* upper edge of the bucket
    /// holding the q-th sample, clamped into the observed
    /// `[min_ns, max_ns]` envelope.  The returned value always lies in
    /// (or at the edge of) the quantile's own bucket — never in the
    /// next one up.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return match Self::bucket_upper_edge_ns(i) {
                    Some(edge) => edge.clamp(self.min_ns, self.max_ns),
                    None => self.max_ns,
                };
            }
        }
        self.max_ns
    }

    /// Merge another histogram in (for multi-worker aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us min={:.1}us max={:.1}us",
            self.count,
            self.mean_ns() / 1000.0,
            self.quantile_ns(0.50) as f64 / 1000.0,
            self.quantile_ns(0.99) as f64 / 1000.0,
            self.min_ns() as f64 / 1000.0,
            self.max_ns as f64 / 1000.0,
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prop;

    #[test]
    fn records_and_counts() {
        let mut h = LatencyHistogram::new();
        for ns in [100, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 100_000);
        assert!((h.mean_ns() - 20_300.0).abs() < 1.0);
        assert_eq!(h.sum_ns(), 101_500);
    }

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(i * 1000);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99, "{p50} vs {p99}");
        assert!(p50 >= 64, "sane lower bound");
    }

    #[test]
    fn empty_histogram_is_calm() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1000);
        b.record(2000);
        b.record(3000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_ns(), 3000);
    }

    #[test]
    fn bucket_edges_are_inclusive_and_consistent_with_bucket_of() {
        // every finite bucket's inclusive edge lands in its OWN bucket,
        // and edge+1 lands in the next one — the exact off-by-one the
        // old exclusive-edge quantile got wrong
        for i in 0..LatencyHistogram::NUM_BUCKETS - 1 {
            let edge = LatencyHistogram::bucket_upper_edge_ns(i).unwrap();
            assert_eq!(LatencyHistogram::bucket_of(edge), i, "edge of bucket {i}");
            assert_eq!(
                LatencyHistogram::bucket_of(edge + 1),
                i + 1,
                "first value past bucket {i}"
            );
        }
        assert!(LatencyHistogram::bucket_upper_edge_ns(
            LatencyHistogram::NUM_BUCKETS - 1
        )
        .is_none());
    }

    #[test]
    fn identical_samples_quantiles_stay_in_their_bucket() {
        // N identical samples: every quantile must report a value inside
        // that sample's own bucket (regression pin for the exclusive-edge
        // off-by-one, which reported a value from the bucket above)
        for ns in [1u64, 63, 64, 100, 127, 128, 999, 65_536, 1 << 52, u64::MAX] {
            let bucket = LatencyHistogram::bucket_of(ns);
            let mut h = LatencyHistogram::new();
            for _ in 0..57 {
                h.record(ns);
            }
            for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let v = h.quantile_ns(q);
                assert_eq!(
                    LatencyHistogram::bucket_of(v),
                    bucket,
                    "q={q} of {ns}-valued histogram reported {v}, outside bucket {bucket}"
                );
                // and within the observed envelope, exactly
                assert!(v >= h.min_ns() && v <= h.max_ns(), "q={q} ns={ns} v={v}");
            }
        }
    }

    #[test]
    fn prop_quantile_within_minmax_envelope() {
        Prop::new("quantile envelope").runs(200).check(|g| {
            let mut h = LatencyHistogram::new();
            let n = g.usize_in(1, 200);
            for _ in 0..n {
                h.record(g.usize_in(100, 10_000_000) as u64);
            }
            for q in [0.0, 0.5, 0.99, 1.0] {
                let v = h.quantile_ns(q);
                assert!(v >= h.min_ns(), "q={q} v={v} min {}", h.min_ns());
                assert!(v <= h.max_ns(), "q={q} v={v} max {}", h.max_ns());
            }
        });
    }

    #[test]
    fn prop_quantile_monotone_in_q() {
        Prop::new("quantile monotone").runs(200).check(|g| {
            let mut h = LatencyHistogram::new();
            let n = g.usize_in(1, 300);
            for _ in 0..n {
                h.record(g.usize_in(1, 50_000_000) as u64);
            }
            let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0];
            let vs: Vec<u64> = qs.iter().map(|&q| h.quantile_ns(q)).collect();
            for w in vs.windows(2) {
                assert!(w[0] <= w[1], "quantiles must be monotone: {vs:?}");
            }
        });
    }

    #[test]
    fn prop_merge_commutative_and_associative() {
        // the invariants a multi-process scrape aggregator relies on:
        // merging shard histograms in any order/grouping yields the same
        // counts, sum, min/max, bucket contents and therefore quantiles
        fn fill(g: &mut crate::testutil::Gen, n: usize) -> LatencyHistogram {
            let mut h = LatencyHistogram::new();
            for _ in 0..n {
                h.record(g.usize_in(1, 100_000_000) as u64);
            }
            h
        }
        fn same(a: &LatencyHistogram, b: &LatencyHistogram) {
            assert_eq!(a.count(), b.count());
            assert_eq!(a.sum_ns(), b.sum_ns());
            assert_eq!(a.min_ns(), b.min_ns());
            assert_eq!(a.max_ns(), b.max_ns());
            assert_eq!(a.bucket_counts(), b.bucket_counts());
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                assert_eq!(a.quantile_ns(q), b.quantile_ns(q), "q={q}");
            }
        }
        Prop::new("merge algebra").runs(100).check(|g| {
            let a = fill(g, g.usize_in(0, 60));
            let b = fill(g, g.usize_in(0, 60));
            let c = fill(g, g.usize_in(0, 60));
            // commutative: a+b == b+a
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            same(&ab, &ba);
            // associative: (a+b)+c == a+(b+c)
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            same(&ab_c, &a_bc);
        });
    }
}
