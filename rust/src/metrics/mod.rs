//! Evaluation metrics (S7): ROC-AUC (the paper's Figures 9-11 metric),
//! accuracy, and latency histograms for the serving path.

pub mod auc;
pub mod histogram;

pub use auc::{binary_auc, macro_auc, Accuracy};
pub use histogram::LatencyHistogram;
