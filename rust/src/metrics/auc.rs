//! ROC AUC via the Mann-Whitney rank statistic (exact, tie-aware) —
//! mirrors `python/compile/train.py::binary_auc` so the two stacks score
//! identically.

/// Exact binary ROC AUC. `labels[i]` is 1 for positives.
/// Degenerate inputs (single-class) return 0.5, as chance.
pub fn binary_auc(scores: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // midranks for ties
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    let mut r = 1.0f64;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let mid = (r + r + (j - i) as f64) / 2.0;
        for &idx in &order[i..=j] {
            ranks[idx] = mid;
        }
        r += (j - i + 1) as f64;
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l == 1).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = ranks
        .iter()
        .zip(labels)
        .filter(|(_, &l)| l == 1)
        .map(|(r, _)| r)
        .sum();
    (rank_sum - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// Macro one-vs-rest AUC for multi-class probabilities
/// (`probs[i]` has one probability per class; labels are class indices).
pub fn macro_auc(probs: &[Vec<f32>], labels: &[u8], num_classes: usize) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let mut total = 0.0;
    for c in 0..num_classes {
        let scores: Vec<f32> = probs.iter().map(|p| p[c]).collect();
        let bin: Vec<u8> = labels.iter().map(|&l| (l as usize == c) as u8).collect();
        total += binary_auc(&scores, &bin);
    }
    total / num_classes as f64
}

/// Simple accuracy accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    correct: u64,
    total: u64,
}

impl Accuracy {
    pub fn push(&mut self, predicted: usize, truth: usize) {
        self.correct += (predicted == truth) as u64;
        self.total += 1;
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prop;

    #[test]
    fn perfect_and_inverted() {
        assert_eq!(binary_auc(&[0.9, 0.8, 0.2, 0.1], &[1, 1, 0, 0]), 1.0);
        assert_eq!(binary_auc(&[0.1, 0.2, 0.8, 0.9], &[1, 1, 0, 0]), 0.0);
    }

    #[test]
    fn all_ties_is_half() {
        assert_eq!(binary_auc(&[0.5; 6], &[1, 0, 1, 0, 1, 0]), 0.5);
    }

    #[test]
    fn degenerate_labels_half() {
        assert_eq!(binary_auc(&[0.1, 0.9], &[1, 1]), 0.5);
        assert_eq!(binary_auc(&[], &[]), 0.5);
    }

    #[test]
    fn matches_hand_computed_case() {
        // scores 0.1 0.4 0.35 0.8, labels 0 0 1 1 -> AUC = 0.75
        let auc = binary_auc(&[0.1, 0.4, 0.35, 0.8], &[0, 0, 1, 1]);
        assert!((auc - 0.75).abs() < 1e-12);
    }

    #[test]
    fn prop_auc_in_unit_interval_and_monotone_invariant() {
        Prop::new("auc bounds + monotone invariance").runs(300).check(|g| {
            let n = g.usize_in(2, 64);
            let scores: Vec<f32> = (0..n).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let labels: Vec<u8> = (0..n).map(|_| g.bool() as u8).collect();
            let a = binary_auc(&scores, &labels);
            assert!((0.0..=1.0).contains(&a));
            // monotone transform of scores must not change AUC
            let t: Vec<f32> = scores.iter().map(|&s| s.tanh() * 2.0 + 5.0).collect();
            let b = binary_auc(&t, &labels);
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        });
    }

    #[test]
    fn prop_complement_symmetry() {
        Prop::new("auc(1-labels) == 1-auc").runs(300).check(|g| {
            let n = g.usize_in(2, 64);
            let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
            let labels: Vec<u8> = (0..n).map(|_| g.bool() as u8).collect();
            if labels.iter().all(|&l| l == 0) || labels.iter().all(|&l| l == 1) {
                return;
            }
            let a = binary_auc(&scores, &labels);
            let inv: Vec<u8> = labels.iter().map(|&l| 1 - l).collect();
            let b = binary_auc(&scores, &inv);
            assert!((a + b - 1.0).abs() < 1e-9);
        });
    }

    #[test]
    fn macro_auc_perfect_three_class() {
        let probs = vec![
            vec![0.8, 0.1, 0.1],
            vec![0.1, 0.8, 0.1],
            vec![0.1, 0.1, 0.8],
            vec![0.7, 0.2, 0.1],
            vec![0.2, 0.7, 0.1],
            vec![0.1, 0.2, 0.7],
        ];
        let labels = [0u8, 1, 2, 0, 1, 2];
        assert_eq!(macro_auc(&probs, &labels, 3), 1.0);
    }

    #[test]
    fn accuracy_accumulator() {
        let mut a = Accuracy::default();
        a.push(1, 1);
        a.push(0, 1);
        a.push(2, 2);
        assert!((a.value() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.total(), 3);
    }
}
