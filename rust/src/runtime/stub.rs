//! Stub PJRT runtime, compiled when the `pjrt` cargo feature is off.
//!
//! Mirrors the API of [`super::client`] exactly so the rest of the crate
//! (coordinator backends, examples, the CLI) typechecks unchanged; the
//! only reachable entry point, [`Runtime::cpu`], reports that this build
//! has no PJRT client.  The coordinator treats that as a failed backend
//! build for the affected pipeline — never a crash or a deadlock.

use std::path::Path;

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` cargo feature \
     (requires the vendored `xla` crate — see rust/Cargo.toml)";

/// Placeholder for the PJRT CPU client. Never constructible in this
/// build: [`Runtime::cpu`] always errors.
pub struct Runtime {
    _unconstructible: (),
}

/// Placeholder for a compiled inference graph. Never constructible in
/// this build.
pub struct Executable {
    _unconstructible: (),
}

impl Runtime {
    /// Always fails in a stub build, with an error naming the fix.
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        String::new()
    }

    pub fn load_hlo(
        &self,
        _path: impl AsRef<Path>,
        _input_shape: (usize, usize, usize),
        _output_size: usize,
    ) -> Result<Executable> {
        bail!(UNAVAILABLE)
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        ""
    }

    pub fn input_shape(&self) -> (usize, usize, usize) {
        (0, 0, 0)
    }

    pub fn batch_size(&self) -> usize {
        0
    }

    pub fn output_size(&self) -> usize {
        0
    }

    pub fn run(&self, _input: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn run_events(
        &self,
        _events: &[&crate::nn::tensor::Mat],
    ) -> Result<Vec<Vec<f32>>> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
