//! PJRT client wrapper: HLO-text -> compile -> execute.
//!
//! Pattern follows /opt/xla-example/load_hlo: the interchange format is
//! HLO *text* because jax >= 0.5 serializes HloModuleProto with 64-bit
//! instruction ids that the vendored xla_extension 0.5.1 rejects; the
//! text parser reassigns ids.  Outputs were lowered with
//! `return_tuple=True`, so execution results unwrap with `to_tuple1`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

/// Owns the PJRT CPU client.  One per process; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    ///
    /// `input_shape` is the event-batch shape the graph was lowered for
    /// (batch, seq_len, input_size); `output_size` the per-event logit
    /// width.  Both are validated at execute time.
    pub fn load_hlo(
        &self,
        path: impl AsRef<Path>,
        input_shape: (usize, usize, usize),
        output_size: usize,
    ) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {}", path.display()))?;
        Ok(Executable {
            exe,
            input_shape,
            output_size,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

/// A compiled inference graph for one (model, batch) pair.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    input_shape: (usize, usize, usize),
    output_size: usize,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// (batch, seq_len, input_size) the graph was lowered for.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.input_shape
    }

    pub fn batch_size(&self) -> usize {
        self.input_shape.0
    }

    pub fn output_size(&self) -> usize {
        self.output_size
    }

    /// Execute on a flat row-major `(batch, seq, feat)` buffer; returns
    /// flat `(batch, output_size)` logits.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let (b, s, f) = self.input_shape;
        ensure!(
            input.len() == b * s * f,
            "input len {} != {}x{}x{}",
            input.len(),
            b,
            s,
            f
        );
        let lit = xla::Literal::vec1(input)
            .reshape(&[b as i64, s as i64, f as i64])
            .context("reshape input literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("device -> host literal")?;
        let tuple = result.to_tuple1().context("unwrap 1-tuple output")?;
        let out = tuple.to_vec::<f32>().context("output literal -> vec")?;
        ensure!(
            out.len() == b * self.output_size,
            "output len {} != {}x{}",
            out.len(),
            b,
            self.output_size
        );
        Ok(out)
    }

    /// Convenience: run a batch of event matrices (padding the tail with
    /// zeros when fewer events than the compiled batch size arrive).
    /// Returns per-event logits for the real events only — the padded
    /// lanes' outputs are computed by the graph but never surfaced, so a
    /// partial chunk is semantically identical to per-event execution.
    /// An empty slice short-circuits to no logits without touching the
    /// device (running an all-padding batch would waste a full execute).
    pub fn run_events(&self, events: &[&crate::nn::tensor::Mat]) -> Result<Vec<Vec<f32>>> {
        if events.is_empty() {
            return Ok(Vec::new());
        }
        let (b, s, f) = self.input_shape;
        ensure!(events.len() <= b, "batch overflow: {} > {b}", events.len());
        let mut flat = vec![0.0f32; b * s * f];
        for (i, e) in events.iter().enumerate() {
            ensure!(e.rows() == s && e.cols() == f, "event shape mismatch");
            flat[i * s * f..(i + 1) * s * f].copy_from_slice(e.data());
        }
        let out = self.run(&flat)?;
        Ok(events
            .iter()
            .enumerate()
            .map(|(i, _)| out[i * self.output_size..(i + 1) * self.output_size].to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests against real artifacts live in
    // rust/tests/aot_roundtrip.rs (they need `make artifacts` to have
    // run); here we only cover the pure logic.
    use super::*;

    #[test]
    fn runtime_cpu_creates() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }
}
