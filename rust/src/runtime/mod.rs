//! PJRT runtime (S9): loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! HLO **text** — see DESIGN.md §3) and executes them on the CPU PJRT
//! client via the `xla` crate.  Python is never involved at runtime.

pub mod client;

pub use client::{Executable, Runtime};
