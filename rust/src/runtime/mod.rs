//! PJRT runtime (S9): loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! HLO **text** — see DESIGN.md §3) and executes them on the CPU PJRT
//! client via the `xla` crate.  Python is never involved at runtime.
//!
//! The `xla` bindings (vendored xla_extension 0.5.1) only exist in the
//! offline build image, so the real client is gated behind the `pjrt`
//! cargo feature.  Without it, [`stub`] provides the same API surface
//! with a `Runtime::cpu()` that returns a clean error — every non-PJRT
//! backend (float / hls) and the whole tier-1 test suite work in any
//! environment.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};
