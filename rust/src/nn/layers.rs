//! Exact-float layer primitives (dense / softmax / layernorm / MHA),
//! in per-event and batch-major (`Mat3`) forms.
//!
//! The batched kernels keep every per-accumulator operation in the same
//! order as the per-event kernels (additions in ascending input index),
//! so a batched forward is **bitwise identical** to running the events
//! one at a time — the coordinator can switch `Backend::infer` to the
//! batch path without perturbing any score (property-tested here and in
//! `nn::transformer`).

use super::tensor::{dot, Mat, Mat3};
use crate::models::weights::MhaWeights;

/// Activation functions used by the zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Sigmoid,
}

impl Activation {
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Relu => x.max(0.0),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }
}

/// `y = act(x @ w + b)` — x: (rows, in), w: (in, out), b: (out).
pub fn dense(x: &Mat, w: &Mat, b: &[f32], act: Activation) -> Mat {
    assert_eq!(x.cols(), w.rows());
    assert_eq!(w.cols(), b.len());
    let mut y = x.matmul(w);
    for r in 0..y.rows() {
        let row = y.row_mut(r);
        for (v, &bias) in row.iter_mut().zip(b) {
            *v = act.apply(*v + bias);
        }
    }
    y
}

/// One row of numerically-stable softmax, in place — shared by the
/// per-event and batched attention paths so the two stay bit-identical.
#[inline]
pub fn softmax_row_in_place(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Numerically-stable softmax over each row.
pub fn softmax_rows(x: &Mat) -> Mat {
    let mut out = x.clone();
    for r in 0..out.rows() {
        softmax_row_in_place(out.row_mut(r));
    }
    out
}

/// One row of layer normalization in place (biased variance, like
/// hls4ml) — shared by the per-event and batched paths.
#[inline]
pub fn layernorm_row_in_place(row: &mut [f32], gamma: &[f32], beta: &[f32]) {
    let k = row.len() as f32;
    let mean = row.iter().sum::<f32>() / k;
    let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / k;
    let inv = 1.0 / var.sqrt().max(1e-12);
    for (v, (&g, &b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
        *v = (*v - mean) * inv * g + b;
    }
}

/// Layer normalization over each row (biased variance, like hls4ml).
pub fn layernorm_rows(x: &Mat, gamma: &[f32], beta: &[f32]) -> Mat {
    assert_eq!(x.cols(), gamma.len());
    assert_eq!(x.cols(), beta.len());
    let mut out = x.clone();
    for r in 0..out.rows() {
        layernorm_row_in_place(out.row_mut(r), gamma, beta);
    }
    out
}

/// Batched layer normalization, in place over every row of every event.
pub fn layernorm_batch(x: &mut Mat3, gamma: &[f32], beta: &[f32]) {
    assert_eq!(x.cols(), gamma.len());
    assert_eq!(x.cols(), beta.len());
    for i in 0..x.flat_rows() {
        layernorm_row_in_place(x.flat_row_mut(i), gamma, beta);
    }
}

/// Batched `y = act(x @ w + b)` over every event at once.
///
/// Weight-stationary loop order: `w` is streamed exactly once per layer
/// call — each weight row is applied to all `batch*rows` activation rows
/// before the next is touched — instead of once per event.  Every output
/// accumulator still sums products in ascending input index, so results
/// are bitwise identical to [`dense`] per event.
pub fn dense_batch(x: &Mat3, w: &Mat, b: &[f32], act: Activation) -> Mat3 {
    assert_eq!(x.cols(), w.rows());
    assert_eq!(w.cols(), b.len());
    let n = x.flat_rows();
    let mut y = Mat3::zeros(x.batch(), x.rows(), w.cols());
    for kk in 0..w.rows() {
        let wrow = w.row(kk);
        for i in 0..n {
            let xv = x.flat_row(i)[kk];
            for (o, &wv) in y.flat_row_mut(i).iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    for i in 0..n {
        for (v, &bias) in y.flat_row_mut(i).iter_mut().zip(b) {
            *v = act.apply(*v + bias);
        }
    }
    y
}

/// One attention head: exact eq. (4) of the paper.
pub fn attention_head(x: &Mat, wq: &Mat, bq: &[f32], wk: &Mat, bk: &[f32],
                      wv: &Mat, bv: &[f32]) -> Mat {
    let q = dense(x, wq, bq, Activation::Linear);
    let k = dense(x, wk, bk, Activation::Linear);
    let v = dense(x, wv, bv, Activation::Linear);
    let scale = 1.0 / (q.cols() as f32).sqrt();
    // scores = q @ k^T * scale
    let mut scores = Mat::zeros(q.rows(), k.rows());
    for i in 0..q.rows() {
        for j in 0..k.rows() {
            *scores.at_mut(i, j) = dot(q.row(i), k.row(j)) * scale;
        }
    }
    softmax_rows(&scores).matmul(&v)
}

/// Full multi-head attention: heads -> concat -> output projection.
pub fn mha(x: &Mat, w: &MhaWeights) -> Mat {
    let heads: Vec<Mat> = (0..w.wq.len())
        .map(|h| {
            attention_head(x, &w.wq[h], &w.bq[h], &w.wk[h], &w.bk[h], &w.wv[h], &w.bv[h])
        })
        .collect();
    // concat along columns (paper stage 4), then project
    let k = heads[0].cols();
    let mut concat = Mat::zeros(x.rows(), heads.len() * k);
    for (h, head) in heads.iter().enumerate() {
        for r in 0..head.rows() {
            concat.row_mut(r)[h * k..(h + 1) * k].copy_from_slice(head.row(r));
        }
    }
    dense(&concat, &w.wo, &w.bo, Activation::Linear)
}

/// Batched multi-head attention: every event's Q/K/V projections and
/// output projection stream each weight matrix once for the whole batch
/// (via [`dense_batch`]); the quadratic score/apply stages run per event
/// with the exact per-row operation order of [`attention_head`], so the
/// result is bitwise identical to [`mha`] per event.
pub fn mha_batch(x: &Mat3, w: &MhaWeights) -> Mat3 {
    let (bsz, s) = (x.batch(), x.rows());
    let heads = w.wq.len();
    let k = w.wq[0].cols();
    let mut concat = Mat3::zeros(bsz, s, heads * k);
    let mut score_row = vec![0.0f32; s];
    for h in 0..heads {
        // stage 1: projections, one weight pass for the whole batch
        let q = dense_batch(x, &w.wq[h], &w.bq[h], Activation::Linear);
        let km = dense_batch(x, &w.wk[h], &w.bk[h], Activation::Linear);
        let vm = dense_batch(x, &w.wv[h], &w.bv[h], Activation::Linear);
        let scale = 1.0 / (k as f32).sqrt();
        for b in 0..bsz {
            for i in 0..s {
                // scores = q_i . k_j * scale, then row softmax
                for (j, sc) in score_row.iter_mut().enumerate() {
                    *sc = dot(q.event_row(b, i), km.event_row(b, j)) * scale;
                }
                softmax_row_in_place(&mut score_row);
                // apply V straight into the concat slot (kk-ascending
                // accumulation, the same order as Mat::matmul)
                let out = &mut concat.event_row_mut(b, i)[h * k..(h + 1) * k];
                out.iter_mut().for_each(|v| *v = 0.0);
                for (kk, &p) in score_row.iter().enumerate() {
                    for (o, &vv) in out.iter_mut().zip(vm.event_row(b, kk)) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    dense_batch(&concat, &w.wo, &w.bo, Activation::Linear)
}

/// Retained block-0 attention state for one stream's float window
/// cache: per-head Q/K/V projections and the *raw* (pre-softmax) scaled
/// score matrix.  Raw scores are kept because softmax is row-global —
/// a cached window's score row gains fresh columns at the next hop, so
/// only the pre-softmax entries are shareable.
#[derive(Clone, Debug)]
pub struct MhaWindowState {
    pub q: Vec<Mat>,
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub scores: Vec<Mat>,
}

impl MhaWindowState {
    pub fn new(heads: usize, s: usize, k: usize) -> Self {
        Self {
            q: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            v: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            k: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            scores: (0..heads).map(|_| Mat::zeros(s, s)).collect(),
        }
    }

    /// Resident bytes of the cached state (f32 payloads).
    pub fn bytes(&self) -> u64 {
        let f = |ms: &[Mat]| ms.iter().map(|m| m.data().len() * 4).sum::<usize>() as u64;
        f(&self.q) + f(&self.k) + f(&self.v) + f(&self.scores)
    }
}

/// Shift the leading `rows - delta` rows of `m` up by `delta` rows in
/// place (memmove semantics) — the cache's "carry the overlap" step.
pub(crate) fn shift_rows_up(m: &mut Mat, delta: usize) {
    let cols = m.cols();
    m.data_mut().copy_within(delta * cols.., 0);
}

/// Shift the `(s - delta) x (s - delta)` trailing sub-block of a square
/// score matrix to its top-left corner in place: new entry `(i, j)` is
/// old entry `(i + delta, j + delta)` — the overlap block of QK^T
/// between two windows `delta` samples apart.
pub(crate) fn shift_score_block(m: &mut Mat, delta: usize) {
    let s = m.cols();
    let keep = s - delta;
    for i in 0..keep {
        let src = (i + delta) * s + delta;
        m.data_mut().copy_within(src..src + keep, i * s);
    }
}

/// Copy of the trailing `fresh` rows of `x` (the new tokens).
pub(crate) fn rows_tail(x: &Mat, fresh: usize) -> Mat {
    let lo = x.rows() - fresh;
    let mut out = Mat::zeros(fresh, x.cols());
    for i in 0..fresh {
        out.row_mut(i).copy_from_slice(x.row(lo + i));
    }
    out
}

/// Multi-head attention over a window cache: with `fresh = None` (or a
/// cold cache) this recomputes everything, populating `st`; with
/// `fresh = Some(delta)`, `0 < delta < S`, the leading `S - delta` rows
/// of `x` are carried over from the previous window, so only the
/// trailing `delta` rows run the Q/K/V projections and only the fresh
/// score rows/columns run the dot-product kernel — the cached overlap
/// block supplies the rest.  **Bitwise identical** to [`mha`] either
/// way: dense rows and score entries depend only on their own input
/// rows, and the softmax/apply-V epilogue below replays [`mha`]'s exact
/// per-row operation order on the same raw score values.
pub fn mha_window(x: &Mat, w: &MhaWeights, st: &mut MhaWindowState, fresh: Option<usize>) -> Mat {
    let s = x.rows();
    let heads = w.wq.len();
    let k = w.wq[0].cols();
    let scale = 1.0 / (k as f32).sqrt();
    let delta = fresh.filter(|&f| f > 0 && f < s);
    let x_fresh = delta.map(|f| rows_tail(x, f));
    let mut concat = Mat::zeros(s, heads * k);
    let mut prob_row = vec![0.0f32; s];
    for h in 0..heads {
        match (delta, &x_fresh) {
            (Some(f), Some(xf)) => {
                let keep = s - f;
                shift_rows_up(&mut st.q[h], f);
                shift_rows_up(&mut st.k[h], f);
                shift_rows_up(&mut st.v[h], f);
                shift_score_block(&mut st.scores[h], f);
                let qf = dense(xf, &w.wq[h], &w.bq[h], Activation::Linear);
                let kf = dense(xf, &w.wk[h], &w.bk[h], Activation::Linear);
                let vf = dense(xf, &w.wv[h], &w.bv[h], Activation::Linear);
                for i in 0..f {
                    st.q[h].row_mut(keep + i).copy_from_slice(qf.row(i));
                    st.k[h].row_mut(keep + i).copy_from_slice(kf.row(i));
                    st.v[h].row_mut(keep + i).copy_from_slice(vf.row(i));
                }
                // fresh score entries: new columns of carried rows, then
                // the all-fresh rows — each entry is an independent dot
                for i in 0..keep {
                    for j in keep..s {
                        *st.scores[h].at_mut(i, j) =
                            dot(st.q[h].row(i), st.k[h].row(j)) * scale;
                    }
                }
                for i in keep..s {
                    for j in 0..s {
                        *st.scores[h].at_mut(i, j) =
                            dot(st.q[h].row(i), st.k[h].row(j)) * scale;
                    }
                }
            }
            _ => {
                st.q[h] = dense(x, &w.wq[h], &w.bq[h], Activation::Linear);
                st.k[h] = dense(x, &w.wk[h], &w.bk[h], Activation::Linear);
                st.v[h] = dense(x, &w.wv[h], &w.bv[h], Activation::Linear);
                for i in 0..s {
                    for j in 0..s {
                        *st.scores[h].at_mut(i, j) =
                            dot(st.q[h].row(i), st.k[h].row(j)) * scale;
                    }
                }
            }
        }
        // softmax + apply-V per row, in [`Mat::matmul`]'s accumulation
        // order, on a copy so the cached raw scores survive the hop
        for i in 0..s {
            prob_row.copy_from_slice(st.scores[h].row(i));
            softmax_row_in_place(&mut prob_row);
            let out = &mut concat.row_mut(i)[h * k..(h + 1) * k];
            for (kk, &p) in prob_row.iter().enumerate() {
                for (o, &vv) in out.iter_mut().zip(st.v[h].row(kk)) {
                    *o += p * vv;
                }
            }
        }
    }
    dense(&concat, &w.wo, &w.bo, Activation::Linear)
}

/// Column-wise mean over the sequence: (S, d) -> (1, d).
pub fn global_average_pool(x: &Mat) -> Mat {
    let mut out = Mat::zeros(1, x.cols());
    for r in 0..x.rows() {
        for (o, &v) in out.row_mut(0).iter_mut().zip(x.row(r)) {
            *o += v;
        }
    }
    let n = x.rows() as f32;
    for o in out.row_mut(0) {
        *o /= n;
    }
    out
}

/// Batched column-wise mean: (B, S, d) -> (B, 1, d).
pub fn global_average_pool_batch(x: &Mat3) -> Mat3 {
    let mut out = Mat3::zeros(x.batch(), 1, x.cols());
    let n = x.rows() as f32;
    for b in 0..x.batch() {
        for r in 0..x.rows() {
            let src = x.event_row(b, r);
            for (o, &v) in out.event_row_mut(b, 0).iter_mut().zip(src) {
                *o += v;
            }
        }
        for o in out.event_row_mut(b, 0) {
            *o /= n;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    fn rand_mat(g: &mut Gen, r: usize, c: usize, s: f32) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c, s))
    }

    #[test]
    fn dense_known_values() {
        let x = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let w = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = dense(&x, &w, &[10.0, -10.0], Activation::Relu);
        assert_eq!(y.data(), &[11.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        Prop::new("softmax rows sum 1").runs(300).check(|g| {
            let (r, c) = (g.usize_in(1, 8), g.usize_in(2, 20));
            let m = rand_mat(g, r, c, 3.0);
            let s = softmax_rows(&m);
            for r in 0..s.rows() {
                let sum: f32 = s.row(r).iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
                assert!(s.row(r).iter().all(|&p| p >= 0.0));
            }
        });
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![101.0, 102.0, 103.0]);
        assert!(softmax_rows(&a).max_abs_diff(&softmax_rows(&b)) < 1e-6);
    }

    #[test]
    fn layernorm_normalizes() {
        Prop::new("layernorm mean0 var1").runs(300).check(|g| {
            let k = g.usize_in(4, 32);
            let rows = g.usize_in(1, 6);
            let m = rand_mat(g, rows, k, 2.0);
            let out = layernorm_rows(&m, &vec![1.0; k], &vec![0.0; k]);
            for r in 0..out.rows() {
                let mean: f32 = out.row(r).iter().sum::<f32>() / k as f32;
                let var: f32 =
                    out.row(r).iter().map(|v| (v - mean).powi(2)).sum::<f32>() / k as f32;
                assert!(mean.abs() < 1e-4, "mean {mean}");
                assert!((var - 1.0).abs() < 1e-3, "var {var}");
            }
        });
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with V = identity-ish inputs, outputs stay within V's row range
        let mut g = Gen::new(3);
        let x = rand_mat(&mut g, 6, 4, 1.0);
        let eye = |n: usize| {
            let mut m = Mat::zeros(n, n);
            for i in 0..n {
                *m.at_mut(i, i) = 1.0;
            }
            m
        };
        let out = attention_head(&x, &eye(4), &[0.0; 4], &eye(4), &[0.0; 4],
                                 &eye(4), &[0.0; 4]);
        let (mut lo, mut hi) = (f32::MAX, f32::MIN);
        for &v in x.data() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        for &v in out.data() {
            assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
        }
    }

    #[test]
    fn gap_of_constant_rows_is_identity() {
        let m = Mat::from_vec(3, 2, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        assert_eq!(global_average_pool(&m).data(), &[1.0, 2.0]);
    }

    #[test]
    fn prop_dense_batch_bitwise_matches_per_event() {
        Prop::new("dense_batch == dense per event").runs(200).check(|g| {
            let (bsz, r, cin, cout) =
                (g.usize_in(1, 6), g.usize_in(1, 6), g.usize_in(1, 10), g.usize_in(1, 8));
            let events: Vec<Mat> = (0..bsz).map(|_| rand_mat(g, r, cin, 1.5)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let w = rand_mat(g, cin, cout, 0.7);
            let b = g.normal_vec(cout, 0.3);
            for act in [Activation::Linear, Activation::Relu, Activation::Sigmoid] {
                let batched = dense_batch(&Mat3::from_events(&refs), &w, &b, act);
                for (i, e) in events.iter().enumerate() {
                    // bitwise: the batched loop order preserves each
                    // accumulator's addition sequence exactly
                    assert_eq!(batched.event(i), dense(e, &w, &b, act));
                }
            }
        });
    }

    #[test]
    fn prop_mha_window_bitwise_matches_mha_across_hops() {
        // a simulated stream: consecutive windows share rows, and the
        // cached path must reproduce the from-scratch MHA bit for bit —
        // including the cold first window, hop >= S (no reuse), and a
        // mid-stream cache invalidation (fresh = None on a warm cache)
        Prop::new("mha_window == mha").runs(40).check(|g| {
            let (s, d) = (g.usize_in(2, 8), 8usize);
            let heads = 2;
            let k = d / heads;
            let w = MhaWeights {
                wq: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bq: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wk: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bk: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wv: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bv: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wo: rand_mat(g, heads * k, d, 0.5),
                bo: g.normal_vec(d, 0.1),
            };
            let hop = g.usize_in(1, s + 2);
            let stream = rand_mat(g, s + 4 * hop, d, 1.0);
            let mut st = MhaWindowState::new(heads, s, k);
            let mut prev_start: Option<usize> = None;
            let mut start = 0usize;
            while start + s <= stream.rows() {
                let mut x = Mat::zeros(s, d);
                for t in 0..s {
                    x.row_mut(t).copy_from_slice(stream.row(start + t));
                }
                let fresh = match prev_start {
                    Some(p) if start - p < s && g.usize_in(0, 9) > 0 => Some(start - p),
                    // occasional None on a warm cache = forced repopulate
                    _ => None,
                };
                let got = mha_window(&x, &w, &mut st, fresh);
                assert_eq!(got, mha(&x, &w), "s={s} hop={hop} start={start}");
                prev_start = Some(start);
                start += hop;
            }
        });
    }

    #[test]
    fn prop_mha_and_layernorm_batch_bitwise_match_per_event() {
        Prop::new("mha/ln batch == per event").runs(50).check(|g| {
            let (bsz, s, d) = (g.usize_in(1, 5), g.usize_in(2, 8), 8usize);
            let heads = 2;
            let k = d / heads;
            let w = MhaWeights {
                wq: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bq: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wk: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bk: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wv: (0..heads).map(|_| rand_mat(g, d, k, 0.5)).collect(),
                bv: (0..heads).map(|_| g.normal_vec(k, 0.1)).collect(),
                wo: rand_mat(g, heads * k, d, 0.5),
                bo: g.normal_vec(d, 0.1),
            };
            let events: Vec<Mat> = (0..bsz).map(|_| rand_mat(g, s, d, 1.0)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let x3 = Mat3::from_events(&refs);
            let batched = mha_batch(&x3, &w);
            for (i, e) in events.iter().enumerate() {
                assert_eq!(batched.event(i), mha(e, &w));
            }
            let gamma = g.normal_vec(d, 1.0);
            let beta = g.normal_vec(d, 0.5);
            let mut ln = x3.clone();
            layernorm_batch(&mut ln, &gamma, &beta);
            for (i, e) in events.iter().enumerate() {
                assert_eq!(ln.event(i), layernorm_rows(e, &gamma, &beta));
            }
            let gap = global_average_pool_batch(&x3);
            for (i, e) in events.iter().enumerate() {
                assert_eq!(gap.event(i), global_average_pool(e));
            }
        });
    }
}
