//! Exact-float layer primitives (dense / softmax / layernorm / MHA).

use super::tensor::{dot, Mat};
use crate::models::weights::MhaWeights;

/// Activation functions used by the zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Sigmoid,
}

impl Activation {
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Relu => x.max(0.0),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }
}

/// `y = act(x @ w + b)` — x: (rows, in), w: (in, out), b: (out).
pub fn dense(x: &Mat, w: &Mat, b: &[f32], act: Activation) -> Mat {
    assert_eq!(x.cols(), w.rows());
    assert_eq!(w.cols(), b.len());
    let mut y = x.matmul(w);
    for r in 0..y.rows() {
        let row = y.row_mut(r);
        for (v, &bias) in row.iter_mut().zip(b) {
            *v = act.apply(*v + bias);
        }
    }
    y
}

/// Numerically-stable softmax over each row.
pub fn softmax_rows(x: &Mat) -> Mat {
    let mut out = x.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Layer normalization over each row (biased variance, like hls4ml).
pub fn layernorm_rows(x: &Mat, gamma: &[f32], beta: &[f32]) -> Mat {
    assert_eq!(x.cols(), gamma.len());
    assert_eq!(x.cols(), beta.len());
    let k = x.cols() as f32;
    let mut out = x.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mean = row.iter().sum::<f32>() / k;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / k;
        let inv = 1.0 / var.sqrt().max(1e-12);
        for (v, (&g, &b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
            *v = (*v - mean) * inv * g + b;
        }
    }
    out
}

/// One attention head: exact eq. (4) of the paper.
pub fn attention_head(x: &Mat, wq: &Mat, bq: &[f32], wk: &Mat, bk: &[f32],
                      wv: &Mat, bv: &[f32]) -> Mat {
    let q = dense(x, wq, bq, Activation::Linear);
    let k = dense(x, wk, bk, Activation::Linear);
    let v = dense(x, wv, bv, Activation::Linear);
    let scale = 1.0 / (q.cols() as f32).sqrt();
    // scores = q @ k^T * scale
    let mut scores = Mat::zeros(q.rows(), k.rows());
    for i in 0..q.rows() {
        for j in 0..k.rows() {
            *scores.at_mut(i, j) = dot(q.row(i), k.row(j)) * scale;
        }
    }
    softmax_rows(&scores).matmul(&v)
}

/// Full multi-head attention: heads -> concat -> output projection.
pub fn mha(x: &Mat, w: &MhaWeights) -> Mat {
    let heads: Vec<Mat> = (0..w.wq.len())
        .map(|h| {
            attention_head(x, &w.wq[h], &w.bq[h], &w.wk[h], &w.bk[h], &w.wv[h], &w.bv[h])
        })
        .collect();
    // concat along columns (paper stage 4), then project
    let k = heads[0].cols();
    let mut concat = Mat::zeros(x.rows(), heads.len() * k);
    for (h, head) in heads.iter().enumerate() {
        for r in 0..head.rows() {
            concat.row_mut(r)[h * k..(h + 1) * k].copy_from_slice(head.row(r));
        }
    }
    dense(&concat, &w.wo, &w.bo, Activation::Linear)
}

/// Column-wise mean over the sequence: (S, d) -> (1, d).
pub fn global_average_pool(x: &Mat) -> Mat {
    let mut out = Mat::zeros(1, x.cols());
    for r in 0..x.rows() {
        for (o, &v) in out.row_mut(0).iter_mut().zip(x.row(r)) {
            *o += v;
        }
    }
    let n = x.rows() as f32;
    for o in out.row_mut(0) {
        *o /= n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    fn rand_mat(g: &mut Gen, r: usize, c: usize, s: f32) -> Mat {
        Mat::from_vec(r, c, g.normal_vec(r * c, s))
    }

    #[test]
    fn dense_known_values() {
        let x = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let w = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = dense(&x, &w, &[10.0, -10.0], Activation::Relu);
        assert_eq!(y.data(), &[11.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        Prop::new("softmax rows sum 1").runs(300).check(|g| {
            let (r, c) = (g.usize_in(1, 8), g.usize_in(2, 20));
            let m = rand_mat(g, r, c, 3.0);
            let s = softmax_rows(&m);
            for r in 0..s.rows() {
                let sum: f32 = s.row(r).iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
                assert!(s.row(r).iter().all(|&p| p >= 0.0));
            }
        });
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![101.0, 102.0, 103.0]);
        assert!(softmax_rows(&a).max_abs_diff(&softmax_rows(&b)) < 1e-6);
    }

    #[test]
    fn layernorm_normalizes() {
        Prop::new("layernorm mean0 var1").runs(300).check(|g| {
            let k = g.usize_in(4, 32);
            let rows = g.usize_in(1, 6);
            let m = rand_mat(g, rows, k, 2.0);
            let out = layernorm_rows(&m, &vec![1.0; k], &vec![0.0; k]);
            for r in 0..out.rows() {
                let mean: f32 = out.row(r).iter().sum::<f32>() / k as f32;
                let var: f32 =
                    out.row(r).iter().map(|v| (v - mean).powi(2)).sum::<f32>() / k as f32;
                assert!(mean.abs() < 1e-4, "mean {mean}");
                assert!((var - 1.0).abs() < 1e-3, "var {var}");
            }
        });
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with V = identity-ish inputs, outputs stay within V's row range
        let mut g = Gen::new(3);
        let x = rand_mat(&mut g, 6, 4, 1.0);
        let eye = |n: usize| {
            let mut m = Mat::zeros(n, n);
            for i in 0..n {
                *m.at_mut(i, i) = 1.0;
            }
            m
        };
        let out = attention_head(&x, &eye(4), &[0.0; 4], &eye(4), &[0.0; 4],
                                 &eye(4), &[0.0; 4]);
        let (mut lo, mut hi) = (f32::MAX, f32::MIN);
        for &v in x.data() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        for &v in out.data() {
            assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
        }
    }

    #[test]
    fn gap_of_constant_rows_is_identity() {
        let m = Mat::from_vec(3, 2, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        assert_eq!(global_average_pool(&m).data(), &[1.0, 2.0]);
    }
}
