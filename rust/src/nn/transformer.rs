//! Float transformer forward pass — mirrors `python/compile/model.py`
//! exactly (integration tests compare against the jax `logits_exact`
//! tensors exported to `artifacts/<m>.eval.nnw`).

use super::layers::{
    dense, dense_batch, global_average_pool, global_average_pool_batch,
    layernorm_batch, layernorm_rows, mha, mha_batch, Activation,
};
use super::tensor::{Mat, Mat3};
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;

/// Exact-float inference engine for one zoo model.
#[derive(Clone, Debug)]
pub struct FloatTransformer {
    cfg: ModelConfig,
    weights: Weights,
}

impl FloatTransformer {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Self { cfg, weights }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Forward one event `(seq_len, input_size)` -> logits `(output_size)`.
    pub fn forward(&self, x: &Mat) -> Vec<f32> {
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let w = &self.weights;
        let mut h = dense(x, &w.embed.0, &w.embed.1, Activation::Linear);
        for b in &w.blocks {
            let attn = mha(&h, &b.mha);
            h = h.add(&attn); // residual
            if let Some(ln) = &b.ln1 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
            let y = dense(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu);
            let y = dense(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear);
            h = h.add(&y); // residual
            if let Some(ln) = &b.ln2 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
        }
        let pooled = global_average_pool(&h);
        let hid = dense(&pooled, &w.head.0, &w.head.1, Activation::Relu);
        let logits = dense(&hid, &w.out.0, &w.out.1, Activation::Linear);
        logits.row(0).to_vec()
    }

    /// Forward a whole batch of events at once -> per-event logits.
    ///
    /// Batch-major execution: every layer streams its weight matrix once
    /// for the entire batch (see [`crate::nn::layers::dense_batch`]).
    /// Bitwise identical to calling [`Self::forward`] per event — the
    /// batched kernels preserve each accumulator's operation order — so
    /// the serving path can batch freely without perturbing scores.
    pub fn forward_batch(&self, xs: &[&Mat]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        for x in xs {
            assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
            assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        }
        let w = &self.weights;
        let x3 = Mat3::from_events(xs);
        let mut h = dense_batch(&x3, &w.embed.0, &w.embed.1, Activation::Linear);
        for b in &w.blocks {
            let attn = mha_batch(&h, &b.mha);
            h = h.add(&attn); // residual
            if let Some(ln) = &b.ln1 {
                layernorm_batch(&mut h, &ln.gamma, &ln.beta);
            }
            let y = dense_batch(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu);
            let y = dense_batch(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear);
            h = h.add(&y); // residual
            if let Some(ln) = &b.ln2 {
                layernorm_batch(&mut h, &ln.gamma, &ln.beta);
            }
        }
        let pooled = global_average_pool_batch(&h);
        let hid = dense_batch(&pooled, &w.head.0, &w.head.1, Activation::Relu);
        let logits = dense_batch(&hid, &w.out.0, &w.out.1, Activation::Linear);
        (0..xs.len()).map(|i| logits.event_row(i, 0).to_vec()).collect()
    }

    /// Logits -> probabilities per the model's head.
    pub fn probs(&self, logits: &[f32]) -> Vec<f32> {
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => {
                logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
            }
            FinalActivation::Softmax => {
                let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
                let s: f32 = e.iter().sum();
                e.into_iter().map(|v| v / s).collect()
            }
        }
    }

    /// Scalar anomaly/positive-class score used by the AUC machinery:
    /// sigmoid prob for binary-sigmoid heads, prob of class 1 for
    /// softmax heads (class "anomalous"/"signal" by dataset convention).
    pub fn score(&self, logits: &[f32]) -> f32 {
        let p = self.probs(logits);
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => p[0],
            FinalActivation::Softmax => p[1.min(p.len() - 1)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo;
    use crate::testutil::Gen;

    #[test]
    fn forward_shapes_all_zoo_models() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 7);
            let t = FloatTransformer::new(m.config.clone(), w);
            let mut g = Gen::new(1);
            let x = Mat::from_vec(
                m.config.seq_len,
                m.config.input_size,
                g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
            );
            let logits = t.forward(&x);
            assert_eq!(logits.len(), m.config.output_size);
            assert!(logits.iter().all(|v| v.is_finite()));
            let p = t.probs(&logits);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
            if m.config.output_size > 1 {
                let s: f32 = p.iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic]
    fn forward_rejects_bad_shape() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 1));
        t.forward(&Mat::zeros(3, 3));
    }

    #[test]
    fn forward_is_deterministic() {
        let m = &zoo()[1];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 9));
        let mut g = Gen::new(2);
        let x = Mat::from_vec(
            m.config.seq_len,
            m.config.input_size,
            g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
        );
        assert_eq!(t.forward(&x), t.forward(&x));
    }

    #[test]
    fn forward_batch_is_bitwise_identical_to_per_event() {
        for m in zoo() {
            let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 11));
            let mut g = Gen::new(8);
            let events: Vec<Mat> = (0..5)
                .map(|_| {
                    Mat::from_vec(
                        m.config.seq_len,
                        m.config.input_size,
                        g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
                    )
                })
                .collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let batched = t.forward_batch(&refs);
            assert_eq!(batched.len(), events.len());
            for (x, got) in events.iter().zip(&batched) {
                assert_eq!(got, &t.forward(x), "{}", m.config.name);
            }
        }
    }

    #[test]
    fn forward_batch_of_empty_is_empty() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 1));
        assert!(t.forward_batch(&[]).is_empty());
    }

    #[test]
    fn score_in_unit_interval() {
        for m in zoo() {
            let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 3));
            let mut g = Gen::new(4);
            let x = Mat::from_vec(
                m.config.seq_len,
                m.config.input_size,
                g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
            );
            let s = t.score(&t.forward(&x));
            assert!((0.0..=1.0).contains(&s));
        }
    }
}
