//! Float transformer forward pass — mirrors `python/compile/model.py`
//! exactly (integration tests compare against the jax `logits_exact`
//! tensors exported to `artifacts/<m>.eval.nnw`).

use super::layers::{
    dense, dense_batch, global_average_pool, global_average_pool_batch,
    layernorm_batch, layernorm_rows, mha, mha_batch, mha_window, rows_tail,
    shift_rows_up, Activation, MhaWindowState,
};
use super::tensor::{Mat, Mat3};
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;
use crate::stream::ReuseCounters;

/// Exact-float inference engine for one zoo model.
#[derive(Clone, Debug)]
pub struct FloatTransformer {
    cfg: ModelConfig,
    weights: Weights,
}

/// Per-stream state for [`FloatTransformer::forward_incremental`]: the
/// previous window's embed output plus its block-0 attention state
/// (Q/K/V rows and raw QK^T scores), keyed by the window's absolute
/// sample position.  One cache per stream per shard — never share one
/// across interleaved streams.
#[derive(Clone, Debug)]
pub struct FloatWindowCache {
    /// Start position of the cached window (None = cold).
    pos: Option<u64>,
    /// Embed-dense output rows for the cached window (S, d_model).
    embed: Mat,
    /// Block-0 attention state (see [`MhaWindowState`]).
    mha: MhaWindowState,
    counters: ReuseCounters,
}

impl FloatWindowCache {
    pub fn counters(&self) -> &ReuseCounters {
        &self.counters
    }

    /// Drop the cached window (e.g. on stream restart): the next
    /// [`FloatTransformer::forward_incremental`] call recomputes fully.
    pub fn invalidate(&mut self) {
        self.pos = None;
    }
}

impl FloatTransformer {
    pub fn new(cfg: ModelConfig, weights: Weights) -> Self {
        Self { cfg, weights }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Forward one event `(seq_len, input_size)` -> logits `(output_size)`.
    pub fn forward(&self, x: &Mat) -> Vec<f32> {
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let w = &self.weights;
        let mut h = dense(x, &w.embed.0, &w.embed.1, Activation::Linear);
        for b in &w.blocks {
            let attn = mha(&h, &b.mha);
            h = h.add(&attn); // residual
            if let Some(ln) = &b.ln1 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
            let y = dense(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu);
            let y = dense(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear);
            h = h.add(&y); // residual
            if let Some(ln) = &b.ln2 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
        }
        let pooled = global_average_pool(&h);
        let hid = dense(&pooled, &w.head.0, &w.head.1, Activation::Relu);
        let logits = dense(&hid, &w.out.0, &w.out.1, Activation::Linear);
        logits.row(0).to_vec()
    }

    /// Forward a whole batch of events at once -> per-event logits.
    ///
    /// Batch-major execution: every layer streams its weight matrix once
    /// for the entire batch (see [`crate::nn::layers::dense_batch`]).
    /// Bitwise identical to calling [`Self::forward`] per event — the
    /// batched kernels preserve each accumulator's operation order — so
    /// the serving path can batch freely without perturbing scores.
    pub fn forward_batch(&self, xs: &[&Mat]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        for x in xs {
            assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
            assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        }
        let w = &self.weights;
        let x3 = Mat3::from_events(xs);
        let mut h = dense_batch(&x3, &w.embed.0, &w.embed.1, Activation::Linear);
        for b in &w.blocks {
            let attn = mha_batch(&h, &b.mha);
            h = h.add(&attn); // residual
            if let Some(ln) = &b.ln1 {
                layernorm_batch(&mut h, &ln.gamma, &ln.beta);
            }
            let y = dense_batch(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu);
            let y = dense_batch(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear);
            h = h.add(&y); // residual
            if let Some(ln) = &b.ln2 {
                layernorm_batch(&mut h, &ln.gamma, &ln.beta);
            }
        }
        let pooled = global_average_pool_batch(&h);
        let hid = dense_batch(&pooled, &w.head.0, &w.head.1, Activation::Relu);
        let logits = dense_batch(&hid, &w.out.0, &w.out.1, Activation::Linear);
        (0..xs.len()).map(|i| logits.event_row(i, 0).to_vec()).collect()
    }

    /// Fresh per-stream cache for [`Self::forward_incremental`].
    pub fn window_cache(&self) -> FloatWindowCache {
        let s = self.cfg.seq_len;
        let w = &self.weights;
        let (heads, k) = match w.blocks.first() {
            Some(b) => (b.mha.wq.len(), b.mha.wq[0].cols()),
            None => (0, 0),
        };
        FloatWindowCache {
            pos: None,
            embed: Mat::zeros(s, w.embed.0.cols()),
            mha: MhaWindowState::new(heads, s, k),
            counters: ReuseCounters::default(),
        }
    }

    /// Forward one stream window starting at absolute sample `pos`,
    /// reusing the overlap with the cached previous window when sound.
    ///
    /// The zoo transformers carry no positional encoding, so when two
    /// consecutive windows share `S - delta` token rows the embed
    /// output, the block-0 Q/K/V rows, and the `(S-delta)^2` overlap
    /// block of raw block-0 QK^T scores for those rows are **bitwise
    /// identical** — each depends only on its own token row(s).  This
    /// entry recomputes exactly the fresh rows/entries and is bitwise
    /// identical to [`Self::forward`] (property-tested); anything that
    /// makes reuse unsound — cold cache, non-overlapping or backwards
    /// `pos` (stream restart), a model without attention blocks —
    /// falls back to a full recompute that repopulates the cache.
    pub fn forward_incremental(
        &self,
        x: &Mat,
        pos: u64,
        cache: &mut FloatWindowCache,
    ) -> Vec<f32> {
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let s = self.cfg.seq_len;
        let w = &self.weights;
        let delta = match cache.pos {
            Some(p) if pos > p && pos - p < s as u64 && !w.blocks.is_empty() => {
                (pos - p) as usize
            }
            _ => 0, // full recompute (and repopulate)
        };
        cache.pos = Some(pos);
        if w.blocks.is_empty() {
            cache.counters.windows_full += 1;
            cache.counters.rows_recomputed += s as u64;
            return self.forward(x);
        }
        let heads = w.blocks[0].mha.wq.len() as u64;
        let su = s as u64;
        let mut h = if delta > 0 {
            let keep = s - delta;
            shift_rows_up(&mut cache.embed, delta);
            let ef = dense(&rows_tail(x, delta), &w.embed.0, &w.embed.1, Activation::Linear);
            for i in 0..delta {
                cache.embed.row_mut(keep + i).copy_from_slice(ef.row(i));
            }
            let c = &mut cache.counters;
            c.windows_incremental += 1;
            c.rows_reused += keep as u64;
            c.rows_recomputed += delta as u64;
            c.score_block_hits += heads;
            c.score_entries_reused += heads * (keep as u64) * (keep as u64);
            c.score_entries_fresh += heads * (su * su - (keep as u64) * (keep as u64));
            cache.embed.clone()
        } else {
            cache.embed = dense(x, &w.embed.0, &w.embed.1, Activation::Linear);
            let c = &mut cache.counters;
            c.windows_full += 1;
            c.rows_recomputed += su;
            c.score_entries_fresh += heads * su * su;
            cache.embed.clone()
        };
        cache.counters.cache_bytes = cache
            .counters
            .cache_bytes
            .max(cache.embed.data().len() as u64 * 4 + cache.mha.bytes());
        for (bi, b) in w.blocks.iter().enumerate() {
            let attn = if bi == 0 {
                let fresh = if delta > 0 { Some(delta) } else { None };
                mha_window(&h, &b.mha, &mut cache.mha, fresh)
            } else {
                mha(&h, &b.mha)
            };
            h = h.add(&attn); // residual
            if let Some(ln) = &b.ln1 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
            let y = dense(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu);
            let y = dense(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear);
            h = h.add(&y); // residual
            if let Some(ln) = &b.ln2 {
                h = layernorm_rows(&h, &ln.gamma, &ln.beta);
            }
        }
        let pooled = global_average_pool(&h);
        let hid = dense(&pooled, &w.head.0, &w.head.1, Activation::Relu);
        let logits = dense(&hid, &w.out.0, &w.out.1, Activation::Linear);
        logits.row(0).to_vec()
    }

    /// Logits -> probabilities per the model's head.
    pub fn probs(&self, logits: &[f32]) -> Vec<f32> {
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => {
                logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
            }
            FinalActivation::Softmax => {
                let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
                let s: f32 = e.iter().sum();
                e.into_iter().map(|v| v / s).collect()
            }
        }
    }

    /// Scalar anomaly/positive-class score used by the AUC machinery:
    /// sigmoid prob for binary-sigmoid heads, prob of class 1 for
    /// softmax heads (class "anomalous"/"signal" by dataset convention).
    pub fn score(&self, logits: &[f32]) -> f32 {
        let p = self.probs(logits);
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => p[0],
            FinalActivation::Softmax => p[1.min(p.len() - 1)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo;
    use crate::testutil::Gen;

    #[test]
    fn forward_shapes_all_zoo_models() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 7);
            let t = FloatTransformer::new(m.config.clone(), w);
            let mut g = Gen::new(1);
            let x = Mat::from_vec(
                m.config.seq_len,
                m.config.input_size,
                g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
            );
            let logits = t.forward(&x);
            assert_eq!(logits.len(), m.config.output_size);
            assert!(logits.iter().all(|v| v.is_finite()));
            let p = t.probs(&logits);
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
            if m.config.output_size > 1 {
                let s: f32 = p.iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic]
    fn forward_rejects_bad_shape() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 1));
        t.forward(&Mat::zeros(3, 3));
    }

    #[test]
    fn forward_is_deterministic() {
        let m = &zoo()[1];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 9));
        let mut g = Gen::new(2);
        let x = Mat::from_vec(
            m.config.seq_len,
            m.config.input_size,
            g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
        );
        assert_eq!(t.forward(&x), t.forward(&x));
    }

    #[test]
    fn forward_batch_is_bitwise_identical_to_per_event() {
        for m in zoo() {
            let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 11));
            let mut g = Gen::new(8);
            let events: Vec<Mat> = (0..5)
                .map(|_| {
                    Mat::from_vec(
                        m.config.seq_len,
                        m.config.input_size,
                        g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
                    )
                })
                .collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let batched = t.forward_batch(&refs);
            assert_eq!(batched.len(), events.len());
            for (x, got) in events.iter().zip(&batched) {
                assert_eq!(got, &t.forward(x), "{}", m.config.name);
            }
        }
    }

    #[test]
    fn forward_batch_of_empty_is_empty() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 1));
        assert!(t.forward_batch(&[]).is_empty());
    }

    #[test]
    fn incremental_forward_bitwise_matches_full_across_zoo_and_hops() {
        // windows cut from one continuous stream: the incremental path
        // must equal the from-scratch forward bit for bit at every hop,
        // including hop >= S (zero reuse) and the cold first window
        for m in zoo() {
            let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 13));
            let s = m.config.seq_len;
            let d = m.config.input_size;
            let mut g = Gen::new(17);
            for hop in [s.div_ceil(4).max(1), s.div_ceil(2).max(1), s, s + 3] {
                let total = s + 3 * hop;
                let stream: Vec<f32> = g.normal_vec(total * d, 1.0);
                let mut cache = t.window_cache();
                let mut start = 0usize;
                while start + s <= total {
                    let x = Mat::from_vec(s, d, stream[start * d..(start + s) * d].to_vec());
                    let inc = t.forward_incremental(&x, start as u64, &mut cache);
                    assert_eq!(inc, t.forward(&x), "{} hop {hop} start {start}",
                               m.config.name);
                    start += hop;
                }
            }
        }
    }

    #[test]
    fn incremental_steady_state_counters_are_exact() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 5));
        let s = m.config.seq_len;
        let d = m.config.input_size;
        let heads = t.weights().blocks[0].mha.wq.len() as u64;
        let hop = (s / 4).max(1);
        let mut g = Gen::new(23);
        let n_windows = 5usize;
        let total = s + (n_windows - 1) * hop;
        let stream: Vec<f32> = g.normal_vec(total * d, 1.0);
        let mut cache = t.window_cache();
        for w in 0..n_windows {
            let start = w * hop;
            let x = Mat::from_vec(s, d, stream[start * d..(start + s) * d].to_vec());
            t.forward_incremental(&x, start as u64, &mut cache);
        }
        let c = cache.counters();
        let (su, ku) = (s as u64, (s - hop) as u64);
        assert_eq!(c.windows_full, 1, "only the cold window recomputes fully");
        assert_eq!(c.windows_incremental, n_windows as u64 - 1);
        // each warm window recomputes exactly hop prefix rows...
        assert_eq!(c.rows_recomputed, su + (n_windows as u64 - 1) * hop as u64);
        assert_eq!(c.rows_reused, (n_windows as u64 - 1) * ku);
        // ...and exactly heads * (S^2 - (S-hop)^2) fresh score entries
        assert_eq!(
            c.score_entries_fresh,
            heads * su * su + (n_windows as u64 - 1) * heads * (su * su - ku * ku)
        );
        assert_eq!(c.score_entries_reused, (n_windows as u64 - 1) * heads * ku * ku);
        assert_eq!(c.score_block_hits, (n_windows as u64 - 1) * heads);
        assert!(c.cache_bytes > 0);
    }

    #[test]
    fn incremental_stream_restart_falls_back_to_full_recompute() {
        let m = &zoo()[0];
        let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 5));
        let (s, d) = (m.config.seq_len, m.config.input_size);
        let mut g = Gen::new(29);
        let mk = |g: &mut Gen| {
            Mat::from_vec(s, d, g.normal_vec(s * d, 1.0))
        };
        let mut cache = t.window_cache();
        let a = mk(&mut g);
        t.forward_incremental(&a, 1000, &mut cache);
        // position going backwards = restarted stream: must not reuse,
        // and must still be bitwise correct
        let b = mk(&mut g);
        let got = t.forward_incremental(&b, 0, &mut cache);
        assert_eq!(got, t.forward(&b));
        assert_eq!(cache.counters().windows_full, 2);
        assert_eq!(cache.counters().windows_incremental, 0);
        // same position again (delta = 0) is also a full recompute
        let c = mk(&mut g);
        let got = t.forward_incremental(&c, 0, &mut cache);
        assert_eq!(got, t.forward(&c));
        assert_eq!(cache.counters().windows_full, 3);
        // explicit invalidation too
        let dmat = mk(&mut g);
        cache.invalidate();
        let got = t.forward_incremental(&dmat, 5, &mut cache);
        assert_eq!(got, t.forward(&dmat));
        assert_eq!(cache.counters().windows_full, 4);
    }

    #[test]
    fn score_in_unit_interval() {
        for m in zoo() {
            let t = FloatTransformer::new(m.config.clone(), synthetic_weights(&m.config, 3));
            let mut g = Gen::new(4);
            let x = Mat::from_vec(
                m.config.seq_len,
                m.config.input_size,
                g.normal_vec(m.config.seq_len * m.config.input_size, 1.0),
            );
            let s = t.score(&t.forward(&x));
            assert!((0.0..=1.0).contains(&s));
        }
    }
}
