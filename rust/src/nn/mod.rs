//! Float reference network (S4) — the "Keras output" of the paper's AUC
//! ratio plots.  Exact f32 math, no LUTs, no quantization; the HLS
//! simulator ([`crate::hls`]) is validated against this module, and the
//! AUC sweep (Figures 9-11) compares the two.

pub mod layers;
pub mod tensor;
pub mod transformer;

pub use tensor::Mat;
pub use transformer::FloatTransformer;
