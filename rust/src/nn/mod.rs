//! Float reference network (S4) — the "Keras output" of the paper's AUC
//! ratio plots.  Exact f32 math, no LUTs, no quantization; the HLS
//! simulator ([`crate::hls`]) is validated against this module, and the
//! AUC sweep (Figures 9-11) compares the two.
//!
//! # Batched execution model
//!
//! Both this module and the HLS simulator expose a batch-major forward
//! (`forward_batch`) next to the per-event one, built on three rules:
//!
//! * **Loop order** — batched kernels are *weight-stationary*: each
//!   weight matrix streams through the MAC loops exactly once per layer
//!   for the whole batch ([`tensor::Mat3`] packs the events contiguously
//!   so all `batch*rows` activation rows are in flight together),
//!   instead of once per event.
//! * **Scratch reuse** — the fixed-point path hoists its per-event
//!   allocations (f64 accumulator tiles, score/output row buffers, the
//!   MHA FIFO traffic) into a reusable arena
//!   ([`crate::hls::scratch::Scratch`]) owned by the transformer, so the
//!   hot loop allocates nothing per event.
//! * **Bit-exactness contract** — batching must never change a score:
//!   every accumulator still sums its terms in ascending input index and
//!   every intermediate lands on the same `FixedSpec` grid in the same
//!   order, so `forward_batch` is **bitwise identical** to running
//!   events one at a time.  Property tests enforce this for both the
//!   float and the fixed path (`nn::layers`, `nn::transformer`,
//!   `hls::dense`, `hls::mha`, `hls::transformer`).

pub mod layers;
pub mod tensor;
pub mod transformer;

pub use tensor::{Mat, Mat3};
pub use transformer::{FloatTransformer, FloatWindowCache};
