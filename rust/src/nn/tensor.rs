//! Minimal dense row-major matrix — the tensor type of both the float
//! reference network and the HLS simulator.  No BLAS in the offline crate
//! set; the MAC loops are hand-written (and are themselves the L3 hot path
//! the perf pass optimizes — see EXPERIMENTS.md §Perf).

use std::fmt;

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {rows}x{cols} vs {}", data.len());
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// `self @ other` (naive triple loop with row-major streaming).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate() {
                let b_row = other.row(kk);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Map every element.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise add (same shape).
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Maximum absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

/// Dot product of two equal-length slices (the innermost MAC loop).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transposed().transposed(), a);
        assert_eq!(a.transposed().at(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(2, 3));
    }

    #[test]
    fn dot_matches_matmul() {
        let a = Mat::from_vec(1, 4, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(4, 1, vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).at(0, 0), dot(a.row(0), &[5., 6., 7., 8.]));
    }
}
