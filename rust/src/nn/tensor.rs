//! Minimal dense row-major matrix — the tensor type of both the float
//! reference network and the HLS simulator.  No BLAS in the offline crate
//! set; the MAC loops are hand-written (and are themselves the L3 hot path
//! the perf pass optimizes — see EXPERIMENTS.md §Perf).

use std::fmt;

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {rows}x{cols} vs {}", data.len());
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// `self @ other` (naive triple loop with row-major streaming).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}",
                   self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate() {
                let b_row = other.row(kk);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Map every element.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise add (same shape).
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Consume the matrix, returning its backing storage (row-major).
    /// The stream windowizer recycles served windows' buffers through
    /// the scratch pool with this.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Maximum absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

/// Row-major `batch x rows x cols` f32 tensor — a batch of same-shape
/// events held contiguously so batched kernels can stream one weight
/// matrix across every event in a single pass (weight-stationary loop
/// order; see the batched-execution notes in [`crate::nn`]).
///
/// The layout doubles as a flat `(batch*rows, cols)` matrix: the
/// `flat_row` accessors expose that view, which is what the batched
/// dense/layernorm kernels iterate (events are row-independent there).
#[derive(Clone, PartialEq)]
pub struct Mat3 {
    batch: usize,
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat3 {
    pub fn zeros(batch: usize, rows: usize, cols: usize) -> Self {
        Self { batch, rows, cols, data: vec![0.0; batch * rows * cols] }
    }

    /// Pack a batch of same-shape events into one contiguous tensor.
    /// Panics on an empty batch or a shape mismatch (callers validate
    /// event geometry at the router boundary).
    pub fn from_events(events: &[&Mat]) -> Self {
        assert!(!events.is_empty(), "empty batch");
        let (rows, cols) = (events[0].rows(), events[0].cols());
        let mut data = Vec::with_capacity(events.len() * rows * cols);
        for e in events {
            assert_eq!(
                (e.rows(), e.cols()),
                (rows, cols),
                "ragged batch: {}x{} vs {rows}x{cols}",
                e.rows(),
                e.cols()
            );
            data.extend_from_slice(e.data());
        }
        Self { batch: events.len(), rows, cols, data }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total row count of the flat `(batch*rows, cols)` view.
    pub fn flat_rows(&self) -> usize {
        self.batch * self.rows
    }

    /// Row `i` of the flat `(batch*rows, cols)` view.
    #[inline]
    pub fn flat_row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn flat_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `r` of event `b`.
    #[inline]
    pub fn event_row(&self, b: usize, r: usize) -> &[f32] {
        debug_assert!(b < self.batch && r < self.rows);
        self.flat_row(b * self.rows + r)
    }

    #[inline]
    pub fn event_row_mut(&mut self, b: usize, r: usize) -> &mut [f32] {
        debug_assert!(b < self.batch && r < self.rows);
        self.flat_row_mut(b * self.rows + r)
    }

    /// Event `b` as a contiguous `(rows, cols)` row-major slice.
    #[inline]
    pub fn event_slice(&self, b: usize) -> &[f32] {
        let n = self.rows * self.cols;
        &self.data[b * n..(b + 1) * n]
    }

    /// Copy event `b` out as a standalone matrix (test/boundary helper).
    pub fn event(&self, b: usize) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.event_slice(b).to_vec())
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Map every element in place.
    pub fn map_in_place(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Elementwise add (same shape) — the batched residual adder.
    pub fn add(&self, other: &Mat3) -> Mat3 {
        assert_eq!(
            (self.batch, self.rows, self.cols),
            (other.batch, other.rows, other.cols)
        );
        Mat3 {
            batch: self.batch,
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }
}

impl fmt::Debug for Mat3 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat3({}x{}x{})", self.batch, self.rows, self.cols)
    }
}

/// Dot product of two equal-length slices (the innermost MAC loop).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transposed().transposed(), a);
        assert_eq!(a.transposed().at(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(2, 3));
    }

    #[test]
    fn mat3_packs_events_contiguously() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let t = Mat3::from_events(&[&a, &b]);
        assert_eq!((t.batch(), t.rows(), t.cols()), (2, 2, 2));
        assert_eq!(t.flat_rows(), 4);
        assert_eq!(t.event_row(0, 1), &[3., 4.]);
        assert_eq!(t.event_row(1, 0), &[5., 6.]);
        assert_eq!(t.flat_row(3), &[7., 8.]);
        assert_eq!(t.event(0), a);
        assert_eq!(t.event(1), b);
    }

    #[test]
    #[should_panic(expected = "ragged batch")]
    fn mat3_rejects_ragged_batch() {
        let a = Mat::zeros(2, 2);
        let b = Mat::zeros(3, 2);
        Mat3::from_events(&[&a, &b]);
    }

    #[test]
    #[should_panic(expected = "empty batch")]
    fn mat3_rejects_empty_batch() {
        Mat3::from_events(&[]);
    }

    #[test]
    fn mat3_add_and_map_match_mat_semantics() {
        let a = Mat::from_vec(1, 3, vec![1., -2., 3.]);
        let t = Mat3::from_events(&[&a, &a]);
        let sum = t.add(&t);
        assert_eq!(sum.event(0), a.add(&a));
        let mut m = t.clone();
        m.map_in_place(|v| v.max(0.0));
        assert_eq!(m.event(1), a.map(|v| v.max(0.0)));
    }

    #[test]
    fn dot_matches_matmul() {
        let a = Mat::from_vec(1, 4, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(4, 1, vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).at(0, 0), dot(a.row(0), &[5., 6., 7., 8.]));
    }
}
