//! Hand-rolled CLI argument parsing (clap is not in the offline crate
//! set).  Flags are `--name value` or `--name=value`; the first bare
//! token is the subcommand.

use std::collections::HashMap;

/// Parsed command line: subcommand + flag map.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        let key = stripped.to_string();
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => {
                                (key, it.next().unwrap())
                            }
                            // bare flag -> boolean
                            _ => (key, "true".to_string()),
                        }
                    }
                };
                if out.flags.insert(key.clone(), val).is_some() {
                    return Err(format!("duplicate flag --{key}"));
                }
            } else if out.command.is_empty() {
                out.command = a;
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{key}: cannot parse '{v}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Error on unknown flags (catches typos).
    pub fn expect_only(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k} (expected one of: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table-latency --model engine --reuse 4");
        assert_eq!(a.command, "table-latency");
        assert_eq!(a.get("model"), Some("engine"));
        assert_eq!(a.get_parse("reuse", 1u32).unwrap(), 4);
    }

    #[test]
    fn equals_form_and_bool_flags() {
        let a = parse("serve --backend=pjrt --verbose");
        assert_eq!(a.get("backend"), Some("pjrt"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn defaults_and_parse_errors() {
        let a = parse("x");
        assert_eq!(a.get_or("model", "engine"), "engine");
        let b = parse("x --n notanumber");
        assert!(b.get_parse("n", 0u64).is_err());
    }

    #[test]
    fn replicas_flag_parses_for_serve() {
        // the serve subcommand's worker-pool width rides this parser
        let a = parse("serve --backend float --replicas 4");
        assert_eq!(a.get_parse("replicas", 1usize).unwrap(), 4);
        let b = parse("serve --backend float");
        assert_eq!(b.get_parse("replicas", 1usize).unwrap(), 1, "defaults to 1");
        let c = parse("serve --replicas=8");
        assert_eq!(c.get_parse("replicas", 1usize).unwrap(), 8);
    }

    #[test]
    fn precision_plan_flag_parses_for_serve_and_synth() {
        // the per-site precision plan rides this parser on serve, synth
        // and mixed-precision; both flag forms must yield the path
        let a = parse("serve --backend hls --precision-plan plans/engine.plan");
        assert_eq!(a.get("precision-plan"), Some("plans/engine.plan"));
        let b = parse("synth --model engine --precision-plan=mixed.txt --reuse 2");
        assert_eq!(b.get("precision-plan"), Some("mixed.txt"));
        assert!(b
            .expect_only(&["model", "reuse", "int", "frac", "precision-plan"])
            .is_ok());
        // absent flag stays absent (the uniform design point)
        assert_eq!(parse("synth --model engine").get("precision-plan"), None);
    }

    #[test]
    fn mixed_precision_flags_parse() {
        let a = parse("mixed-precision --model btag --floor 0.995 --min-frac 3 --save-plan p.txt");
        assert_eq!(a.command, "mixed-precision");
        assert_eq!(a.get_parse("floor", 0.99f64).unwrap(), 0.995);
        assert_eq!(a.get_parse("min-frac", 2u32).unwrap(), 3);
        assert_eq!(a.get("save-plan"), Some("p.txt"));
    }

    #[test]
    fn reuse_plan_flag_parses_for_synth_and_serve() {
        // the per-site parallelism plan rides this parser next to the
        // precision plan; both flag forms must yield the path
        let a = parse("synth --model engine --reuse 2 --reuse-plan plans/engine.reuse");
        assert_eq!(a.get("reuse-plan"), Some("plans/engine.reuse"));
        assert_eq!(a.get_parse("reuse", 1u32).unwrap(), 2);
        let b = parse("serve --backend hls --models engine --reuse-plan=mixed.reuse");
        assert_eq!(b.get("reuse-plan"), Some("mixed.reuse"));
        // absent flag stays absent (the uniform design point)
        assert_eq!(parse("synth --model engine").get("reuse-plan"), None);
    }

    #[test]
    fn pareto_flags_parse() {
        let a = parse(
            "pareto --model gw --floor 0.995 --iters 128 --reuse-choices 1,2,4 --seed 9 \
             --save-plan front.reuse",
        );
        assert_eq!(a.command, "pareto");
        assert_eq!(a.get_parse("iters", 64usize).unwrap(), 128);
        assert_eq!(a.get("reuse-choices"), Some("1,2,4"));
        assert_eq!(a.get_parse("seed", 0u64).unwrap(), 9);
        assert_eq!(a.get("save-plan"), Some("front.reuse"));
        assert!(a
            .expect_only(&[
                "model", "int", "frac", "floor", "min-frac", "events", "iters", "seed",
                "reuse-choices", "save-plan",
            ])
            .is_ok());
    }

    #[test]
    fn stream_flags_parse() {
        // the continuous-stream subcommand rides this parser
        let a = parse(
            "stream --model engine --backend hls --samples 40000 --hop 25 \
             --threshold 3.5 --amp-lo 5 --amp-hi 9 --mean-gap 1200 --replicas 2",
        );
        assert_eq!(a.command, "stream");
        assert_eq!(a.get_parse("samples", 0u64).unwrap(), 40_000);
        assert_eq!(a.get_parse("hop", 50usize).unwrap(), 25);
        assert_eq!(a.get_parse("threshold", 3.0f32).unwrap(), 3.5);
        assert_eq!(a.get_parse("amp-lo", 0.0f64).unwrap(), 5.0);
        assert_eq!(a.get_parse("mean-gap", 0.0f64).unwrap(), 1200.0);
        assert!(a
            .expect_only(&[
                "model", "backend", "samples", "hop", "seed", "mean-gap", "amp-lo",
                "amp-hi", "threshold", "batch", "replicas", "rate", "ring",
            ])
            .is_ok());
        // absent flags fall back to model-derived defaults at the caller
        let b = parse("stream --backend float");
        assert_eq!(b.get("hop"), None);
        assert_eq!(b.get_parse("hop", 25usize).unwrap(), 25);
    }

    #[test]
    fn lint_plan_flags_parse() {
        // the static verifier subcommand rides this parser
        let a = parse(
            "lint-plan --model btag --preset mixed --events 32 --seed 7 \
             --json reports/plan.json --strict",
        );
        assert_eq!(a.command, "lint-plan");
        assert_eq!(a.get("preset"), Some("mixed"));
        assert_eq!(a.get_parse("events", 16usize).unwrap(), 32);
        assert_eq!(a.get_parse("seed", 0u64).unwrap(), 7);
        assert_eq!(a.get("json"), Some("reports/plan.json"));
        assert!(a.has("strict"));
        assert!(a
            .expect_only(&[
                "model", "int", "frac", "reuse", "precision-plan", "reuse-plan", "preset",
                "events", "seed", "json", "strict",
            ])
            .is_ok());
        // worst-case mode is the 0-event spelling, not a separate flag
        let b = parse("lint-plan --model engine --events 0");
        assert_eq!(b.get_parse("events", 16usize).unwrap(), 0);
        assert!(!b.has("strict"), "strict defaults off (advisory lint)");
    }

    #[test]
    fn network_serving_flags_parse() {
        // the network serving plane rides this parser on `serve`
        let a = parse(
            "serve --backend hls --models engine --listen 127.0.0.1:7071 \
             --metrics-addr 127.0.0.1:7091 --autoscale 1..4 --ring 4096",
        );
        assert_eq!(a.get("listen"), Some("127.0.0.1:7071"));
        assert_eq!(a.get("metrics-addr"), Some("127.0.0.1:7091"));
        assert_eq!(a.get("autoscale"), Some("1..4"));
        assert_eq!(a.get_parse("ring", 8192usize).unwrap(), 4096);
        assert!(a
            .expect_only(&[
                "backend", "events", "rate", "batch", "models", "replicas",
                "precision-plan", "reuse", "reuse-plan", "listen", "metrics-addr",
                "autoscale", "ring",
            ])
            .is_ok());
        // batch mode stays the default: no --listen, no plane flags
        let b = parse("serve --backend float");
        assert_eq!(b.get("listen"), None);
        assert!(!b.has("autoscale"));
    }

    #[test]
    fn send_flags_parse() {
        // the loopback client subcommand rides this parser
        let a = parse(
            "send --to 127.0.0.1:7071 --model engine --events 4000 --rate 200000 \
             --burst 64 --swap-at 2000 --precision-plan swap.plan --shutdown",
        );
        assert_eq!(a.command, "send");
        assert_eq!(a.get("to"), Some("127.0.0.1:7071"));
        assert_eq!(a.get_parse("events", 0u64).unwrap(), 4000);
        assert_eq!(a.get_parse("rate", 0u64).unwrap(), 200_000);
        assert_eq!(a.get_parse("burst", 1u64).unwrap(), 64);
        assert_eq!(a.get_parse("swap-at", 0u64).unwrap(), 2000);
        assert_eq!(a.get("precision-plan"), Some("swap.plan"));
        assert!(a.has("shutdown"));
        assert!(a
            .expect_only(&[
                "to", "model", "events", "rate", "burst", "seed", "swap-at",
                "precision-plan", "reuse-plan", "shutdown",
            ])
            .is_ok());
        // a shutdown-only invocation carries no event flags at all
        let b = parse("send --to 127.0.0.1:7071 --shutdown");
        assert!(b.has("shutdown"));
        assert_eq!(b.get("events"), None);
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(["--a", "1", "--a", "2"].map(String::from)).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("run --modle engine");
        assert!(a.expect_only(&["model"]).is_err());
        let b = parse("run --model engine");
        assert!(b.expect_only(&["model"]).is_ok());
    }

    #[test]
    fn unexpected_positional_rejected() {
        assert!(Args::parse(["cmd", "stray"].map(String::from)).is_err());
    }
}
