//! Autoscaler: a reconcile loop that samples per-shard queue depth and
//! live p99 latency and moves each pool's replica count inside a
//! configured `min..max` band.
//!
//! The loop is observe -> decide -> act-one-step: each tick it reads the
//! router's instantaneous queue depths (and the live shards' merged p99
//! when a target is set), runs the *pure* [`decide`] policy, and applies
//! at most ONE scale step per pool.  Single-stepping keeps the system
//! analyzable — a burst grows the pool over several ticks instead of
//! jumping to max, and the calm-down hysteresis (`calm_ticks`) keeps a
//! decaying queue from flapping the pool width.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::pool::ServingPlane;

/// Autoscaler policy knobs.
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Replica band (inclusive); the initial width is clamped into it.
    pub min: usize,
    pub max: usize,
    /// Reconcile tick interval.
    pub interval: Duration,
    /// Scale up when any shard's queue exceeds this fraction of its ring
    /// capacity (the backpressure-imminent signal).
    pub up_fill: f64,
    /// Optional latency trigger: scale up when the live p99 exceeds this
    /// many nanoseconds.  `None` scales on queue depth alone.
    pub p99_up_ns: Option<u64>,
    /// Consecutive calm ticks (total queued events == 0 across shards)
    /// required before one scale-down step — hysteresis against flapping.
    pub calm_ticks: u32,
}

impl AutoscaleConfig {
    pub fn band(min: usize, max: usize) -> Self {
        Self {
            min: min.max(1),
            max: max.max(min.max(1)),
            interval: Duration::from_millis(20),
            up_fill: 0.5,
            p99_up_ns: None,
            calm_ticks: 25,
        }
    }
}

/// Parse a `min..max` band ("1..4").
pub fn parse_autoscale(s: &str) -> Result<(usize, usize)> {
    let (lo, hi) = s
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("autoscale band must be min..max, got '{s}'"))?;
    let lo: usize = lo.trim().parse().map_err(|_| {
        anyhow::anyhow!("autoscale min '{lo}' is not a number")
    })?;
    let hi: usize = hi.trim().parse().map_err(|_| {
        anyhow::anyhow!("autoscale max '{hi}' is not a number")
    })?;
    anyhow::ensure!(lo >= 1, "autoscale min must be >= 1");
    anyhow::ensure!(hi >= lo, "autoscale band {lo}..{hi} is inverted");
    Ok((lo, hi))
}

/// One reconcile decision for one pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Up,
    Down,
    Hold,
}

/// The pure scaling policy (unit-testable without threads or pools).
///
/// * Below `min`: always Up (the band is a hard floor).
/// * Overload (any shard past `up_fill` of the ring, or p99 past the
///   target): Up while below `max`.
/// * Calm (zero queued events) for `calm` consecutive ticks: Down while
///   above `min`.  Latency never triggers a scale-down — the cumulative
///   p99 is too sluggish a signal to shrink on.
pub fn decide(
    depths: &[(usize, usize)],
    ring_capacity: usize,
    p99_ns: Option<u64>,
    replicas: usize,
    cfg: &AutoscaleConfig,
    calm: u32,
) -> Decision {
    if replicas < cfg.min {
        return Decision::Up;
    }
    let hot_queue = depths
        .iter()
        .any(|&(_, d)| d as f64 > cfg.up_fill * ring_capacity as f64);
    let hot_latency = match (cfg.p99_up_ns, p99_ns) {
        (Some(target), Some(p99)) => p99 > target,
        _ => false,
    };
    if (hot_queue || hot_latency) && replicas < cfg.max {
        return Decision::Up;
    }
    let total: usize = depths.iter().map(|&(_, d)| d).sum();
    if total == 0 && calm >= cfg.calm_ticks && replicas > cfg.min {
        return Decision::Down;
    }
    Decision::Hold
}

/// The running reconcile loop (one thread for the whole plane).
pub struct Scaler {
    stop: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl Scaler {
    pub fn start(cfg: AutoscaleConfig, plane: Arc<ServingPlane>) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let join = std::thread::spawn(move || {
            let mut calm = vec![0u32; plane.pools().len()];
            while !stop_t.load(Ordering::Acquire) {
                for (i, pool) in plane.pools().iter().enumerate() {
                    let depths = plane
                        .router()
                        .queue_depths(pool.model())
                        .unwrap_or_default();
                    let total: usize = depths.iter().map(|&(_, d)| d).sum();
                    calm[i] = if total == 0 { calm[i].saturating_add(1) } else { 0 };
                    let p99 = if cfg.p99_up_ns.is_some() {
                        pool.live_p99_ns()
                    } else {
                        None
                    };
                    match decide(
                        &depths,
                        pool.ring_capacity(),
                        p99,
                        pool.replicas(),
                        &cfg,
                        calm[i],
                    ) {
                        Decision::Up => {
                            pool.scale_up(plane.router());
                            pool.note_scale_up();
                        }
                        Decision::Down => {
                            if pool.scale_down(plane.router()) {
                                pool.note_scale_down();
                            }
                            calm[i] = 0;
                        }
                        Decision::Hold => {}
                    }
                }
                std::thread::sleep(cfg.interval);
            }
        });
        Self { stop, join }
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Release);
        let _ = self.join.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(min: usize, max: usize) -> AutoscaleConfig {
        AutoscaleConfig { calm_ticks: 3, ..AutoscaleConfig::band(min, max) }
    }

    #[test]
    fn parses_bands_and_rejects_nonsense() {
        assert_eq!(parse_autoscale("1..4").unwrap(), (1, 4));
        assert_eq!(parse_autoscale("2..2").unwrap(), (2, 2));
        assert_eq!(parse_autoscale(" 3 .. 8 ").unwrap(), (3, 8));
        assert!(parse_autoscale("4").is_err());
        assert!(parse_autoscale("4..1").is_err());
        assert!(parse_autoscale("0..4").is_err());
        assert!(parse_autoscale("a..b").is_err());
    }

    #[test]
    fn scales_up_under_queue_pressure_until_max() {
        let c = cfg(1, 4);
        // one shard past half of a 100-deep ring
        let depths = [(0usize, 60usize)];
        assert_eq!(decide(&depths, 100, None, 1, &c, 0), Decision::Up);
        assert_eq!(decide(&depths, 100, None, 3, &c, 0), Decision::Up);
        // at max: hold even under pressure
        assert_eq!(decide(&depths, 100, None, 4, &c, 0), Decision::Hold);
        // below the fill threshold: hold
        assert_eq!(decide(&[(0, 20)], 100, None, 1, &c, 0), Decision::Hold);
    }

    #[test]
    fn latency_target_triggers_growth() {
        let mut c = cfg(1, 4);
        c.p99_up_ns = Some(1_000_000);
        let calmq = [(0usize, 0usize)];
        assert_eq!(decide(&calmq, 100, Some(2_000_000), 2, &c, 0), Decision::Up);
        assert_eq!(decide(&calmq, 100, Some(500_000), 2, &c, 0), Decision::Hold);
        // p99 never shrinks the pool, even when absurdly low
        assert_eq!(decide(&calmq, 100, Some(1), 2, &c, 0), Decision::Hold);
    }

    #[test]
    fn calm_hysteresis_gates_scale_down() {
        let c = cfg(1, 4);
        let calmq = [(0usize, 0usize), (1, 0)];
        // not calm long enough
        assert_eq!(decide(&calmq, 100, None, 3, &c, 2), Decision::Hold);
        // calm long enough: one step down
        assert_eq!(decide(&calmq, 100, None, 3, &c, 3), Decision::Down);
        // at min: never below
        assert_eq!(decide(&calmq, 100, None, 1, &c, 100), Decision::Hold);
        // queued events reset the urge to shrink
        assert_eq!(decide(&[(0, 5)], 100, None, 3, &c, 50), Decision::Hold);
    }

    #[test]
    fn below_min_always_grows() {
        let c = cfg(2, 4);
        assert_eq!(decide(&[(0, 0)], 100, None, 1, &c, 100), Decision::Up);
        // even an empty pool (mid-scale) grows toward min
        assert_eq!(decide(&[], 100, None, 0, &c, 0), Decision::Up);
    }

    #[test]
    fn band_constructor_clamps() {
        let c = AutoscaleConfig::band(0, 0);
        assert_eq!((c.min, c.max), (1, 1));
        let c = AutoscaleConfig::band(3, 1);
        assert_eq!((c.min, c.max), (3, 3));
    }
}
