//! Network ingestion frontier: length-prefixed TCP framing for
//! [`TriggerEvent`]-shaped payloads, decoded into the router's normal
//! submit path by the serving plane (`super::pool`).
//!
//! # Frame format (all integers little-endian)
//!
//! ```text
//! [u32 frame_len] [u8 kind] [payload; frame_len - 1 bytes]
//! ```
//!
//! `frame_len` counts the kind byte plus the payload. Kinds:
//!
//! * `0` — EVENT: `u64 id`, `u8 model_len`, `model_len` UTF-8 bytes,
//!   `u8 flags` (bit 0: label follows, bit 1: stream position follows),
//!   optional `u8 label`, optional `u64 stream_pos`, `u32 rows`,
//!   `u32 cols`, then `rows * cols` f32 values row-major.
//! * `1` — SHUTDOWN: empty payload; the server drains and reports.
//! * `2` — SWAP_PLAN: `u8 model_len` + model bytes, `u32 precision_len`
//!   + serialized precision-plan text, `u32 reuse_len` + serialized
//!   reuse-plan text (a zero length means "no override for this dial").
//!
//! The framing is deliberately dumb: one length prefix, fixed-width
//! fields, no compression — decode cost must stay negligible against a
//! microsecond-scale inference budget.  A reader treats EOF *between*
//! frames as a clean close and EOF *inside* a frame as an error.

use std::io::{self, Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::nn::tensor::Mat;

/// Hard cap on a single frame (16 MiB): a corrupt or hostile length
/// prefix must not allocate unbounded memory.
pub const MAX_FRAME_BYTES: u32 = 1 << 24;

/// Cap on `rows * cols` of one event (far above any zoo model's
/// `seq_len * input_size`, far below an allocation bomb).
pub const MAX_EVENT_ELEMS: u64 = 1 << 22;

const KIND_EVENT: u8 = 0;
const KIND_SHUTDOWN: u8 = 1;
const KIND_SWAP: u8 = 2;

const FLAG_LABEL: u8 = 1;
const FLAG_STREAM_POS: u8 = 2;

/// A decoded event frame: the wire-side twin of
/// [`super::event::TriggerEvent`] (the arrival timestamp is stamped at
/// decode, not carried on the wire — clocks don't cross sockets).
#[derive(Clone, Debug, PartialEq)]
pub struct NetEvent {
    pub id: u64,
    pub model: String,
    pub x: Mat,
    pub label: Option<u8>,
    pub stream_pos: Option<u64>,
}

/// A decoded plan-swap request: rebuild `model`'s backend under new
/// plan overrides, one shard at a time, without dropping anything.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSwap {
    pub model: String,
    /// Serialized precision-plan overrides (`PrecisionPlan::serialize`
    /// text); `None` keeps the pipeline's uniform base.
    pub precision: Option<String>,
    /// Serialized reuse-plan overrides; `None` keeps the uniform base.
    pub reuse: Option<String>,
}

/// One decoded frame off the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Event(NetEvent),
    Shutdown,
    Swap(PlanSwap),
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str8(buf: &mut Vec<u8>, s: &str) -> io::Result<()> {
    if s.len() > u8::MAX as usize {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("name too long for a u8 length: {} bytes", s.len()),
        ));
    }
    buf.push(s.len() as u8);
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

/// Encode one frame onto `w` (a single buffered write: the frame body is
/// assembled in memory first so a slow socket never sees a torn frame).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let mut body = Vec::with_capacity(64);
    match frame {
        Frame::Event(e) => {
            body.push(KIND_EVENT);
            put_u64(&mut body, e.id);
            put_str8(&mut body, &e.model)?;
            let mut flags = 0u8;
            if e.label.is_some() {
                flags |= FLAG_LABEL;
            }
            if e.stream_pos.is_some() {
                flags |= FLAG_STREAM_POS;
            }
            body.push(flags);
            if let Some(l) = e.label {
                body.push(l);
            }
            if let Some(p) = e.stream_pos {
                put_u64(&mut body, p);
            }
            put_u32(&mut body, e.x.rows() as u32);
            put_u32(&mut body, e.x.cols() as u32);
            for &v in e.x.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Shutdown => body.push(KIND_SHUTDOWN),
        Frame::Swap(s) => {
            body.push(KIND_SWAP);
            put_str8(&mut body, &s.model)?;
            for text in [&s.precision, &s.reuse] {
                let t = text.as_deref().unwrap_or("");
                put_u32(&mut body, t.len() as u32);
                body.extend_from_slice(t.as_bytes());
            }
        }
    }
    if body.len() as u64 > MAX_FRAME_BYTES as u64 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame of {} bytes exceeds MAX_FRAME_BYTES", body.len()),
        ));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    Ok(())
}

/// Cursor over one received frame body with bounds-checked reads.
struct Body<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Body<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "frame truncated: field runs past the length prefix",
            ));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_n(&mut self, n: usize) -> io::Result<String> {
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 name"))
    }

    fn done(&self) -> io::Result<()> {
        if self.at != self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{} trailing bytes after frame payload", self.buf.len() - self.at),
            ));
        }
        Ok(())
    }
}

/// Decode the next frame off `r`.  Returns `Ok(None)` on a clean EOF at
/// a frame boundary; EOF mid-frame, an oversized length prefix, an
/// unknown kind byte, or a malformed payload are all `InvalidData`-class
/// errors.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    // distinguish clean close (0 bytes before the prefix) from torn
    // frame (EOF inside the prefix or body)
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut len_buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "EOF inside a frame length prefix",
            ));
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} outside (0, {MAX_FRAME_BYTES}]"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    let mut b = Body { buf: &body, at: 0 };
    let kind = b.u8()?;
    let frame = match kind {
        KIND_EVENT => {
            let id = b.u64()?;
            let model_len = b.u8()? as usize;
            let model = b.str_n(model_len)?;
            let flags = b.u8()?;
            let label = if flags & FLAG_LABEL != 0 { Some(b.u8()?) } else { None };
            let stream_pos =
                if flags & FLAG_STREAM_POS != 0 { Some(b.u64()?) } else { None };
            let rows = b.u32()? as usize;
            let cols = b.u32()? as usize;
            let elems = rows as u64 * cols as u64;
            if rows == 0 || cols == 0 || elems > MAX_EVENT_ELEMS {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("event shape {rows}x{cols} outside bounds"),
                ));
            }
            let raw = b.take(elems as usize * 4)?;
            let mut data = Vec::with_capacity(elems as usize);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            b.done()?;
            Frame::Event(NetEvent {
                id,
                model,
                x: Mat::from_vec(rows, cols, data),
                label,
                stream_pos,
            })
        }
        KIND_SHUTDOWN => {
            b.done()?;
            Frame::Shutdown
        }
        KIND_SWAP => {
            let model_len = b.u8()? as usize;
            let model = b.str_n(model_len)?;
            let mut texts = [None, None];
            for slot in texts.iter_mut() {
                let n = b.u32()? as usize;
                if n > 0 {
                    *slot = Some(b.str_n(n)?);
                }
            }
            b.done()?;
            let [precision, reuse] = texts;
            Frame::Swap(PlanSwap { model, precision, reuse })
        }
        k => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown frame kind {k}"),
            ));
        }
    };
    Ok(Some(frame))
}

/// Accept connections on `listener` and forward every decoded frame into
/// `tx`.  One reader thread per connection; the SPSC single-producer
/// contract downstream is preserved because all readers funnel into ONE
/// mpsc channel whose sole consumer is the plane's dispatcher thread.
///
/// The acceptor polls non-blocking so `stop` can end it promptly; reader
/// threads use a short read timeout for the same reason.  A decode error
/// closes that one connection (logged once) without disturbing others.
pub fn spawn_acceptor(
    listener: TcpListener,
    tx: mpsc::Sender<Frame>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    listener
        .set_nonblocking(true)
        .expect("nonblocking listener");
    std::thread::spawn(move || {
        let mut readers = Vec::new();
        while !stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    let tx = tx.clone();
                    let stop = stop.clone();
                    readers.push(std::thread::spawn(move || {
                        let mut stream = stream;
                        stream
                            .set_read_timeout(Some(Duration::from_millis(500)))
                            .ok();
                        loop {
                            if stop.load(Ordering::Acquire) {
                                return;
                            }
                            match read_frame(&mut stream) {
                                Ok(Some(frame)) => {
                                    if tx.send(frame).is_err() {
                                        return; // dispatcher gone
                                    }
                                }
                                Ok(None) => return, // clean close
                                Err(e)
                                    if e.kind() == io::ErrorKind::WouldBlock
                                        || e.kind() == io::ErrorKind::TimedOut =>
                                {
                                    // idle connection: re-check stop.
                                    // NOTE: a timeout can only hit between
                                    // frames here (clients write whole
                                    // frames in one syscall); a genuinely
                                    // torn frame surfaces as the decode
                                    // error below on the next bytes.
                                    continue;
                                }
                                Err(e) => {
                                    eprintln!("net: closing {peer}: {e}");
                                    return;
                                }
                            }
                        }
                    }));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("net: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        for r in readers {
            let _ = r.join();
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn event(id: u64, label: Option<u8>, pos: Option<u64>) -> Frame {
        let x = Mat::from_vec(3, 2, vec![0.5, -1.25, 3.75, 0.0, f32::MIN_POSITIVE, 42.0]);
        Frame::Event(NetEvent { id, model: "engine".into(), x, label, stream_pos: pos })
    }

    fn round_trip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, f).unwrap();
        let mut c = Cursor::new(buf);
        let got = read_frame(&mut c).unwrap().expect("one frame");
        // and the stream is cleanly exhausted
        assert!(read_frame(&mut c).unwrap().is_none());
        got
    }

    #[test]
    fn event_frames_round_trip_bitwise() {
        for f in [
            event(0, None, None),
            event(7, Some(1), None),
            event(u64::MAX, None, Some(12345)),
            event(99, Some(0), Some(u64::MAX)),
        ] {
            let got = round_trip(&f);
            assert_eq!(got, f);
            // f32 payload really is bitwise, not approximate
            if let (Frame::Event(a), Frame::Event(b)) = (&f, &got) {
                let bits = |m: &Mat| m.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a.x), bits(&b.x));
            }
        }
    }

    #[test]
    fn control_frames_round_trip() {
        assert_eq!(round_trip(&Frame::Shutdown), Frame::Shutdown);
        let swap = Frame::Swap(PlanSwap {
            model: "engine".into(),
            precision: Some("block0.ffn1 ap_fixed<18,8>".into()),
            reuse: None,
        });
        assert_eq!(round_trip(&swap), swap);
        let both = Frame::Swap(PlanSwap {
            model: "gw".into(),
            precision: Some("softmax ap_fixed<12,3>".into()),
            reuse: Some("pool R2".into()),
        });
        assert_eq!(round_trip(&both), both);
    }

    #[test]
    fn many_frames_stream_back_to_back() {
        let mut buf = Vec::new();
        let frames: Vec<Frame> =
            (0..20).map(|i| event(i, Some((i % 2) as u8), None)).collect();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        let mut c = Cursor::new(buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut c).unwrap().unwrap(), f);
        }
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), Frame::Shutdown);
        assert!(read_frame(&mut c).unwrap().is_none());
    }

    #[test]
    fn clean_eof_is_none_torn_frame_is_error() {
        // empty stream: clean close
        assert!(read_frame(&mut Cursor::new(Vec::new())).unwrap().is_none());
        // cut inside the length prefix
        let mut buf = Vec::new();
        write_frame(&mut buf, &event(1, None, None)).unwrap();
        let torn_prefix = &buf[..2];
        assert!(read_frame(&mut Cursor::new(torn_prefix.to_vec())).is_err());
        // cut inside the body
        let torn_body = &buf[..buf.len() - 3];
        assert!(read_frame(&mut Cursor::new(torn_body.to_vec())).is_err());
    }

    #[test]
    fn hostile_inputs_are_refused_without_allocating() {
        // oversized length prefix
        let mut buf = (MAX_FRAME_BYTES + 1).to_le_bytes().to_vec();
        buf.push(KIND_EVENT);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
        // zero-length frame
        assert!(read_frame(&mut Cursor::new(0u32.to_le_bytes().to_vec())).is_err());
        // unknown kind
        let mut buf = 1u32.to_le_bytes().to_vec();
        buf.push(77);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
        // absurd event shape: claim 2^31 x 2^31 but send no data
        let mut body = vec![KIND_EVENT];
        body.extend_from_slice(&0u64.to_le_bytes());
        body.push(1);
        body.push(b'e');
        body.push(0); // flags
        body.extend_from_slice(&(1u32 << 31).to_le_bytes());
        body.extend_from_slice(&(1u32 << 31).to_le_bytes());
        let mut buf = (body.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&body);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
        // trailing garbage after a valid payload
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        buf[0] += 1; // lengthen the frame by one byte...
        buf.push(0xAB); // ...and supply it
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn acceptor_forwards_frames_over_loopback() {
        use std::net::TcpStream;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_acceptor(listener, tx, stop.clone());
        // two concurrent producers funnel into one channel
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        for i in 0..5 {
            write_frame(&mut a, &event(i, Some(1), None)).unwrap();
            write_frame(&mut b, &event(100 + i, None, Some(i))).unwrap();
        }
        drop(a);
        drop(b);
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(rx.recv_timeout(Duration::from_secs(5)).expect("frame"));
        }
        let mut ids: Vec<u64> = got
            .iter()
            .map(|f| match f {
                Frame::Event(e) => e.id,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 100, 101, 102, 103, 104]);
        stop.store(true, Ordering::Release);
        h.join().unwrap();
    }
}
