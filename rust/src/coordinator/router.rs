//! Model router: validates and dispatches events to sharded per-model
//! worker pools.
//!
//! Each model owns a set of SPSC rings, one per batcher+backend worker
//! (shard).  Sources call [`Router::submit`]; the event is placed on the
//! round-robin shard, or — if that ring is momentarily full — on the
//! least-loaded other shard (backpressure-aware overflow).  Only when
//! every shard is full is the event shed.  Backpressure stays explicit:
//! a trigger must degrade by shedding, never by stalling the detector
//! readout.
//!
//! The shard set is **dynamic**: the serving plane's autoscaler and the
//! hot plan swap add and remove shards on a live route
//! ([`Router::add_shard`] / [`Router::remove_shard`]) while a source
//! keeps submitting.  A shard carries a stable id assigned by the
//! caller; ids are unique per route but not dense after scaling.
//!
//! **Producer contract:** the rings are strictly single-producer — at
//! most ONE thread may submit events for a given model at a time
//! (different models may be driven from different threads).  The trigger
//! server upholds this by running exactly one source per pipeline; the
//! network plane funnels every connection through one dispatcher thread.
//! Shard add/remove may race a submit: submits hold the route's shard
//! read lock, membership changes take the write lock, so a producer
//! handle is never pushed to after `remove_shard` returns it.

use super::event::TriggerEvent;
use super::spsc::Producer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Outcome of a submit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Submit {
    Accepted,
    /// Every shard ring full (or the pool momentarily empty mid-scale) —
    /// event shed.
    Shed,
    /// No pipeline for this model name.
    UnknownModel,
    /// Event shape does not match the model.
    BadShape,
}

/// One live shard of a route: a stable id plus the producing ring half.
struct ShardSlot {
    id: usize,
    tx: Producer<TriggerEvent>,
}

struct Route {
    /// Live shard set; read-locked per submit, write-locked by the
    /// (rare) scale/swap membership changes.
    shards: RwLock<Vec<ShardSlot>>,
    /// Round-robin dispatch cursor.
    cursor: AtomicU64,
    seq_len: usize,
    input_size: usize,
    accepted: AtomicU64,
    shed: AtomicU64,
    /// Events that overflowed their round-robin shard and were accepted
    /// by the least-loaded one instead (per-shard accepted counts come
    /// from the workers' `ShardStats`; only this overflow signal needs
    /// router-side accounting).
    rebalanced: AtomicU64,
}

impl Route {
    fn note_accept(&self) -> Submit {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        Submit::Accepted
    }
}

/// Thread-safe router handle (sources share it via `Arc`).
pub struct Router {
    routes: HashMap<&'static str, Route>,
}

impl Router {
    pub fn new() -> Self {
        Self { routes: HashMap::new() }
    }

    /// Register a sharded pipeline: the producing half of every shard
    /// ring plus the expected event geometry.  Shard ids are assigned
    /// densely `0..shards.len()`.  A single-shard route behaves exactly
    /// like the pre-pool design (one attempt, shed on full).
    ///
    /// Panics on an empty shard list or a duplicate model: silently
    /// replacing a route would orphan the old shards' producers, leaving
    /// their workers blocked on rings that never close.
    pub fn add_route(
        &mut self,
        model: &'static str,
        shards: Vec<Producer<TriggerEvent>>,
        seq_len: usize,
        input_size: usize,
    ) {
        assert!(!shards.is_empty(), "route '{model}' needs at least one shard");
        self.add_dynamic_route(model, seq_len, input_size);
        for (id, tx) in shards.into_iter().enumerate() {
            assert!(self.add_shard(model, id, tx), "route '{model}' just added");
        }
    }

    /// Register a route with an *empty* shard set — the serving plane's
    /// spawn path, where shards are attached one by one with
    /// [`Router::add_shard`].  Submits shed until the first shard lands.
    ///
    /// Panics on a duplicate model (see [`Router::add_route`]).
    pub fn add_dynamic_route(&mut self, model: &'static str, seq_len: usize, input_size: usize) {
        assert!(
            !self.routes.contains_key(model),
            "route '{model}' registered twice"
        );
        self.routes.insert(
            model,
            Route {
                shards: RwLock::new(Vec::new()),
                cursor: AtomicU64::new(0),
                seq_len,
                input_size,
                accepted: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                rebalanced: AtomicU64::new(0),
            },
        );
    }

    /// Attach a shard (stable `id`, producing ring half) to a live
    /// route.  Returns false if the model has no route.  Panics on a
    /// duplicate id — the retire path looks shards up by id, and two
    /// slots answering to one id would orphan a ring.
    pub fn add_shard(&self, model: &str, id: usize, tx: Producer<TriggerEvent>) -> bool {
        let Some(route) = self.routes.get(model) else {
            return false;
        };
        let mut shards = route.shards.write().unwrap();
        assert!(
            shards.iter().all(|s| s.id != id),
            "shard id {id} already live on route '{model}'"
        );
        shards.push(ShardSlot { id, tx });
        true
    }

    /// Detach shard `id` from a live route, returning its producer so
    /// the caller can `close()` it and drain the worker.  `None` if the
    /// model or id is unknown.  Subsequent submits simply stop seeing
    /// the shard (in-flight events already on its ring are unaffected).
    pub fn remove_shard(&self, model: &str, id: usize) -> Option<Producer<TriggerEvent>> {
        let route = self.routes.get(model)?;
        let mut shards = route.shards.write().unwrap();
        let i = shards.iter().position(|s| s.id == id)?;
        Some(shards.remove(i).tx)
    }

    /// Validate + dispatch one event.
    ///
    /// Concurrency contract: at most one thread may submit for a given
    /// model at a time (the shard rings are SPSC; see the module docs).
    pub fn submit(&self, event: TriggerEvent) -> Submit {
        let Some(route) = self.routes.get(event.model) else {
            return Submit::UnknownModel;
        };
        if event.x.rows() != route.seq_len || event.x.cols() != route.input_size {
            return Submit::BadShape;
        }
        let shards = route.shards.read().unwrap();
        let n = shards.len();
        if n == 0 {
            // mid-scale empty pool: shed (never stall) like a full ring
            route.shed.fetch_add(1, Ordering::Relaxed);
            return Submit::Shed;
        }
        let rr = (route.cursor.fetch_add(1, Ordering::Relaxed) as usize) % n;
        match shards[rr].tx.try_push(event) {
            Ok(()) => route.note_accept(),
            Err(event) => {
                // round-robin shard full: overflow to the least-loaded
                // other shard before giving up (with one shard this is
                // the seed behavior: single attempt, then shed)
                if n > 1 {
                    if let Some(alt) = (0..n)
                        .filter(|&i| i != rr)
                        .min_by_key(|&i| shards[i].tx.len())
                    {
                        if shards[alt].tx.try_push(event).is_ok() {
                            route.rebalanced.fetch_add(1, Ordering::Relaxed);
                            return route.note_accept();
                        }
                    }
                }
                route.shed.fetch_add(1, Ordering::Relaxed);
                Submit::Shed
            }
        }
    }

    /// Close every shard of every pipeline (drain + shut down).
    pub fn close_all(&self) {
        for r in self.routes.values() {
            for s in r.shards.read().unwrap().iter() {
                s.tx.close();
            }
        }
    }

    /// (accepted, shed) counters for a model, summed over shards.
    pub fn counters(&self, model: &str) -> Option<(u64, u64)> {
        self.routes.get(model).map(|r| {
            (r.accepted.load(Ordering::Relaxed), r.shed.load(Ordering::Relaxed))
        })
    }

    /// Events accepted via overflow to a non-round-robin shard.
    pub fn rebalanced(&self, model: &str) -> Option<u64> {
        self.routes.get(model).map(|r| r.rebalanced.load(Ordering::Relaxed))
    }

    /// Worker-pool width of a model's route.
    pub fn replicas(&self, model: &str) -> Option<usize> {
        self.routes.get(model).map(|r| r.shards.read().unwrap().len())
    }

    /// Instantaneous `(shard_id, queued_events)` per live shard — the
    /// autoscaler's load signal and the per-shard queue-depth gauge of
    /// the metrics endpoint.
    pub fn queue_depths(&self, model: &str) -> Option<Vec<(usize, usize)>> {
        self.routes.get(model).map(|r| {
            r.shards
                .read()
                .unwrap()
                .iter()
                .map(|s| (s.id, s.tx.len()))
                .collect()
        })
    }

    pub fn models(&self) -> Vec<&'static str> {
        self.routes.keys().copied().collect()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

/// Shared router handle.
pub type SharedRouter = Arc<Router>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spsc::{ring, Consumer};
    use crate::nn::tensor::Mat;

    fn router_with_engine(
        cap: usize,
        shards: usize,
    ) -> (Router, Vec<Consumer<TriggerEvent>>) {
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..shards {
            let (tx, rx) = ring(cap);
            txs.push(tx);
            rxs.push(rx);
        }
        let mut r = Router::new();
        r.add_route("engine", txs, 50, 1);
        (r, rxs)
    }

    fn ev(model: &'static str, rows: usize, cols: usize) -> TriggerEvent {
        TriggerEvent::new(0, model, Mat::zeros(rows, cols), None)
    }

    #[test]
    fn accepts_valid_events() {
        let (r, rxs) = router_with_engine(8, 1);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(rxs[0].try_pop().unwrap().model, "engine");
        assert_eq!(r.counters("engine").unwrap(), (1, 0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shape() {
        let (r, _rxs) = router_with_engine(8, 1);
        assert_eq!(r.submit(ev("nope", 50, 1)), Submit::UnknownModel);
        assert_eq!(r.submit(ev("engine", 49, 1)), Submit::BadShape);
        assert_eq!(r.counters("engine").unwrap(), (0, 0));
    }

    #[test]
    fn sheds_on_full_ring() {
        let (r, _rxs) = router_with_engine(2, 1);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        let (acc, shed) = r.counters("engine").unwrap();
        assert_eq!((acc, shed), (2, 1));
    }

    #[test]
    fn round_robin_spreads_across_shards() {
        let (r, rxs) = router_with_engine(8, 4);
        for _ in 0..8 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        for rx in &rxs {
            assert_eq!(rx.len(), 2, "round-robin must spread evenly");
        }
        assert_eq!(r.rebalanced("engine").unwrap(), 0);
        assert_eq!(r.replicas("engine").unwrap(), 4);
    }

    #[test]
    fn overflow_goes_to_least_loaded_shard() {
        // fill both shards round-robin, drain shard 1, then hit the full
        // round-robin target (shard 0): the event must overflow onto the
        // now-empty shard 1 instead of shedding
        let (r, rxs) = router_with_engine(2, 2);
        for _ in 0..4 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(rxs[0].len(), 2);
        assert_eq!(rxs[1].len(), 2);
        while rxs[1].try_pop().is_some() {}
        // cursor is at 4 -> next round-robin pick is the full shard 0
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(rxs[1].len(), 1, "overflow landed on the drained shard");
        assert_eq!(r.rebalanced("engine").unwrap(), 1);
        assert_eq!(r.counters("engine").unwrap(), (5, 0));
    }

    #[test]
    fn sheds_only_when_every_shard_is_full() {
        let (r, _rxs) = router_with_engine(2, 3);
        // 3 shards x capacity 2 = 6 slots; all six submits must land
        for _ in 0..6 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        let (acc, shed) = r.counters("engine").unwrap();
        assert_eq!((acc, shed), (6, 1));
    }

    #[test]
    fn persistently_full_shard_does_not_pin_rotation() {
        // regression for the cursor audit: the round-robin cursor
        // advances on *every* submit (including overflowed ones), so one
        // stuck-full shard can neither pin the cursor on itself nor
        // starve the healthy shards of their round-robin turns
        let (r, rxs) = router_with_engine(2, 3);
        // fill every shard round-robin, then drain shards 1 and 2:
        // shard 0 is wedged full (its worker never drains), the others
        // are empty, and the cursor sits just before the wedged shard
        for _ in 0..6 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        while rxs[1].try_pop().is_some() {}
        while rxs[2].try_pop().is_some() {}
        // 4 more submits must keep rotating: rr hits shard 0 twice
        // (overflowing to the least-loaded healthy shard both times) and
        // shards 1/2 once each directly
        for _ in 0..4 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(rxs[0].len(), 2, "wedged shard untouched past capacity");
        assert_eq!(rxs[1].len(), 2, "healthy shards absorb the load");
        assert_eq!(rxs[2].len(), 2, "healthy shards absorb the load");
        assert_eq!(r.rebalanced("engine").unwrap(), 2, "one overflow per rr pass over shard 0");
        assert_eq!(r.counters("engine").unwrap(), (10, 0));
    }

    #[test]
    fn cursor_advances_past_shed_submits() {
        // a shed must still consume a cursor tick: the next accepted
        // event lands on the *next* shard in rotation, not back on the
        // shard that just shed
        let (r, rxs) = router_with_engine(1, 2);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted); // rr=0
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted); // rr=1
        // both full: this one sheds at rr=0 (and its overflow probe)
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        // drain shard 0 only; the cursor must now be at rr=1, so the
        // next submit overflows off the still-full shard 1 onto shard 0
        while rxs[0].try_pop().is_some() {}
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(rxs[0].len(), 1, "post-shed submit rotated to the drained shard");
        assert_eq!(r.rebalanced("engine").unwrap(), 1);
        let (acc, shed) = r.counters("engine").unwrap();
        assert_eq!((acc, shed), (3, 1));
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_route_registration_panics() {
        // silently replacing a route would orphan the old shards and
        // leave their workers blocked forever — fail loudly instead
        let (tx1, _rx1) = ring(4);
        let (tx2, _rx2) = ring(4);
        let mut r = Router::new();
        r.add_route("engine", vec![tx1], 50, 1);
        r.add_route("engine", vec![tx2], 50, 1);
    }

    #[test]
    fn single_shard_route_keeps_seed_semantics() {
        // one shard: a full ring sheds immediately (no rebalance attempt)
        let (r, _rxs) = router_with_engine(2, 1);
        for _ in 0..2 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        assert_eq!(r.rebalanced("engine").unwrap(), 0);
        assert_eq!(r.replicas("engine").unwrap(), 1);
    }

    #[test]
    fn dynamic_route_sheds_until_first_shard_attaches() {
        let mut r = Router::new();
        r.add_dynamic_route("engine", 50, 1);
        assert_eq!(r.replicas("engine").unwrap(), 0);
        // empty pool: shed, never panic (the `% 0` hazard of the static
        // design) and never stall
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        let (tx, rx) = ring(8);
        assert!(r.add_shard("engine", 7, tx));
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(rx.len(), 1);
        assert_eq!(r.queue_depths("engine").unwrap(), vec![(7, 1)]);
        let (acc, shed) = r.counters("engine").unwrap();
        assert_eq!((acc, shed), (1, 1));
    }

    #[test]
    fn add_and_remove_shards_on_a_live_route() {
        let (r, rxs) = router_with_engine(8, 2);
        let (tx, rx2) = ring(8);
        assert!(r.add_shard("engine", 9, tx), "attach to a live route");
        assert_eq!(r.replicas("engine").unwrap(), 3);
        for _ in 0..6 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(rx2.len(), 2, "new shard takes its round-robin turns");
        // retire shard 0: its producer comes back for close+drain, the
        // queued events stay on the ring, and routing continues over the
        // survivors
        let tx0 = r.remove_shard("engine", 0).expect("shard 0 live");
        assert_eq!(r.replicas("engine").unwrap(), 2);
        tx0.close();
        assert_eq!(rxs[0].len(), 2, "in-flight events survive the detach");
        for _ in 0..4 {
            assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        }
        assert_eq!(rxs[0].len(), 2, "retired shard receives nothing new");
        assert_eq!(r.remove_shard("engine", 0), None, "already detached");
        assert_eq!(r.remove_shard("nope", 1), None);
        let depths = r.queue_depths("engine").unwrap();
        assert_eq!(depths.len(), 2);
        assert!(depths.iter().any(|&(id, _)| id == 9));
    }

    #[test]
    #[should_panic(expected = "already live")]
    fn duplicate_shard_id_panics() {
        let (r, _rxs) = router_with_engine(8, 2);
        let (tx, _rx) = ring(8);
        r.add_shard("engine", 1, tx);
    }

    #[test]
    fn add_shard_to_unknown_model_is_refused() {
        let r = Router::new();
        let (tx, _rx) = ring(8);
        assert!(!r.add_shard("engine", 0, tx));
    }
}
