//! Model router: validates and dispatches events to per-model pipelines.
//!
//! The router owns one SPSC producer per model; sources call
//! [`Router::submit`] and the event lands in the right pipeline's ring.
//! Backpressure is explicit: a full ring rejects the event and the drop
//! is counted (a trigger must degrade by shedding, never by stalling the
//! detector readout).

use super::event::TriggerEvent;
use super::spsc::Producer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Outcome of a submit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Submit {
    Accepted,
    /// Ring full — event shed.
    Shed,
    /// No pipeline for this model name.
    UnknownModel,
    /// Event shape does not match the model.
    BadShape,
}

struct Route {
    tx: Producer<TriggerEvent>,
    seq_len: usize,
    input_size: usize,
    accepted: AtomicU64,
    shed: AtomicU64,
}

/// Thread-safe router handle (sources share it via `Arc`).
pub struct Router {
    routes: HashMap<&'static str, Route>,
}

impl Router {
    pub fn new() -> Self {
        Self { routes: HashMap::new() }
    }

    /// Register a pipeline: the producing half of its ring plus the
    /// expected event geometry.
    pub fn add_route(
        &mut self,
        model: &'static str,
        tx: Producer<TriggerEvent>,
        seq_len: usize,
        input_size: usize,
    ) {
        self.routes.insert(
            model,
            Route {
                tx,
                seq_len,
                input_size,
                accepted: AtomicU64::new(0),
                shed: AtomicU64::new(0),
            },
        );
    }

    /// Validate + dispatch one event.
    pub fn submit(&self, event: TriggerEvent) -> Submit {
        let Some(route) = self.routes.get(event.model) else {
            return Submit::UnknownModel;
        };
        if event.x.rows() != route.seq_len || event.x.cols() != route.input_size {
            return Submit::BadShape;
        }
        match route.tx.try_push(event) {
            Ok(()) => {
                route.accepted.fetch_add(1, Ordering::Relaxed);
                Submit::Accepted
            }
            Err(_) => {
                route.shed.fetch_add(1, Ordering::Relaxed);
                Submit::Shed
            }
        }
    }

    /// Close every pipeline (drain + shut down).
    pub fn close_all(&self) {
        for r in self.routes.values() {
            r.tx.close();
        }
    }

    /// (accepted, shed) counters for a model.
    pub fn counters(&self, model: &str) -> Option<(u64, u64)> {
        self.routes.get(model).map(|r| {
            (r.accepted.load(Ordering::Relaxed), r.shed.load(Ordering::Relaxed))
        })
    }

    pub fn models(&self) -> Vec<&'static str> {
        self.routes.keys().copied().collect()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

/// Shared router handle.
pub type SharedRouter = Arc<Router>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spsc::ring;
    use crate::nn::tensor::Mat;

    fn router_with_engine(cap: usize) -> (Router, super::super::spsc::Consumer<TriggerEvent>) {
        let (tx, rx) = ring(cap);
        let mut r = Router::new();
        r.add_route("engine", tx, 50, 1);
        (r, rx)
    }

    fn ev(model: &'static str, rows: usize, cols: usize) -> TriggerEvent {
        TriggerEvent::new(0, model, Mat::zeros(rows, cols), None)
    }

    #[test]
    fn accepts_valid_events() {
        let (r, rx) = router_with_engine(8);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(rx.try_pop().unwrap().model, "engine");
        assert_eq!(r.counters("engine").unwrap(), (1, 0));
    }

    #[test]
    fn rejects_unknown_model_and_bad_shape() {
        let (r, _rx) = router_with_engine(8);
        assert_eq!(r.submit(ev("nope", 50, 1)), Submit::UnknownModel);
        assert_eq!(r.submit(ev("engine", 49, 1)), Submit::BadShape);
        assert_eq!(r.counters("engine").unwrap(), (0, 0));
    }

    #[test]
    fn sheds_on_full_ring() {
        let (r, _rx) = router_with_engine(2);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Accepted);
        assert_eq!(r.submit(ev("engine", 50, 1)), Submit::Shed);
        let (acc, shed) = r.counters("engine").unwrap();
        assert_eq!((acc, shed), (2, 1));
    }
}
