//! The network serving plane: dynamic per-model worker pools behind the
//! TCP ingestion frontier (`super::net`), with elastic scaling
//! (`super::scaler`), a scrapeable metrics endpoint
//! (`super::metrics_http`), and zero-drop hot plan swaps.
//!
//! Relationship to [`super::server::TriggerServer`]: the batch server
//! spawns a fixed pool per model and runs sources to completion; the
//! plane runs the SAME shard worker loop (`server::serve_shard`) under a
//! *dynamic* shard set — shards are spawned and retired on a live route
//! while one dispatcher thread keeps submitting.  `replicas` in the
//! pipeline config is the plane's initial width, not a fixed one.
//!
//! # Zero-drop invariants
//!
//! * Retiring a shard detaches it from the router FIRST (no new events
//!   can land), then closes its ring; the worker drains every queued
//!   event before exiting, and its stats fold into the pool's retired
//!   total.  Nothing on a ring is ever discarded by scaling.
//! * A hot plan swap spawns each replacement shard (adopting the newly
//!   compiled engine) BEFORE retiring the old one, one shard at a time —
//!   the pool never has fewer live shards than it started with, so the
//!   swap is zero-drop even at one replica.
//! * The swap re-runs the static plan verifier and compiles the new
//!   engine before the first drain; a refused plan leaves the pool
//!   untouched.

use std::collections::{BTreeMap, HashMap};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::backend::{Backend, BackendKind};
use super::batcher::Batcher;
use super::event::TriggerEvent;
use super::net::{self, Frame, NetEvent, PlanSwap};
use super::router::{Router, Submit};
use super::scaler::{AutoscaleConfig, Scaler};
use super::server::{
    resolve_pipeline, serve_shard, CompiledInfo, PipelineConfig, ServerConfig,
    ServerReport, SourceMode,
};
use super::spsc;
use super::stats::{PipelineStats, ShardLive, ShardStats};
use crate::hls::{FixedTransformer, ParallelismPlan, PrecisionPlan, SynthesisReport};
use crate::metrics::LatencyHistogram;
use crate::models::{weights::Weights, ModelConfig};
use crate::runtime::Runtime;

/// One live shard: the publishing handle scraped by metrics plus the
/// worker's join handle (the worker returns its full local stats).
struct ShardHandle {
    live: Arc<ShardLive>,
    join: std::thread::JoinHandle<PipelineStats>,
}

/// Mutable pool state behind one mutex: the current plans + engine that
/// new shards adopt, and the live shard map keyed by stable id.
struct PoolInner {
    plan: PrecisionPlan,
    par: ParallelismPlan,
    /// Compile-once engine current shards were (or will be) built from;
    /// `None` for float/PJRT pools.
    engine: Option<FixedTransformer>,
    /// Next stable shard id (monotonic; ids are never reused, so retired
    /// and live stats never collide).
    next_shard: usize,
    shards: BTreeMap<usize, ShardHandle>,
}

/// One model's elastic worker pool on the serving plane.
pub struct ModelPool {
    model: &'static str,
    pc: PipelineConfig,
    mcfg: ModelConfig,
    weights: Arc<Weights>,
    artifacts: PathBuf,
    inner: Mutex<PoolInner>,
    /// Folded stats of every retired shard (the live ones still hold
    /// their own); at shutdown this becomes the model's report entry.
    retired: Mutex<PipelineStats>,
    /// Modeled FPGA design point under the *current* plan (updated on
    /// swap; `None` for float/PJRT pools).
    modeled: Mutex<Option<SynthesisReport>>,
    compiled: Mutex<Option<CompiledInfo>>,
    swaps: AtomicU64,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
}

impl ModelPool {
    fn new(
        pc: PipelineConfig,
        resolved: super::server::ResolvedPipeline,
        artifacts: PathBuf,
    ) -> Self {
        let super::server::ResolvedPipeline {
            mcfg,
            weights,
            plan,
            par,
            engine,
            modeled,
            compiled,
        } = resolved;
        Self {
            model: pc.model,
            pc,
            mcfg,
            weights,
            artifacts,
            inner: Mutex::new(PoolInner {
                plan,
                par,
                engine,
                next_shard: 0,
                shards: BTreeMap::new(),
            }),
            retired: Mutex::new(PipelineStats::default()),
            modeled: Mutex::new(modeled),
            compiled: Mutex::new(compiled),
            swaps: AtomicU64::new(0),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
        }
    }

    pub fn model(&self) -> &'static str {
        self.model
    }

    /// Ring capacity each shard is built with (the autoscaler's fill
    /// denominator).
    pub fn ring_capacity(&self) -> usize {
        self.pc.ring_capacity
    }

    pub fn replicas(&self) -> usize {
        self.inner.lock().unwrap().shards.len()
    }

    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    pub fn scale_ups(&self) -> u64 {
        self.scale_ups.load(Ordering::Relaxed)
    }

    pub fn scale_downs(&self) -> u64 {
        self.scale_downs.load(Ordering::Relaxed)
    }

    /// Spawn one shard adopting the pool's current plan/engine, attach
    /// it to the router, return its stable id.
    fn spawn_shard_locked(&self, router: &Router, inner: &mut PoolInner) -> usize {
        let id = inner.next_shard;
        inner.next_shard += 1;
        let (tx, rx) = spsc::ring::<TriggerEvent>(self.pc.ring_capacity);
        let live = Arc::new(ShardLive::new(id));
        let live_w = live.clone();
        let engine = inner.engine.clone();
        let plan = inner.plan.clone();
        let par = inner.par.clone();
        let pc = self.pc.clone();
        let mcfg = self.mcfg.clone();
        let weights = self.weights.clone();
        let artifacts = self.artifacts.clone();
        let join = std::thread::spawn(move || -> PipelineStats {
            let built = (|| -> Result<(Option<Runtime>, Backend)> {
                if let Some(engine) = engine {
                    return Ok((None, Backend::from_hls_engine(engine, par.clone())));
                }
                let runtime = if pc.backend == BackendKind::Pjrt {
                    Some(Runtime::cpu()?)
                } else {
                    None
                };
                let backend = Backend::build(
                    pc.backend,
                    &mcfg,
                    &weights,
                    &plan,
                    &par,
                    runtime.as_ref(),
                    &artifacts,
                )?;
                Ok((runtime, backend))
            })();
            match built {
                Ok((_runtime, backend)) => {
                    let batcher = Batcher::new(pc.batch, rx);
                    let stream_reuse =
                        matches!(&pc.source, SourceMode::Stream(ss) if ss.reuse);
                    serve_shard(&backend, batcher, stream_reuse, id, Some(&live_w))
                }
                Err(e) => {
                    // a shard that cannot build must still drain its ring
                    // until retired, or the route would wedge; everything
                    // it drains is a worker-side drop
                    eprintln!("shard {id}: backend build failed, draining: {e:#}");
                    let mut batcher = Batcher::new(pc.batch, rx);
                    let mut stats = PipelineStats::default();
                    while let Some(batch) = batcher.next_batch() {
                        stats.dropped += batch.len() as u64;
                        live_w.publish(stats.shard_snapshot(id));
                    }
                    live_w.publish(stats.shard_snapshot(id));
                    stats
                }
            }
        });
        inner.shards.insert(id, ShardHandle { live, join });
        // attach last: the worker (or at least its ring) exists before
        // the router can land events on it
        let attached = router.add_shard(self.model, id, tx);
        assert!(attached, "pool '{}' has a route", self.model);
        id
    }

    /// Detach shard `id` from the router, close its ring, drain-join the
    /// worker, fold its stats into the retired total.  Zero-drop: every
    /// event already queued is scored before the worker exits.
    fn retire_shard_locked(&self, router: &Router, inner: &mut PoolInner, id: usize) {
        let handle = inner.shards.remove(&id).expect("retiring a live shard");
        if let Some(tx) = router.remove_shard(self.model, id) {
            tx.close();
        }
        let stats = handle.join.join().expect("shard worker");
        self.retired.lock().unwrap().absorb_shard(id, &stats);
    }

    /// Add one shard (initial spawn and autoscaler growth).  Returns the
    /// new shard's id.
    pub fn scale_up(&self, router: &Router) -> usize {
        let mut inner = self.inner.lock().unwrap();
        self.spawn_shard_locked(router, &mut inner)
    }

    /// Retire the newest shard.  Refuses (returns false) at one shard —
    /// the pool itself never goes dark; only shutdown empties it.
    pub fn scale_down(&self, router: &Router) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.shards.len() <= 1 {
            return false;
        }
        let id = *inner.shards.keys().next_back().expect("non-empty");
        self.retire_shard_locked(router, &mut inner, id);
        true
    }

    /// Autoscaler bookkeeping (kept separate from the mechanics so
    /// initial spawns and swap churn don't count as scaling decisions).
    pub fn note_scale_up(&self) {
        self.scale_ups.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_scale_down(&self) {
        self.scale_downs.fetch_add(1, Ordering::Relaxed);
    }

    /// Hot plan swap: verify the candidate plans, compile the new engine
    /// once, then roll the pool one shard at a time (spawn replacement
    /// on the new engine → drain + retire the old shard).  Zero-drop by
    /// construction; a refused plan is an `Err` with the pool untouched.
    pub fn swap_plan(
        &self,
        router: &Router,
        precision: Option<&str>,
        reuse: Option<&str>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.pc.backend == BackendKind::Hls,
            "hot plan swap needs the hls backend; pool '{}' serves {:?}",
            self.model,
            self.pc.backend
        );
        let mut inner = self.inner.lock().unwrap();
        // resolve the candidate plans over the pipeline's uniform bases
        let mut plan = PrecisionPlan::uniform(self.mcfg.num_blocks, self.pc.quant);
        if let Some(text) = precision {
            plan.apply_overrides(text)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("swap precision plan for '{}'", self.model))?;
        }
        let mut par = ParallelismPlan::uniform(self.mcfg.num_blocks, self.pc.reuse);
        if let Some(text) = reuse {
            par.apply_overrides(text)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("swap reuse plan for '{}'", self.model))?;
        }
        // the verifier gates the swap BEFORE any drain starts: a bad
        // plan must leave the live pool untouched
        let verdict = crate::analysis::verify_plan(
            &self.mcfg,
            &self.weights,
            &plan,
            &par,
            &crate::analysis::VerifyConfig::default(),
        );
        if verdict.has_errors() {
            let first = verdict.errors().next().expect("has_errors");
            anyhow::bail!(
                "swap refused for '{}': plan verification failed \
                 ({} error(s)); first: site '{}': {}",
                self.model,
                verdict.count(crate::analysis::Severity::Error),
                first.site,
                first.message
            );
        }
        // compile once; every replacement shard adopts this artifact
        let engine = FixedTransformer::with_plan(self.mcfg.clone(), &self.weights, plan.clone());
        *self.modeled.lock().unwrap() = Some(engine.synthesize(&par));
        *self.compiled.lock().unwrap() = Some(CompiledInfo {
            build_micros: engine.compiled().build_micros(),
            bytes: engine.compiled().bytes(),
            replicas: inner.shards.len().max(1),
        });
        inner.plan = plan;
        inner.par = par;
        inner.engine = Some(engine);
        // rolling replacement: spawn-on-new-plan first, retire-old
        // second, one shard at a time — capacity never dips, so even a
        // one-replica pool swaps without dropping anything
        let old_ids: Vec<usize> = inner.shards.keys().copied().collect();
        for id in old_ids {
            self.spawn_shard_locked(router, &mut inner);
            self.retire_shard_locked(router, &mut inner, id);
        }
        self.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Cumulative stats snapshots of the live shards (retired shards
    /// live in `retired`).
    fn live_snapshots(&self) -> Vec<ShardStats> {
        let inner = self.inner.lock().unwrap();
        inner.shards.values().map(|h| h.live.snapshot()).collect()
    }

    /// p99 latency over the live shards' merged histograms (the
    /// autoscaler's latency signal); `None` before any event is scored.
    pub fn live_p99_ns(&self) -> Option<u64> {
        let mut merged = LatencyHistogram::new();
        for s in self.live_snapshots() {
            merged.merge(&s.latency);
        }
        if merged.count() == 0 {
            None
        } else {
            Some(merged.quantile_ns(0.99))
        }
    }

    /// Retire every live shard and return the pool's final folded stats
    /// (shed/rebalanced are filled in by the plane from the router).
    fn drain_all(&self, router: &Router) -> PipelineStats {
        let mut inner = self.inner.lock().unwrap();
        let ids: Vec<usize> = inner.shards.keys().copied().collect();
        for id in ids {
            self.retire_shard_locked(router, &mut inner, id);
        }
        self.retired.lock().unwrap().clone()
    }
}

/// One model's scrape-time view, assembled lock-briefly from the router
/// counters, the live shards' published snapshots, and the retired fold.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    pub model: &'static str,
    /// Router-side accepted (queued) count.
    pub router_accepted: u64,
    pub shed: u64,
    pub rebalanced: u64,
    pub replicas: usize,
    /// Instantaneous `(shard_id, queued_events)` per live shard.
    pub queue_depths: Vec<(usize, usize)>,
    /// Cumulative per-shard stats: every retired shard, then every live
    /// one (ids never collide — they are assigned monotonically).
    pub shards: Vec<ShardStats>,
    pub swaps: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
}

impl ModelSnapshot {
    /// Worker-side scored total across retired + live shards.
    pub fn scored(&self) -> u64 {
        self.shards.iter().map(|s| s.accepted).sum()
    }

    /// Worker-side dropped total across retired + live shards.
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.dropped).sum()
    }

    /// Merged latency histogram across retired + live shards — the
    /// exposition source; its buckets agree with every in-process
    /// `LatencyHistogram` by construction (same type, same edges).
    pub fn latency(&self) -> LatencyHistogram {
        let mut merged = LatencyHistogram::new();
        for s in &self.shards {
            merged.merge(&s.latency);
        }
        merged
    }
}

/// Whole-plane scrape-time view.
#[derive(Clone, Debug)]
pub struct PlaneSnapshot {
    pub models: Vec<ModelSnapshot>,
    /// Events refused because no pool serves their model name.
    pub rejected_unknown: u64,
    /// Events refused because their matrix shape mismatched the model.
    pub rejected_bad_shape: u64,
    pub uptime_secs: f64,
}

/// The serving plane: router + per-model elastic pools + the counters
/// the dispatcher maintains.  Shared (`Arc`) between the dispatcher,
/// the autoscaler, and the metrics endpoint.
pub struct ServingPlane {
    router: Arc<Router>,
    pools: Vec<Arc<ModelPool>>,
    by_name: HashMap<&'static str, usize>,
    rejected_unknown: AtomicU64,
    rejected_bad_shape: AtomicU64,
    started: Instant,
}

impl ServingPlane {
    /// Resolve every pipeline (verifier-gated), register dynamic routes,
    /// and spawn each pool's initial shards.  `initial_clamp` bounds the
    /// starting width (the autoscaler's min..max band when autoscaling).
    pub fn new(cfg: &ServerConfig, initial_clamp: Option<(usize, usize)>) -> Result<Self> {
        anyhow::ensure!(!cfg.pipelines.is_empty(), "serving plane needs >= 1 pipeline");
        {
            let mut seen = std::collections::HashSet::new();
            for pc in &cfg.pipelines {
                anyhow::ensure!(
                    seen.insert(pc.model),
                    "duplicate pipeline for model '{}'",
                    pc.model
                );
            }
        }
        let mut router = Router::new();
        let mut pools = Vec::new();
        let mut by_name = HashMap::new();
        for pc in &cfg.pipelines {
            let resolved = resolve_pipeline(&cfg.artifacts_dir, pc)?;
            router.add_dynamic_route(
                pc.model,
                resolved.mcfg.seq_len,
                resolved.mcfg.input_size,
            );
            by_name.insert(pc.model, pools.len());
            pools.push(Arc::new(ModelPool::new(
                pc.clone(),
                resolved,
                cfg.artifacts_dir.clone(),
            )));
        }
        let plane = Self {
            router: Arc::new(router),
            pools,
            by_name,
            rejected_unknown: AtomicU64::new(0),
            rejected_bad_shape: AtomicU64::new(0),
            started: Instant::now(),
        };
        for pool in &plane.pools {
            let mut want = pool.pc.replicas.max(1);
            if let Some((lo, hi)) = initial_clamp {
                want = want.clamp(lo.max(1), hi.max(1));
            }
            for _ in 0..want {
                pool.scale_up(&plane.router);
            }
        }
        Ok(plane)
    }

    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    pub fn pools(&self) -> &[Arc<ModelPool>] {
        &self.pools
    }

    /// Submit one decoded network event through the router.  Must be
    /// called from a single dispatcher thread per the SPSC contract.
    pub fn submit_net(&self, ev: NetEvent) -> Submit {
        let Some(&idx) = self.by_name.get(ev.model.as_str()) else {
            self.rejected_unknown.fetch_add(1, Ordering::Relaxed);
            return Submit::UnknownModel;
        };
        let model = self.pools[idx].model;
        let mut te = match ev.stream_pos {
            Some(pos) => TriggerEvent::stream_window(ev.id, model, ev.x, pos),
            None => TriggerEvent::new(ev.id, model, ev.x, ev.label),
        };
        te.label = ev.label;
        let outcome = self.router.submit(te);
        if outcome == Submit::BadShape {
            self.rejected_bad_shape.fetch_add(1, Ordering::Relaxed);
        }
        outcome
    }

    /// Apply a decoded plan-swap request to its model's pool.
    pub fn swap(&self, req: &PlanSwap) -> Result<()> {
        let Some(&idx) = self.by_name.get(req.model.as_str()) else {
            anyhow::bail!("swap for unknown model '{}'", req.model);
        };
        self.pools[idx].swap_plan(
            &self.router,
            req.precision.as_deref(),
            req.reuse.as_deref(),
        )
    }

    /// Scrape-time view of the whole plane (cheap: published snapshots +
    /// atomic counters; no worker is interrupted).
    pub fn snapshot(&self) -> PlaneSnapshot {
        let mut models = Vec::with_capacity(self.pools.len());
        for pool in &self.pools {
            let (router_accepted, shed) =
                self.router.counters(pool.model).unwrap_or((0, 0));
            let mut shards = self.retired_shards(pool);
            shards.extend(pool.live_snapshots());
            models.push(ModelSnapshot {
                model: pool.model,
                router_accepted,
                shed,
                rebalanced: self.router.rebalanced(pool.model).unwrap_or(0),
                replicas: self.router.replicas(pool.model).unwrap_or(0),
                queue_depths: self.router.queue_depths(pool.model).unwrap_or_default(),
                shards,
                swaps: pool.swaps(),
                scale_ups: pool.scale_ups(),
                scale_downs: pool.scale_downs(),
            });
        }
        models.sort_by_key(|m| m.model);
        PlaneSnapshot {
            models,
            rejected_unknown: self.rejected_unknown.load(Ordering::Relaxed),
            rejected_bad_shape: self.rejected_bad_shape.load(Ordering::Relaxed),
            uptime_secs: self.started.elapsed().as_secs_f64(),
        }
    }

    fn retired_shards(&self, pool: &ModelPool) -> Vec<ShardStats> {
        pool.retired.lock().unwrap().shards.clone()
    }

    /// Drain every pool and assemble the final [`ServerReport`] (same
    /// shape the batch server returns, so reporting tooling is shared).
    pub fn shutdown(&self) -> ServerReport {
        let mut per_model = HashMap::new();
        let mut modeled_designs = HashMap::new();
        let mut compiled = HashMap::new();
        for pool in &self.pools {
            let mut stats = pool.drain_all(&self.router);
            let (_accepted, shed) = self.router.counters(pool.model).unwrap_or((0, 0));
            stats.shed = shed;
            stats.rebalanced = self.router.rebalanced(pool.model).unwrap_or(0);
            per_model.insert(pool.model, stats);
            if let Some(m) = pool.modeled.lock().unwrap().clone() {
                modeled_designs.insert(pool.model, m);
            }
            if let Some(ci) = *pool.compiled.lock().unwrap() {
                compiled.insert(pool.model, ci);
            }
        }
        ServerReport {
            per_model,
            modeled_designs,
            compiled,
            stream_truth: HashMap::new(),
            wall: self.started.elapsed(),
        }
    }
}

/// Extras for [`serve_net`] beyond the ingestion listener.
pub struct NetServeOptions {
    /// Bound listener for the Prometheus metrics endpoint.
    pub metrics: Option<TcpListener>,
    /// Autoscaler policy; `None` keeps the initial replica count fixed.
    pub autoscale: Option<AutoscaleConfig>,
}

/// Run the serving plane on a bound listener until a SHUTDOWN frame
/// arrives, then drain everything and return the final report.
///
/// Thread layout: N connection readers -> one mpsc channel -> THIS
/// thread as the single dispatcher (upholding the SPSC single-producer
/// contract for every route), plus the optional autoscaler and metrics
/// threads which never submit.
pub fn serve_net(
    cfg: &ServerConfig,
    listener: TcpListener,
    opts: NetServeOptions,
) -> Result<ServerReport> {
    let clamp = opts.autoscale.as_ref().map(|a| (a.min, a.max));
    let plane = Arc::new(ServingPlane::new(cfg, clamp)?);
    let (tx, rx) = mpsc::channel::<Frame>();
    let stop = Arc::new(AtomicBool::new(false));
    let acceptor = net::spawn_acceptor(listener, tx, stop.clone());
    let metrics = opts
        .metrics
        .map(|l| super::metrics_http::MetricsServer::start(l, plane.clone()));
    let scaler = opts.autoscale.map(|a| Scaler::start(a, plane.clone()));
    // the dispatcher loop: the ONE producer thread for every route
    while let Ok(frame) = rx.recv() {
        match frame {
            Frame::Event(ev) => {
                // Shed/UnknownModel/BadShape are all counted; the
                // dispatcher itself never blocks and never stops
                let _ = plane.submit_net(ev);
            }
            Frame::Swap(req) => {
                if let Err(e) = plane.swap(&req) {
                    // a refused swap is an operator error, not a server
                    // failure: log and keep serving on the old plan
                    eprintln!("plan swap refused: {e:#}");
                }
            }
            Frame::Shutdown => break,
        }
    }
    stop.store(true, Ordering::Release);
    if let Some(s) = scaler {
        s.stop();
    }
    let report = plane.shutdown();
    if let Some(m) = metrics {
        m.stop();
    }
    let _ = acceptor.join();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::WeightsSource;
    use crate::nn::tensor::Mat;

    fn plane_cfg(backend: BackendKind) -> ServerConfig {
        ServerConfig {
            pipelines: vec![PipelineConfig {
                weights: WeightsSource::Synthetic(1),
                ..PipelineConfig::new("engine", backend)
            }],
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        }
    }

    fn net_event(id: u64, seq_len: usize, input_size: usize) -> NetEvent {
        let data: Vec<f32> = (0..seq_len * input_size)
            .map(|k| ((id as usize * 31 + k * 7) % 97) as f32 / 97.0 - 0.5)
            .collect();
        NetEvent {
            id,
            model: "engine".into(),
            x: Mat::from_vec(seq_len, input_size, data),
            label: Some((id % 2) as u8),
            stream_pos: None,
        }
    }

    fn engine_shape() -> (usize, usize) {
        let c = &crate::models::zoo::zoo_model("engine").unwrap().config;
        (c.seq_len, c.input_size)
    }

    #[test]
    fn plane_serves_submitted_events_and_reports() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Float), None).unwrap();
        let (sl, is) = engine_shape();
        for i in 0..200 {
            assert_eq!(plane.submit_net(net_event(i, sl, is)), Submit::Accepted);
        }
        let report = plane.shutdown();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted, 200);
        assert_eq!(s.shed, 0);
        assert_eq!(s.dropped, 0);
        assert!(s.online_auc().is_some(), "labels flow through the wire path");
    }

    #[test]
    fn unknown_model_and_bad_shape_are_counted_not_fatal() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Float), None).unwrap();
        let (sl, is) = engine_shape();
        let mut bogus = net_event(0, sl, is);
        bogus.model = "bogus".into();
        assert_eq!(plane.submit_net(bogus), Submit::UnknownModel);
        let misshapen = NetEvent {
            id: 1,
            model: "engine".into(),
            x: Mat::zeros(sl + 1, is),
            label: None,
            stream_pos: None,
        };
        assert_eq!(plane.submit_net(misshapen), Submit::BadShape);
        assert_eq!(plane.submit_net(net_event(2, sl, is)), Submit::Accepted);
        let snap = plane.snapshot();
        assert_eq!(snap.rejected_unknown, 1);
        assert_eq!(snap.rejected_bad_shape, 1);
        let report = plane.shutdown();
        assert_eq!(report.per_model["engine"].accepted, 1);
    }

    #[test]
    fn scaling_preserves_every_event_and_folds_retired_stats() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Float), None).unwrap();
        let pool = plane.pools()[0].clone();
        let (sl, is) = engine_shape();
        assert_eq!(pool.replicas(), 1);
        let mut sent = 0u64;
        for i in 0..100 {
            plane.submit_net(net_event(i, sl, is));
            sent += 1;
        }
        pool.scale_up(plane.router());
        pool.scale_up(plane.router());
        assert_eq!(pool.replicas(), 3);
        assert_eq!(plane.router().replicas("engine"), Some(3));
        for i in 100..220 {
            plane.submit_net(net_event(i, sl, is));
            sent += 1;
        }
        // scale back down: retired shards' events must not vanish
        assert!(pool.scale_down(plane.router()));
        assert!(pool.scale_down(plane.router()));
        assert_eq!(pool.replicas(), 1);
        assert!(!pool.scale_down(plane.router()), "refuses to go dark");
        for i in 220..260 {
            plane.submit_net(net_event(i, sl, is));
            sent += 1;
        }
        let report = plane.shutdown();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.shed, sent, "every event accounted");
        assert_eq!(s.dropped, 0, "scaling dropped nothing");
        assert_eq!(s.shed, 0, "1024-deep rings absorb this easily");
        // retired + final shards all present, ids unique
        let mut ids: Vec<usize> = s.shards.iter().map(|sh| sh.shard).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), s.shards.len(), "stable ids never collide");
        assert_eq!(s.shards.len(), 3, "three shards ever existed");
    }

    #[test]
    fn snapshot_exposes_live_pool_state() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Float), None).unwrap();
        let (sl, is) = engine_shape();
        for i in 0..50 {
            plane.submit_net(net_event(i, sl, is));
        }
        // quiesce: wait until the workers have scored everything
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let snap = plane.snapshot();
            let m = &snap.models[0];
            if m.scored() == 50 {
                assert_eq!(m.model, "engine");
                assert_eq!(m.replicas, 1);
                assert_eq!(m.router_accepted, 50);
                assert_eq!(m.dropped(), 0);
                assert_eq!(m.latency().count(), 50, "merged histogram sees all");
                assert_eq!(m.queue_depths.len(), 1);
                break;
            }
            assert!(Instant::now() < deadline, "workers never caught up");
            std::thread::yield_now();
        }
        plane.shutdown();
    }

    #[test]
    fn swap_needs_the_hls_backend() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Float), None).unwrap();
        let err = plane.swap(&PlanSwap {
            model: "engine".into(),
            precision: Some("block0.ffn1 ap_fixed<18,8>".into()),
            reuse: None,
        });
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("hls"));
        plane.shutdown();
    }

    #[test]
    fn bad_swap_is_refused_with_the_pool_untouched() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Hls), None).unwrap();
        let pool = plane.pools()[0].clone();
        let before = pool.replicas();
        // the saturating plan the static verifier refuses
        let err = plane.swap(&PlanSwap {
            model: "engine".into(),
            precision: Some("block1.ffn1 ap_fixed<2,1>".into()),
            reuse: None,
        });
        assert!(err.is_err(), "verifier must refuse the saturating plan");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("verification failed"), "{msg}");
        assert!(msg.contains("block1.ffn1"), "{msg}");
        assert_eq!(pool.replicas(), before, "no shard was drained");
        assert_eq!(pool.swaps(), 0);
        plane.shutdown();
    }

    #[test]
    fn hot_swap_drops_nothing_and_serves_on_the_new_plan() {
        let plane = ServingPlane::new(&plane_cfg(BackendKind::Hls), None).unwrap();
        let pool = plane.pools()[0].clone();
        let (sl, is) = engine_shape();
        for i in 0..60 {
            assert_eq!(plane.submit_net(net_event(i, sl, is)), Submit::Accepted);
        }
        // widening plan: verifier-clean
        plane
            .swap(&PlanSwap {
                model: "engine".into(),
                precision: Some("block0.ffn1 ap_fixed<18,8>".into()),
                reuse: Some("pool R2".into()),
            })
            .unwrap();
        assert_eq!(pool.swaps(), 1);
        assert_eq!(pool.replicas(), 1, "rolling swap restores the width");
        for i in 60..120 {
            assert_eq!(plane.submit_net(net_event(i, sl, is)), Submit::Accepted);
        }
        let report = plane.shutdown();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted, 120, "swap drained, nothing lost");
        assert_eq!(s.dropped, 0);
        assert_eq!(s.shed, 0);
        // the modeled design reflects the NEW plans
        let modeled = report.modeled_designs.get("engine").expect("hls design");
        assert!(modeled.plan.summary().contains("mixed"), "{}", modeled.plan.summary());
        assert!(
            modeled.parallelism.summary().contains("mixed"),
            "{}",
            modeled.parallelism.summary()
        );
        // pre-swap shard 0 retired, post-swap shard 1 retired at shutdown
        let ids: Vec<usize> = s.shards.iter().map(|sh| sh.shard).collect();
        assert_eq!(ids, vec![0, 1]);
    }
}
