//! Bounded single-producer / single-consumer ring — the event hot path.
//!
//! Lock-free (two cache-padded atomic cursors over a power-of-two slot
//! array), because the trigger source -> batcher handoff is the most
//! frequent operation in the whole coordinator.  Safety argument: the
//! producer only writes `tail` and reads `head`; the consumer only
//! writes `head` and reads `tail`; slot `i` is written exactly once
//! between the producer observing `i - cap < head` and the consumer
//! observing `i < tail`, with Acquire/Release ordering establishing the
//! happens-before edge on the slot contents.

use crossbeam_utils::CachePadded;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

struct Inner<T> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: u64,
    head: CachePadded<AtomicU64>, // next slot to pop
    tail: CachePadded<AtomicU64>, // next slot to push
    closed: AtomicBool,
}

// SAFETY AUDIT: `UnsafeCell<MaybeUninit<T>>` suppresses the auto impls,
// but `Inner` is only ever shared between exactly one Producer and one
// Consumer (the halves are not Clone), and every slot access goes through
// the cursor protocol below: a slot is touched by at most one thread at a
// time, with the Release store on the advancing cursor publishing the
// write to the Acquire load on the other side.  `T: Send` is required
// because items physically move across the thread boundary; no `T: Sync`
// is needed because no `&T` is ever shared.
unsafe impl<T: Send> Send for Inner<T> {}
// SAFETY AUDIT: see the Send impl — `&Inner` is shared across the two
// halves' threads, but all mutation funnels through the atomics plus the
// single-owner slot protocol, never through aliased `&mut T`.
unsafe impl<T: Send> Sync for Inner<T> {}

/// Create a ring of capacity `cap` (rounded up to a power of two).
pub fn ring<T: Send>(cap: usize) -> (Producer<T>, Consumer<T>) {
    let cap = cap.next_power_of_two().max(2);
    let slots: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(Inner {
        slots,
        mask: cap as u64 - 1,
        head: CachePadded::new(AtomicU64::new(0)),
        tail: CachePadded::new(AtomicU64::new(0)),
        closed: AtomicBool::new(false),
    });
    (Producer { inner: inner.clone() }, Consumer { inner })
}

/// Convenience alias used in module docs/tests.
pub type SpscRing = ();

/// Producing half (single thread only).
pub struct Producer<T> {
    inner: Arc<Inner<T>>,
}

/// Consuming half (single thread only).
pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
}

impl<T: Send> Producer<T> {
    /// Try to push; returns the item back if the ring is full (the
    /// caller decides the backpressure policy: drop / retry / block).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let tail = self.inner.tail.load(Ordering::Relaxed);
        let head = self.inner.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.inner.mask {
            return Err(item); // full
        }
        let idx = (tail & self.inner.mask) as usize;
        // SAFETY AUDIT: slot `tail & mask` is exclusively ours right now:
        // the consumer only reads slots with index < tail (Acquire-loaded
        // below its pop), and this slot's previous occupant was popped —
        // the Acquire load of `head` above observed `tail - cap < head`,
        // so the consumer's Release store after reading it happens-before
        // this write.  `write` on MaybeUninit does not drop any previous
        // value, which is correct: the slot is conceptually uninitialized
        // (its old item was moved out by `assume_init_read`).
        unsafe {
            (*self.inner.slots[idx].get()).write(item);
        }
        self.inner.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Mark the stream finished (consumer's `pop` will drain then None).
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
    }

    pub fn is_full(&self) -> bool {
        let tail = self.inner.tail.load(Ordering::Relaxed);
        let head = self.inner.head.load(Ordering::Acquire);
        tail.wrapping_sub(head) > self.inner.mask
    }

    /// Events currently queued (a snapshot: the consumer may drain
    /// concurrently).  The router uses this as the load signal when
    /// overflowing a full round-robin shard to the least-loaded one.
    pub fn len(&self) -> usize {
        let tail = self.inner.tail.load(Ordering::Relaxed);
        let head = self.inner.head.load(Ordering::Acquire);
        tail.wrapping_sub(head) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T: Send> Consumer<T> {
    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let head = self.inner.head.load(Ordering::Relaxed);
        let tail = self.inner.tail.load(Ordering::Acquire);
        if head == tail {
            return None; // empty
        }
        let idx = (head & self.inner.mask) as usize;
        // SAFETY AUDIT: `head < tail` was just established with an
        // Acquire load of `tail`, so the producer's `write` to this slot
        // (sequenced before its Release store of `tail`) happens-before
        // this read — the slot is initialized.  `assume_init_read` moves
        // the item out exactly once: the Release store of `head + 1`
        // below transfers the now-vacant slot back to the producer, and
        // no other pop can observe this `head` value (single consumer).
        let item = unsafe { (*self.inner.slots[idx].get()).assume_init_read() };
        self.inner.head.store(head.wrapping_add(1), Ordering::Release);
        Some(item)
    }

    /// Spin-then-yield pop; returns None only after close + drain.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            if let Some(v) = self.try_pop() {
                return Some(v);
            }
            if self.inner.closed.load(Ordering::Acquire) {
                // racy final drain: check once more after the close flag
                return self.try_pop();
            }
            spins += 1;
            if spins < 16 {
                std::hint::spin_loop();
            } else if spins < 4096 {
                std::thread::yield_now();
            } else {
                // long-idle consumer: sleep briefly so single-core hosts
                // give the producers a full quantum
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }

    pub fn len(&self) -> usize {
        let head = self.inner.head.load(Ordering::Relaxed);
        let tail = self.inner.tail.load(Ordering::Acquire);
        tail.wrapping_sub(head) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        // drain remaining initialized slots so T's destructors run
        let mut head = self.inner.head.load(Ordering::Relaxed);
        let tail = self.inner.tail.load(Ordering::Acquire);
        while head != tail {
            let idx = (head & self.inner.mask) as usize;
            // SAFETY AUDIT: every slot in `[head, tail)` holds an item
            // the producer published (Acquire load of `tail` above) and
            // no pop consumed; `&mut self` proves the consumer thread is
            // done popping, so each slot is dropped exactly once.  If the
            // producer outlives us it can refill these slots — `write`
            // does not double-drop — and the final Release store of
            // `head` keeps its full/empty arithmetic coherent.
            unsafe {
                (*self.inner.slots[idx].get()).assume_init_drop();
            }
            head = head.wrapping_add(1);
        }
        self.inner.head.store(head, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let (p, c) = ring::<u32>(4);
        for i in 0..4 {
            p.try_push(i).unwrap();
        }
        assert!(p.try_push(99).is_err(), "full ring rejects");
        for i in 0..4 {
            assert_eq!(c.try_pop(), Some(i));
        }
        assert_eq!(c.try_pop(), None);
    }

    #[test]
    fn producer_len_tracks_occupancy() {
        let (p, c) = ring::<u32>(8);
        assert!(p.is_empty());
        p.try_push(1).unwrap();
        p.try_push(2).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(c.try_pop(), Some(1));
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let (p, c) = ring::<u8>(3);
        for i in 0..4 {
            p.try_push(i).unwrap(); // cap 4
        }
        assert!(p.try_push(9).is_err());
        drop(c);
    }

    #[test]
    fn close_drains_then_none() {
        let (p, c) = ring::<u32>(8);
        p.try_push(1).unwrap();
        p.close();
        assert_eq!(c.pop_blocking(), Some(1));
        assert_eq!(c.pop_blocking(), None);
    }

    #[test]
    fn cross_thread_stress_preserves_sequence() {
        let (p, c) = ring::<u64>(64);
        let n = 100_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                let mut item = i;
                loop {
                    match p.try_push(item) {
                        Ok(()) => break,
                        Err(back) => {
                            item = back;
                            std::hint::spin_loop();
                        }
                    }
                }
            }
            p.close();
        });
        let mut expected = 0u64;
        while let Some(v) = c.pop_blocking() {
            assert_eq!(v, expected);
            expected += 1;
        }
        producer.join().unwrap();
        assert_eq!(expected, n);
    }

    #[test]
    fn interleaved_push_pop_stress_over_a_tiny_ring() {
        // the cursor protocol's worst case: a capacity-2 ring, so nearly
        // every push lands in a slot the consumer *just* vacated and
        // every happens-before edge in the safety audit is exercised
        // constantly; heap-owning items let miri catch any double-drop,
        // leak or uninitialized read the interleaving could produce
        let (p, c) = ring::<Box<u64>>(2);
        let n: u64 = if cfg!(miri) { 400 } else { 40_000 };
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                let mut item = Box::new(i);
                loop {
                    match p.try_push(item) {
                        Ok(()) => break,
                        Err(back) => {
                            item = back;
                            std::thread::yield_now();
                        }
                    }
                }
                // stutter the producer so the consumer alternates between
                // seeing a full, half-full and empty ring
                if i % 3 == 0 {
                    std::thread::yield_now();
                }
            }
            p.close();
        });
        let mut expected = 0u64;
        let mut checksum = 0u64;
        while let Some(v) = c.pop_blocking() {
            assert_eq!(*v, expected, "FIFO order violated");
            checksum = checksum.wrapping_add(*v);
            expected += 1;
        }
        producer.join().unwrap();
        assert_eq!(expected, n, "every pushed item must be popped exactly once");
        assert_eq!(checksum, n * (n - 1) / 2);
    }

    #[test]
    fn drop_runs_destructors() {
        use std::sync::atomic::AtomicUsize;
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        #[derive(Debug)]
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let (p, c) = ring::<D>(8);
        p.try_push(D).unwrap();
        p.try_push(D).unwrap();
        drop(p);
        drop(c);
        assert_eq!(DROPS.load(Ordering::SeqCst), 2);
    }
}
