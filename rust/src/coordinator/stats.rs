//! Per-pipeline counters and the aggregated serving report.

use crate::metrics::LatencyHistogram;

/// Counters for one model pipeline.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub accepted: u64,
    /// Events rejected at the source ring (backpressure drops).
    pub dropped: u64,
    pub batches: u64,
    pub batch_fill_sum: u64,
    pub latency: LatencyHistogram,
    /// Online classification accounting (when labels are known).
    pub scored_pos: Vec<f32>,
    pub scored_labels: Vec<u8>,
}

impl PipelineStats {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum as f64 / self.batches as f64
        }
    }

    /// Online AUC over the scored stream (when generated with labels).
    pub fn online_auc(&self) -> Option<f64> {
        if self.scored_labels.is_empty() {
            return None;
        }
        Some(crate::metrics::binary_auc(&self.scored_pos, &self.scored_labels))
    }

    pub fn merge(&mut self, other: &PipelineStats) {
        self.accepted += other.accepted;
        self.dropped += other.dropped;
        self.batches += other.batches;
        self.batch_fill_sum += other.batch_fill_sum;
        self.latency.merge(&other.latency);
        self.scored_pos.extend_from_slice(&other.scored_pos);
        self.scored_labels.extend_from_slice(&other.scored_labels);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_fill_mean() {
        let mut s = PipelineStats::default();
        s.batches = 2;
        s.batch_fill_sum = 12;
        assert_eq!(s.mean_batch_fill(), 6.0);
        assert_eq!(PipelineStats::default().mean_batch_fill(), 0.0);
    }

    #[test]
    fn online_auc_none_without_labels() {
        assert!(PipelineStats::default().online_auc().is_none());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PipelineStats::default();
        a.accepted = 3;
        let mut b = PipelineStats::default();
        b.accepted = 4;
        b.dropped = 1;
        b.scored_pos.push(0.9);
        b.scored_labels.push(1);
        a.merge(&b);
        assert_eq!(a.accepted, 7);
        assert_eq!(a.dropped, 1);
        assert_eq!(a.scored_pos.len(), 1);
    }
}
