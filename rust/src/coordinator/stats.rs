//! Per-pipeline counters and the aggregated serving report.
//!
//! With sharded worker pools each replica (shard) keeps its own local
//! [`PipelineStats`]; the server folds them into one per-model total via
//! [`PipelineStats::absorb_shard`], which also records a [`ShardStats`]
//! snapshot per replica so pool imbalance is visible in the report.
//!
//! Two distinct loss counters, never mixed:
//! - [`PipelineStats::shed`] — source-side: the router found every shard
//!   ring full and refused the event (it was never queued).
//! - [`PipelineStats::dropped`] — worker-side: the event was accepted
//!   onto a ring but its batch failed inference and was discarded.

use crate::metrics::LatencyHistogram;
use crate::stream::{ReuseCounters, WindowScore};

/// Per-replica (shard) accounting within one model's worker pool.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Shard index within the pool (stable id; dynamically spawned
    /// shards keep counting up, so ids are unique but not dense).
    pub shard: usize,
    pub accepted: u64,
    /// Worker-side batch-failure drops on this shard.
    pub dropped: u64,
    pub batches: u64,
    pub batch_fill_sum: u64,
    /// Stream windows this shard scored.
    pub windows: u64,
    /// This shard's incremental-reuse cache counters.
    pub reuse: ReuseCounters,
    pub latency: LatencyHistogram,
}

impl ShardStats {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum as f64 / self.batches as f64
        }
    }
}

/// Counters for one model pipeline (a whole worker pool).
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub accepted: u64,
    /// Events refused at the source: the router found every shard ring
    /// full (backpressure shed; the event never reached a worker).
    pub shed: u64,
    /// Events accepted onto a ring but discarded worker-side because
    /// their batch failed inference.  Disjoint from `shed`.
    pub dropped: u64,
    /// Events that overflowed their round-robin shard and were accepted
    /// by the least-loaded one instead (pool imbalance signal; always 0
    /// for a single-replica pipeline).
    pub rebalanced: u64,
    pub batches: u64,
    pub batch_fill_sum: u64,
    pub latency: LatencyHistogram,
    /// Online classification accounting (when labels are known).
    pub scored_pos: Vec<f32>,
    pub scored_labels: Vec<u8>,
    /// Per-window records of stream-mode ingestion (empty for pre-cut
    /// event sources).  Fed to `stream::analyze` for trigger clustering;
    /// order is per-shard arrival order, NOT stream order — the analyzer
    /// sorts.
    pub windows: Vec<WindowScore>,
    /// Incremental cross-window reuse accounting for stream-mode
    /// ingestion (all-zero for pre-cut event sources or with reuse
    /// disabled); folded across shard caches.
    pub reuse: ReuseCounters,
    /// Per-shard view of the pool (empty on worker-local stats; one
    /// entry per replica after server aggregation).
    pub shards: Vec<ShardStats>,
}

impl PipelineStats {
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum as f64 / self.batches as f64
        }
    }

    /// Total events lost on either side of the rings (source shed +
    /// worker drop); `accepted + lost()` accounts for every submitted
    /// event.
    pub fn lost(&self) -> u64 {
        self.shed + self.dropped
    }

    /// Online AUC over the scored stream (when generated with labels).
    /// Rank-based and therefore independent of the shard interleaving
    /// order the scores arrived in.
    pub fn online_auc(&self) -> Option<f64> {
        if self.scored_labels.is_empty() {
            return None;
        }
        Some(crate::metrics::binary_auc(&self.scored_pos, &self.scored_labels))
    }

    /// The [`ShardStats`] view of this worker-local stats block — what
    /// `absorb_shard` records and what a live shard publishes for the
    /// metrics endpoint while still serving.
    pub fn shard_snapshot(&self, shard: usize) -> ShardStats {
        ShardStats {
            shard,
            accepted: self.accepted,
            dropped: self.dropped,
            batches: self.batches,
            batch_fill_sum: self.batch_fill_sum,
            windows: self.windows.len() as u64,
            reuse: self.reuse,
            latency: self.latency.clone(),
        }
    }

    /// Fold one replica's worker-local stats into this per-model total,
    /// recording the shard-level snapshot (including the shard's window
    /// count and reuse counters, so per-shard stream imbalance stays
    /// visible after aggregation).
    pub fn absorb_shard(&mut self, shard: usize, s: &PipelineStats) {
        self.shards.push(s.shard_snapshot(shard));
        self.accepted += s.accepted;
        self.shed += s.shed;
        self.dropped += s.dropped;
        self.rebalanced += s.rebalanced;
        self.batches += s.batches;
        self.batch_fill_sum += s.batch_fill_sum;
        self.latency.merge(&s.latency);
        self.scored_pos.extend_from_slice(&s.scored_pos);
        self.scored_labels.extend_from_slice(&s.scored_labels);
        self.windows.extend_from_slice(&s.windows);
        self.reuse.merge(&s.reuse);
    }

    pub fn merge(&mut self, other: &PipelineStats) {
        self.accepted += other.accepted;
        self.shed += other.shed;
        self.dropped += other.dropped;
        self.rebalanced += other.rebalanced;
        self.batches += other.batches;
        self.batch_fill_sum += other.batch_fill_sum;
        self.latency.merge(&other.latency);
        self.scored_pos.extend_from_slice(&other.scored_pos);
        self.scored_labels.extend_from_slice(&other.scored_labels);
        self.windows.extend_from_slice(&other.windows);
        self.reuse.merge(&other.reuse);
        self.shards.extend(other.shards.iter().cloned());
    }
}

/// Cumulative snapshot a *live* shard worker publishes after every batch
/// so the metrics endpoint can scrape mid-run state without touching the
/// worker's hot-path stats.
#[derive(Debug, Default)]
pub struct ShardLive {
    snapshot: std::sync::Mutex<ShardStats>,
}

impl ShardLive {
    pub fn new(shard: usize) -> Self {
        Self {
            snapshot: std::sync::Mutex::new(ShardStats { shard, ..ShardStats::default() }),
        }
    }

    pub fn publish(&self, s: ShardStats) {
        *self.snapshot.lock().unwrap() = s;
    }

    pub fn snapshot(&self) -> ShardStats {
        self.snapshot.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_fill_mean() {
        let mut s = PipelineStats::default();
        s.batches = 2;
        s.batch_fill_sum = 12;
        assert_eq!(s.mean_batch_fill(), 6.0);
        assert_eq!(PipelineStats::default().mean_batch_fill(), 0.0);
    }

    #[test]
    fn online_auc_none_without_labels() {
        assert!(PipelineStats::default().online_auc().is_none());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PipelineStats::default();
        a.accepted = 3;
        let mut b = PipelineStats::default();
        b.accepted = 4;
        b.shed = 1;
        b.dropped = 2;
        b.scored_pos.push(0.9);
        b.scored_labels.push(1);
        a.merge(&b);
        assert_eq!(a.accepted, 7);
        assert_eq!(a.shed, 1);
        assert_eq!(a.dropped, 2);
        assert_eq!(a.lost(), 3);
        assert_eq!(a.scored_pos.len(), 1);
    }

    #[test]
    fn shed_and_dropped_stay_distinct() {
        // the accounting-overwrite regression: a pipeline can lose
        // events on BOTH sides of the rings at once, and neither counter
        // may clobber the other
        let mut total = PipelineStats::default();
        let mut worker = PipelineStats::default();
        worker.accepted = 90;
        worker.dropped = 10; // batch-failure drops, worker side
        total.absorb_shard(0, &worker);
        total.shed = 25; // router-side shed, set by the server fold
        assert_eq!(total.dropped, 10, "worker drops survive the fold");
        assert_eq!(total.shed, 25, "source shed is its own counter");
        assert_eq!(total.lost(), 35);
        assert_eq!(total.shards[0].dropped, 10, "per-shard drops recorded");
    }

    #[test]
    fn absorb_shard_sums_to_model_total() {
        let mut total = PipelineStats::default();
        for shard in 0..3usize {
            let mut s = PipelineStats::default();
            s.accepted = 10 + shard as u64;
            s.dropped = shard as u64;
            s.batches = 2;
            s.batch_fill_sum = 10 + shard as u64;
            s.latency.record(1000 * (shard as u64 + 1));
            s.scored_pos.push(0.5);
            s.scored_labels.push((shard % 2) as u8);
            s.windows.push(WindowScore {
                pos: 100 * shard as u64,
                score: 0.5,
                latency_ns: 900,
            });
            s.reuse.windows_incremental = 4;
            s.reuse.rows_reused = 40;
            s.reuse.cache_bytes = 1000 + shard as u64;
            total.absorb_shard(shard, &s);
        }
        assert_eq!(total.windows.len(), 3, "stream records fold across shards");
        assert_eq!(total.reuse.windows_incremental, 12, "reuse counters fold");
        assert_eq!(total.reuse.rows_reused, 120);
        assert_eq!(total.reuse.cache_bytes, 1002, "bytes high-water across shards");
        assert_eq!(total.accepted, 33);
        assert_eq!(total.dropped, 3);
        assert_eq!(total.batches, 6);
        assert_eq!(total.latency.count(), 3);
        assert_eq!(total.shards.len(), 3);
        assert_eq!(
            total.shards.iter().map(|s| s.accepted).sum::<u64>(),
            total.accepted
        );
        assert_eq!(
            total.shards.iter().map(|s| s.dropped).sum::<u64>(),
            total.dropped,
            "per-shard drops sum to the model total"
        );
        assert_eq!(
            total.shards.iter().map(|s| s.latency.count()).sum::<u64>(),
            total.latency.count()
        );
        // the snapshot-loss regression: window counts and reuse counters
        // must survive into the per-shard snapshots
        assert_eq!(
            total.shards.iter().map(|s| s.windows).sum::<u64>(),
            total.windows.len() as u64,
            "per-shard window counts carried through"
        );
        for (shard, sh) in total.shards.iter().enumerate() {
            assert_eq!(sh.windows, 1);
            assert_eq!(sh.reuse.windows_incremental, 4, "per-shard reuse kept");
            assert_eq!(sh.reuse.cache_bytes, 1000 + shard as u64);
        }
        assert_eq!(total.shards[2].shard, 2);
    }

    #[test]
    fn single_shard_absorb_is_identity_on_totals() {
        // replicas=1 must reproduce the unsharded accounting exactly
        let mut s = PipelineStats::default();
        s.accepted = 7;
        s.batches = 2;
        s.batch_fill_sum = 7;
        s.latency.record(500);
        s.latency.record(900);
        s.scored_pos.extend([0.1, 0.9]);
        s.scored_labels.extend([0, 1]);
        let mut total = PipelineStats::default();
        total.absorb_shard(0, &s);
        assert_eq!(total.accepted, s.accepted);
        assert_eq!(total.batches, s.batches);
        assert_eq!(total.batch_fill_sum, s.batch_fill_sum);
        assert_eq!(total.latency.count(), s.latency.count());
        assert_eq!(total.latency.mean_ns(), s.latency.mean_ns());
        assert_eq!(total.scored_pos, s.scored_pos);
        assert_eq!(total.scored_labels, s.scored_labels);
        assert_eq!(total.online_auc(), s.online_auc());
        assert_eq!(total.shards.len(), 1);
    }

    #[test]
    fn shard_live_publishes_cumulative_snapshots() {
        let live = ShardLive::new(3);
        assert_eq!(live.snapshot().shard, 3);
        assert_eq!(live.snapshot().accepted, 0);
        let mut s = PipelineStats::default();
        s.accepted = 42;
        s.windows.push(WindowScore { pos: 0, score: 0.1, latency_ns: 10 });
        live.publish(s.shard_snapshot(3));
        let snap = live.snapshot();
        assert_eq!(snap.accepted, 42);
        assert_eq!(snap.windows, 1);
        assert_eq!(snap.shard, 3);
    }
}
