//! L3 trigger coordinator (S10) — the streaming server of the physics
//! use-cases the paper motivates (§I: "a refined online selection
//! system ... to efficiently process and triage data").
//!
//! Architecture (std threads; tokio is not in the offline crate set, and
//! a µs-latency trigger path is better served by dedicated threads than
//! an async scheduler anyway):
//!
//! ```text
//!  sources (N threads)           per-model pipeline
//!  ┌──────────────┐  SPSC ring   ┌─────────┐  batch  ┌───────────┐
//!  │ detector sim ├─────────────►│ batcher ├────────►│ inference │─► scores
//!  └──────────────┘  (bounded,   └─────────┘ (size/  └───────────┘   + stats
//!        ...          backpressure)           deadline)  backend:
//!                                                        hls-sim | nn | PJRT
//! ```

pub mod backend;
pub mod batcher;
pub mod event;
pub mod router;
pub mod server;
pub mod spsc;
pub mod stats;

pub use backend::{Backend, BackendKind};
pub use batcher::{BatchPolicy, Batcher};
pub use event::TriggerEvent;
pub use router::{Router, Submit};
pub use server::{PipelineConfig, ServerConfig, ServerReport, TriggerServer, WeightsSource};
pub use spsc::SpscRing;
