//! L3 trigger coordinator (S10) — the streaming server of the physics
//! use-cases the paper motivates (§I: "a refined online selection
//! system ... to efficiently process and triage data").
//!
//! Architecture (std threads; tokio is not in the offline crate set, and
//! a µs-latency trigger path is better served by dedicated threads than
//! an async scheduler anyway).  Each model is served by a **sharded
//! worker pool**: `replicas` independent SPSC rings, each consumed by
//! its own batcher+backend worker thread.  The router fans sources out
//! round-robin and overflows a momentarily-full shard to the
//! least-loaded one; only when every shard is full is the event shed.
//!
//! ```text
//!  sources (N threads)          per-model worker pool (replicas = R)
//!  ┌──────────────┐  round     ┌─ shard 0: ring ─ batcher ─ backend ─┐
//!  │ detector sim ├─ robin ───►├─ shard 1: ring ─ batcher ─ backend ─┤─► scores
//!  └──────────────┘  + least-  │    ...                              │   + shard
//!        ...         loaded    └─ shard R-1: ring ─ batcher ─ backend┘   stats
//!                    overflow    (bounded rings, shed when all full)
//! ```
//!
//! `replicas = 1` reproduces the original single-worker pipeline
//! bit-for-bit; the `e2e_serving` bench sweeps 1/2/4/8 replicas at a
//! fixed offered load to measure pool scaling.  Per-shard accounting
//! ([`stats::ShardStats`]) folds into the per-model [`PipelineStats`]
//! report.
//!
//! Ingestion comes in two modes ([`SourceMode`]): pre-cut labeled zoo
//! events (the seed behavior), or **continuous-stream** ingestion — a
//! [`crate::data::gw::StrainStream`] windowized in the source thread
//! ([`crate::stream::Windowizer`]), with the router consuming windows
//! through the same SPSC backpressure path and workers recording
//! per-window scores for trigger clustering (`crate::stream::analyze`).
//!
//! On top of the batch server sits the **network serving plane**
//! (`repro serve --listen`): external producers speak the
//! length-prefixed TCP framing of [`net`], every connection funnels into
//! ONE dispatcher thread (preserving the rings' single-producer
//! contract), and each model's pool becomes *elastic* — the [`scaler`]
//! reconcile loop grows/shrinks the shard set between `--autoscale
//! min..max` on queue depth and p99, and [`pool`] performs zero-drop hot
//! plan swaps (spawn replacement on the newly verified+compiled plan,
//! then drain the old shard, one at a time).  [`metrics_http`] exposes
//! the whole thing as Prometheus text built verbatim on
//! [`crate::metrics::LatencyHistogram`] buckets.

pub mod backend;
pub mod batcher;
pub mod event;
pub mod metrics_http;
pub mod net;
pub mod pool;
pub mod router;
pub mod scaler;
pub mod server;
pub mod spsc;
pub mod stats;

pub use backend::{Backend, BackendKind, BackendWindowCache};
pub use batcher::{BatchPolicy, Batcher};
pub use event::TriggerEvent;
pub use metrics_http::{render_prometheus, MetricsServer};
pub use net::{Frame, NetEvent, PlanSwap};
pub use pool::{serve_net, ModelPool, NetServeOptions, PlaneSnapshot, ServingPlane};
pub use router::{Router, Submit};
pub use scaler::{parse_autoscale, AutoscaleConfig, Scaler};
pub use server::{
    PipelineConfig, ServerConfig, ServerReport, SourceMode, StreamSource, TriggerServer,
    WeightsSource,
};
pub use spsc::SpscRing;
pub use stats::{PipelineStats, ShardStats};
