//! Scrapeable metrics endpoint: Prometheus text exposition (version
//! 0.0.4) rendered straight off a [`PlaneSnapshot`], served by a tiny
//! single-threaded HTTP responder.
//!
//! The latency histogram is exported VERBATIM from
//! [`LatencyHistogram`]'s log2 buckets: each finite bucket's `le` label
//! is its *inclusive* integer upper edge in nanoseconds
//! (`LatencyHistogram::bucket_upper_edge_ns`), the last bucket is
//! `+Inf`, and the cumulative counts are exact — a scrape aggregator
//! merging several planes sees the same algebra the in-process
//! [`LatencyHistogram::merge`] implements (commutative + associative;
//! pinned by the histogram property tests).
//!
//! Metric families (all prefixed `repro_`):
//!
//! | family | type | labels |
//! |---|---|---|
//! | `repro_uptime_seconds` | gauge | — |
//! | `repro_events_rejected_unknown_model_total` | counter | — |
//! | `repro_events_rejected_bad_shape_total` | counter | — |
//! | `repro_events_accepted_total` | counter | model |
//! | `repro_events_shed_total` | counter | model |
//! | `repro_events_rebalanced_total` | counter | model |
//! | `repro_events_scored_total` | counter | model |
//! | `repro_events_dropped_total` | counter | model |
//! | `repro_batches_total` | counter | model |
//! | `repro_windows_total` | counter | model |
//! | `repro_reuse_windows_incremental_total` | counter | model |
//! | `repro_reuse_rows_reused_total` | counter | model |
//! | `repro_plan_swaps_total` | counter | model |
//! | `repro_scale_ups_total` | counter | model |
//! | `repro_scale_downs_total` | counter | model |
//! | `repro_shards` | gauge | model |
//! | `repro_shard_queue_depth` | gauge | model, shard |
//! | `repro_shard_scored_total` | counter | model, shard |
//! | `repro_shard_dropped_total` | counter | model, shard |
//! | `repro_event_latency_ns` | histogram | model |
//!
//! `accepted` counts router-side queueing; `scored` counts worker-side
//! completions — under load the two differ by exactly the in-flight
//! queue depth, and `accepted == scored + dropped` once drained.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::pool::{PlaneSnapshot, ServingPlane};
use crate::metrics::LatencyHistogram;

/// Render one snapshot as Prometheus text exposition 0.0.4.
pub fn render_prometheus(snap: &PlaneSnapshot) -> String {
    let mut out = String::with_capacity(4096);
    let mut family = |out: &mut String, name: &str, kind: &str, help: &str| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
    };

    family(&mut out, "repro_uptime_seconds", "gauge", "Seconds since the plane started.");
    let _ = writeln!(out, "repro_uptime_seconds {:.3}", snap.uptime_secs);
    family(
        &mut out,
        "repro_events_rejected_unknown_model_total",
        "counter",
        "Events refused: no pool serves the named model.",
    );
    let _ = writeln!(
        out,
        "repro_events_rejected_unknown_model_total {}",
        snap.rejected_unknown
    );
    family(
        &mut out,
        "repro_events_rejected_bad_shape_total",
        "counter",
        "Events refused: matrix shape mismatched the model.",
    );
    let _ = writeln!(
        out,
        "repro_events_rejected_bad_shape_total {}",
        snap.rejected_bad_shape
    );

    // per-model counter families, each rendered for every model under
    // one HELP/TYPE header (exposition requires families be contiguous)
    struct F {
        name: &'static str,
        kind: &'static str,
        help: &'static str,
        get: fn(&super::pool::ModelSnapshot) -> u64,
    }
    let families = [
        F {
            name: "repro_events_accepted_total",
            kind: "counter",
            help: "Events the router queued onto a shard ring.",
            get: |m| m.router_accepted,
        },
        F {
            name: "repro_events_shed_total",
            kind: "counter",
            help: "Events shed at the router: every shard ring full.",
            get: |m| m.shed,
        },
        F {
            name: "repro_events_rebalanced_total",
            kind: "counter",
            help: "Events accepted by a non-round-robin shard under backpressure.",
            get: |m| m.rebalanced,
        },
        F {
            name: "repro_events_scored_total",
            kind: "counter",
            help: "Events scored by the workers (retired + live shards).",
            get: |m| m.scored(),
        },
        F {
            name: "repro_events_dropped_total",
            kind: "counter",
            help: "Events dropped worker-side: their batch failed inference.",
            get: |m| m.dropped(),
        },
        F {
            name: "repro_batches_total",
            kind: "counter",
            help: "Batches executed across all shards.",
            get: |m| m.shards.iter().map(|s| s.batches).sum(),
        },
        F {
            name: "repro_windows_total",
            kind: "counter",
            help: "Stream windows scored across all shards.",
            get: |m| m.shards.iter().map(|s| s.windows).sum(),
        },
        F {
            name: "repro_reuse_windows_incremental_total",
            kind: "counter",
            help: "Stream windows served through the incremental-reuse path.",
            get: |m| m.shards.iter().map(|s| s.reuse.windows_incremental).sum(),
        },
        F {
            name: "repro_reuse_rows_reused_total",
            kind: "counter",
            help: "Prefix token rows carried over between overlapping windows.",
            get: |m| m.shards.iter().map(|s| s.reuse.rows_reused).sum(),
        },
        F {
            name: "repro_plan_swaps_total",
            kind: "counter",
            help: "Completed zero-drop hot plan swaps.",
            get: |m| m.swaps,
        },
        F {
            name: "repro_scale_ups_total",
            kind: "counter",
            help: "Autoscaler scale-up steps taken.",
            get: |m| m.scale_ups,
        },
        F {
            name: "repro_scale_downs_total",
            kind: "counter",
            help: "Autoscaler scale-down steps taken.",
            get: |m| m.scale_downs,
        },
    ];
    for f in &families {
        family(&mut out, f.name, f.kind, f.help);
        for m in &snap.models {
            let _ = writeln!(out, "{}{{model=\"{}\"}} {}", f.name, m.model, (f.get)(m));
        }
    }

    family(&mut out, "repro_shards", "gauge", "Live worker shards in the pool.");
    for m in &snap.models {
        let _ = writeln!(out, "repro_shards{{model=\"{}\"}} {}", m.model, m.replicas);
    }
    family(
        &mut out,
        "repro_shard_queue_depth",
        "gauge",
        "Events queued on one shard's ring right now.",
    );
    for m in &snap.models {
        for &(id, depth) in &m.queue_depths {
            let _ = writeln!(
                out,
                "repro_shard_queue_depth{{model=\"{}\",shard=\"{id}\"}} {depth}",
                m.model
            );
        }
    }
    family(
        &mut out,
        "repro_shard_scored_total",
        "counter",
        "Events scored per shard (retired + live).",
    );
    for m in &snap.models {
        for s in &m.shards {
            let _ = writeln!(
                out,
                "repro_shard_scored_total{{model=\"{}\",shard=\"{}\"}} {}",
                m.model, s.shard, s.accepted
            );
        }
    }
    family(
        &mut out,
        "repro_shard_dropped_total",
        "counter",
        "Events dropped per shard (batch inference failures).",
    );
    for m in &snap.models {
        for s in &m.shards {
            let _ = writeln!(
                out,
                "repro_shard_dropped_total{{model=\"{}\",shard=\"{}\"}} {}",
                m.model, s.shard, s.dropped
            );
        }
    }

    // the latency histogram, straight off LatencyHistogram's buckets:
    // le labels are the INCLUSIVE integer edges, cumulative counts
    family(
        &mut out,
        "repro_event_latency_ns",
        "histogram",
        "End-to-end event latency (arrival to scored), nanoseconds.",
    );
    for m in &snap.models {
        let h = m.latency();
        let mut cum = 0u64;
        for (i, &c) in h.bucket_counts().iter().enumerate() {
            cum += c;
            match LatencyHistogram::bucket_upper_edge_ns(i) {
                Some(edge) => {
                    let _ = writeln!(
                        out,
                        "repro_event_latency_ns_bucket{{model=\"{}\",le=\"{edge}\"}} {cum}",
                        m.model
                    );
                }
                None => {
                    let _ = writeln!(
                        out,
                        "repro_event_latency_ns_bucket{{model=\"{}\",le=\"+Inf\"}} {cum}",
                        m.model
                    );
                }
            }
        }
        let _ = writeln!(
            out,
            "repro_event_latency_ns_sum{{model=\"{}\"}} {}",
            m.model,
            h.sum_ns()
        );
        let _ = writeln!(
            out,
            "repro_event_latency_ns_count{{model=\"{}\"}} {}",
            m.model,
            h.count()
        );
    }
    out
}

/// Minimal HTTP responder for scrapes: accepts serially, answers any GET
/// with the current exposition.  Not a general web server — one scrape
/// every few seconds is the design load.
pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    join: std::thread::JoinHandle<()>,
}

impl MetricsServer {
    pub fn start(listener: TcpListener, plane: Arc<ServingPlane>) -> Self {
        listener.set_nonblocking(true).expect("nonblocking listener");
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let join = std::thread::spawn(move || {
            while !stop_t.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((mut stream, _peer)) => {
                        stream
                            .set_read_timeout(Some(Duration::from_secs(2)))
                            .ok();
                        if let Err(e) = respond(&mut stream, &plane) {
                            eprintln!("metrics: scrape failed: {e}");
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => {
                        eprintln!("metrics: accept failed: {e}");
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        });
        Self { stop, join }
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Release);
        let _ = self.join.join();
    }
}

fn respond(stream: &mut std::net::TcpStream, plane: &ServingPlane) -> std::io::Result<()> {
    // read until the header terminator (cap 8 KiB — a scrape request is
    // one line plus a few headers)
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(()); // peer closed before finishing the request
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let request = String::from_utf8_lossy(&buf);
    let is_get = request.lines().next().is_some_and(|l| l.starts_with("GET "));
    let (status, body) = if is_get {
        ("200 OK", render_prometheus(&plane.snapshot()))
    } else {
        ("405 Method Not Allowed", String::from("metrics endpoint: GET only\n"))
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; \
         charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::{ModelSnapshot, PlaneSnapshot};
    use crate::coordinator::stats::ShardStats;
    use std::collections::HashMap;

    fn snapshot_with_latencies(ns: &[u64]) -> (PlaneSnapshot, LatencyHistogram) {
        let mut h = LatencyHistogram::new();
        let mut shard = ShardStats { shard: 0, ..ShardStats::default() };
        for &v in ns {
            h.record(v);
            shard.latency.record(v);
            shard.accepted += 1;
        }
        shard.batches = 3;
        let snap = PlaneSnapshot {
            models: vec![ModelSnapshot {
                model: "engine",
                router_accepted: ns.len() as u64,
                shed: 2,
                rebalanced: 1,
                replicas: 1,
                queue_depths: vec![(0, 4)],
                shards: vec![shard],
                swaps: 1,
                scale_ups: 2,
                scale_downs: 1,
            }],
            rejected_unknown: 3,
            rejected_bad_shape: 0,
            uptime_secs: 1.5,
        };
        (snap, h)
    }

    /// Parse exposition text into (name, labels, value) samples,
    /// validating the line grammar as we go.
    fn parse(text: &str) -> Vec<(String, String, f64)> {
        let mut samples = Vec::new();
        let mut typed: HashMap<String, String> = HashMap::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap().to_string();
                let kind = it.next().unwrap().to_string();
                assert!(
                    ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                    "bad TYPE: {line}"
                );
                assert!(typed.insert(name, kind).is_none(), "duplicate TYPE: {line}");
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let (head, value) = line.rsplit_once(' ').unwrap_or_else(|| {
                panic!("sample line without value: {line}")
            });
            let value: f64 = value.parse().unwrap_or_else(|_| {
                panic!("non-numeric value in: {line}")
            });
            let (name, labels) = match head.split_once('{') {
                Some((n, l)) => {
                    assert!(l.ends_with('}'), "unclosed labels: {line}");
                    (n.to_string(), l[..l.len() - 1].to_string())
                }
                None => (head.to_string(), String::new()),
            };
            // every sample belongs to a declared family (histogram
            // samples map to their base name)
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .filter(|b| typed.contains_key(*b))
                .unwrap_or(&name);
            assert!(typed.contains_key(base), "sample without TYPE: {line}");
            // counters end in _total (histogram series are exempt)
            if typed.get(base).map(String::as_str) == Some("counter") {
                assert!(name.ends_with("_total"), "counter without _total: {line}");
            }
            samples.push((name, labels, value));
        }
        samples
    }

    #[test]
    fn exposition_is_valid_and_buckets_match_the_histogram_exactly() {
        let ns = [100u64, 100, 900, 64, 63, 5_000_000, u64::MAX];
        let (snap, h) = snapshot_with_latencies(&ns);
        let text = render_prometheus(&snap);
        let samples = parse(&text);

        // pull the engine's bucket series back out
        let buckets: Vec<(String, f64)> = samples
            .iter()
            .filter(|(n, l, _)| {
                n == "repro_event_latency_ns_bucket" && l.contains("model=\"engine\"")
            })
            .map(|(_, l, v)| {
                let le = l
                    .split("le=\"")
                    .nth(1)
                    .and_then(|s| s.split('"').next())
                    .expect("le label")
                    .to_string();
                (le, *v)
            })
            .collect();
        assert_eq!(buckets.len(), LatencyHistogram::NUM_BUCKETS);

        // edges agree EXACTLY with the in-process histogram, cumulative
        // counts agree exactly with its bucket contents
        let mut cum = 0u64;
        for (i, (le, v)) in buckets.iter().enumerate() {
            cum += h.bucket_counts()[i];
            assert_eq!(*v, cum as f64, "cumulative count at bucket {i}");
            match LatencyHistogram::bucket_upper_edge_ns(i) {
                Some(edge) => assert_eq!(le, &edge.to_string(), "edge of bucket {i}"),
                None => assert_eq!(le, "+Inf"),
            }
        }
        // cumulative monotone + +Inf == _count
        for w in buckets.windows(2) {
            assert!(w[0].1 <= w[1].1, "bucket counts must be cumulative");
        }
        let count = samples
            .iter()
            .find(|(n, l, _)| n == "repro_event_latency_ns_count" && l.contains("engine"))
            .unwrap()
            .2;
        assert_eq!(count, ns.len() as f64);
        assert_eq!(buckets.last().unwrap().1, count, "+Inf equals _count");
        let sum = samples
            .iter()
            .find(|(n, l, _)| n == "repro_event_latency_ns_sum" && l.contains("engine"))
            .unwrap()
            .2;
        assert_eq!(sum, h.sum_ns() as f64, "sum matches (f64-rounded)");
    }

    #[test]
    fn counters_and_gauges_export_the_snapshot() {
        let (snap, _) = snapshot_with_latencies(&[1000, 2000]);
        let text = render_prometheus(&snap);
        let samples = parse(&text);
        let get = |name: &str, label_frag: &str| -> f64 {
            samples
                .iter()
                .find(|(n, l, _)| n == name && l.contains(label_frag))
                .unwrap_or_else(|| panic!("missing {name}{{{label_frag}}}"))
                .2
        };
        assert_eq!(get("repro_events_accepted_total", "engine"), 2.0);
        assert_eq!(get("repro_events_shed_total", "engine"), 2.0);
        assert_eq!(get("repro_events_scored_total", "engine"), 2.0);
        assert_eq!(get("repro_events_dropped_total", "engine"), 0.0);
        assert_eq!(get("repro_events_rebalanced_total", "engine"), 1.0);
        assert_eq!(get("repro_shards", "engine"), 1.0);
        assert_eq!(get("repro_shard_queue_depth", "shard=\"0\""), 4.0);
        assert_eq!(get("repro_shard_scored_total", "shard=\"0\""), 2.0);
        assert_eq!(get("repro_plan_swaps_total", "engine"), 1.0);
        assert_eq!(get("repro_scale_ups_total", "engine"), 2.0);
        assert_eq!(get("repro_scale_downs_total", "engine"), 1.0);
        assert_eq!(get("repro_batches_total", "engine"), 3.0);
        let unknowns = samples
            .iter()
            .find(|(n, _, _)| n == "repro_events_rejected_unknown_model_total")
            .unwrap()
            .2;
        assert_eq!(unknowns, 3.0);
    }

    #[test]
    fn empty_plane_renders_cleanly() {
        let snap = PlaneSnapshot {
            models: Vec::new(),
            rejected_unknown: 0,
            rejected_bad_shape: 0,
            uptime_secs: 0.0,
        };
        let text = render_prometheus(&snap);
        // still a valid exposition: families declared, no model samples
        let samples = parse(&text);
        assert!(samples
            .iter()
            .any(|(n, _, _)| n == "repro_uptime_seconds"));
        assert!(!samples
            .iter()
            .any(|(n, _, _)| n.starts_with("repro_event_latency_ns")));
    }
}
