//! Trigger events and scored results.

use crate::nn::tensor::Mat;
use std::time::Instant;

/// One detector event entering the trigger.
#[derive(Clone, Debug)]
pub struct TriggerEvent {
    /// Monotonic per-source sequence number.
    pub id: u64,
    /// Zoo model this event is routed to ("engine" / "btag" / "gw").
    pub model: &'static str,
    /// `(seq_len, input_size)` features.
    pub x: Mat,
    /// Ground truth when generated synthetically (for online AUC).
    pub label: Option<u8>,
    /// Arrival timestamp (latency accounting starts here).
    pub t_arrival: Instant,
}

impl TriggerEvent {
    pub fn new(id: u64, model: &'static str, x: Mat, label: Option<u8>) -> Self {
        Self { id, model, x, label, t_arrival: Instant::now() }
    }
}

/// A scored event leaving the trigger.
#[derive(Clone, Debug)]
pub struct ScoredEvent {
    pub id: u64,
    pub model: &'static str,
    /// Output probabilities.
    pub probs: Vec<f32>,
    /// Positive-class score (AUC convention).
    pub score: f32,
    pub label: Option<u8>,
    /// End-to-end latency in nanoseconds (arrival -> scored).
    pub latency_ns: u64,
    /// Batch this event was served in (diagnostics).
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_carries_payload() {
        let e = TriggerEvent::new(7, "engine", Mat::zeros(50, 1), Some(1));
        assert_eq!(e.id, 7);
        assert_eq!(e.model, "engine");
        assert_eq!(e.label, Some(1));
        assert!(e.t_arrival.elapsed().as_secs() < 1);
    }
}
