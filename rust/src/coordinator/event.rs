//! Trigger events and scored results.

use crate::nn::tensor::Mat;
use std::time::Instant;

/// One detector event entering the trigger.
#[derive(Clone, Debug)]
pub struct TriggerEvent {
    /// Monotonic per-source sequence number.
    pub id: u64,
    /// Zoo model this event is routed to ("engine" / "btag" / "gw").
    pub model: &'static str,
    /// `(seq_len, input_size)` features.
    pub x: Mat,
    /// Ground truth when generated synthetically (for online AUC).
    pub label: Option<u8>,
    /// For stream-mode ingestion: the absolute sample index of this
    /// window's first row.  `Some` makes the worker record a per-window
    /// `stream::WindowScore` so the trigger analyzer can cluster the
    /// scored stream; `None` (pre-cut events) keeps the seed behavior.
    pub stream_pos: Option<u64>,
    /// Arrival timestamp (latency accounting starts here).
    pub t_arrival: Instant,
}

impl TriggerEvent {
    pub fn new(id: u64, model: &'static str, x: Mat, label: Option<u8>) -> Self {
        Self { id, model, x, label, stream_pos: None, t_arrival: Instant::now() }
    }

    /// A window cut from a continuous stream at sample offset `pos`.
    /// Arrival time is *now* — the moment the window's last sample
    /// exists — so recorded latency is true latency-from-arrival.
    pub fn stream_window(id: u64, model: &'static str, x: Mat, pos: u64) -> Self {
        Self { id, model, x, label: None, stream_pos: Some(pos), t_arrival: Instant::now() }
    }
}

/// A scored event leaving the trigger.
#[derive(Clone, Debug)]
pub struct ScoredEvent {
    pub id: u64,
    pub model: &'static str,
    /// Output probabilities.
    pub probs: Vec<f32>,
    /// Positive-class score (AUC convention).
    pub score: f32,
    pub label: Option<u8>,
    /// End-to-end latency in nanoseconds (arrival -> scored).
    pub latency_ns: u64,
    /// Batch this event was served in (diagnostics).
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_carries_payload() {
        let e = TriggerEvent::new(7, "engine", Mat::zeros(50, 1), Some(1));
        assert_eq!(e.id, 7);
        assert_eq!(e.model, "engine");
        assert_eq!(e.label, Some(1));
        assert_eq!(e.stream_pos, None, "pre-cut events carry no stream position");
        assert!(e.t_arrival.elapsed().as_secs() < 1);
    }

    #[test]
    fn stream_window_carries_its_offset_and_no_label() {
        let e = TriggerEvent::stream_window(3, "engine", Mat::zeros(50, 1), 1250);
        assert_eq!(e.stream_pos, Some(1250));
        assert_eq!(e.label, None);
        assert_eq!(e.id, 3);
    }
}
