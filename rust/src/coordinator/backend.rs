//! Inference backends the coordinator can route batches to.
//!
//! * [`BackendKind::Float`] — exact-float Rust reference (no artifacts).
//! * [`BackendKind::Hls`] — the bit-accurate fixed-point HLS simulator
//!   (what the FPGA would compute); latency is dominated by simulation,
//!   the *modeled* FPGA latency comes from `synthesize()`.
//! * [`BackendKind::Pjrt`] — the AOT artifact through the PJRT CPU
//!   client (the production serving path of this reproduction).
//!
//! Backend *handles* are per-replica — in a sharded worker pool each
//! replica owns its own `Backend` value (and, for PJRT, its own client)
//! so pool scaling never serializes on a single inference engine — but
//! the heavy HLS state is not duplicated: a [`FixedTransformer`] clone
//! shares the site-quantized weights and the build-once
//! [`CompiledModel`] artifact behind `Arc`s, so R replicas of one model
//! hold R handles to one immutable compiled copy
//! ([`Backend::from_hls_engine`], checked by pointer equality in the
//! coordinator tests).

use anyhow::{Context, Result};
use std::sync::Arc;

use crate::hls::{
    CompiledModel, FixedTransformer, ParallelismPlan, PrecisionPlan, SynthesisReport,
};
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;
use crate::nn::tensor::Mat;
use crate::nn::{FloatTransformer, FloatWindowCache};
use crate::runtime::{Executable, Runtime};
use crate::stream::ReuseCounters;

/// Which engine serves a model's batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Float,
    Hls,
    Pjrt,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "float" | "nn" => Ok(BackendKind::Float),
            "hls" | "fixed" => Ok(BackendKind::Hls),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (float|hls|pjrt)"),
        }
    }
}

/// A ready-to-serve inference engine for one model.
pub enum Backend {
    Float(FloatTransformer),
    Hls {
        engine: FixedTransformer,
        /// The modeled FPGA design point's per-site reuse map — pure
        /// schedule metadata (simulation output never depends on it);
        /// [`Backend::modeled_design`] synthesizes under it.
        par: ParallelismPlan,
    },
    /// batch-1 and batch-N executables (router picks by batch fill).
    Pjrt { cfg: ModelConfig, b1: Executable, bn: Executable },
}

impl Backend {
    /// Build a backend for `cfg`.
    ///
    /// `runtime` is required for [`BackendKind::Pjrt`] and ignored
    /// otherwise.  `plan` configures the HLS quantization — a
    /// [`PrecisionPlan::uniform`] reproduces the legacy single
    /// `QuantConfig` engine bitwise, a heterogeneous plan builds the
    /// mixed-precision engine.  `par` configures the modeled FPGA
    /// schedule the HLS design point reports (it cannot change a
    /// probability).
    pub fn build(
        kind: BackendKind,
        cfg: &ModelConfig,
        weights: &Weights,
        plan: &PrecisionPlan,
        par: &ParallelismPlan,
        runtime: Option<&Runtime>,
        artifacts: &std::path::Path,
    ) -> Result<Self> {
        anyhow::ensure!(
            plan.num_blocks() == cfg.num_blocks,
            "precision plan has {} blocks, model '{}' has {}",
            plan.num_blocks(),
            cfg.name,
            cfg.num_blocks
        );
        anyhow::ensure!(
            par.num_blocks() == cfg.num_blocks,
            "parallelism plan has {} blocks, model '{}' has {}",
            par.num_blocks(),
            cfg.name,
            cfg.num_blocks
        );
        Ok(match kind {
            BackendKind::Float => {
                Backend::Float(FloatTransformer::new(cfg.clone(), weights.clone()))
            }
            BackendKind::Hls => Backend::Hls {
                engine: FixedTransformer::with_plan(cfg.clone(), weights, plan.clone()),
                par: par.clone(),
            },
            BackendKind::Pjrt => {
                let rt = runtime.context("PJRT backend needs a Runtime")?;
                let load = |batch: usize| {
                    rt.load_hlo(
                        artifacts.join(format!("{}.b{batch}.hlo.txt", cfg.name)),
                        (batch, cfg.seq_len, cfg.input_size),
                        cfg.output_size,
                    )
                };
                Backend::Pjrt { cfg: cfg.clone(), b1: load(1)?, bn: load(8)? }
            }
        })
    }

    /// Wrap an already-built HLS engine — the replica-shard path: the
    /// server builds (and compiles) each model's engine once, then hands
    /// every worker a cheap clone sharing the same `Arc<CompiledModel>`.
    pub fn from_hls_engine(engine: FixedTransformer, par: ParallelismPlan) -> Self {
        Backend::Hls { engine, par }
    }

    /// The HLS backend's compiled artifact (`None` for other kinds) —
    /// replica sharing is observable as `Arc::ptr_eq` across backends.
    pub fn compiled(&self) -> Option<&Arc<CompiledModel>> {
        match self {
            Backend::Hls { engine, .. } => Some(engine.compiled()),
            _ => None,
        }
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Float(_) => BackendKind::Float,
            Backend::Hls { .. } => BackendKind::Hls,
            Backend::Pjrt { .. } => BackendKind::Pjrt,
        }
    }

    /// The modeled FPGA design point of an HLS backend (its precision ×
    /// parallelism plans synthesized); `None` for engines that model no
    /// hardware.
    pub fn modeled_design(&self) -> Option<SynthesisReport> {
        match self {
            Backend::Hls { engine, par } => Some(engine.synthesize(par)),
            _ => None,
        }
    }

    /// Score a batch of events: returns per-event probabilities.
    ///
    /// Float and HLS execute **batch-native** (`forward_batch`): each
    /// layer streams its weights once for the whole batch, and for HLS
    /// the result is bitwise identical to per-event scoring (see the
    /// bit-exactness contract in [`crate::nn`]).
    pub fn infer(&self, batch: &[&Mat]) -> Result<Vec<Vec<f32>>> {
        if batch.is_empty() {
            // the batcher never emits empty batches, but direct callers
            // can — and the PJRT path would otherwise burn a full padded
            // executable run (stub builds would error) on zero events
            return Ok(Vec::new());
        }
        match self {
            Backend::Float(t) => {
                Ok(t.forward_batch(batch).iter().map(|l| t.probs(l)).collect())
            }
            Backend::Hls { engine, .. } => Ok(engine.forward_batch(batch)),
            Backend::Pjrt { cfg, b1, bn } => {
                let logits = if batch.len() == 1 {
                    b1.run_events(batch)?
                } else {
                    // split oversized batches; `run_events` zero-pads a
                    // partial chunk up to the executable's batch size and
                    // truncates the outputs back to the real events, and
                    // a final 1-event tail takes the batch-1 executable
                    // instead of a mostly-padding batch-N run
                    let mut out = Vec::with_capacity(batch.len());
                    for (start, end) in split_plan(batch.len(), bn.batch_size()) {
                        let chunk = &batch[start..end];
                        let exe = if chunk.len() == 1 { b1 } else { bn };
                        out.extend(exe.run_events(chunk)?);
                    }
                    out
                };
                Ok(logits
                    .into_iter()
                    .map(|l| logits_to_probs(cfg, &l))
                    .collect())
            }
        }
    }

    /// Positive-class score for AUC accounting.
    pub fn score(&self, probs: &[f32]) -> f32 {
        if probs.len() == 1 {
            probs[0]
        } else {
            probs[1.min(probs.len() - 1)]
        }
    }

    /// A fresh per-stream incremental cache for [`Self::infer_window`].
    /// One per (shard, stream): the router hands each shard a strided
    /// sub-stream, and the cache keys reuse off that shard's own
    /// position deltas.
    pub fn window_cache(&self) -> BackendWindowCache {
        match self {
            Backend::Float(t) => BackendWindowCache::Float(t.window_cache()),
            Backend::Hls { engine, .. } => BackendWindowCache::Hls(engine.window_cache()),
            Backend::Pjrt { .. } => BackendWindowCache::Full(ReuseCounters::default()),
        }
    }

    /// Score one stream window at absolute sample position `pos`,
    /// reusing the overlapping-row work retained in `cache` when sound
    /// (consecutive windows of one stream, hop < seq_len).  **Bitwise
    /// identical** to `infer(&[x])` on every backend — PJRT has no
    /// incremental path and falls back to a full single-window infer
    /// (counted as a full window in the cache's counters).
    pub fn infer_window(
        &self,
        x: &Mat,
        pos: u64,
        cache: &mut BackendWindowCache,
    ) -> Result<Vec<f32>> {
        match (self, cache) {
            (Backend::Float(t), BackendWindowCache::Float(c)) => {
                Ok(t.probs(&t.forward_incremental(x, pos, c)))
            }
            (Backend::Hls { engine, .. }, BackendWindowCache::Hls(c)) => {
                Ok(engine.forward_incremental(x, pos, c))
            }
            (Backend::Pjrt { .. }, BackendWindowCache::Full(counters)) => {
                counters.windows_full += 1;
                counters.rows_recomputed += x.rows() as u64;
                Ok(self.infer(&[x])?.remove(0))
            }
            _ => anyhow::bail!("window cache built for a different backend kind"),
        }
    }
}

/// Per-stream incremental state for [`Backend::infer_window`], matching
/// the backend kind it was built from.
pub enum BackendWindowCache {
    Float(FloatWindowCache),
    Hls(crate::hls::WindowCache),
    /// Backends with no incremental path (PJRT): full-recompute
    /// accounting only.
    Full(ReuseCounters),
}

impl BackendWindowCache {
    /// Reuse/recompute accounting accumulated through this cache.
    pub fn counters(&self) -> ReuseCounters {
        match self {
            BackendWindowCache::Float(c) => c.counters(),
            BackendWindowCache::Hls(c) => c.counters(),
            BackendWindowCache::Full(c) => *c,
        }
    }

    /// Drop any retained window: the next call recomputes in full.
    pub fn invalidate(&mut self) {
        match self {
            BackendWindowCache::Float(c) => c.invalidate(),
            BackendWindowCache::Hls(c) => c.invalidate(),
            BackendWindowCache::Full(_) => {}
        }
    }
}

/// Chunk boundaries for running `len` events through a batch-`cap`
/// executable: full `cap`-sized chunks plus one final partial chunk.
/// `cap = 0` (an unloadable executable would report that) degrades to
/// per-event chunks instead of panicking in `chunks()`.
fn split_plan(len: usize, cap: usize) -> Vec<(usize, usize)> {
    let cap = cap.max(1);
    let mut plan = Vec::with_capacity(len.div_ceil(cap));
    let mut start = 0;
    while start < len {
        let end = (start + cap).min(len);
        plan.push((start, end));
        start = end;
    }
    plan
}

fn logits_to_probs(cfg: &ModelConfig, logits: &[f32]) -> Vec<f32> {
    match cfg.final_activation() {
        FinalActivation::Sigmoid => {
            logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
        }
        FinalActivation::Softmax => {
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
            let s: f32 = e.iter().sum();
            e.into_iter().map(|v| v / s).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::QuantConfig;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::testutil::Gen;

    fn uniform(cfg: &ModelConfig, i: u32, f: u32) -> PrecisionPlan {
        PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(i, f))
    }

    fn upar(cfg: &ModelConfig) -> ParallelismPlan {
        ParallelismPlan::uniform(cfg.num_blocks, crate::hls::ReuseFactor(1))
    }

    fn events(cfg: &ModelConfig, n: usize) -> Vec<Mat> {
        let mut g = Gen::new(9);
        (0..n)
            .map(|_| {
                Mat::from_vec(
                    cfg.seq_len,
                    cfg.input_size,
                    g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
                )
            })
            .collect()
    }

    #[test]
    fn float_and_hls_backends_agree_roughly() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 13);
        let f = Backend::build(BackendKind::Float, &cfg, &w, &uniform(&cfg, 8, 12),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let h = Backend::build(BackendKind::Hls, &cfg, &w, &uniform(&cfg, 8, 12),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let evs = events(&cfg, 4);
        let refs: Vec<&Mat> = evs.iter().collect();
        let pf = f.infer(&refs).unwrap();
        let ph = h.infer(&refs).unwrap();
        for (a, b) in pf.iter().zip(&ph) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 0.25, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn empty_batch_returns_no_scores() {
        // regression: an empty batch used to fall through to the backend
        // (for PJRT, a padded `bn.run_events(&[])` execution)
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 13);
        for kind in [BackendKind::Float, BackendKind::Hls] {
            let b = Backend::build(kind, &cfg, &w, &uniform(&cfg, 8, 12),
                                   &upar(&cfg), None, std::path::Path::new(".")).unwrap();
            assert!(b.infer(&[]).unwrap().is_empty(), "{kind:?}");
        }
    }

    #[test]
    fn batched_infer_scores_match_single_event_infer() {
        // batching is a throughput knob, never a semantics knob: the
        // batch-native Float/HLS paths must reproduce per-event scores
        // bitwise
        let cfg = zoo_model("btag").unwrap().config;
        let w = synthetic_weights(&cfg, 3);
        for kind in [BackendKind::Float, BackendKind::Hls] {
            let b = Backend::build(kind, &cfg, &w, &uniform(&cfg, 8, 12),
                                   &upar(&cfg), None, std::path::Path::new(".")).unwrap();
            let evs = events(&cfg, 5);
            let refs: Vec<&Mat> = evs.iter().collect();
            let batched = b.infer(&refs).unwrap();
            for (e, want) in evs.iter().zip(&batched) {
                assert_eq!(&b.infer(&[e]).unwrap()[0], want, "{kind:?}");
            }
        }
    }

    #[test]
    fn split_plan_covers_oversized_batches() {
        // regression for the oversized-batch audit: every event exactly
        // once, chunks never exceed the executable's batch size, the
        // final partial chunk is preserved (then zero-padded inside
        // `run_events`, which truncates outputs back to real events)
        assert_eq!(split_plan(17, 8), vec![(0, 8), (8, 16), (16, 17)]);
        assert_eq!(split_plan(16, 8), vec![(0, 8), (8, 16)]);
        assert_eq!(split_plan(3, 8), vec![(0, 3)]);
        assert_eq!(split_plan(0, 8), Vec::<(usize, usize)>::new());
        // a zero-capacity executable degrades to per-event chunks
        assert_eq!(split_plan(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
        for (len, cap) in [(1usize, 1usize), (9, 4), (25, 8), (7, 16)] {
            let plan = split_plan(len, cap);
            let mut covered = 0;
            for (s, e) in &plan {
                assert_eq!(*s, covered, "contiguous");
                assert!(*e > *s && e - s <= cap.max(1));
                covered = *e;
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn infer_window_bitwise_matches_infer_on_overlapping_stream() {
        // the serving-layer face of the incremental tentpole: streamed
        // windows through the per-shard cache score bitwise identically
        // to a naive full infer, Float and HLS alike
        let cfg = zoo_model("gw").unwrap().config;
        let w = synthetic_weights(&cfg, 23);
        let (s, d) = (cfg.seq_len, cfg.input_size);
        let hop = (s / 4).max(1);
        let mut g = Gen::new(41);
        let buf = g.normal_vec((s + hop * 5) * d, 1.0);
        for kind in [BackendKind::Float, BackendKind::Hls] {
            let b = Backend::build(kind, &cfg, &w, &uniform(&cfg, 6, 10),
                                   &upar(&cfg), None, std::path::Path::new(".")).unwrap();
            let mut cache = b.window_cache();
            for wi in 0..5usize {
                let pos = wi * hop;
                let x = Mat::from_vec(s, d, buf[pos * d..(pos + s) * d].to_vec());
                let inc = b.infer_window(&x, pos as u64, &mut cache).unwrap();
                assert_eq!(inc, b.infer(&[&x]).unwrap()[0], "{kind:?} window {wi}");
            }
            let c = cache.counters();
            assert_eq!(c.windows_full, 1, "{kind:?}");
            assert_eq!(c.windows_incremental, 4, "{kind:?}");
            // invalidate() drops the carry without breaking correctness
            cache.invalidate();
            let pos = 5 * hop;
            let x = Mat::from_vec(s, d, buf[pos * d..(pos + s) * d].to_vec());
            let inc = b.infer_window(&x, pos as u64, &mut cache).unwrap();
            assert_eq!(inc, b.infer(&[&x]).unwrap()[0], "{kind:?} post-invalidate");
            assert_eq!(cache.counters().windows_full, 2, "{kind:?}");
        }
    }

    #[test]
    fn infer_window_rejects_mismatched_cache() {
        let cfg = zoo_model("btag").unwrap().config;
        let w = synthetic_weights(&cfg, 24);
        let f = Backend::build(BackendKind::Float, &cfg, &w, &uniform(&cfg, 6, 10),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let h = Backend::build(BackendKind::Hls, &cfg, &w, &uniform(&cfg, 6, 10),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let mut hc = h.window_cache();
        let x = events(&cfg, 1).remove(0);
        assert!(f.infer_window(&x, 0, &mut hc).is_err());
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 13);
        let r = Backend::build(BackendKind::Pjrt, &cfg, &w, &uniform(&cfg, 8, 12),
                               &upar(&cfg), None, std::path::Path::new("."));
        assert!(r.is_err());
    }

    #[test]
    fn hls_backend_from_uniform_plan_matches_direct_engine_bitwise() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 14);
        let b = Backend::build(BackendKind::Hls, &cfg, &w, &uniform(&cfg, 6, 10),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let t = FixedTransformer::new(cfg.clone(), &w, QuantConfig::new(6, 10));
        let evs = events(&cfg, 3);
        let refs: Vec<&Mat> = evs.iter().collect();
        let probs = b.infer(&refs).unwrap();
        for (e, got) in evs.iter().zip(&probs) {
            assert_eq!(got, &t.forward(e));
        }
    }

    #[test]
    fn hls_backend_honors_a_mixed_plan() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 15);
        let mut plan = uniform(&cfg, 6, 12);
        plan.set_data("block0.ffn1", crate::fixed::FixedSpec::new(8, 4)).unwrap();
        let b = Backend::build(BackendKind::Hls, &cfg, &w, &plan,
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        let t = FixedTransformer::with_plan(cfg.clone(), &w, plan);
        let evs = events(&cfg, 2);
        let refs: Vec<&Mat> = evs.iter().collect();
        let probs = b.infer(&refs).unwrap();
        for (e, got) in evs.iter().zip(&probs) {
            assert_eq!(got, &t.forward(e), "mixed-plan backend must match its engine");
        }
    }

    #[test]
    fn replica_backends_share_one_compiled_artifact() {
        // satellite: R replica shards of one model hold R handles to ONE
        // immutable compiled copy — pointer equality, not just equal bits
        let cfg = zoo_model("gw").unwrap().config;
        let w = synthetic_weights(&cfg, 21);
        let engine = FixedTransformer::with_plan(cfg.clone(), &w, uniform(&cfg, 6, 10));
        let replicas: Vec<Backend> = (0..3)
            .map(|_| Backend::from_hls_engine(engine.clone(), upar(&cfg)))
            .collect();
        let first = replicas[0].compiled().expect("hls backend has an artifact");
        assert!(first.bytes() > 0);
        for r in &replicas[1..] {
            assert!(Arc::ptr_eq(first, r.compiled().unwrap()), "replicas must share");
        }
        // sharing is invisible to serving: every replica scores bitwise
        // identically
        let evs = events(&cfg, 2);
        let refs: Vec<&Mat> = evs.iter().collect();
        let want = replicas[0].infer(&refs).unwrap();
        for r in &replicas[1..] {
            assert_eq!(r.infer(&refs).unwrap(), want);
        }
        // non-HLS backends expose no artifact
        let f = Backend::build(BackendKind::Float, &cfg, &w, &uniform(&cfg, 6, 10),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        assert!(f.compiled().is_none());
    }

    #[test]
    fn plan_with_wrong_block_count_is_clean_error() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 16);
        let plan = PrecisionPlan::uniform(cfg.num_blocks + 2, QuantConfig::new(6, 10));
        let r = Backend::build(BackendKind::Hls, &cfg, &w, &plan,
                               &upar(&cfg), None, std::path::Path::new("."));
        assert!(r.is_err());
        assert!(format!("{:#}", r.unwrap_err()).contains("blocks"));
    }

    #[test]
    fn backend_kind_parses() {
        use std::str::FromStr;
        assert_eq!(BackendKind::from_str("hls").unwrap(), BackendKind::Hls);
        assert_eq!(BackendKind::from_str("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::from_str("gpu").is_err());
    }

    #[test]
    fn parallelism_plan_with_wrong_block_count_is_clean_error() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 17);
        let par = ParallelismPlan::uniform(cfg.num_blocks + 1, crate::hls::ReuseFactor(2));
        let r = Backend::build(BackendKind::Hls, &cfg, &w, &uniform(&cfg, 6, 10),
                               &par, None, std::path::Path::new("."));
        assert!(r.is_err());
        assert!(format!("{:#}", r.unwrap_err()).contains("parallelism plan"));
    }

    #[test]
    fn hls_backend_reports_its_modeled_design_under_the_reuse_plan() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 18);
        let mut par = upar(&cfg);
        par.set("pool", crate::hls::ReuseFactor(2)).unwrap();
        let b = Backend::build(BackendKind::Hls, &cfg, &w, &uniform(&cfg, 6, 10),
                               &par, None, std::path::Path::new(".")).unwrap();
        let rep = b.modeled_design().expect("hls models hardware");
        assert_eq!(rep.parallelism, par);
        assert!(rep.parallelism.is_uniform().is_none());
        // float backends model no FPGA
        let f = Backend::build(BackendKind::Float, &cfg, &w, &uniform(&cfg, 6, 10),
                               &upar(&cfg), None, std::path::Path::new(".")).unwrap();
        assert!(f.modeled_design().is_none());
    }
}
