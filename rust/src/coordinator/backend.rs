//! Inference backends the coordinator can route batches to.
//!
//! * [`BackendKind::Float`] — exact-float Rust reference (no artifacts).
//! * [`BackendKind::Hls`] — the bit-accurate fixed-point HLS simulator
//!   (what the FPGA would compute); latency is dominated by simulation,
//!   the *modeled* FPGA latency comes from `synthesize()`.
//! * [`BackendKind::Pjrt`] — the AOT artifact through the PJRT CPU
//!   client (the production serving path of this reproduction).
//!
//! Backends are not shared between threads: in a sharded worker pool
//! each replica builds its own `Backend` (and, for PJRT, its own client)
//! so pool scaling never serializes on a single inference engine.

use anyhow::{Context, Result};

use crate::hls::{FixedTransformer, QuantConfig};
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;
use crate::nn::tensor::Mat;
use crate::nn::FloatTransformer;
use crate::runtime::{Executable, Runtime};

/// Which engine serves a model's batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Float,
    Hls,
    Pjrt,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "float" | "nn" => Ok(BackendKind::Float),
            "hls" | "fixed" => Ok(BackendKind::Hls),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (float|hls|pjrt)"),
        }
    }
}

/// A ready-to-serve inference engine for one model.
pub enum Backend {
    Float(FloatTransformer),
    Hls(FixedTransformer),
    /// batch-1 and batch-N executables (router picks by batch fill).
    Pjrt { cfg: ModelConfig, b1: Executable, bn: Executable },
}

impl Backend {
    /// Build a backend for `cfg`.
    ///
    /// `runtime` is required for [`BackendKind::Pjrt`] and ignored
    /// otherwise; `quant` configures the HLS design point.
    pub fn build(
        kind: BackendKind,
        cfg: &ModelConfig,
        weights: &Weights,
        quant: QuantConfig,
        runtime: Option<&Runtime>,
        artifacts: &std::path::Path,
    ) -> Result<Self> {
        Ok(match kind {
            BackendKind::Float => {
                Backend::Float(FloatTransformer::new(cfg.clone(), weights.clone()))
            }
            BackendKind::Hls => {
                Backend::Hls(FixedTransformer::new(cfg.clone(), weights, quant))
            }
            BackendKind::Pjrt => {
                let rt = runtime.context("PJRT backend needs a Runtime")?;
                let load = |batch: usize| {
                    rt.load_hlo(
                        artifacts.join(format!("{}.b{batch}.hlo.txt", cfg.name)),
                        (batch, cfg.seq_len, cfg.input_size),
                        cfg.output_size,
                    )
                };
                Backend::Pjrt { cfg: cfg.clone(), b1: load(1)?, bn: load(8)? }
            }
        })
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Float(_) => BackendKind::Float,
            Backend::Hls(_) => BackendKind::Hls,
            Backend::Pjrt { .. } => BackendKind::Pjrt,
        }
    }

    /// Score a batch of events: returns per-event probabilities.
    pub fn infer(&self, batch: &[&Mat]) -> Result<Vec<Vec<f32>>> {
        match self {
            Backend::Float(t) => Ok(batch
                .iter()
                .map(|x| t.probs(&t.forward(x)))
                .collect()),
            Backend::Hls(t) => Ok(batch.iter().map(|x| t.forward(x)).collect()),
            Backend::Pjrt { cfg, b1, bn } => {
                let logits = if batch.len() == 1 {
                    b1.run_events(batch)?
                } else if batch.len() <= bn.batch_size() {
                    bn.run_events(batch)?
                } else {
                    // split oversized batches
                    let mut out = Vec::with_capacity(batch.len());
                    for chunk in batch.chunks(bn.batch_size()) {
                        out.extend(bn.run_events(chunk)?);
                    }
                    out
                };
                Ok(logits
                    .into_iter()
                    .map(|l| logits_to_probs(cfg, &l))
                    .collect())
            }
        }
    }

    /// Positive-class score for AUC accounting.
    pub fn score(&self, probs: &[f32]) -> f32 {
        if probs.len() == 1 {
            probs[0]
        } else {
            probs[1.min(probs.len() - 1)]
        }
    }
}

fn logits_to_probs(cfg: &ModelConfig, logits: &[f32]) -> Vec<f32> {
    match cfg.final_activation() {
        FinalActivation::Sigmoid => {
            logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
        }
        FinalActivation::Softmax => {
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
            let s: f32 = e.iter().sum();
            e.into_iter().map(|v| v / s).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::testutil::Gen;

    fn events(cfg: &ModelConfig, n: usize) -> Vec<Mat> {
        let mut g = Gen::new(9);
        (0..n)
            .map(|_| {
                Mat::from_vec(
                    cfg.seq_len,
                    cfg.input_size,
                    g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
                )
            })
            .collect()
    }

    #[test]
    fn float_and_hls_backends_agree_roughly() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 13);
        let f = Backend::build(BackendKind::Float, &cfg, &w, QuantConfig::new(8, 12),
                               None, std::path::Path::new(".")).unwrap();
        let h = Backend::build(BackendKind::Hls, &cfg, &w, QuantConfig::new(8, 12),
                               None, std::path::Path::new(".")).unwrap();
        let evs = events(&cfg, 4);
        let refs: Vec<&Mat> = evs.iter().collect();
        let pf = f.infer(&refs).unwrap();
        let ph = h.infer(&refs).unwrap();
        for (a, b) in pf.iter().zip(&ph) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 0.25, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 13);
        let r = Backend::build(BackendKind::Pjrt, &cfg, &w, QuantConfig::new(8, 12),
                               None, std::path::Path::new("."));
        assert!(r.is_err());
    }

    #[test]
    fn backend_kind_parses() {
        use std::str::FromStr;
        assert_eq!(BackendKind::from_str("hls").unwrap(), BackendKind::Hls);
        assert_eq!(BackendKind::from_str("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::from_str("gpu").is_err());
    }
}
