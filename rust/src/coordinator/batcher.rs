//! Dynamic batcher: size-or-deadline batching, the same policy a serving
//! router (vLLM-style) uses, scaled down to trigger latencies.
//!
//! In a sharded worker pool every replica runs its own `Batcher` over
//! its own SPSC ring, so batches never mix events from different shards
//! and arrival order is preserved *within* a shard (cross-shard order is
//! deliberately unspecified — the router already interleaves).

use super::event::TriggerEvent;
use super::spsc::Consumer;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Close the batch at this many events...
    pub max_batch: usize,
    /// ...or when the oldest event has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_micros(200) }
    }
}

/// Longest single park while waiting out a batch deadline: short enough
/// that the wake-up lands within a scheduler quantum of the deadline,
/// long enough that an idle shard actually sleeps instead of spinning.
const PARK_SLICE: Duration = Duration::from_micros(50);

/// Pulls events off a ring and forms batches.
pub struct Batcher {
    policy: BatchPolicy,
    rx: Consumer<TriggerEvent>,
    pending: Vec<TriggerEvent>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, rx: Consumer<TriggerEvent>) -> Self {
        assert!(policy.max_batch >= 1);
        Self { policy, rx, pending: Vec::with_capacity(policy.max_batch) }
    }

    /// Block until a batch is ready (or the stream closed).  Returns
    /// `None` when the source is closed and fully drained.
    pub fn next_batch(&mut self) -> Option<Vec<TriggerEvent>> {
        // first event: block for it
        if self.pending.is_empty() {
            match self.rx.pop_blocking() {
                Some(e) => self.pending.push(e),
                None => return None,
            }
        }
        let deadline = Instant::now() + self.policy.max_wait;
        let mut idle = 0u32;
        while self.pending.len() < self.policy.max_batch {
            match self.rx.try_pop() {
                Some(e) => {
                    self.pending.push(e);
                    idle = 0;
                }
                None => {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    // staged idle backoff: a brief spin for the
                    // low-latency case, a few yields, then short parks
                    // bounded by the time left — an idle shard stops
                    // burning its core (a pure spin starves the producer
                    // on small machines) without overshooting max_wait
                    idle += 1;
                    if idle < 16 {
                        std::hint::spin_loop();
                    } else if idle < 64 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep((deadline - now).min(PARK_SLICE));
                    }
                }
            }
        }
        Some(std::mem::take(&mut self.pending))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spsc::ring;
    use crate::nn::tensor::Mat;

    fn ev(id: u64) -> TriggerEvent {
        TriggerEvent::new(id, "engine", Mat::zeros(2, 1), None)
    }

    #[test]
    fn batches_fill_to_max() {
        let (p, c) = ring(64);
        for i in 0..10 {
            p.try_push(ev(i)).unwrap();
        }
        p.close();
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) },
            c,
        );
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 4);
        assert_eq!(b1[0].id, 0);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = b.next_batch().unwrap();
        assert_eq!(b3.len(), 2, "tail batch flushes on close");
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (p, c) = ring(8);
        p.try_push(ev(1)).unwrap();
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
            c,
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(50));
        p.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn idle_wait_honors_the_deadline_within_tolerance() {
        // regression for the staged backoff: with one pending event and
        // an otherwise idle ring, next_batch must hold the batch open
        // until ~max_wait (not flush early) and the parked waits must
        // not overshoot the deadline by more than scheduler noise
        let (p, c) = ring(8);
        p.try_push(ev(1)).unwrap();
        let max_wait = Duration::from_millis(5);
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait }, c);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.len(), 1);
        assert!(
            waited >= max_wait - Duration::from_micros(200),
            "flushed {waited:?} before the {max_wait:?} deadline"
        );
        assert!(
            waited < max_wait + Duration::from_millis(30),
            "overshot the {max_wait:?} deadline: waited {waited:?}"
        );
        drop(p);
    }

    #[test]
    fn no_event_lost_or_duplicated_under_concurrency() {
        let (p, c) = ring(32);
        let n = 5_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                let mut item = ev(i);
                loop {
                    match p.try_push(item) {
                        Ok(()) => break,
                        Err(back) => {
                            item = back;
                            std::thread::yield_now();
                        }
                    }
                }
            }
            p.close();
        });
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 7, max_wait: Duration::from_micros(20) },
            c,
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 7);
            for e in batch {
                seen.push(e.id);
            }
        }
        producer.join().unwrap();
        assert_eq!(seen.len(), n as usize);
        // SPSC + batcher must preserve arrival order exactly
        for (i, &id) in seen.iter().enumerate() {
            assert_eq!(id, i as u64);
        }
    }
}
