//! The trigger server: sources -> router -> per-model batcher+backend
//! workers -> aggregated report.  This is the end-to-end serving driver
//! of the reproduction (EXPERIMENTS.md E6).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::backend::{Backend, BackendKind};
use super::batcher::{BatchPolicy, Batcher};
use super::event::TriggerEvent;
use super::router::{Router, Submit};
use super::spsc;
use super::stats::PipelineStats;
use crate::data::generator_for;
use crate::hls::QuantConfig;
use crate::models::weights::{synthetic_weights, Weights};
use crate::models::zoo::zoo_model;
use crate::models::NnwFile;
use crate::nn::tensor::Mat;
use crate::runtime::Runtime;

/// Where a pipeline's weights come from.
#[derive(Clone, Copy, Debug)]
pub enum WeightsSource {
    /// `artifacts/<model>.weights.nnw` (the trained PTQ checkpoint).
    Artifacts,
    /// Deterministic random weights (artifact-free tests).
    Synthetic(u64),
}

/// Per-model serving configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub model: &'static str,
    pub backend: BackendKind,
    pub quant: QuantConfig,
    pub batch: BatchPolicy,
    pub ring_capacity: usize,
    pub weights: WeightsSource,
}

impl PipelineConfig {
    pub fn new(model: &'static str, backend: BackendKind) -> Self {
        Self {
            model,
            backend,
            quant: QuantConfig::new(6, 10),
            batch: BatchPolicy::default(),
            ring_capacity: 1024,
            weights: WeightsSource::Artifacts,
        }
    }
}

/// Whole-server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub pipelines: Vec<PipelineConfig>,
    /// Events each source generates before closing.
    pub events_per_source: u64,
    /// Source pacing in events/second (0 = as fast as possible).
    pub rate_per_source: u64,
    pub artifacts_dir: PathBuf,
}

/// Aggregated result of one server run.
#[derive(Debug)]
pub struct ServerReport {
    pub per_model: HashMap<&'static str, PipelineStats>,
    pub wall: Duration,
}

impl ServerReport {
    pub fn total_scored(&self) -> u64 {
        self.per_model.values().map(|s| s.accepted).sum()
    }

    pub fn throughput_eps(&self) -> f64 {
        self.total_scored() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for ServerReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} events in {:.3}s ({:.0} ev/s)",
            self.total_scored(),
            self.wall.as_secs_f64(),
            self.throughput_eps()
        )?;
        let mut models: Vec<_> = self.per_model.iter().collect();
        models.sort_by_key(|(m, _)| **m);
        for (m, s) in models {
            writeln!(
                f,
                "  {m:8} accepted={} dropped={} batches={} fill={:.2} {}{}",
                s.accepted,
                s.dropped,
                s.batches,
                s.mean_batch_fill(),
                s.latency.summary(),
                s.online_auc()
                    .map(|a| format!(" auc={a:.4}"))
                    .unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

/// Build + run a trigger server to completion.
pub struct TriggerServer;

impl TriggerServer {
    /// Run the configured pipelines until every source has emitted its
    /// quota and every event is scored; return the aggregated report.
    pub fn run(cfg: &ServerConfig) -> Result<ServerReport> {
        let t0 = Instant::now();
        let mut router = Router::new();
        let mut workers = Vec::new();
        // readiness barrier: sources must not fire until every backend
        // is built (PJRT compilation takes seconds; without the barrier
        // the rings fill with stale events and latency numbers measure
        // compile time, not serving)
        let ready = Arc::new((std::sync::Mutex::new(0usize), std::sync::Condvar::new()));

        // per-model pipelines
        for pc in &cfg.pipelines {
            let zoo = zoo_model(pc.model)
                .with_context(|| format!("unknown zoo model '{}'", pc.model))?;
            let mcfg = zoo.config.clone();
            let weights = load_weights(&cfg.artifacts_dir, pc, &mcfg)?;
            let (tx, rx) = spsc::ring::<TriggerEvent>(pc.ring_capacity);
            router.add_route(pc.model, tx, mcfg.seq_len, mcfg.input_size);
            let pc = pc.clone();
            let artifacts = cfg.artifacts_dir.clone();
            let ready_w = ready.clone();
            workers.push(std::thread::spawn(move || -> Result<(
                &'static str,
                PipelineStats,
            )> {
                // PJRT runtime is created inside the worker so each
                // pipeline owns its client (no cross-thread sharing).
                let runtime = if pc.backend == BackendKind::Pjrt {
                    Some(Runtime::cpu()?)
                } else {
                    None
                };
                let backend = Backend::build(
                    pc.backend,
                    &mcfg,
                    &weights,
                    pc.quant,
                    runtime.as_ref(),
                    &artifacts,
                );
                // signal readiness whether the build succeeded or not,
                // so a failed pipeline can't deadlock the sources
                {
                    let (lock, cv) = &*ready_w;
                    *lock.lock().unwrap() += 1;
                    cv.notify_all();
                }
                let backend = backend?;
                let mut batcher = Batcher::new(pc.batch, rx);
                let mut stats = PipelineStats::default();
                while let Some(batch) = batcher.next_batch() {
                    let mats: Vec<&Mat> = batch.iter().map(|e| &e.x).collect();
                    let probs = backend.infer(&mats)?;
                    let now = Instant::now();
                    stats.batches += 1;
                    stats.batch_fill_sum += batch.len() as u64;
                    for (e, p) in batch.iter().zip(&probs) {
                        stats.accepted += 1;
                        let lat = now.duration_since(e.t_arrival);
                        stats.latency.record_duration(lat);
                        if let Some(label) = e.label {
                            stats.scored_pos.push(backend.score(p));
                            stats.scored_labels.push((label == 1) as u8);
                        }
                    }
                }
                Ok((pc.model, stats))
            }));
        }

        let router = Arc::new(router);

        // wait for all backends (see `ready` above)
        {
            let (lock, cv) = &*ready;
            let mut count = lock.lock().unwrap();
            while *count < cfg.pipelines.len() {
                count = cv.wait(count).unwrap();
            }
        }

        // sources
        let mut sources = Vec::new();
        for pc in &cfg.pipelines {
            let router = router.clone();
            let model = pc.model;
            let n = cfg.events_per_source;
            let rate = cfg.rate_per_source;
            sources.push(std::thread::spawn(move || -> (u64, u64) {
                let mut gen = generator_for(model, 0xFEED ^ n).expect("zoo generator");
                let mut shed = 0u64;
                let t_start = Instant::now();
                for i in 0..n {
                    if rate > 0 {
                        // pace the source: event i is due at i/rate seconds;
                        // sleep for the bulk of the wait, yield for the rest
                        // (pure spinning starves the pipeline on small hosts)
                        let due = Duration::from_nanos(i * 1_000_000_000 / rate);
                        loop {
                            let elapsed = t_start.elapsed();
                            if elapsed >= due {
                                break;
                            }
                            let remaining = due - elapsed;
                            if remaining > Duration::from_micros(300) {
                                std::thread::sleep(remaining - Duration::from_micros(200));
                            } else {
                                std::thread::yield_now();
                            }
                        }
                    }
                    let e = gen.next_event();
                    let ev = TriggerEvent::new(i, model, e.x, Some(e.label));
                    match router.submit(ev) {
                        Submit::Accepted => {}
                        Submit::Shed => shed += 1,
                        s => panic!("source rejected: {s:?}"),
                    }
                }
                (n, shed)
            }));
        }

        let mut source_shed: HashMap<&'static str, u64> = HashMap::new();
        for (s, pc) in sources.into_iter().zip(&cfg.pipelines) {
            let (_n, shed) = s.join().expect("source thread");
            *source_shed.entry(pc.model).or_default() += shed;
        }
        router.close_all();

        let mut per_model = HashMap::new();
        for w in workers {
            let (model, mut stats) = w.join().expect("worker thread")?;
            stats.dropped = source_shed.get(model).copied().unwrap_or(0);
            per_model.insert(model, stats);
        }

        Ok(ServerReport { per_model, wall: t0.elapsed() })
    }
}

fn load_weights(
    dir: &std::path::Path,
    pc: &PipelineConfig,
    mcfg: &crate::models::ModelConfig,
) -> Result<Weights> {
    match pc.weights {
        WeightsSource::Synthetic(seed) => Ok(synthetic_weights(mcfg, seed)),
        WeightsSource::Artifacts => {
            let path = dir.join(format!("{}.weights.nnw", pc.model));
            let file = NnwFile::load(&path)?;
            Weights::from_nnw(mcfg, &file)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(backend: BackendKind, n: u64) -> ServerConfig {
        ServerConfig {
            pipelines: vec![PipelineConfig {
                weights: WeightsSource::Synthetic(1),
                ..PipelineConfig::new("engine", backend)
            }],
            events_per_source: n,
            rate_per_source: 0,
            artifacts_dir: PathBuf::from("."),
        }
    }

    #[test]
    fn float_pipeline_serves_every_event() {
        let report = TriggerServer::run(&base_cfg(BackendKind::Float, 300)).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.dropped, 300);
        assert!(s.accepted > 0);
        assert!(s.latency.count() == s.accepted);
        assert!(s.online_auc().is_some());
    }

    #[test]
    fn hls_pipeline_runs() {
        let report = TriggerServer::run(&base_cfg(BackendKind::Hls, 40)).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.dropped, 40);
        assert!(s.mean_batch_fill() >= 1.0);
    }

    #[test]
    fn multi_model_server() {
        let mut cfg = base_cfg(BackendKind::Float, 120);
        cfg.pipelines.push(PipelineConfig {
            weights: WeightsSource::Synthetic(2),
            ..PipelineConfig::new("gw", BackendKind::Float)
        });
        let report = TriggerServer::run(&cfg).unwrap();
        assert_eq!(report.per_model.len(), 2);
        assert!(report.throughput_eps() > 0.0);
        let text = format!("{report}");
        assert!(text.contains("engine") && text.contains("gw"));
    }

    #[test]
    fn backpressure_sheds_instead_of_stalling() {
        // tiny ring + slow hls backend + fast source => shedding
        let mut cfg = base_cfg(BackendKind::Hls, 500);
        cfg.pipelines[0].ring_capacity = 4;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.dropped, 500);
        assert!(s.dropped > 0, "expected shedding under overload");
    }
}
