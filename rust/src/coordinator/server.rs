//! The trigger server: sources -> router -> sharded per-model worker
//! pools (N replicas x batcher+backend) -> aggregated report.  This is
//! the end-to-end serving driver of the reproduction (EXPERIMENTS.md E6).
//!
//! Each pipeline owns `replicas` independent shards.  A shard is one
//! SPSC ring consumed by one worker thread running its own [`Batcher`]
//! and its own [`Backend`] instance (PJRT replicas each own their
//! client; no cross-thread sharing).  The router fans sources out across
//! the shards round-robin, overflowing to the least-loaded shard under
//! momentary backpressure; per-shard stats are folded into the per-model
//! report at shutdown.  `replicas = 1` reproduces the original
//! single-worker pipeline exactly.
//!
//! The shard worker loop itself ([`serve_shard`]) and the per-pipeline
//! resolution step ([`resolve_pipeline`]) are shared with the network
//! serving plane (`super::pool`), which runs the same workers under a
//! dynamic shard set.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::backend::{Backend, BackendKind};
use super::batcher::{BatchPolicy, Batcher};
use super::event::TriggerEvent;
use super::router::{Router, Submit};
use super::spsc;
use super::stats::{PipelineStats, ShardLive};
use crate::data::generator_for;
use crate::data::gw::{Injection, StrainConfig, StrainStream};
use crate::hls::{
    FixedTransformer, ParallelismPlan, PrecisionPlan, QuantConfig, ReuseFactor, SynthesisReport,
};
use crate::models::weights::{synthetic_weights, Weights};
use crate::models::zoo::zoo_model;
use crate::models::{ModelConfig, NnwFile};
use crate::nn::tensor::Mat;
use crate::runtime::Runtime;
use crate::stream::WindowScore;
use crate::testutil::XorShift;

/// Where a pipeline's weights come from.
#[derive(Clone, Copy, Debug)]
pub enum WeightsSource {
    /// `artifacts/<model>.weights.nnw` (the trained PTQ checkpoint).
    Artifacts,
    /// Deterministic random weights (artifact-free tests).
    Synthetic(u64),
    /// Analytic excess-power detector weights
    /// ([`crate::models::weights::detector_weights`]): the artifact-free
    /// stand-in that genuinely detects injected chirps.  LN-free
    /// architectures only (the zoo's `engine`).
    Detector,
}

/// How a pipeline's source thread produces events.
#[derive(Clone, Debug)]
pub enum SourceMode {
    /// Pre-cut labeled events from the model's zoo generator (the seed
    /// behavior).
    Events,
    /// Continuous-stream ingestion: a [`StrainStream`] windowized into
    /// overlapping model windows; the router consumes windows through
    /// the same SPSC backpressure path, and workers record per-window
    /// scores for trigger clustering.
    Stream(StreamSource),
}

/// Configuration of one stream-mode source.
#[derive(Clone, Debug)]
pub struct StreamSource {
    /// Total samples to stream (windows emitted:
    /// `(samples - seq_len) / hop + 1` once `samples >= seq_len`).
    pub samples: u64,
    /// Window hop in samples (`seq_len/2` = 50% overlap; > `seq_len`
    /// leaves coverage gaps).
    pub hop: usize,
    /// The strain source (seed, injection schedule, amplitudes).
    /// `channels` must match the model's `input_size`.
    pub strain: StrainConfig,
    /// Incremental cross-window reuse: each worker shard keeps a
    /// [`super::backend::BackendWindowCache`] and serves overlapping
    /// windows through `Backend::infer_window` (bitwise identical to a
    /// full recompute; [`PipelineStats::reuse`] accounts for the saved
    /// work).  `false` forces the naive full-recompute path.
    pub reuse: bool,
}

/// Per-model serving configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub model: &'static str,
    pub backend: BackendKind,
    pub quant: QuantConfig,
    /// Serialized precision-plan overrides (the `--precision-plan` file
    /// text): applied over a uniform `quant` base when the pipeline's
    /// engine is built.  `None` serves the uniform design point.
    pub precision_plan: Option<String>,
    /// Uniform base reuse factor of the modeled FPGA design point.
    pub reuse: ReuseFactor,
    /// Serialized parallelism-plan overrides (the `--reuse-plan` file
    /// text): applied over a uniform `reuse` base, resolved before any
    /// pool spawns.  Schedule metadata only — scores never change.
    pub reuse_plan: Option<String>,
    pub batch: BatchPolicy,
    /// Capacity of each shard's ring (not the pool total).
    pub ring_capacity: usize,
    pub weights: WeightsSource,
    /// Worker-pool width: number of batcher+backend replicas serving
    /// this model.  1 reproduces the original single-worker pipeline.
    /// The network serving plane treats this as the *initial* width
    /// (the autoscaler then moves it within its min..max band).
    pub replicas: usize,
    /// What the source thread feeds this pipeline (pre-cut events by
    /// default; `SourceMode::Stream` windowizes a continuous stream).
    pub source: SourceMode,
}

impl PipelineConfig {
    pub fn new(model: &'static str, backend: BackendKind) -> Self {
        Self {
            model,
            backend,
            quant: QuantConfig::new(6, 10),
            precision_plan: None,
            reuse: ReuseFactor(1),
            reuse_plan: None,
            batch: BatchPolicy::default(),
            ring_capacity: 1024,
            weights: WeightsSource::Artifacts,
            replicas: 1,
            source: SourceMode::Events,
        }
    }

    /// Builder-style override of the worker-pool width.
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }
}

/// Whole-server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub pipelines: Vec<PipelineConfig>,
    /// Events each event-mode source generates before closing (stream
    /// sources are sized by their own `samples`).
    pub events_per_source: u64,
    /// Source pacing (0 = as fast as possible): events/second for
    /// event-mode sources, samples/second for stream sources.
    pub rate_per_source: u64,
    /// Event-mode arrival shape when paced: 1 = the seed's uniform
    /// spacing; > 1 = randomized bursts (sizes uniform in
    /// `[1, 2*burst)`, exponential inter-burst gaps at the same mean
    /// rate) — the compound-Poisson traffic a real trigger feed has.
    pub burst_per_source: u64,
    pub artifacts_dir: PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            pipelines: Vec::new(),
            events_per_source: 1000,
            rate_per_source: 0,
            burst_per_source: 1,
            artifacts_dir: crate::artifacts_dir(),
        }
    }
}

/// Cost of the compile-once plan execution for one HLS pipeline: the
/// plan's weight mantissas were lifted exactly once at resolution time,
/// and every replica shard serves through that one immutable artifact.
#[derive(Clone, Copy, Debug)]
pub struct CompiledInfo {
    /// Wall time of the one `CompiledModel::build` for this model.
    pub build_micros: u64,
    /// Size of the shared artifact (weight tiles + bias rows + lifts).
    pub bytes: usize,
    /// How many replica shards share the single `Arc<CompiledModel>`.
    pub replicas: usize,
}

/// Aggregated result of one server run.
#[derive(Debug)]
pub struct ServerReport {
    pub per_model: HashMap<&'static str, PipelineStats>,
    /// Modeled FPGA design point per HLS pipeline (precision ×
    /// parallelism plans synthesized at resolution time) — what the
    /// served engine *would* cost and achieve on the part.
    pub modeled_designs: HashMap<&'static str, SynthesisReport>,
    /// Per-HLS-pipeline compile-once accounting (see [`CompiledInfo`]).
    pub compiled: HashMap<&'static str, CompiledInfo>,
    /// Stream-mode ground truth: the injections each stream source
    /// planted (empty for event-mode pipelines).  Pair with the model's
    /// recorded `PipelineStats::windows` in `stream::analyze` for the
    /// detection-efficiency report.
    pub stream_truth: HashMap<&'static str, Vec<Injection>>,
    pub wall: Duration,
}

impl ServerReport {
    pub fn total_scored(&self) -> u64 {
        self.per_model.values().map(|s| s.accepted).sum()
    }

    pub fn throughput_eps(&self) -> f64 {
        self.total_scored() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for ServerReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} events in {:.3}s ({:.0} ev/s)",
            self.total_scored(),
            self.wall.as_secs_f64(),
            self.throughput_eps()
        )?;
        let mut models: Vec<_> = self.per_model.iter().collect();
        models.sort_by_key(|(m, _)| **m);
        for (m, s) in models {
            if let Some(rep) = self.modeled_designs.get(m) {
                writeln!(
                    f,
                    "  {m:8} modeled FPGA: {} {} | clk {:.3} ns | II {} cyc | \
                     latency {:.3} us | DSP {} FF {}",
                    rep.plan.summary(),
                    rep.parallelism.summary(),
                    rep.clk_ns,
                    rep.interval_cycles,
                    rep.latency_us,
                    rep.total.dsp,
                    rep.total.ff,
                )?;
            }
            if let Some(ci) = self.compiled.get(m) {
                writeln!(
                    f,
                    "  {m:8} compiled plan: built once in {} us, {:.1} KiB \
                     shared by {} replica(s)",
                    ci.build_micros,
                    ci.bytes as f64 / 1024.0,
                    ci.replicas,
                )?;
            }
            writeln!(
                f,
                "  {m:8} accepted={} shed={} dropped={} batches={} fill={:.2} {}{}",
                s.accepted,
                s.shed,
                s.dropped,
                s.batches,
                s.mean_batch_fill(),
                s.latency.summary(),
                s.online_auc()
                    .map(|a| format!(" auc={a:.4}"))
                    .unwrap_or_default()
            )?;
            if !s.windows.is_empty() {
                writeln!(
                    f,
                    "    stream: {} windows scored (cluster with stream::analyze \
                     for triggers + detection efficiency)",
                    s.windows.len()
                )?;
                if s.reuse.windows() > 0 {
                    writeln!(
                        f,
                        "    reuse: {}/{} windows incremental | prefix rows \
                         {:.1}% reused | score entries {:.1}% reused | cache \
                         {:.1} KiB high-water",
                        s.reuse.windows_incremental,
                        s.reuse.windows(),
                        100.0 * s.reuse.row_reuse_fraction(),
                        100.0 * s.reuse.score_reuse_fraction(),
                        s.reuse.cache_bytes as f64 / 1024.0,
                    )?;
                }
            }
            // shard breakdown only matters for real pools
            if s.shards.len() > 1 {
                writeln!(
                    f,
                    "    pool: {} shards, {} events rebalanced off a full round-robin shard",
                    s.shards.len(),
                    s.rebalanced
                )?;
                for sh in &s.shards {
                    writeln!(
                        f,
                        "    shard {}: accepted={} dropped={} batches={} fill={:.2} {}",
                        sh.shard,
                        sh.accepted,
                        sh.dropped,
                        sh.batches,
                        sh.mean_batch_fill(),
                        sh.latency.summary(),
                    )?;
                }
            }
        }
        Ok(())
    }
}

/// One pipeline's fully resolved serving inputs: model config, weights,
/// both plans (verifier-gated for HLS), the compile-once engine, and the
/// modeled design point.  Produced by [`resolve_pipeline`] *before* any
/// worker spawns, so every plan error is a clean `Err`.
pub(crate) struct ResolvedPipeline {
    pub mcfg: ModelConfig,
    pub weights: Arc<Weights>,
    pub plan: PrecisionPlan,
    pub par: ParallelismPlan,
    /// The compile-once HLS engine (None for float/PJRT backends).
    pub engine: Option<FixedTransformer>,
    pub modeled: Option<SynthesisReport>,
    pub compiled: Option<CompiledInfo>,
}

/// Resolve one pipeline: zoo lookup, weights, precision + parallelism
/// plans over their uniform bases, static plan verification, and (for
/// HLS) the single shared engine build.  Shared by the batch server and
/// the network serving plane; also the gate the hot plan swap re-runs
/// before draining anything.
pub(crate) fn resolve_pipeline(
    artifacts_dir: &std::path::Path,
    pc: &PipelineConfig,
) -> Result<ResolvedPipeline> {
    let zoo = zoo_model(pc.model)
        .with_context(|| format!("unknown zoo model '{}'", pc.model))?;
    let mcfg = zoo.config.clone();
    let weights = Arc::new(load_weights(artifacts_dir, pc, &mcfg)?);
    // resolve both plans up front: a malformed plan must be a clean Err
    // before any pool spawns
    let mut plan = PrecisionPlan::uniform(mcfg.num_blocks, pc.quant);
    if let Some(text) = &pc.precision_plan {
        plan.apply_overrides(text)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("precision plan for model '{}'", pc.model))?;
    }
    let mut par = ParallelismPlan::uniform(mcfg.num_blocks, pc.reuse);
    if let Some(text) = &pc.reuse_plan {
        par.apply_overrides(text)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("reuse plan for model '{}'", pc.model))?;
    }
    // stream geometry must be a clean Err before any pool spawns (a
    // mismatched window shape would otherwise shed every single window
    // at the router)
    if let SourceMode::Stream(ss) = &pc.source {
        anyhow::ensure!(
            ss.strain.channels == mcfg.input_size,
            "stream source for model '{}' has {} channels, model takes {}",
            pc.model,
            ss.strain.channels,
            mcfg.input_size
        );
        anyhow::ensure!(ss.hop >= 1, "stream hop must be >= 1");
    }
    // the modeled FPGA design point of an HLS pipeline, reported
    // alongside the serving stats (computed once here, not per replica).
    // The engine itself is also kept: the pool's replica shards clone it
    // (Arc-shared weights + compiled plan) instead of re-lifting the
    // weight mantissas R times.
    let (mut engine, mut modeled, mut compiled) = (None, None, None);
    if pc.backend == BackendKind::Hls {
        // static plan verification gates the spawn: a plan the verifier
        // flags as ERROR (saturating grid, degenerate schedule) must be
        // a clean Err here, not a silently mis-triggering pool
        let verdict = crate::analysis::verify_plan(
            &mcfg,
            &weights,
            &plan,
            &par,
            &crate::analysis::VerifyConfig::default(),
        );
        if verdict.has_errors() {
            let first = verdict.errors().next().expect("has_errors");
            anyhow::bail!(
                "plan verification failed for model '{}' ({} error(s)); \
                 first: site '{}': {}",
                pc.model,
                verdict.count(crate::analysis::Severity::Error),
                first.site,
                first.message
            );
        }
        let e = FixedTransformer::with_plan(mcfg.clone(), &weights, plan.clone());
        modeled = Some(e.synthesize(&par));
        compiled = Some(CompiledInfo {
            build_micros: e.compiled().build_micros(),
            bytes: e.compiled().bytes(),
            replicas: pc.replicas.max(1),
        });
        engine = Some(e);
    }
    Ok(ResolvedPipeline { mcfg, weights, plan, par, engine, modeled, compiled })
}

/// One shard's worker loop: pull batches off the ring, score them,
/// account per-event latency/labels/windows.  Runs until the ring is
/// closed and drained; returns the shard-local stats.
///
/// A batch whose inference *fails* is dropped (counted in
/// `stats.dropped`, logged once) and the shard keeps serving — a trigger
/// worker must degrade by dropping, never by dying with queued events.
/// When `live` is set, a cumulative [`ShardStats`] snapshot is published
/// after every batch so the metrics endpoint can scrape mid-run.
pub(crate) fn serve_shard(
    backend: &Backend,
    mut batcher: Batcher,
    stream_reuse: bool,
    shard: usize,
    live: Option<&ShardLive>,
) -> PipelineStats {
    let mut stats = PipelineStats::default();
    // stream-mode reuse: one incremental cache per shard.  The router
    // hands this shard a strided, in-order subsequence of the stream, so
    // consecutive events' position deltas key the overlap soundly (a
    // delta >= seq_len simply recomputes in full).
    let mut wcache = if stream_reuse { Some(backend.window_cache()) } else { None };
    let mut drop_logged = false;
    while let Some(batch) = batcher.next_batch() {
        let scored: Result<Vec<Vec<f32>>> = if let Some(wc) = wcache.as_mut() {
            // per-event, in arrival order — reuse needs the previous
            // window resident
            let mut out = Vec::with_capacity(batch.len());
            let mut failed = None;
            for e in &batch {
                let r = match e.stream_pos {
                    Some(pos) => backend.infer_window(&e.x, pos, wc),
                    None => backend.infer(&[&e.x]).map(|mut v| v.remove(0)),
                };
                match r {
                    Ok(p) => out.push(p),
                    Err(err) => {
                        failed = Some(err);
                        break;
                    }
                }
            }
            match failed {
                None => Ok(out),
                Some(err) => Err(err),
            }
        } else {
            let mats: Vec<&Mat> = batch.iter().map(|e| &e.x).collect();
            backend.infer(&mats)
        };
        let probs = match scored {
            Ok(p) => p,
            Err(e) => {
                stats.dropped += batch.len() as u64;
                if !drop_logged {
                    eprintln!(
                        "shard {shard}: inference failed, dropping batch of {}: {e:#}",
                        batch.len()
                    );
                    drop_logged = true;
                }
                // a half-applied incremental step leaves the cache
                // unsound for the next overlap — recompute cold
                if let Some(wc) = wcache.as_mut() {
                    wc.invalidate();
                }
                if let Some(l) = live {
                    l.publish(stats.shard_snapshot(shard));
                }
                continue;
            }
        };
        let now = Instant::now();
        stats.batches += 1;
        stats.batch_fill_sum += batch.len() as u64;
        for (e, p) in batch.iter().zip(&probs) {
            stats.accepted += 1;
            let lat = now.duration_since(e.t_arrival);
            stats.latency.record_duration(lat);
            if let Some(label) = e.label {
                stats.scored_pos.push(backend.score(p));
                stats.scored_labels.push((label == 1) as u8);
            }
            if let Some(pos) = e.stream_pos {
                stats.windows.push(WindowScore {
                    pos,
                    score: backend.score(p),
                    latency_ns: lat.as_nanos().min(u64::MAX as u128) as u64,
                });
            }
        }
        if let Some(wc) = &wcache {
            stats.reuse = wc.counters();
        }
        if let Some(l) = live {
            l.publish(stats.shard_snapshot(shard));
        }
    }
    if let Some(wc) = &wcache {
        stats.reuse = wc.counters();
    }
    if let Some(l) = live {
        l.publish(stats.shard_snapshot(shard));
    }
    stats
}

/// Build + run a trigger server to completion.
pub struct TriggerServer;

impl TriggerServer {
    /// Run the configured pipelines until every source has emitted its
    /// quota and every event is scored; return the aggregated report.
    pub fn run(cfg: &ServerConfig) -> Result<ServerReport> {
        let t0 = Instant::now();
        // reject duplicate models before any threads spawn: a duplicate
        // route would orphan the first pipeline's pool (workers blocked
        // on rings nobody closes)
        {
            let mut seen = std::collections::HashSet::new();
            for pc in &cfg.pipelines {
                anyhow::ensure!(
                    seen.insert(pc.model),
                    "duplicate pipeline for model '{}'",
                    pc.model
                );
            }
        }
        // resolve every pipeline's model + weights BEFORE spawning any
        // thread: a failure past the first spawn would leak an entire
        // pool (workers blocked on rings nobody ever closes)
        let mut modeled_designs: HashMap<&'static str, SynthesisReport> = HashMap::new();
        let mut compiled: HashMap<&'static str, CompiledInfo> = HashMap::new();
        let mut resolved = Vec::with_capacity(cfg.pipelines.len());
        for pc in &cfg.pipelines {
            let r = resolve_pipeline(&cfg.artifacts_dir, pc)?;
            if let Some(m) = &r.modeled {
                modeled_designs.insert(pc.model, m.clone());
            }
            if let Some(ci) = r.compiled {
                compiled.insert(pc.model, ci);
            }
            resolved.push((pc, r));
        }

        let mut router = Router::new();
        let mut workers = Vec::new();
        // readiness barrier: sources must not fire until every replica's
        // backend is built (PJRT compilation takes seconds; without the
        // barrier the rings fill with stale events and latency numbers
        // measure compile time, not serving)
        let total_workers: usize =
            cfg.pipelines.iter().map(|p| p.replicas.max(1)).sum();
        let ready = Arc::new((std::sync::Mutex::new(0usize), std::sync::Condvar::new()));

        // per-model worker pools
        for (pc, r) in resolved {
            let ResolvedPipeline { mcfg, weights, plan, par, engine, .. } = r;
            let replicas = pc.replicas.max(1);
            let mut shard_txs = Vec::with_capacity(replicas);
            for shard in 0..replicas {
                let (tx, rx) = spsc::ring::<TriggerEvent>(pc.ring_capacity);
                shard_txs.push(tx);
                let pc = pc.clone();
                let mcfg = mcfg.clone();
                let weights = weights.clone();
                let plan = plan.clone();
                let par = par.clone();
                // cheap: Arc-shared weights + compiled artifact, so all
                // R shards serve through ONE immutable copy
                let engine = engine.clone();
                let artifacts = cfg.artifacts_dir.clone();
                let ready_w = ready.clone();
                workers.push(std::thread::spawn(move || -> Result<(
                    &'static str,
                    usize,
                    PipelineStats,
                )> {
                    // each replica owns its backend (and, for PJRT, its
                    // own client — no cross-thread sharing).  The build
                    // result is held until *after* the readiness signal
                    // so a failed replica can't deadlock the sources.
                    let built = (|| -> Result<(Option<Runtime>, Backend)> {
                        // HLS: adopt the engine built once at resolution
                        // time instead of re-lifting the plan's weight
                        // mantissas per replica
                        if let Some(engine) = engine {
                            return Ok((None, Backend::from_hls_engine(engine, par.clone())));
                        }
                        let runtime = if pc.backend == BackendKind::Pjrt {
                            Some(Runtime::cpu()?)
                        } else {
                            None
                        };
                        let backend = Backend::build(
                            pc.backend,
                            &mcfg,
                            &weights,
                            &plan,
                            &par,
                            runtime.as_ref(),
                            &artifacts,
                        )?;
                        Ok((runtime, backend))
                    })();
                    {
                        let (lock, cv) = &*ready_w;
                        *lock.lock().unwrap() += 1;
                        cv.notify_all();
                    }
                    // keep the runtime alive as long as its executables
                    let (_runtime, backend) = built?;
                    let batcher = Batcher::new(pc.batch, rx);
                    let stream_reuse =
                        matches!(&pc.source, SourceMode::Stream(ss) if ss.reuse);
                    let stats = serve_shard(&backend, batcher, stream_reuse, shard, None);
                    Ok((pc.model, shard, stats))
                }));
            }
            router.add_route(pc.model, shard_txs, mcfg.seq_len, mcfg.input_size);
        }

        let router = Arc::new(router);

        // wait for all replicas (see `ready` above)
        {
            let (lock, cv) = &*ready;
            let mut count = lock.lock().unwrap();
            while *count < total_workers {
                count = cv.wait(count).unwrap();
            }
        }

        // sources
        let mut sources = Vec::new();
        for pc in &cfg.pipelines {
            let router = router.clone();
            let model = pc.model;
            let n = cfg.events_per_source;
            let rate = cfg.rate_per_source;
            let burst = cfg.burst_per_source.max(1);
            let mode = pc.source.clone();
            sources.push(std::thread::spawn(move || -> SourceOutcome {
                match mode {
                    SourceMode::Events => {
                        run_event_source(&router, model, n, rate, burst)
                    }
                    SourceMode::Stream(ss) => run_stream_source(&router, model, &ss, rate),
                }
            }));
        }

        let mut source_shed: HashMap<&'static str, u64> = HashMap::new();
        let mut stream_truth: HashMap<&'static str, Vec<Injection>> = HashMap::new();
        for (s, pc) in sources.into_iter().zip(&cfg.pipelines) {
            let out = s.join().expect("source thread");
            *source_shed.entry(pc.model).or_default() += out.shed;
            if !out.injections.is_empty() {
                stream_truth.entry(pc.model).or_default().extend(out.injections);
            }
        }
        router.close_all();

        // fold per-shard worker stats into per-model totals, in shard
        // order so the aggregation is deterministic
        let mut shard_results = Vec::with_capacity(workers.len());
        for w in workers {
            shard_results.push(w.join().expect("worker thread")?);
        }
        shard_results.sort_by_key(|(model, shard, _)| (*model, *shard));
        let mut per_model: HashMap<&'static str, PipelineStats> = HashMap::new();
        for (model, shard, stats) in &shard_results {
            per_model
                .entry(*model)
                .or_default()
                .absorb_shard(*shard, stats);
        }
        for (model, stats) in per_model.iter_mut() {
            // source-side shed is a router/source counter, distinct from
            // the worker-side `dropped` the absorb above summed — the
            // two loss paths never overwrite each other
            stats.shed = source_shed.get(model).copied().unwrap_or(0);
            stats.rebalanced = router.rebalanced(model).unwrap_or(0);
        }

        Ok(ServerReport { per_model, modeled_designs, compiled, stream_truth, wall: t0.elapsed() })
    }
}

/// What one source thread produced.
struct SourceOutcome {
    shed: u64,
    /// Stream-mode ground truth (empty for event sources).
    injections: Vec<Injection>,
}

/// Sleep-then-yield until `due` past `t_start` (pure spinning starves
/// the pipeline on small hosts).  Also the pacing primitive of the
/// `repro send` loopback client.
pub fn pace_until(t_start: Instant, due: Duration) {
    loop {
        let elapsed = t_start.elapsed();
        if elapsed >= due {
            return;
        }
        let remaining = due - elapsed;
        if remaining > Duration::from_micros(300) {
            std::thread::sleep(remaining - Duration::from_micros(200));
        } else {
            std::thread::yield_now();
        }
    }
}

/// The seed event source: `n` labeled zoo events, paced to `rate`
/// events/s when nonzero.  `burst > 1` randomizes arrivals into bursts
/// (sizes uniform in `[1, 2*burst)`, exponential inter-burst gaps) while
/// preserving the same mean rate — bursty detector traffic for the soak
/// tests.
fn run_event_source(
    router: &Router,
    model: &'static str,
    n: u64,
    rate: u64,
    burst: u64,
) -> SourceOutcome {
    let mut gen = generator_for(model, 0xFEED ^ n).expect("zoo generator");
    let mut shed = 0u64;
    let t_start = Instant::now();
    let mut rng = XorShift::new(0xB1157 ^ n);
    let mut burst_left = 0u64;
    let mut burst_due = Duration::ZERO;
    for i in 0..n {
        if rate > 0 {
            if burst <= 1 {
                // uniform pacing: event i is due at i/rate seconds
                pace_until(t_start, Duration::from_nanos(i * 1_000_000_000 / rate));
            } else {
                if burst_left == 0 {
                    burst_left = 1 + rng.next_u64() % (2 * burst - 1);
                    // exponential gap sized so the long-run rate matches:
                    // mean gap = burst_size_mean / rate
                    let mean_ns = burst as f64 * 1e9 / rate as f64;
                    burst_due += Duration::from_nanos(rng.exponential(mean_ns) as u64);
                    pace_until(t_start, burst_due);
                }
                burst_left -= 1;
            }
        }
        let e = gen.next_event();
        let ev = TriggerEvent::new(i, model, e.x, Some(e.label));
        match router.submit(ev) {
            Submit::Accepted => {}
            Submit::Shed => shed += 1,
            s => panic!("source rejected: {s:?}"),
        }
    }
    SourceOutcome { shed, injections: Vec::new() }
}

/// Stream-mode source: drive a continuous [`StrainStream`] through a
/// [`Windowizer`] and submit every completed window through the router's
/// normal SPSC backpressure path.  Pacing (`rate` > 0) is in samples/s.
fn run_stream_source(
    router: &Router,
    model: &'static str,
    ss: &StreamSource,
    rate: u64,
) -> SourceOutcome {
    use crate::stream::Windowizer;
    let seq_len = zoo_model(model).expect("resolved earlier").config.seq_len;
    let mut strain = StrainStream::new(ss.strain.clone());
    let mut wz = Windowizer::new(seq_len, ss.strain.channels, ss.hop);
    let mut sample = vec![0.0f32; ss.strain.channels];
    let mut shed = 0u64;
    let mut windows = 0u64;
    let t_start = Instant::now();
    for i in 0..ss.samples {
        if rate > 0 {
            pace_until(t_start, Duration::from_nanos(i * 1_000_000_000 / rate));
        }
        strain.next_sample(&mut sample);
        if let Some(w) = wz.push(&sample) {
            let ev = TriggerEvent::stream_window(windows, model, w.x, w.start);
            windows += 1;
            match router.submit(ev) {
                Submit::Accepted => {}
                Submit::Shed => shed += 1,
                s => panic!("stream source rejected: {s:?}"),
            }
        }
    }
    SourceOutcome { shed, injections: strain.take_injections() }
}

fn load_weights(
    dir: &std::path::Path,
    pc: &PipelineConfig,
    mcfg: &crate::models::ModelConfig,
) -> Result<Weights> {
    match pc.weights {
        WeightsSource::Synthetic(seed) => Ok(synthetic_weights(mcfg, seed)),
        WeightsSource::Detector => {
            anyhow::ensure!(
                !mcfg.use_layernorm,
                "detector weights need an LN-free model, '{}' has LayerNorm",
                mcfg.name
            );
            Ok(crate::models::weights::detector_weights(mcfg))
        }
        WeightsSource::Artifacts => {
            let path = dir.join(format!("{}.weights.nnw", pc.model));
            let file = NnwFile::load(&path)?;
            Weights::from_nnw(mcfg, &file)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(backend: BackendKind, n: u64) -> ServerConfig {
        ServerConfig {
            pipelines: vec![PipelineConfig {
                weights: WeightsSource::Synthetic(1),
                ..PipelineConfig::new("engine", backend)
            }],
            events_per_source: n,
            rate_per_source: 0,
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        }
    }

    fn stream_cfg(samples: u64, hop: usize) -> ServerConfig {
        let seq_len = zoo_model("engine").unwrap().config.seq_len;
        ServerConfig {
            pipelines: vec![PipelineConfig {
                weights: WeightsSource::Detector,
                source: SourceMode::Stream(StreamSource {
                    samples,
                    hop,
                    strain: StrainConfig::new(0xA11CE, 1, seq_len),
                    reuse: true,
                }),
                ..PipelineConfig::new("engine", BackendKind::Float)
            }],
            events_per_source: 0,
            rate_per_source: 0,
            artifacts_dir: PathBuf::from("."),
            ..Default::default()
        }
    }

    #[test]
    fn float_pipeline_serves_every_event() {
        let report = TriggerServer::run(&base_cfg(BackendKind::Float, 300)).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 300);
        assert_eq!(s.dropped, 0, "no batch failures on the float backend");
        assert!(s.accepted > 0);
        assert!(s.latency.count() == s.accepted);
        assert!(s.online_auc().is_some());
        assert_eq!(s.shards.len(), 1, "default is a single-replica pool");
    }

    #[test]
    fn hls_pipeline_runs() {
        let report = TriggerServer::run(&base_cfg(BackendKind::Hls, 40)).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 40);
        assert!(s.mean_batch_fill() >= 1.0);
    }

    #[test]
    fn multi_model_server() {
        let mut cfg = base_cfg(BackendKind::Float, 120);
        cfg.pipelines.push(PipelineConfig {
            weights: WeightsSource::Synthetic(2),
            ..PipelineConfig::new("gw", BackendKind::Float)
        });
        let report = TriggerServer::run(&cfg).unwrap();
        assert_eq!(report.per_model.len(), 2);
        assert!(report.throughput_eps() > 0.0);
        let text = format!("{report}");
        assert!(text.contains("engine") && text.contains("gw"));
    }

    #[test]
    fn backpressure_sheds_instead_of_stalling() {
        // tiny ring + slow hls backend + fast source => shedding
        let mut cfg = base_cfg(BackendKind::Hls, 500);
        cfg.pipelines[0].ring_capacity = 4;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 500);
        assert!(s.shed > 0, "expected source-side shedding under overload");
        assert_eq!(
            s.dropped, 0,
            "shed events are router-side; the workers dropped nothing"
        );
        // the report names both loss counters
        let text = format!("{report}");
        assert!(text.contains("shed="), "{text}");
        assert!(text.contains("dropped="), "{text}");
    }

    #[test]
    fn sharded_pool_serves_every_event_with_shard_accounting() {
        let mut cfg = base_cfg(BackendKind::Float, 300);
        cfg.pipelines[0].replicas = 3;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        // ring capacity (1024/shard) dwarfs the event count: no shedding
        assert_eq!(s.accepted, 300);
        assert_eq!(s.lost(), 0);
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards.iter().map(|sh| sh.accepted).sum::<u64>(), 300);
        assert_eq!(
            s.shards.iter().map(|sh| sh.batches).sum::<u64>(),
            s.batches
        );
        assert_eq!(
            s.shards.iter().map(|sh| sh.latency.count()).sum::<u64>(),
            s.latency.count()
        );
        // rebalanced events are a subset of accepted ones
        assert!(s.rebalanced <= s.accepted);
        // the report renders the shard breakdown
        let text = format!("{report}");
        assert!(text.contains("shard 0:") && text.contains("shard 2:"), "{text}");
        assert!(text.contains("rebalanced"), "{text}");
    }

    #[test]
    fn sharded_auc_is_bit_identical_to_single_replica() {
        // same deterministic source + weights, no shedding in either run
        // => identical score *sets*; the rank-based AUC is order-free, so
        // the two pools must agree exactly
        let run = |replicas: usize| {
            let mut cfg = base_cfg(BackendKind::Float, 240);
            cfg.pipelines[0].replicas = replicas;
            let report = TriggerServer::run(&cfg).unwrap();
            let s = &report.per_model["engine"];
            assert_eq!(s.lost(), 0, "ring must not shed at this event count");
            s.online_auc().unwrap()
        };
        let single = run(1);
        let pooled = run(4);
        assert!(
            (single - pooled).abs() < 1e-12,
            "replicas=1 auc {single} vs replicas=4 auc {pooled}"
        );
    }

    #[test]
    fn serve_round_trips_a_serialized_precision_plan() {
        // engine has 3 blocks; serialize a mixed plan, feed the text
        // through the pipeline config (what `repro serve
        // --precision-plan` does), and the server must come up and score
        // every event through the heterogeneous engine
        let mut plan = PrecisionPlan::uniform(3, QuantConfig::new(6, 10));
        plan.set_data("block1.ffn1", crate::fixed::FixedSpec::new(10, 4)).unwrap();
        plan.set_data("softmax", crate::fixed::FixedSpec::new(12, 3)).unwrap();
        let text = plan.serialize();
        // the text itself round-trips
        let mut rt = PrecisionPlan::uniform(3, QuantConfig::new(6, 10));
        rt.apply_overrides(&text).unwrap();
        assert_eq!(rt, plan);
        let mut cfg = base_cfg(BackendKind::Hls, 30);
        cfg.pipelines[0].precision_plan = Some(text);
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 30);
        assert!(s.accepted > 0);
    }

    #[test]
    fn serve_round_trips_a_serialized_reuse_plan() {
        // mirror of the precision-plan round trip for the parallelism
        // dial: feed `--reuse-plan` text through the pipeline config;
        // the server must come up, score every event (reuse is schedule
        // metadata, never semantics), and report the modeled design
        // point under the mixed plan
        let mut plan = ParallelismPlan::uniform(3, ReuseFactor(1));
        plan.set("pool", ReuseFactor(2)).unwrap();
        plan.set("block1.ffn1", ReuseFactor(4)).unwrap();
        let text = plan.serialize();
        let mut rt = ParallelismPlan::uniform(3, ReuseFactor(1));
        rt.apply_overrides(&text).unwrap();
        assert_eq!(rt, plan);
        let mut cfg = base_cfg(BackendKind::Hls, 30);
        cfg.pipelines[0].reuse_plan = Some(text);
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 30);
        assert!(s.accepted > 0);
        let modeled = report.modeled_designs.get("engine").expect("hls models a design");
        assert_eq!(modeled.parallelism, plan);
        let text = format!("{report}");
        assert!(text.contains("modeled FPGA"), "{text}");
        assert!(text.contains("Rmixed<1..4>"), "{text}");
    }

    #[test]
    fn malformed_reuse_plan_errors_before_spawning() {
        let mut cfg = base_cfg(BackendKind::Hls, 10);
        cfg.pipelines[0].reuse_plan = Some("block0.ffn1 R0".into());
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("block0.ffn1"), "{msg}");
        assert!(msg.contains("engine"), "{msg}");
    }

    #[test]
    fn float_pipeline_reports_no_modeled_design() {
        let report = TriggerServer::run(&base_cfg(BackendKind::Float, 20)).unwrap();
        assert!(report.modeled_designs.is_empty());
        assert!(report.compiled.is_empty(), "float pipelines compile nothing");
        assert!(!format!("{report}").contains("compiled plan"));
    }

    #[test]
    fn hls_pool_reports_the_shared_compiled_artifact() {
        // the compile-once line of `repro serve`: build time + artifact
        // size recorded at resolution, replica count of the pool that
        // shares it (Arc sharing itself is asserted in backend.rs —
        // `replica_backends_share_one_compiled_artifact`)
        let mut cfg = base_cfg(BackendKind::Hls, 60);
        cfg.pipelines[0].replicas = 3;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 60);
        assert_eq!(s.shards.len(), 3);
        let ci = report.compiled.get("engine").expect("hls pipeline reports its artifact");
        assert!(ci.bytes > 0, "artifact has weight tiles");
        assert_eq!(ci.replicas, 3);
        let text = format!("{report}");
        assert!(text.contains("compiled plan: built once in"), "{text}");
        assert!(text.contains("shared by 3 replica(s)"), "{text}");
    }

    #[test]
    fn malformed_precision_plan_errors_before_spawning() {
        let mut cfg = base_cfg(BackendKind::Hls, 10);
        cfg.pipelines[0].precision_plan = Some("blurb ap_fixed<8,3>".into());
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("blurb"), "{msg}");
        assert!(msg.contains("engine"), "{msg}");
    }

    #[test]
    fn saturating_precision_plan_is_refused_before_spawning() {
        // a plan the static verifier flags as ERROR must be a clean Err
        // during up-front resolution — no pool spawns, no modeled design.
        // ap_fixed<2,1> caps block1.ffn1's input cast at 0.5 while the
        // residual stream runs well past it on the fixed probe inputs.
        let mut cfg = base_cfg(BackendKind::Hls, 10);
        cfg.pipelines[0].precision_plan = Some("block1.ffn1 ap_fixed<2,1>".into());
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err(), "verifier must refuse the saturating plan");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("plan verification failed"), "{msg}");
        assert!(msg.contains("block1.ffn1"), "{msg}");
        assert!(msg.contains("engine"), "{msg}");
    }

    #[test]
    fn clamp_violating_precision_plan_is_refused_before_spawning() {
        // the structural pass (data int bits above the 10-bit accumulator
        // clamp) gates the spawn profile-free — deterministic regardless
        // of the probe margin
        let mut cfg = base_cfg(BackendKind::Hls, 10);
        cfg.pipelines[0].precision_plan = Some("block0.ffn1 ap_fixed<16,12>".into());
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("block0.ffn1"), "{msg}");
        assert!(msg.contains("plan verification failed"), "{msg}");
    }

    #[test]
    fn later_pipeline_setup_error_is_a_clean_err() {
        // an unknown model in the *second* pipeline must fail during
        // up-front resolution, before the first pipeline's pool spawns
        let mut cfg = base_cfg(BackendKind::Float, 10);
        cfg.pipelines.push(PipelineConfig {
            weights: WeightsSource::Synthetic(2),
            ..PipelineConfig::new("bogus", BackendKind::Float)
        });
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("bogus"));
    }

    #[test]
    fn duplicate_model_pipelines_error_before_spawning() {
        // a duplicate route would orphan the first pool; must be a clean
        // Err up front, not a hang at join time
        let mut cfg = base_cfg(BackendKind::Float, 10);
        cfg.pipelines.push(cfg.pipelines[0].clone());
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("duplicate pipeline"));
    }

    #[test]
    fn stream_mode_scores_every_window_with_positions_and_truth() {
        let (samples, hop) = (6_000u64, 25usize);
        let report = TriggerServer::run(&stream_cfg(samples, hop)).unwrap();
        let s = &report.per_model["engine"];
        let seq_len = zoo_model("engine").unwrap().config.seq_len as u64;
        let expect = (samples - seq_len) / hop as u64 + 1;
        assert_eq!(s.accepted + s.lost(), expect);
        assert_eq!(s.lost(), 0, "1024-deep ring must absorb this stream");
        assert_eq!(s.windows.len() as u64, expect, "every window recorded");
        assert!(s.scored_labels.is_empty(), "stream windows carry no labels");
        // positions are exactly the hop grid (sort: batches interleave)
        let mut got: Vec<u64> = s.windows.iter().map(|w| w.pos).collect();
        got.sort_unstable();
        let want: Vec<u64> = (0..expect).map(|k| k * hop as u64).collect();
        assert_eq!(got, want);
        // truth came through, and every center is inside the stream
        let truth = &report.stream_truth["engine"];
        assert!(!truth.is_empty());
        assert!(truth.iter().all(|i| i.t0 < samples + seq_len));
        // the report mentions the streamed windows
        let text = format!("{report}");
        assert!(text.contains("windows scored"), "{text}");
    }

    #[test]
    fn stream_reuse_counters_fold_into_the_report() {
        // single shard, hop < seq_len: the first window is cold, every
        // later one goes through the incremental path with exactly
        // seq_len - hop carried rows
        let (samples, hop) = (6_000u64, 25usize);
        let report = TriggerServer::run(&stream_cfg(samples, hop)).unwrap();
        let s = &report.per_model["engine"];
        let seq_len = zoo_model("engine").unwrap().config.seq_len as u64;
        let expect = (samples - seq_len) / hop as u64 + 1;
        assert_eq!(s.lost(), 0);
        assert_eq!(s.reuse.windows(), expect);
        assert_eq!(s.reuse.windows_full, 1);
        assert_eq!(s.reuse.windows_incremental, expect - 1);
        assert_eq!(s.reuse.rows_reused, (expect - 1) * (seq_len - hop as u64));
        assert!(s.reuse.cache_bytes > 0);
        // per-shard snapshots carry the stream accounting too
        assert_eq!(s.shards.len(), 1);
        assert_eq!(s.shards[0].windows, expect);
        assert_eq!(s.shards[0].reuse, s.reuse, "single-shard reuse snapshot");
        let text = format!("{report}");
        assert!(text.contains("reuse:"), "{text}");
        assert!(text.contains("windows incremental"), "{text}");
    }

    #[test]
    fn stream_reuse_scores_bitwise_match_the_naive_path() {
        // the serving-level contract: reuse on/off (and sharded/unsharded)
        // must produce the exact same (pos, score) set
        let run = |reuse: bool, replicas: usize| {
            let mut cfg = stream_cfg(5_000, 30);
            if let SourceMode::Stream(ss) = &mut cfg.pipelines[0].source {
                ss.reuse = reuse;
            }
            cfg.pipelines[0].replicas = replicas;
            let report = TriggerServer::run(&cfg).unwrap();
            let s = &report.per_model["engine"];
            assert_eq!(s.lost(), 0, "ring must not shed this stream");
            let mut w: Vec<(u64, u32)> =
                s.windows.iter().map(|w| (w.pos, w.score.to_bits())).collect();
            w.sort_unstable();
            (w, s.reuse.any_reuse())
        };
        let (naive, naive_reuse) = run(false, 1);
        let (inc, inc_reuse) = run(true, 1);
        assert!(!naive_reuse, "reuse=false must not engage the cache");
        assert!(inc_reuse, "hop < seq_len must engage reuse");
        assert_eq!(inc, naive, "incremental scores must be bitwise identical");
        // a sharded pool sees strided deltas; still bitwise identical
        let (pooled, _) = run(true, 3);
        assert_eq!(pooled, naive, "sharded incremental scores must match");
    }

    #[test]
    fn stream_channel_mismatch_errors_before_spawning() {
        let mut cfg = stream_cfg(2_000, 25);
        if let SourceMode::Stream(ss) = &mut cfg.pipelines[0].source {
            ss.strain.channels = 3; // engine takes 1
        }
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("channels"), "{msg}");
        assert!(msg.contains("engine"), "{msg}");
    }

    #[test]
    fn detector_weights_reject_ln_models_cleanly() {
        let mut cfg = base_cfg(BackendKind::Float, 10);
        cfg.pipelines[0] = PipelineConfig {
            weights: WeightsSource::Detector,
            ..PipelineConfig::new("gw", BackendKind::Float)
        };
        let err = TriggerServer::run(&cfg);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("LN-free"));
    }

    #[test]
    fn bursty_paced_source_still_delivers_every_event() {
        let mut cfg = base_cfg(BackendKind::Float, 400);
        cfg.rate_per_source = 20_000;
        cfg.burst_per_source = 16;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 400);
        assert_eq!(s.lost(), 0, "bursts of ~16 cannot fill a 1024 ring");
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let mut cfg = base_cfg(BackendKind::Float, 50);
        cfg.pipelines[0].replicas = 0;
        let report = TriggerServer::run(&cfg).unwrap();
        let s = &report.per_model["engine"];
        assert_eq!(s.accepted + s.lost(), 50);
        assert_eq!(s.shards.len(), 1);
    }

    #[test]
    fn with_replicas_builder() {
        let pc = PipelineConfig::new("engine", BackendKind::Float).with_replicas(4);
        assert_eq!(pc.replicas, 4);
        let d = PipelineConfig::new("engine", BackendKind::Float);
        assert_eq!(d.replicas, 1, "default stays single-replica");
    }
}
