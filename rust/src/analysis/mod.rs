//! Static plan verification (`repro lint-plan`): prove properties of a
//! `(PrecisionPlan, ParallelismPlan)` pair before a single event is
//! scored.
//!
//! Three dataflow passes over the site-graph IR ([`crate::ir`]), each
//! emitting severity-ranked, site-addressed diagnostics:
//!
//! 1. **interval / overflow** — compare per-site value intervals against
//!    each site's data and accumulator grids.  In *profile* mode
//!    (`events > 0`) intervals come from a deterministic probe run
//!    recorded through `forward_recorded` (plus the weight magnitudes),
//!    exactly the numbers `calibrate_plan` sees; saturation is an ERROR,
//!    provably over-provisioned integer bits a WARN (a bit-shave hint).
//!    In *worst-case* mode (`events == 0`) intervals are ∞-norm bounds
//!    propagated from the weights alone — explicitly pessimistic, for
//!    plans with no representative input distribution.  The per-site
//!    accumulator bound (`max_j |b_j| + Σ_i |w_ij|·a`) and the
//!    structural accumulator-clamp check run in both modes.
//! 2. **hotpath eligibility** — statically evaluate the
//!    [`crate::fixed::mantissa`] predicates the kernels dispatch on
//!    (`f32_grid_exact`, `f64_sum_exact`, the apply-V static gate) per
//!    site and WARN on every f64-reference fallback, so perf cliffs are
//!    diagnosable before benching.  Evaluated force-independently: the
//!    `f64-reference` feature pins the *dispatch*, not the prediction.
//! 3. **schedule / FIFO consistency** — walk the site graph's edges:
//!    producer/consumer II mismatches report their `fifo_depth` sizing
//!    and binding constraint (INFO), reuse factors that do not evenly
//!    divide a site's per-row work WARN through the checked-builder
//!    helper, and degenerate schedules are ERRORs.
//!
//! The verdict contract: a report with no ERRORs under profile mode is
//! *dynamically sound for the profiled inputs* — replaying the same
//! probe events through `FixedTransformer::forward` never hits a
//! saturation rail (property-tested below).  `repro serve`/`stream`
//! refuse ERROR-level plans before any worker pool spawns, and
//! `pareto_explore` prunes structurally-invalid candidates pre-scoring
//! via [`static_plan_errors`].

use crate::benchjson::escape;
use crate::fixed::mantissa::{f32_grid_exact, f64_sum_exact, int_mac_eligible};
use crate::fixed::spec::ACCUM_INT_BITS;
use crate::fixed::FixedSpec;
use crate::hls::calibration::int_bits_for_range;
use crate::hls::pipeline::{check_reuse_divides, fifo_depth_checked};
use crate::hls::precision::{calibrate_plan, record_weight_ranges, RangeProfile};
use crate::hls::{FixedTransformer, ParallelismPlan, PrecisionPlan, QuantConfig};
use crate::ir::{NodeOp, SiteGraph};
use crate::models::config::ModelConfig;
use crate::models::weights::Weights;
use crate::nn::tensor::Mat;
use crate::testutil::Gen;

/// Probe-run size of the default profile mode: enough events to exercise
/// every site's range without making `repro serve` startup noticeable.
pub const PROBE_EVENTS: usize = 16;
/// Seed of the default probe run.  Fixed so `lint-plan`, the serve-time
/// gate and the soundness property tests all profile bit-identical
/// inputs — a clean verdict is reproducible, not sampled.
pub const PROBE_SEED: u64 = 0x11A7_5EED;

/// Diagnostic severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// The plan is unsafe to deploy (saturation, schedule deadlock).
    Error,
    /// Suboptimal but functional (over-provisioned bits, f64 fallback).
    Warning,
    /// Structural observation (FIFO sizing, dynamic-gate reminder).
    Info,
}

impl Severity {
    fn label(self) -> &'static str {
        match self {
            Severity::Error => "ERROR",
            Severity::Warning => "WARN",
            Severity::Info => "INFO",
        }
    }

    fn json(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }
}

/// One site-addressed finding of a verifier pass.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Which pass emitted it: `"interval"`, `"hotpath"` or `"schedule"`.
    pub pass: &'static str,
    /// The layer site (or `from->to` edge) the finding is anchored to.
    pub site: String,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] site '{}': {}",
            self.severity.label(),
            self.pass,
            self.site,
            self.message
        )
    }
}

/// The verifier's verdict for one `(model, precision, parallelism)`
/// triple: every diagnostic, sorted most-severe-first.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub model: String,
    pub diags: Vec<Diagnostic>,
}

impl VerifyReport {
    fn new(model: String, mut diags: Vec<Diagnostic>) -> Self {
        diags.sort_by_key(|d| d.severity);
        Self { model, diags }
    }

    pub fn has_errors(&self) -> bool {
        self.count(Severity::Error) > 0
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn count(&self, s: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity == s).count()
    }

    /// Human-readable rendering: a summary line plus one line per
    /// diagnostic, most severe first.
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "plan verification: {} — {} error(s), {} warning(s), {} info(s)\n",
            self.model,
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info)
        );
        for d in &self.diags {
            out.push_str(&format!("  {d}\n"));
        }
        if self.diags.is_empty() {
            out.push_str("  clean: no diagnostics\n");
        }
        out
    }

    /// One machine-readable JSON line (the `lint-plan --json` /
    /// `ci/bench_diff.py --plans` interchange format):
    /// `{"plan":...,"model":...,"errors":N,"warnings":N,"infos":N,
    ///   "diagnostics":[{"severity":...,"pass":...,"site":...,"message":...},...]}`.
    pub fn render_json(&self, label: &str) -> String {
        let diags: Vec<String> = self
            .diags
            .iter()
            .map(|d| {
                format!(
                    "{{\"severity\":\"{}\",\"pass\":\"{}\",\"site\":\"{}\",\"message\":\"{}\"}}",
                    d.severity.json(),
                    d.pass,
                    escape(&d.site),
                    escape(&d.message)
                )
            })
            .collect();
        format!(
            "{{\"plan\":\"{}\",\"model\":\"{}\",\"errors\":{},\"warnings\":{},\"infos\":{},\"diagnostics\":[{}]}}",
            escape(label),
            escape(&self.model),
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info),
            diags.join(",")
        )
    }
}

/// How the interval pass obtains its value intervals.
#[derive(Clone, Copy, Debug)]
pub struct VerifyConfig {
    /// Probe events for profile mode; `0` selects worst-case mode.
    pub events: usize,
    /// Probe-run seed (profile mode only).
    pub seed: u64,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        Self { events: PROBE_EVENTS, seed: PROBE_SEED }
    }
}

/// The deterministic probe inputs of profile mode: `n` unit-normal
/// events of the model's input shape from a seeded generator.
pub fn probe_events(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<Mat> {
    let mut g = Gen::new(seed);
    (0..n)
        .map(|_| {
            Mat::from_vec(
                cfg.seq_len,
                cfg.input_size,
                g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
            )
        })
        .collect()
}

/// [`calibrate_plan`] iterated to a fixpoint under the verifier's own
/// saturation criterion: after the wide-reference calibration pass,
/// re-profile under the *actual* plan and bump any site whose observed
/// pre-cast range exceeds its grid's `max_value()` — the exact condition
/// the interval pass flags as ERROR — until no site bumps (≤ 8 rounds).
/// A fixpoint plan therefore verifies clean on the same events by
/// construction, closing the one-LSB gap between
/// `int_bits_for_range`'s `2^(I-1)` coverage rule and the grid's true
/// ceiling `2^(I-1) - 2^-frac`.
pub fn calibrate_plan_fixpoint(
    cfg: &ModelConfig,
    float_weights: &Weights,
    events: &[Mat],
    frac_bits: u32,
) -> PrecisionPlan {
    let mut plan = calibrate_plan(cfg, float_weights, events, frac_bits);
    for _ in 0..8 {
        let t = FixedTransformer::with_plan(cfg.clone(), float_weights, plan.clone());
        let mut prof = RangeProfile::new();
        for x in events {
            t.forward_recorded(x, Some(&mut prof));
        }
        record_weight_ranges(&mut prof, float_weights);
        let mut bumped = false;
        for site in plan.site_names() {
            let Some(obs) = prof.max_abs(&site) else { continue };
            let q = plan.get(&site).expect("site_names yields known sites");
            if obs > q.data.max_value() {
                let frac = q.data.frac();
                let mut i = q.data.integer() + 1;
                while i < 14 && FixedSpec::new(i + frac, i).max_value() < obs {
                    i += 1;
                }
                plan.set_data(&site, FixedSpec::new(i + frac, i))
                    .expect("site_names yields known sites");
                bumped = true;
            }
        }
        if !bumped {
            break;
        }
    }
    plan
}

/// Run all three passes and return the verdict.  Panics when the plans'
/// block counts do not match the config (same contract as the engine
/// constructors); callers resolving untrusted plan files construct the
/// plans via `PrecisionPlan::uniform(cfg.num_blocks, ..)` first, so the
/// counts match by construction.
pub fn verify_plan(
    cfg: &ModelConfig,
    weights: &Weights,
    pp: &PrecisionPlan,
    par: &ParallelismPlan,
    vc: &VerifyConfig,
) -> VerifyReport {
    let graph = SiteGraph::build(cfg, pp, par, None);
    let mut diags = Vec::new();
    structural_pass(pp, &mut diags);
    if vc.events > 0 {
        interval_profile_pass(cfg, weights, pp, vc, &mut diags);
    } else {
        interval_worst_case_pass(cfg, weights, pp, &mut diags);
    }
    accumulator_pass(weights, pp, &mut diags);
    hotpath_pass(cfg, &graph, &mut diags);
    schedule_pass(&graph, &mut diags);
    VerifyReport::new(cfg.name.clone(), diags)
}

/// Profile-free ERROR count for one plan triple — the Pareto explorer's
/// pre-scoring pruning filter.  Covers the structural checks only (the
/// accumulator-clamp rule and degenerate schedules): everything that can
/// be decided without weights or a probe run.
pub fn static_plan_errors(
    cfg: &ModelConfig,
    pp: &PrecisionPlan,
    par: &ParallelismPlan,
) -> usize {
    let graph = SiteGraph::build(cfg, pp, par, None);
    let mut diags = Vec::new();
    structural_pass(pp, &mut diags);
    schedule_pass(&graph, &mut diags);
    diags.iter().filter(|d| d.severity == Severity::Error).count()
}

/// Structural accumulator-clamp rule: every accumulation is clamped onto
/// a `ACCUM_INT_BITS`-integer-bit grid (`FixedSpec::accum`), so a data
/// grid whose own integer range exceeds the clamp can round-trip values
/// the accumulator provably cannot hold.
fn structural_pass(pp: &PrecisionPlan, diags: &mut Vec<Diagnostic>) {
    for site in pp.site_names() {
        let q = pp.get(&site).expect("site_names yields known sites");
        if q.data.integer() > ACCUM_INT_BITS {
            diags.push(Diagnostic {
                severity: Severity::Error,
                pass: "interval",
                site,
                message: format!(
                    "data grid {} exceeds the {ACCUM_INT_BITS}-int-bit accumulator \
                     clamp range ({} can hold at most {:.1})",
                    q.data,
                    q.accum,
                    q.accum.max_value()
                ),
            });
        }
    }
}

/// Profile-mode interval pass: probe-run ranges (plus weight magnitudes)
/// against each site's data grid.
fn interval_profile_pass(
    cfg: &ModelConfig,
    weights: &Weights,
    pp: &PrecisionPlan,
    vc: &VerifyConfig,
    diags: &mut Vec<Diagnostic>,
) {
    let t = FixedTransformer::with_plan(cfg.clone(), weights, pp.clone());
    let mut prof = RangeProfile::new();
    for x in probe_events(cfg, vc.events, vc.seed) {
        t.forward_recorded(&x, Some(&mut prof));
    }
    record_weight_ranges(&mut prof, weights);
    for site in pp.site_names() {
        let Some(obs) = prof.max_abs(&site) else { continue };
        let q = pp.get(&site).expect("site_names yields known sites");
        if obs > q.data.max_value() {
            diags.push(Diagnostic {
                severity: Severity::Error,
                pass: "interval",
                site,
                message: format!(
                    "observed |x| {:.4} exceeds data grid {} max {:.4} — the cast \
                     saturates on the probe inputs",
                    obs,
                    q.data,
                    q.data.max_value()
                ),
            });
        } else {
            let required = int_bits_for_range(obs);
            if q.data.integer() > required + 1 {
                diags.push(Diagnostic {
                    severity: Severity::Warning,
                    pass: "interval",
                    site,
                    message: format!(
                        "integer bits over-provisioned: {} carries {} integer bits, \
                         observed |x| {:.4} needs {} (shave {} bits)",
                        q.data,
                        q.data.integer(),
                        obs,
                        required,
                        q.data.integer() - required
                    ),
                });
            }
        }
    }
}

/// `max_j (|b_j| + a · Σ_i |w_ij|)` — the worst dot-product magnitude a
/// dense site can produce from inputs bounded by `a`.
fn mac_bound(w: &Mat, b: &[f32], a: f64) -> f64 {
    let mut worst = 0f64;
    for j in 0..w.cols() {
        let mut s = 0f64;
        for i in 0..w.rows() {
            s += w.at(i, j).abs() as f64;
        }
        let bias = b.get(j).map(|x| x.abs() as f64).unwrap_or(0.0);
        worst = worst.max(bias + a * s);
    }
    worst
}

/// Push an accumulator-saturation ERROR when the worst-case MAC bound
/// for one site exceeds its accum grid.
fn check_accum(diags: &mut Vec<Diagnostic>, site: String, q: QuantConfig, bound: f64) {
    if bound > q.accum.max_value() {
        diags.push(Diagnostic {
            severity: Severity::Error,
            pass: "interval",
            site,
            message: format!(
                "worst-case accumulator bound {:.1} exceeds {} max {:.1} — \
                 the MAC can saturate before the output cast",
                bound,
                q.accum,
                q.accum.max_value()
            ),
        });
    }
}

/// Every MAC site's worst-case accumulator bound vs its accum grid, with
/// inputs at the site's own grid ceiling (inputs are cast onto the data
/// grid before the MAC, so the bound is rigorous).  Runs in both modes.
fn accumulator_pass(weights: &Weights, pp: &PrecisionPlan, diags: &mut Vec<Diagnostic>) {
    let q = pp.embed();
    check_accum(
        diags,
        "embed".into(),
        q,
        mac_bound(&weights.embed.0, &weights.embed.1, q.data.max_value()),
    );
    for (b, bw) in weights.blocks.iter().enumerate() {
        let bp = *pp.block(b);
        let a = bp.qkv.data.max_value();
        let qkv_bound = (0..bw.mha.wq.len())
            .flat_map(|h| {
                [
                    mac_bound(&bw.mha.wq[h], &bw.mha.bq[h], a),
                    mac_bound(&bw.mha.wk[h], &bw.mha.bk[h], a),
                    mac_bound(&bw.mha.wv[h], &bw.mha.bv[h], a),
                ]
            })
            .fold(0f64, f64::max);
        check_accum(diags, format!("block{b}.mha.qkv"), bp.qkv, qkv_bound);
        check_accum(
            diags,
            format!("block{b}.mha.out"),
            bp.mha_out,
            mac_bound(&bw.mha.wo, &bw.mha.bo, bp.mha_out.data.max_value()),
        );
        check_accum(
            diags,
            format!("block{b}.ffn1"),
            bp.ffn1,
            mac_bound(&bw.ffn1.0, &bw.ffn1.1, bp.ffn1.data.max_value()),
        );
        check_accum(
            diags,
            format!("block{b}.ffn2"),
            bp.ffn2,
            mac_bound(&bw.ffn2.0, &bw.ffn2.1, bp.ffn2.data.max_value()),
        );
    }
    let q = pp.head();
    check_accum(
        diags,
        "head".into(),
        q,
        mac_bound(&weights.head.0, &weights.head.1, q.data.max_value()),
    );
    let q = pp.out();
    check_accum(
        diags,
        "out".into(),
        q,
        mac_bound(&weights.out.0, &weights.out.1, q.data.max_value()),
    );
}

/// Worst-case interval mode: ∞-norm bounds propagated from the embed
/// grid's AXI cast through every kernel, flagging any site whose
/// pre-clamp bound exceeds its grid ceiling.  Explicitly pessimistic
/// (triangle-inequality bounds compound per layer) — an opt-in audit
/// mode (`lint-plan --events 0`), not the serve gate.
fn interval_worst_case_pass(
    cfg: &ModelConfig,
    weights: &Weights,
    pp: &PrecisionPlan,
    diags: &mut Vec<Diagnostic>,
) {
    // ∞-norm bound of a LayerNorm output: normalized deviations are
    // bounded by sqrt(d) before the affine
    let ln_bound = |ln: &crate::models::weights::LnWeights| -> f64 {
        let g_max = ln.gamma.iter().fold(0f32, |m, &x| m.max(x.abs())) as f64;
        let b_max = ln.beta.iter().fold(0f32, |m, &x| m.max(x.abs())) as f64;
        g_max * (cfg.d_model as f64).sqrt() + b_max
    };
    // AXI boundary: inputs are clamped onto the embed grid
    let mut a = pp.embed().data.max_value();
    a = flag_bound(
        diags,
        "embed".into(),
        mac_bound(&weights.embed.0, &weights.embed.1, a),
        pp.embed().data,
    );
    for (b, bw) in weights.blocks.iter().enumerate() {
        let bp = *pp.block(b);
        // Q/K/V projections; softmax probabilities live in [0,1], so the
        // apply-V output is a convex combination bounded by max |v|
        let v_bound = (0..bw.mha.wv.len())
            .map(|h| mac_bound(&bw.mha.wv[h], &bw.mha.bv[h], a))
            .fold(0f64, f64::max)
            .min(bp.qkv.data.max_value());
        let wo_bound = mac_bound(&bw.mha.wo, &bw.mha.bo, v_bound);
        // residual add on the mha.out grid
        a = flag_bound(diags, format!("block{b}.mha.out"), a + wo_bound, bp.mha_out.data);
        if cfg.use_layernorm {
            let ln = bw.ln1.as_ref().expect("use_layernorm implies ln weights");
            a = flag_bound(diags, format!("block{b}.ln1"), ln_bound(ln), bp.ln1.data);
        }
        let pre_ffn = a;
        // ReLU does not increase magnitude
        let f1 = flag_bound(
            diags,
            format!("block{b}.ffn1"),
            mac_bound(&bw.ffn1.0, &bw.ffn1.1, a),
            bp.ffn1.data,
        );
        let f2 = mac_bound(&bw.ffn2.0, &bw.ffn2.1, f1);
        a = flag_bound(diags, format!("block{b}.ffn2"), pre_ffn + f2, bp.ffn2.data);
        if cfg.use_layernorm {
            let ln = bw.ln2.as_ref().expect("use_layernorm implies ln weights");
            a = flag_bound(diags, format!("block{b}.ln2"), ln_bound(ln), bp.ln2.data);
        }
    }
    // pooling is a mean: bound unchanged
    a = flag_bound(diags, "pool".into(), a, pp.pool().data);
    let h = flag_bound(
        diags,
        "head".into(),
        mac_bound(&weights.head.0, &weights.head.1, a),
        pp.head().data,
    );
    flag_bound(
        diags,
        "out".into(),
        mac_bound(&weights.out.0, &weights.out.1, h),
        pp.out().data,
    );
    // attention and final-activation probabilities reach 1.0
    if 1.0 > pp.softmax().data.max_value() {
        diags.push(Diagnostic {
            severity: Severity::Error,
            pass: "interval",
            site: "softmax".into(),
            message: format!(
                "softmax grid {} cannot represent probability 1.0",
                pp.softmax().data
            ),
        });
    }
}

/// Flag a worst-case saturation ERROR when `bound` exceeds the grid's
/// ceiling; return the clamped bound (what actually flows downstream).
fn flag_bound(diags: &mut Vec<Diagnostic>, site: String, bound: f64, spec: FixedSpec) -> f64 {
    if bound > spec.max_value() {
        diags.push(Diagnostic {
            severity: Severity::Error,
            pass: "interval",
            site,
            message: format!(
                "worst-case bound {:.3} exceeds data grid {} max {:.3} \
                 (∞-norm propagation; pessimistic)",
                bound,
                spec,
                spec.max_value()
            ),
        });
    }
    bound.min(spec.max_value())
}

/// Push a hotpath-fallback WARN for one site.
fn warn_fallback(diags: &mut Vec<Diagnostic>, site: String, what: &str, data: FixedSpec) {
    diags.push(Diagnostic {
        severity: Severity::Warning,
        pass: "hotpath",
        site,
        message: format!(
            "{what} falls back to the f64 reference path at {data} \
             (grid or accumulation not provably exact in the integer lanes)"
        ),
    });
}

/// Statically evaluate every kernel's dispatch predicate (minus the
/// global force switch) and WARN on each f64-reference fallback.
fn hotpath_pass(cfg: &ModelConfig, graph: &SiteGraph, diags: &mut Vec<Diagnostic>) {
    for n in &graph.nodes {
        match &n.op {
            NodeOp::Dense { n_in, .. } => {
                if !int_mac_eligible(n.data, n.accum, *n_in) {
                    warn_fallback(diags, n.precision_site.clone(), "MAC", n.data);
                }
            }
            NodeOp::Mha { heads, head_dim, out, softmax, .. } => {
                // Q/K/V projections (n_in = d_model) and QK^T scores
                // (n_in = head_dim) both dispatch on the qkv site
                if !int_mac_eligible(n.data, n.accum, cfg.d_model) {
                    warn_fallback(diags, n.precision_site.clone(), "projection MAC", n.data);
                }
                if !int_mac_eligible(n.data, n.accum, *head_dim) {
                    warn_fallback(diags, n.precision_site.clone(), "QK^T score MAC", n.data);
                }
                // output projection (n_in = heads * head_dim)
                if !int_mac_eligible(out.data, out.accum, heads * head_dim) {
                    let wo_site = n.precision_site.replace(".qkv", ".out");
                    warn_fallback(diags, wo_site, "output-projection MAC", out.data);
                }
                // softmax exp-sum over the attention row
                if !(f32_grid_exact(softmax.data) && f64_sum_exact(softmax.data, cfg.seq_len)) {
                    warn_fallback(diags, "softmax".into(), "softmax exp-sum", softmax.data);
                }
                // apply-V static gate; the integer path additionally
                // guards per row on the f32 exactness limit
                if f32_grid_exact(softmax.data) && f32_grid_exact(n.data) {
                    diags.push(Diagnostic {
                        severity: Severity::Info,
                        pass: "hotpath",
                        site: n.precision_site.clone(),
                        message: "apply-V takes the integer path under a per-row \
                                  dynamic bound (rows at the f32 exactness limit \
                                  fall back individually)"
                            .into(),
                    });
                } else {
                    warn_fallback(diags, n.precision_site.clone(), "apply-V", n.data);
                }
            }
            NodeOp::LayerNorm { d } => {
                if !(int_mac_eligible(n.data, n.accum, *d) && f64_sum_exact(n.data, *d)) {
                    warn_fallback(
                        diags,
                        n.precision_site.clone(),
                        "LayerNorm mean/variance",
                        n.data,
                    );
                }
            }
            NodeOp::Pool { rows } => {
                if !(f32_grid_exact(n.data) && f64_sum_exact(n.data, *rows)) {
                    warn_fallback(diags, n.precision_site.clone(), "pooling sum", n.data);
                }
            }
        }
    }
}

/// Walk the graph's edges and nodes for schedule consistency: II
/// mismatches (INFO with the FIFO sizing and binding constraint),
/// non-dividing reuse factors (WARN via the checked-builder rule), and
/// degenerate schedules (ERROR).
fn schedule_pass(graph: &SiteGraph, diags: &mut Vec<Diagnostic>) {
    for (i, n) in graph.nodes.iter().enumerate() {
        let per_row = match &n.op {
            NodeOp::Dense { n_in, .. } => *n_in,
            NodeOp::LayerNorm { d } => *d,
            // the MHA/pool builders divide the stream width they emit
            NodeOp::Mha { .. } | NodeOp::Pool { .. } => graph
                .edges
                .iter()
                .find(|e| e.from == i)
                .map(|e| e.elems)
                .unwrap_or(0),
        };
        if per_row > 0 {
            if let Err(e) = check_reuse_divides(&n.precision_site, n.reuse, per_row) {
                diags.push(Diagnostic {
                    severity: Severity::Warning,
                    pass: "schedule",
                    site: n.precision_site.clone(),
                    message: e,
                });
            }
        }
        if n.stage.ii == 0 || n.stage.rows == 0 {
            diags.push(Diagnostic {
                severity: Severity::Error,
                pass: "schedule",
                site: n.precision_site.clone(),
                message: format!(
                    "degenerate schedule: II {} / rows {} cannot stream",
                    n.stage.ii, n.stage.rows
                ),
            });
        }
    }
    for e in &graph.edges {
        let p = &graph.nodes[e.from];
        let c = &graph.nodes[e.to];
        match fifo_depth_checked(&p.stage, &c.stage) {
            Ok(depth) if depth > 1 => {
                diags.push(Diagnostic {
                    severity: Severity::Info,
                    pass: "schedule",
                    site: format!("{}->{}", p.name, c.name),
                    message: format!(
                        "consumer II {} exceeds producer II {} — stream FIFO \
                         depth {} rows ({} bits); the consumer II is the \
                         binding constraint",
                        c.stage.ii,
                        p.stage.ii,
                        depth,
                        depth * e.elems as u64 * e.spec.width() as u64
                    ),
                });
            }
            Ok(_) => {}
            Err(msg) => diags.push(Diagnostic {
                severity: Severity::Error,
                pass: "schedule",
                site: format!("{}->{}", p.name, c.name),
                message: msg,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ReuseFactor;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo;

    fn setup(name: &str) -> (ModelConfig, Weights, Vec<Mat>) {
        let cfg = zoo().into_iter().find(|m| m.config.name == name).unwrap().config;
        let weights = synthetic_weights(&cfg, 0x5EED_5);
        let events = probe_events(&cfg, PROBE_EVENTS, PROBE_SEED);
        (cfg, weights, events)
    }

    #[test]
    fn zoo_fixpoint_plans_verify_clean_and_are_dynamically_sound() {
        for m in zoo() {
            let (cfg, weights, events) = setup(&m.config.name);
            let plan = calibrate_plan_fixpoint(&cfg, &weights, &events, 10);
            let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
            let report =
                verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
            assert!(
                !report.has_errors(),
                "{}: {}",
                cfg.name,
                report.render_text()
            );
            // soundness: replay the probe inputs dynamically; no site's
            // pre-cast stream may exceed its grid ceiling (the
            // saturation rail the ERROR diagnostic predicts)
            let t = FixedTransformer::with_plan(cfg.clone(), &weights, plan.clone());
            let mut prof = RangeProfile::new();
            for x in &events {
                t.forward_recorded(x, Some(&mut prof));
            }
            record_weight_ranges(&mut prof, &weights);
            for site in plan.site_names() {
                let Some(obs) = prof.max_abs(&site) else { continue };
                let q = plan.get(&site).unwrap();
                assert!(
                    obs <= q.data.max_value(),
                    "{}/{site}: dynamic |x| {obs} saturates {} despite a clean verdict",
                    cfg.name,
                    q.data
                );
            }
        }
    }

    #[test]
    fn prop_clean_verdict_holds_on_random_in_range_inputs() {
        // soundness beyond the probe replay: on the LN-free model every
        // layer's magnitude is monotone in input amplitude (dense/MHA are
        // linear in the stream, softmax weights are convex), so fresh
        // random windows at half the probe amplitude are strictly inside
        // the calibrated envelope — a clean verdict must mean the
        // quantized forward pass never saturates a single site on them.
        // (LayerNorm is scale-invariant, so this amplitude argument only
        // binds on `engine`; the LN models are covered by the exact
        // probe replay in the fixpoint soundness test.)
        let (cfg, weights, events) = setup("engine");
        let plan = calibrate_plan_fixpoint(&cfg, &weights, &events, 10);
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        let report = verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
        assert!(!report.has_errors(), "{}", report.render_text());
        let t = FixedTransformer::with_plan(cfg.clone(), &weights, plan.clone());
        for seed in [1u64, 0xDECADE, 0xFEED_F00D] {
            let mut g = Gen::new(seed);
            let mut prof = RangeProfile::new();
            for _ in 0..8 {
                let x = Mat::from_vec(
                    cfg.seq_len,
                    cfg.input_size,
                    g.normal_vec(cfg.seq_len * cfg.input_size, 0.5),
                );
                t.forward_recorded(&x, Some(&mut prof));
            }
            for site in plan.site_names() {
                let Some(obs) = prof.max_abs(&site) else { continue };
                let q = plan.get(&site).unwrap();
                assert!(
                    obs <= q.data.max_value(),
                    "seed {seed:#x} {site}: |x| {obs} saturates {} despite a clean verdict",
                    q.data
                );
            }
        }
    }

    #[test]
    fn narrowed_ffn1_yields_a_site_named_error_on_every_zoo_model() {
        for m in zoo() {
            let (cfg, weights, events) = setup(&m.config.name);
            let mut plan = calibrate_plan_fixpoint(&cfg, &weights, &events, 10);
            // measure the site's observed range, then narrow the grid
            // two integer bits below what it needs (clamped to I>=1)
            let t = FixedTransformer::with_plan(cfg.clone(), &weights, plan.clone());
            let mut prof = RangeProfile::new();
            for x in &events {
                t.forward_recorded(x, Some(&mut prof));
            }
            record_weight_ranges(&mut prof, &weights);
            let site = "block0.ffn1";
            let obs = prof.max_abs(site).expect("ffn1 is profiled");
            let i_cal = plan.get(site).unwrap().data.integer();
            let narrowed = [
                FixedSpec::try_new(i_cal.saturating_sub(2).max(1) + 10, i_cal.saturating_sub(2).max(1)),
                FixedSpec::try_new(7, 1),
                FixedSpec::try_new(2, 1),
            ]
            .into_iter()
            .flatten()
            .find(|s| s.max_value() < obs)
            .expect("ffn1 range exceeds the narrowest representable grid");
            plan.set_data(site, narrowed).unwrap();
            let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
            let report =
                verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
            assert!(report.has_errors(), "{}: narrowing must error", cfg.name);
            assert!(
                report.errors().any(|d| d.site == site && d.pass == "interval"),
                "{}: {}",
                cfg.name,
                report.render_text()
            );
        }
    }

    #[test]
    fn uniform_default_plans_take_the_hotpath_everywhere() {
        for m in zoo() {
            let (cfg, weights, _) = setup(&m.config.name);
            let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
            let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
            let report =
                verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
            assert_eq!(
                report
                    .diags
                    .iter()
                    .filter(|d| d.pass == "hotpath" && d.severity == Severity::Warning)
                    .count(),
                0,
                "{}: {}",
                cfg.name,
                report.render_text()
            );
        }
    }

    #[test]
    fn wide_grid_site_predicts_f64_fallback_and_batch_stays_bitwise() {
        let (cfg, weights, events) = setup("engine");
        let mut plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        // width 30 > 25: f32_grid_exact fails, the MAC must fall back
        plan.set_data("block1.ffn1", FixedSpec::new(30, 4)).unwrap();
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        let report = verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
        assert!(!report.has_errors(), "{}", report.render_text());
        let fallbacks: Vec<&Diagnostic> = report
            .diags
            .iter()
            .filter(|d| d.pass == "hotpath" && d.severity == Severity::Warning)
            .collect();
        assert_eq!(fallbacks.len(), 1, "{}", report.render_text());
        assert_eq!(fallbacks[0].site, "block1.ffn1");
        // the prediction mirrors the kernel's own dispatch predicate
        let q = plan.get("block1.ffn1").unwrap();
        assert!(!int_mac_eligible(q.data, q.accum, cfg.d_model));
        let q0 = plan.get("block0.ffn1").unwrap();
        assert!(int_mac_eligible(q0.data, q0.accum, cfg.d_model));
        // mixed-eligibility dispatch must not break the batch contract:
        // per-event and batched forwards stay bit-identical
        let t = FixedTransformer::with_plan(cfg.clone(), &weights, plan);
        let refs: Vec<&Mat> = events.iter().take(4).collect();
        let batched = t.forward_batch(&refs);
        for (x, got) in refs.iter().zip(&batched) {
            assert_eq!(&t.forward(x), got);
        }
    }

    #[test]
    fn worst_case_mode_flags_the_narrowed_plan_without_running_events() {
        let (cfg, weights, _) = setup("engine");
        let mut plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        plan.set_data("block0.ffn1", FixedSpec::new(2, 1)).unwrap();
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        let vc = VerifyConfig { events: 0, seed: 0 };
        let report = verify_plan(&cfg, &weights, &plan, &par, &vc);
        assert!(report.has_errors());
        assert!(report.errors().any(|d| d.site == "block0.ffn1"));
    }

    #[test]
    fn schedule_pass_reports_non_dividing_reuse_and_fifo_sizing() {
        let (cfg, weights, _) = setup("engine");
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let mut par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        // d_model = 16: R3 does not divide the ffn1 MAC row
        par.set("block0.ffn1", ReuseFactor(3)).unwrap();
        let report = verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
        assert!(!report.has_errors(), "{}", report.render_text());
        assert!(report.diags.iter().any(|d| {
            d.pass == "schedule"
                && d.severity == Severity::Warning
                && d.site == "block0.ffn1"
                && d.message.contains("does not evenly divide")
        }));
        // the slower consumer's upstream edge gets a FIFO-sizing info
        assert!(report.diags.iter().any(|d| {
            d.pass == "schedule" && d.severity == Severity::Info && d.site.contains("->block0.ffn1")
        }));
    }

    #[test]
    fn structural_clamp_violation_is_a_profile_free_error() {
        let cfg = zoo().into_iter().find(|m| m.config.name == "engine").unwrap().config;
        let mut plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        plan.set_data("block0.ffn1", FixedSpec::new(16, 12)).unwrap();
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        assert!(static_plan_errors(&cfg, &plan, &par) > 0);
        let clean = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        assert_eq!(static_plan_errors(&cfg, &clean, &par), 0);
    }

    #[test]
    fn json_rendering_is_one_escaped_line() {
        let (cfg, weights, _) = setup("engine");
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        let report = verify_plan(&cfg, &weights, &plan, &par, &VerifyConfig::default());
        let line = report.render_json("uniform-6-10");
        assert!(!line.contains('\n'));
        assert!(line.starts_with("{\"plan\":\"uniform-6-10\",\"model\":\"engine\","));
        assert!(line.contains("\"errors\":0"));
        assert!(line.contains("\"diagnostics\":["));
        // severity ordering: errors sort before warnings before infos
        let mut last = Severity::Error;
        for d in &report.diags {
            assert!(d.severity >= last);
            last = d.severity;
        }
    }
}
