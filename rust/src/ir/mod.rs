//! Site-graph IR: the typed layer-site graph shared by the precision
//! and parallelism plans, the schedule model, and the static verifier.
//!
//! The same site vocabulary (embed, per-block `mha.qkv` / `mha.out` /
//! `ln1` / `ffn1` / `ffn2` / `ln2`, pool, head, out, softmax) used to be
//! re-derived in four places: `PrecisionPlan::site_names`,
//! `ParallelismPlan::site_names`, `FixedTransformer::pipeline` and
//! `FixedTransformer::layer_resources`.  This module is now the single
//! authority: [`canonical_site_names`] / [`schedule_site_names`] define
//! the name grammar the planfile loaders resolve against, and
//! [`SiteGraph::build`] materializes the dataflow graph — one node per
//! pipeline stage carrying its `FixedSpec` pair, reuse factor, stage
//! schedule and resource estimate; one edge per inter-stage stream
//! carrying the shape `(elements per row, data grid)` the FIFO model
//! stores.
//!
//! Contract: the graph is a *pure reorganization* of the retired
//! `pipeline()` / `layer_resources()` walks — `synthesize()` rebuilt on
//! it reproduces its reports bit-for-bit (golden-tested in
//! `hls::transformer`).

use crate::fixed::FixedSpec;
use crate::hls::dense::{dense_resources, dense_stage};
use crate::hls::layernorm::{layernorm_resources, layernorm_stage};
use crate::hls::mha::{mha_resources_sited, mha_stage, MhaFifoStats};
use crate::hls::parallelism::ParallelismPlan;
use crate::hls::pipeline::{fifo_depth, PipelineModel, Stage};
use crate::hls::pooling::{pool_resources, pool_stage};
use crate::hls::precision::{PrecisionPlan, QuantConfig};
use crate::hls::resources::{bram18_for_bits, Resources};
use crate::hls::ReuseFactor;
use crate::models::config::ModelConfig;

/// Canonical *precision* site order (execution order; also the
/// serialization and search order): embed, per-block
/// `mha.qkv`/`mha.out`/`ln1`/`ffn1`/`ffn2`/`ln2`, pool, head, out, and
/// the shared softmax LUT site.  `PrecisionPlan::site_names` delegates
/// here.
pub fn canonical_site_names(num_blocks: usize) -> Vec<String> {
    let mut v = vec!["embed".to_string()];
    for b in 0..num_blocks {
        for site in ["mha.qkv", "mha.out", "ln1", "ffn1", "ffn2", "ln2"] {
            v.push(format!("block{b}.{site}"));
        }
    }
    for site in ["pool", "head", "out", "softmax"] {
        v.push(site.to_string());
    }
    v
}

/// Canonical *schedule* site order — the parallelism-plan vocabulary.
/// Identical to [`canonical_site_names`] minus `softmax` (the shared LUT
/// has no reuse dial of its own) and with the per-block order the reuse
/// grammar documents.  `ParallelismPlan::site_names` delegates here.
pub fn schedule_site_names(num_blocks: usize) -> Vec<String> {
    let mut v = vec!["embed".to_string()];
    for b in 0..num_blocks {
        for site in ["mha.qkv", "mha.out", "ln1", "ffn1", "ffn2", "ln2"] {
            v.push(format!("block{b}.{site}"));
        }
    }
    for site in ["pool", "head", "out"] {
        v.push(site.to_string());
    }
    v
}

/// What kind of kernel a graph node runs — the metadata the static
/// verifier needs to reason about each site's arithmetic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeOp {
    /// Dense MAC layer: `n_in`-long dot products, `n_out` outputs/row.
    Dense { n_in: usize, n_out: usize },
    /// Whole attention engine (projections, QK^T, softmax, apply-V, Wo).
    /// The node's own spec/reuse are the QKV site; the output path and
    /// the shared softmax LUT site ride along here.
    Mha {
        heads: usize,
        head_dim: usize,
        out: QuantConfig,
        softmax: QuantConfig,
        out_reuse: ReuseFactor,
    },
    /// LayerNorm over `d` channels per row.
    LayerNorm { d: usize },
    /// Global average pool over `rows` sequence positions.
    Pool { rows: usize },
}

/// One typed layer site of the dataflow graph.
#[derive(Clone, Debug)]
pub struct SiteNode {
    /// Stage name (`embed`, `block0.mha`, ..., `out`) — matches the
    /// schedule and report naming exactly.
    pub name: String,
    /// Precision-plan site whose grid this node's data rides on (the MHA
    /// node reports its QKV site; the out/softmax sites are in the op).
    pub precision_site: String,
    pub op: NodeOp,
    /// Data grid of the node (weights + activations).
    pub data: FixedSpec,
    /// Accumulator grid of the node.
    pub accum: FixedSpec,
    /// The site's reuse factor (schedule dial).
    pub reuse: ReuseFactor,
    /// Composed stage schedule (depth, II, rows).
    pub stage: Stage,
    /// Analytic resource estimate of the node.
    pub resources: Resources,
}

/// One inter-stage stream: producer row flows to consumer, carried on
/// the producer's output grid.  What the inter-stage FIFO stores.
#[derive(Clone, Debug)]
pub struct SiteEdge {
    /// Index of the producing node in [`SiteGraph::nodes`].
    pub from: usize,
    /// Index of the consuming node.
    pub to: usize,
    /// Elements per row on the stream.
    pub elems: usize,
    /// Data grid the stream is carried on.
    pub spec: FixedSpec,
}

/// The site-graph IR of one `(TransformerConfig, PrecisionPlan,
/// ParallelismPlan)` triple — built once, consumed by `synthesize()`,
/// `pareto_explore` and the static verifier.
#[derive(Clone, Debug)]
pub struct SiteGraph {
    pub nodes: Vec<SiteNode>,
    pub edges: Vec<SiteEdge>,
}

impl SiteGraph {
    /// Materialize the graph.  Panics when the plans' block counts do
    /// not match the config (same contract as the engine constructors).
    /// `fifo` carries observed MHA FIFO high-water stats when a forward
    /// pass has run (sizes the attention engine's BRAM share).
    pub fn build(
        cfg: &ModelConfig,
        pp: &PrecisionPlan,
        par: &ParallelismPlan,
        fifo: Option<MhaFifoStats>,
    ) -> Self {
        assert_eq!(pp.num_blocks(), cfg.num_blocks, "precision plan/config block mismatch");
        assert_eq!(par.num_blocks(), cfg.num_blocks, "parallelism plan/config block mismatch");
        let c = cfg;
        let mut nodes: Vec<SiteNode> = Vec::new();
        let mut push = |precision_site: String,
                        op: NodeOp,
                        q: QuantConfig,
                        reuse: ReuseFactor,
                        stage: Stage,
                        resources: Resources| {
            nodes.push(SiteNode {
                name: stage.name.clone(),
                precision_site,
                op,
                data: q.data,
                accum: q.accum,
                reuse,
                stage,
                resources,
            });
        };
        push(
            "embed".into(),
            NodeOp::Dense { n_in: c.input_size, n_out: c.d_model },
            pp.embed(),
            par.embed(),
            dense_stage("embed", c.seq_len, c.input_size.max(2), par.embed(), pp.embed().data),
            dense_resources(c.input_size, c.d_model, pp.embed().data, par.embed()),
        );
        for b in 0..c.num_blocks {
            let bp = *pp.block(b);
            let rp = *par.block(b);
            let mut m = mha_stage(
                c.seq_len,
                c.d_model,
                c.head_dim,
                rp.mha(),
                &bp.mha(pp.softmax()),
            );
            m.name = format!("block{b}.mha");
            push(
                format!("block{b}.mha.qkv"),
                NodeOp::Mha {
                    heads: c.num_heads,
                    head_dim: c.head_dim,
                    out: bp.mha_out,
                    softmax: pp.softmax(),
                    out_reuse: rp.mha_out,
                },
                bp.qkv,
                rp.qkv,
                m,
                mha_resources_sited(
                    c.seq_len,
                    c.d_model,
                    c.num_heads,
                    c.head_dim,
                    bp.qkv.data,
                    bp.mha_out.data,
                    pp.softmax().data,
                    rp.mha(),
                    fifo,
                ),
            );
            if c.use_layernorm {
                push(
                    format!("block{b}.ln1"),
                    NodeOp::LayerNorm { d: c.d_model },
                    bp.ln1,
                    rp.ln1,
                    layernorm_stage(&format!("block{b}.ln1"), c.seq_len, c.d_model, rp.ln1, bp.ln1.data),
                    layernorm_resources(c.d_model, bp.ln1.data, rp.ln1),
                );
            }
            push(
                format!("block{b}.ffn1"),
                NodeOp::Dense { n_in: c.d_model, n_out: c.ffn_dim },
                bp.ffn1,
                rp.ffn1,
                dense_stage(&format!("block{b}.ffn1"), c.seq_len, c.d_model, rp.ffn1, bp.ffn1.data),
                dense_resources(c.d_model, c.ffn_dim, bp.ffn1.data, rp.ffn1),
            );
            push(
                format!("block{b}.ffn2"),
                NodeOp::Dense { n_in: c.ffn_dim, n_out: c.d_model },
                bp.ffn2,
                rp.ffn2,
                dense_stage(&format!("block{b}.ffn2"), c.seq_len, c.ffn_dim, rp.ffn2, bp.ffn2.data),
                dense_resources(c.ffn_dim, c.d_model, bp.ffn2.data, rp.ffn2),
            );
            if c.use_layernorm {
                push(
                    format!("block{b}.ln2"),
                    NodeOp::LayerNorm { d: c.d_model },
                    bp.ln2,
                    rp.ln2,
                    layernorm_stage(&format!("block{b}.ln2"), c.seq_len, c.d_model, rp.ln2, bp.ln2.data),
                    layernorm_resources(c.d_model, bp.ln2.data, rp.ln2),
                );
            }
        }
        push(
            "pool".into(),
            NodeOp::Pool { rows: c.seq_len },
            pp.pool(),
            par.pool(),
            pool_stage("pool", c.seq_len, par.pool()),
            pool_resources(c.d_model, pp.pool().data, par.pool()),
        );
        push(
            "head".into(),
            NodeOp::Dense { n_in: c.d_model, n_out: c.head_hidden },
            pp.head(),
            par.head(),
            dense_stage("head", 1, c.d_model, par.head(), pp.head().data),
            dense_resources(c.d_model, c.head_hidden, pp.head().data, par.head()),
        );
        push(
            "out".into(),
            NodeOp::Dense { n_in: c.head_hidden, n_out: c.output_size },
            pp.out(),
            par.out(),
            dense_stage("out", 1, c.head_hidden, par.out(), pp.out().data),
            dense_resources(c.head_hidden, c.output_size, pp.out().data, par.out()),
        );
        // edges: the linear dataflow chain, each stream carried on the
        // grid the producer emits (the retired `stream_shape` table)
        let edges = (1..nodes.len())
            .map(|to| {
                let (elems, spec) = stream_shape(cfg, pp, &nodes[to - 1].name);
                SiteEdge { from: to - 1, to, elems, spec }
            })
            .collect();
        Self { nodes, edges }
    }

    /// The schedule view: every node's stage in pipeline order.
    pub fn pipeline_model(&self) -> PipelineModel {
        let mut p = PipelineModel::default();
        for n in &self.nodes {
            p.push(n.stage.clone());
        }
        p
    }

    /// BRAM of the inter-stage streams, sized from producer/consumer II
    /// mismatch ([`fifo_depth`]).  A matched chain (every uniform
    /// parallelism plan) needs only ping-pong registers — depth 1, zero
    /// BRAM; heterogeneous reuse pays for its rate conversions here.
    pub fn fifo_resources(&self) -> Resources {
        let mut bits = 0u64;
        for e in &self.edges {
            let depth = fifo_depth(&self.nodes[e.from].stage, &self.nodes[e.to].stage);
            if depth <= 1 {
                continue; // a register slot, not a RAM
            }
            bits += depth * e.elems as u64 * e.spec.width() as u64;
        }
        Resources::new(0, 0, 0, bram18_for_bits(bits))
    }

    /// Look a node up by stage name.
    pub fn node(&self, name: &str) -> Option<&SiteNode> {
        self.nodes.iter().find(|n| n.name == name)
    }
}

/// Shape of the stream a stage emits: (elements per row, the data grid
/// it is carried on) — what the inter-stage FIFO stores.
fn stream_shape(c: &ModelConfig, p: &PrecisionPlan, stage_name: &str) -> (usize, FixedSpec) {
    if let Some(rest) = stage_name.strip_prefix("block") {
        if let Some((idx, field)) = rest.split_once('.') {
            if let Ok(b) = idx.parse::<usize>() {
                let bp = p.block(b);
                return match field {
                    "mha" => (c.d_model, bp.mha_out.data),
                    "ln1" => (c.d_model, bp.ln1.data),
                    "ffn1" => (c.ffn_dim, bp.ffn1.data),
                    "ffn2" => (c.d_model, bp.ffn2.data),
                    "ln2" => (c.d_model, bp.ln2.data),
                    _ => (c.d_model, bp.ffn2.data),
                };
            }
        }
    }
    match stage_name {
        "embed" => (c.d_model, p.embed().data),
        "pool" => (c.d_model, p.pool().data),
        "head" => (c.head_hidden, p.head().data),
        _ => (c.output_size, p.out().data),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{zoo, zoo_model};

    fn graph_for(model: &str, r: u32) -> (ModelConfig, SiteGraph) {
        let cfg = zoo_model(model).unwrap().config;
        let pp = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(r));
        let g = SiteGraph::build(&cfg, &pp, &par, None);
        (cfg, g)
    }

    #[test]
    fn canonical_names_are_the_plan_vocabulary() {
        // the plans delegate here; pin the grammar itself
        let names = canonical_site_names(2);
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "block0.mha.qkv");
        assert_eq!(names[6], "block0.ln2");
        assert_eq!(names.last().unwrap(), "softmax");
        assert_eq!(names.len(), 1 + 2 * 6 + 4);
        let sched = schedule_site_names(2);
        assert_eq!(sched.len(), names.len() - 1);
        assert!(!sched.iter().any(|s| s == "softmax"));
        assert_eq!(&names[..names.len() - 1], &sched[..]);
    }

    #[test]
    fn graph_is_a_linear_chain_in_stage_order() {
        for m in zoo() {
            let (cfg, g) = graph_for(&m.config.name, 1);
            let per_block = if cfg.use_layernorm { 5 } else { 3 };
            assert_eq!(g.nodes.len(), 1 + cfg.num_blocks * per_block + 3);
            assert_eq!(g.edges.len(), g.nodes.len() - 1);
            for (i, e) in g.edges.iter().enumerate() {
                assert_eq!((e.from, e.to), (i, i + 1));
            }
            assert_eq!(g.nodes[0].name, "embed");
            assert_eq!(g.nodes[1].name, "block0.mha");
            assert_eq!(g.nodes.last().unwrap().name, "out");
            // node names and stage names agree everywhere
            for n in &g.nodes {
                assert_eq!(n.name, n.stage.name);
            }
        }
    }

    #[test]
    fn mha_node_carries_its_three_precision_sites() {
        let cfg = zoo_model("engine").unwrap().config;
        let mut pp = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        pp.set_data("block1.mha.qkv", FixedSpec::new(12, 4)).unwrap();
        pp.set_data("block1.mha.out", FixedSpec::new(10, 3)).unwrap();
        pp.set_data("softmax", FixedSpec::new(14, 5)).unwrap();
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        let g = SiteGraph::build(&cfg, &pp, &par, None);
        let n = g.node("block1.mha").unwrap();
        assert_eq!(n.precision_site, "block1.mha.qkv");
        assert_eq!(n.data, FixedSpec::new(12, 4));
        match &n.op {
            NodeOp::Mha { out, softmax, heads, head_dim, .. } => {
                assert_eq!(out.data, FixedSpec::new(10, 3));
                assert_eq!(softmax.data, FixedSpec::new(14, 5));
                assert_eq!(*heads, cfg.num_heads);
                assert_eq!(*head_dim, cfg.head_dim);
            }
            op => panic!("mha node has op {op:?}"),
        }
    }

    #[test]
    fn edges_carry_the_producer_stream_shape() {
        let (cfg, g) = graph_for("gw", 1);
        // embed -> block0.mha streams d_model elems on the embed grid
        let e0 = &g.edges[0];
        assert_eq!(e0.elems, cfg.d_model);
        assert_eq!(e0.spec, g.nodes[0].data);
        // ffn1 -> ffn2 streams ffn_dim elems on the ffn1 grid
        let ffn1_idx = g.nodes.iter().position(|n| n.name == "block0.ffn1").unwrap();
        let e = g.edges.iter().find(|e| e.from == ffn1_idx).unwrap();
        assert_eq!(e.elems, cfg.ffn_dim);
        assert_eq!(e.spec, g.nodes[ffn1_idx].data);
    }

    #[test]
    fn uniform_plan_graph_has_no_fifo_bram() {
        for m in zoo() {
            let (_, g) = graph_for(&m.config.name, 2);
            assert_eq!(g.fifo_resources(), Resources::ZERO, "{}", m.config.name);
        }
    }

    #[test]
    fn ii_mismatch_shows_up_as_fifo_bram_on_the_edge_model() {
        let cfg = zoo_model("btag").unwrap().config;
        let pp = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let mut par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        par.set("block0.ffn1", ReuseFactor(8)).unwrap();
        let g = SiteGraph::build(&cfg, &pp, &par, None);
        assert!(g.fifo_resources().bram18 > 0);
    }

    #[test]
    #[should_panic]
    fn build_rejects_mismatched_block_counts() {
        let cfg = zoo_model("engine").unwrap().config;
        let pp = PrecisionPlan::uniform(cfg.num_blocks + 1, QuantConfig::new(6, 10));
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1));
        SiteGraph::build(&cfg, &pp, &par, None);
    }
}
