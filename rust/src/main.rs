//! `repro` — the CLI of the reproduction.
//!
//! Subcommands (one per experiment family + serving):
//!
//! ```text
//! repro table1
//! repro table-latency     --model engine|btag|gw
//! repro figure-auc        --model engine|btag|gw [--events N] [--threads T] [--quick]
//! repro figure-resources  --model engine|btag|gw
//! repro synth             --model <m> [--reuse R] [--int I] [--frac F] [--precision-plan FILE] [--reuse-plan FILE]
//! repro mixed-precision   --model <m> [--floor 0.99] [--min-frac 2] [--save-plan FILE]
//! repro pareto            --model <m> [--floor 0.99] [--iters N] [--reuse-choices 1,2,4,8] [--save-plan FILE]
//! repro lint-plan         --model <m> [--int I] [--frac F] [--reuse R] [--precision-plan FILE] [--reuse-plan FILE] [--preset mixed] [--events N] [--seed S] [--json FILE] [--strict]
//! repro serve             --backend float|hls|pjrt [--events N] [--rate EPS] [--batch B] [--replicas R] [--precision-plan FILE] [--reuse-plan FILE] [--listen ADDR] [--metrics-addr ADDR] [--autoscale MIN..MAX] [--ring N]
//! repro send              --to ADDR [--model M] [--events N] [--rate EPS] [--burst B] [--swap-at N] [--precision-plan FILE] [--reuse-plan FILE] [--shutdown]
//! repro stream            --backend float|hls [--model engine] [--samples N] [--hop H] [--threshold Z] ...
//! repro report            (everything above, in sequence)
//! ```

use anyhow::{bail, Context, Result};
use hls4ml_transformer::analysis::{verify_plan, VerifyConfig, PROBE_EVENTS, PROBE_SEED};
use hls4ml_transformer::cli::Args;
use hls4ml_transformer::fixed::FixedSpec;
use hls4ml_transformer::coordinator::{
    parse_autoscale, serve_net, server::pace_until, AutoscaleConfig, BackendKind, BatchPolicy,
    Frame, NetEvent,
    NetServeOptions, PipelineConfig, PlanSwap, ServerConfig, SourceMode, StreamSource,
    TriggerServer, WeightsSource,
};
use hls4ml_transformer::data::{generator_for, StrainConfig};
use hls4ml_transformer::experiments::{
    artifacts_ready, auc_figures, latency_tables, load_checkpoints, resource_figures, table1,
};
use hls4ml_transformer::hls::{
    load_plan_file, load_reuse_plan_file, FixedTransformer, ParallelismPlan, PrecisionPlan,
    QuantConfig, ReuseFactor,
};
use hls4ml_transformer::models::weights::synthetic_weights;
use hls4ml_transformer::models::zoo::{zoo, zoo_model};
use hls4ml_transformer::quant::{bit_shave_search, pareto_explore, EvalSet, ParetoConfig};
use hls4ml_transformer::stream::{analyze, StreamParams};
use hls4ml_transformer::testutil::XorShift;
use hls4ml_transformer::{artifacts_dir, benchjson, models::ModelConfig};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "usage: repro <command> [flags]\n\
         commands:\n\
         \x20 table1                              Table I (model specs)\n\
         \x20 table-latency    --model <m>        Tables II-IV (latency vs reuse)\n\
         \x20 figure-auc       --model <m>        Figures 9-11 (AUC vs precision)\n\
         \x20 figure-resources --model <m>        Figures 12-14 (resources)\n\
         \x20 synth            --model <m>        one synthesis report\n\
         \x20                  [--precision-plan F]  per-site precision file\n\
         \x20                  [--reuse-plan F]      per-site reuse file\n\
         \x20 mixed-precision  --model <m>        greedy per-site bit shaving\n\
         \x20                  [--floor 0.99] [--min-frac 2] [--save-plan F]\n\
         \x20 pareto           --model <m>        joint precision x reuse frontier\n\
         \x20                  [--floor 0.99] [--iters N] [--reuse-choices 1,2,4,8]\n\
         \x20                  [--save-plan F]    write the dominating mixed plans\n\
         \x20 lint-plan        --model <m>        static plan verification\n\
         \x20                  [--precision-plan F] [--reuse-plan F]\n\
         \x20                  [--preset mixed]   golden mixed-precision assignment\n\
         \x20                  [--events N]       probe events (0 = worst-case mode)\n\
         \x20                  [--json F]         append one JSON report line\n\
         \x20                  [--strict]         exit nonzero on any ERROR\n\
         \x20 serve            --backend <b>      run the trigger server\n\
         \x20                  [--replicas R]     worker-pool width per model\n\
         \x20                  [--precision-plan F]  per-site precision file (HLS)\n\
         \x20                  [--reuse-plan F]      per-site reuse file (HLS)\n\
         \x20                  [--listen ADDR]    serve framed events over TCP\n\
         \x20                  [--metrics-addr A] Prometheus text endpoint\n\
         \x20                  [--autoscale L..H] elastic replica band per model\n\
         \x20                  [--ring N]         per-shard SPSC ring capacity\n\
         \x20 send             --to ADDR          drive a --listen server:\n\
         \x20                  [--model engine] [--events N] [--rate EPS]\n\
         \x20                  [--burst B] [--seed S]\n\
         \x20                  [--swap-at N]      hot plan swap after N events\n\
         \x20                  [--precision-plan F] [--reuse-plan F]\n\
         \x20                  [--shutdown]       send the shutdown frame last\n\
         \x20 stream           --backend <b>      continuous-stream trigger run:\n\
         \x20                  windowized strain -> coordinator -> clustered\n\
         \x20                  triggers, detection efficiency + latency report\n\
         \x20                  [--model engine] [--samples N] [--hop H]\n\
         \x20                  [--threshold Z] [--mean-gap G] [--amp-lo A --amp-hi B]\n\
         \x20                  [--seed S] [--batch B] [--replicas R] [--rate SPS]\n\
         \x20                  [--no-reuse]       naive full recompute per window\n\
         \x20 report                              all experiments in sequence\n\
         models: engine | btag | gw    backends: float | hls | pjrt"
    );
}

fn model_arg(args: &Args) -> Result<ModelConfig> {
    let name = args.get_or("model", "engine");
    Ok(zoo_model(name)
        .with_context(|| format!("unknown model '{name}' (engine|btag|gw)"))?
        .config)
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table1" => {
            args.expect_only(&[]).map_err(anyhow::Error::msg)?;
            print!("{}", table1::render());
        }
        "table-latency" => {
            args.expect_only(&["model"]).map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            print!("{}", latency_tables::render(&cfg, &weights));
        }
        "figure-auc" => {
            args.expect_only(&["model", "events", "threads", "quick"])
                .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let dir = artifacts_dir();
            if !artifacts_ready(&dir, &cfg.name) {
                bail!("figure-auc needs artifacts — run `make artifacts` first");
            }
            let (ptq, qat) = load_checkpoints(&dir, &cfg)?;
            let eval = EvalSet::load(&dir, &cfg)?;
            let events = args.get_parse("events", 256usize).map_err(anyhow::Error::msg)?;
            let eval = eval.truncate(events);
            let threads = args
                .get_parse("threads", default_threads())
                .map_err(anyhow::Error::msg)?;
            let (ints, fracs): (Vec<u32>, Vec<u32>) = if args.has("quick") {
                (vec![6], vec![2, 4, 6, 8, 10])
            } else {
                (vec![6, 7, 8, 9, 10], (2..=11).collect())
            };
            let results = auc_figures::run_figure(&cfg, &ptq, &qat, &eval, &ints, &fracs, threads);
            print!("{}", auc_figures::render(&cfg, &results, &fracs));
        }
        "figure-resources" => {
            args.expect_only(&["model", "int"]).map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            let int_bits = args.get_parse("int", 6u32).map_err(anyhow::Error::msg)?;
            let fracs: Vec<u32> = (2..=11).collect();
            let pts = resource_figures::sweep(&cfg, &weights, int_bits, &[1, 2, 4], &fracs);
            print!("{}", resource_figures::render(&cfg, &pts, &fracs));
        }
        "synth" => {
            args.expect_only(&["model", "reuse", "int", "frac", "precision-plan", "reuse-plan"])
                .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            let reuse = args.get_parse("reuse", 1u32).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(reuse >= 1, "--reuse must be >= 1");
            let int_bits = args.get_parse("int", 6u32).map_err(anyhow::Error::msg)?;
            let frac = args.get_parse("frac", 8u32).map_err(anyhow::Error::msg)?;
            let base = QuantConfig::new(int_bits, frac);
            let plan = match args.get("precision-plan") {
                Some(path) => load_plan_file(path, cfg.num_blocks, base)
                    .map_err(anyhow::Error::msg)?,
                None => PrecisionPlan::uniform(cfg.num_blocks, base),
            };
            let par = match args.get("reuse-plan") {
                Some(path) => load_reuse_plan_file(path, cfg.num_blocks, ReuseFactor(reuse))
                    .map_err(anyhow::Error::msg)?,
                None => ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(reuse)),
            };
            let t = FixedTransformer::with_plan(cfg, &weights, plan);
            let rep = t.synthesize(&par);
            print!("{rep}");
            println!(
                "   VU13P utilization: {}",
                rep.utilization_summary(&hls4ml_transformer::hls::resources::VU13P)
            );
        }
        "mixed-precision" => {
            args.expect_only(&[
                "model", "int", "frac", "floor", "min-frac", "events", "reuse", "save-plan",
            ])
            .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            let int_bits = args.get_parse("int", 6u32).map_err(anyhow::Error::msg)?;
            let frac = args.get_parse("frac", 12u32).map_err(anyhow::Error::msg)?;
            let floor = args.get_parse("floor", 0.99f64).map_err(anyhow::Error::msg)?;
            let min_frac = args.get_parse("min-frac", 2u32).map_err(anyhow::Error::msg)?;
            let events = args.get_parse("events", 64usize).map_err(anyhow::Error::msg)?;
            let reuse = args.get_parse("reuse", 1u32).map_err(anyhow::Error::msg)?;
            let dir = artifacts_dir();
            let eval = if artifacts_ready(&dir, &cfg.name) {
                EvalSet::load(&dir, &cfg)?.truncate(events)
            } else {
                eprintln!(
                    "(note: artifacts missing for {}; margin-labeled synthetic eval)",
                    cfg.name
                );
                EvalSet::synthetic(&cfg, &weights, events, 0xBEEF)
            };
            let uniform = QuantConfig::new(int_bits, frac);
            let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(reuse));
            let r = bit_shave_search(
                &cfg, &weights, &eval, uniform, floor, min_frac, &par,
            );
            println!(
                "mixed-precision search — {} | start {} | auc_ratio floor {floor} | \
                 min frac {min_frac} | {} eval events | {} design points scored | \
                 {} engines compiled",
                cfg.name,
                uniform.data,
                eval.len(),
                r.points_scored,
                r.engines_built
            );
            println!(
                "  uniform: auc_ratio {:.4}  DSP {} FF {} LUT {} BRAM18 {}",
                r.uniform_score.auc_ratio,
                r.uniform_resources.dsp,
                r.uniform_resources.ff,
                r.uniform_resources.lut,
                r.uniform_resources.bram18
            );
            println!(
                "  found:   auc_ratio {:.4}  DSP {} FF {} LUT {} BRAM18 {}  ({} frac bits shaved)",
                r.plan_score.auc_ratio,
                r.plan_resources.dsp,
                r.plan_resources.ff,
                r.plan_resources.lut,
                r.plan_resources.bram18,
                r.bits_shaved
            );
            let saved =
                (r.uniform_resources.dsp + r.uniform_resources.ff) as f64
                    - (r.plan_resources.dsp + r.plan_resources.ff) as f64;
            let base =
                (r.uniform_resources.dsp + r.uniform_resources.ff).max(1) as f64;
            println!("  DSP+FF saved vs uniform: {:.1}%", 100.0 * saved / base);
            match args.get("save-plan") {
                Some(path) => {
                    std::fs::write(path, r.plan.serialize())
                        .with_context(|| format!("writing plan to {path}"))?;
                    println!("  plan written to {path}");
                }
                None => print!("{}", r.plan.serialize()),
            }
        }
        "pareto" => {
            args.expect_only(&[
                "model", "int", "frac", "floor", "min-frac", "events", "iters", "seed",
                "reuse-choices", "save-plan",
            ])
            .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            let int_bits = args.get_parse("int", 6u32).map_err(anyhow::Error::msg)?;
            let frac = args.get_parse("frac", 12u32).map_err(anyhow::Error::msg)?;
            let floor = args.get_parse("floor", 0.99f64).map_err(anyhow::Error::msg)?;
            let min_frac = args.get_parse("min-frac", 2u32).map_err(anyhow::Error::msg)?;
            let events = args.get_parse("events", 64usize).map_err(anyhow::Error::msg)?;
            let iters = args.get_parse("iters", 64usize).map_err(anyhow::Error::msg)?;
            let seed = args.get_parse("seed", 0xF0CA_CC1Au64).map_err(anyhow::Error::msg)?;
            let reuse_choices: Vec<u32> = args
                .get_or("reuse-choices", "1,2,4,8")
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<u32>()
                        .map_err(|_| format!("--reuse-choices: cannot parse '{t}'"))
                })
                .collect::<Result<_, _>>()
                .map_err(anyhow::Error::msg)?;
            let dir = artifacts_dir();
            let eval = if artifacts_ready(&dir, &cfg.name) {
                EvalSet::load(&dir, &cfg)?.truncate(events)
            } else {
                eprintln!(
                    "(note: artifacts missing for {}; margin-labeled synthetic eval)",
                    cfg.name
                );
                EvalSet::synthetic(&cfg, &weights, events, 0xBEEF)
            };
            let pcfg = ParetoConfig {
                auc_floor: floor,
                min_frac,
                reuse_choices,
                anneal_iters: iters,
                seed,
                ..ParetoConfig::default()
            };
            let base = QuantConfig::new(int_bits, frac);
            let res = pareto_explore(&cfg, &weights, &eval, base, &pcfg);
            println!(
                "pareto exploration — {} | base {} | auc_ratio floor {floor} | \
                 {} eval events | {} schedule evals | {} eval-set scorings | \
                 {} statically pruned",
                cfg.name,
                base.data,
                eval.len(),
                res.evals,
                res.scored,
                res.pruned
            );
            println!(
                "  {:>3}  {:>9} {:>9} {:>10} {:>8} {:>9} {:>8}  plan",
                "#", "lat(cyc)", "II(cyc)", "lat(us)", "DSP", "FF", "auc"
            );
            for (i, p) in res.frontier.iter().enumerate() {
                println!(
                    "  {:>3}  {:>9} {:>9} {:>10.3} {:>8} {:>9} {:>8.4}  {} {}",
                    i,
                    p.latency_cycles,
                    p.interval_cycles,
                    p.latency_us,
                    p.resources.dsp,
                    p.resources.ff,
                    p.auc_ratio,
                    p.precision.summary(),
                    p.parallelism.summary(),
                );
                benchjson::emit(
                    &format!("pareto/{}/point{i}", cfg.name),
                    &[
                        ("latency_cycles", p.latency_cycles as f64),
                        ("interval_cycles", p.interval_cycles as f64),
                        ("latency_us", p.latency_us),
                        ("dsp", p.resources.dsp as f64),
                        ("ff", p.resources.ff as f64),
                        ("lut", p.resources.lut as f64),
                        ("bram18", p.resources.bram18 as f64),
                        ("auc_ratio", p.auc_ratio),
                        ("mixed_reuse", p.is_mixed_reuse() as u64 as f64),
                    ],
                );
            }
            match (res.best_uniform.as_ref(), res.mixed_dominator()) {
                (Some(bu), Some(dom)) => {
                    println!(
                        "  best uniform: {} at {} cyc / DSP+FF {}",
                        bu.parallelism.summary(),
                        bu.latency_cycles,
                        bu.cost()
                    );
                    println!(
                        "  dominated by mixed plan {} at {} cyc / DSP+FF {} \
                         (saves {} DSP+FF at <= latency)",
                        dom.parallelism.summary(),
                        dom.latency_cycles,
                        dom.cost(),
                        bu.cost() - dom.cost()
                    );
                    benchjson::emit(
                        &format!("pareto/{}/dominance", cfg.name),
                        &[
                            ("uniform_latency_cycles", bu.latency_cycles as f64),
                            ("uniform_dsp_ff", bu.cost() as f64),
                            ("mixed_latency_cycles", dom.latency_cycles as f64),
                            ("mixed_dsp_ff", dom.cost() as f64),
                            ("dsp_ff_saved", (bu.cost() - dom.cost()) as f64),
                        ],
                    );
                    if let Some(path) = args.get("save-plan") {
                        std::fs::write(path, dom.parallelism.serialize())
                            .with_context(|| format!("writing reuse plan to {path}"))?;
                        let ppath = format!("{path}.precision");
                        std::fs::write(&ppath, dom.precision.serialize())
                            .with_context(|| format!("writing precision plan to {ppath}"))?;
                        println!("  plans written to {path} (+ {ppath})");
                    }
                }
                (Some(bu), None) => {
                    println!(
                        "  best uniform: {} at {} cyc / DSP+FF {} — no mixed plan \
                         dominated it this run",
                        bu.parallelism.summary(),
                        bu.latency_cycles,
                        bu.cost()
                    );
                }
                _ => println!(
                    "  no feasible design point at auc_ratio floor {floor} on the VU13P"
                ),
            }
        }
        "lint-plan" => {
            args.expect_only(&[
                "model", "int", "frac", "reuse", "precision-plan", "reuse-plan", "preset",
                "events", "seed", "json", "strict",
            ])
            .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let weights = weights_or_synthetic(&cfg)?;
            let int_bits = args.get_parse("int", 6u32).map_err(anyhow::Error::msg)?;
            let frac = args.get_parse("frac", 8u32).map_err(anyhow::Error::msg)?;
            let reuse = args.get_parse("reuse", 1u32).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(reuse >= 1, "--reuse must be >= 1");
            let base = QuantConfig::new(int_bits, frac);
            anyhow::ensure!(
                !(args.has("preset") && args.has("precision-plan")),
                "--preset and --precision-plan are mutually exclusive"
            );
            let (mut plan, label): (PrecisionPlan, String) = match args.get("precision-plan")
            {
                Some(path) => (
                    load_plan_file(path, cfg.num_blocks, base).map_err(anyhow::Error::msg)?,
                    format!("{}/{path}", cfg.name),
                ),
                None => (
                    PrecisionPlan::uniform(cfg.num_blocks, base),
                    format!("{}/uniform", cfg.name),
                ),
            };
            let label = match args.get("preset") {
                Some("mixed") => {
                    // the golden mixed assignment of the conformance
                    // corpus: deterministic per-site widths cycling
                    // frac 6..=10 and int 4..=6 over the canonical order
                    for (i, site) in
                        hls4ml_transformer::ir::canonical_site_names(cfg.num_blocks)
                            .iter()
                            .enumerate()
                    {
                        let (int_b, frac_b) = (4 + (i as u32 % 3), 6 + (i as u32 % 5));
                        plan.set_data(site, FixedSpec::new(int_b + frac_b, int_b))
                            .map_err(anyhow::Error::msg)?;
                    }
                    format!("{}/mixed", cfg.name)
                }
                Some(other) => bail!("unknown --preset '{other}' (expected: mixed)"),
                None => label,
            };
            let par = match args.get("reuse-plan") {
                Some(path) => load_reuse_plan_file(path, cfg.num_blocks, ReuseFactor(reuse))
                    .map_err(anyhow::Error::msg)?,
                None => ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(reuse)),
            };
            let vc = VerifyConfig {
                events: args.get_parse("events", PROBE_EVENTS).map_err(anyhow::Error::msg)?,
                seed: args.get_parse("seed", PROBE_SEED).map_err(anyhow::Error::msg)?,
            };
            let report = verify_plan(&cfg, &weights, &plan, &par, &vc);
            print!("{}", report.render_text());
            if let Some(path) = args.get("json") {
                use std::io::Write as _;
                let mut f = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)
                    .with_context(|| format!("--json {path}"))?;
                writeln!(f, "{}", report.render_json(&label))
                    .with_context(|| format!("--json {path}"))?;
                println!("report appended to {path}");
            }
            if args.has("strict") && report.has_errors() {
                bail!(
                    "plan '{label}' has {} verification error(s)",
                    report.count(hls4ml_transformer::analysis::Severity::Error)
                );
            }
        }
        "serve" => {
            args.expect_only(&[
                "backend", "events", "rate", "batch", "models", "replicas", "precision-plan",
                "reuse", "reuse-plan", "listen", "metrics-addr", "autoscale", "ring",
            ])
            .map_err(anyhow::Error::msg)?;
            let backend: BackendKind = args
                .get_or("backend", "float")
                .parse()
                .map_err(|e: anyhow::Error| e)?;
            let events = args.get_parse("events", 5000u64).map_err(anyhow::Error::msg)?;
            let rate = args.get_parse("rate", 0u64).map_err(anyhow::Error::msg)?;
            let batch = args.get_parse("batch", 8usize).map_err(anyhow::Error::msg)?;
            let replicas = args.get_parse("replicas", 1usize).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
            // plan files are per-model (block counts differ): read the
            // text once here, parse against each pipeline's model inside
            // the server (clean Err naming the offending entry)
            let plan_text: Option<String> = match args.get("precision-plan") {
                Some(path) => Some(
                    std::fs::read_to_string(path)
                        .with_context(|| format!("--precision-plan {path}"))?,
                ),
                None => None,
            };
            // only the HLS engine quantizes: silently accepting the flag
            // for float/pjrt would serve the uniform engine while the
            // operator believes the plan is in effect
            anyhow::ensure!(
                plan_text.is_none() || backend == BackendKind::Hls,
                "--precision-plan only applies to the hls backend \
                 (float/pjrt engines are not quantized)"
            );
            let reuse = args.get_parse("reuse", 1u32).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(reuse >= 1, "--reuse must be >= 1");
            let reuse_plan_text: Option<String> = match args.get("reuse-plan") {
                Some(path) => Some(
                    std::fs::read_to_string(path)
                        .with_context(|| format!("--reuse-plan {path}"))?,
                ),
                None => None,
            };
            // the reuse dial shapes the *modeled* FPGA design point, and
            // only the HLS backend models one
            anyhow::ensure!(
                (reuse_plan_text.is_none() && reuse == 1) || backend == BackendKind::Hls,
                "--reuse/--reuse-plan only apply to the hls backend \
                 (float/pjrt engines model no FPGA schedule)"
            );
            let models: Vec<&'static str> = match args.get_or("models", "engine,btag,gw") {
                "all" => vec!["engine", "btag", "gw"],
                list => list
                    .split(',')
                    .map(|m| {
                        zoo_model(m.trim())
                            .map(|z| Box::leak(z.config.name.into_boxed_str()) as &'static str)
                            .with_context(|| format!("unknown model '{m}'"))
                    })
                    .collect::<Result<_>>()?,
            };
            // plans are per-model (site names carry block indices, and
            // block counts differ across the zoo): serving one plan to
            // the whole default model list would reject it on the first
            // model with a different shape, so require a single model
            anyhow::ensure!(
                plan_text.is_none() || models.len() == 1,
                "--precision-plan applies to a single model; pass --models <m> \
                 (plans are per-model: site names carry block indices)"
            );
            anyhow::ensure!(
                reuse_plan_text.is_none() || models.len() == 1,
                "--reuse-plan applies to a single model; pass --models <m> \
                 (plans are per-model: site names carry block indices)"
            );
            let ring = args.get_parse("ring", 8192usize).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(ring >= 2, "--ring must be >= 2");
            let cfg = ServerConfig {
                pipelines: models
                    .into_iter()
                    .map(|m| {
                        let mut pc = PipelineConfig::new(m, backend);
                        pc.batch = BatchPolicy { max_batch: batch, ..Default::default() };
                        pc.replicas = replicas;
                        pc.ring_capacity = ring;
                        pc.precision_plan = plan_text.clone();
                        pc.reuse = ReuseFactor(reuse);
                        pc.reuse_plan = reuse_plan_text.clone();
                        pc
                    })
                    .collect(),
                events_per_source: events,
                rate_per_source: rate,
                artifacts_dir: artifacts_dir(),
                ..Default::default()
            };
            match args.get("listen") {
                None => {
                    // self-driving batch mode (the seed behavior): the
                    // network-plane knobs have nothing to attach to
                    anyhow::ensure!(
                        !args.has("metrics-addr") && !args.has("autoscale"),
                        "--metrics-addr/--autoscale require --listen \
                         (the batch server has no network plane)"
                    );
                    let report = TriggerServer::run(&cfg)?;
                    print!("{report}");
                }
                Some(addr) => {
                    let listener = std::net::TcpListener::bind(addr)
                        .with_context(|| format!("--listen {addr}"))?;
                    println!("listening on {}", listener.local_addr()?);
                    let metrics = match args.get("metrics-addr") {
                        Some(maddr) => {
                            let m = std::net::TcpListener::bind(maddr)
                                .with_context(|| format!("--metrics-addr {maddr}"))?;
                            println!("metrics on http://{}/metrics", m.local_addr()?);
                            Some(m)
                        }
                        None => None,
                    };
                    let autoscale = match args.get("autoscale") {
                        Some(band) => {
                            let (lo, hi) = parse_autoscale(band)?;
                            Some(AutoscaleConfig::band(lo, hi))
                        }
                        None => None,
                    };
                    let report = serve_net(&cfg, listener, NetServeOptions { metrics, autoscale })?;
                    print!("{report}");
                }
            }
        }
        "send" => {
            args.expect_only(&[
                "to", "model", "events", "rate", "burst", "seed", "swap-at", "precision-plan",
                "reuse-plan", "shutdown",
            ])
            .map_err(anyhow::Error::msg)?;
            let to = args.get_or("to", "127.0.0.1:7071");
            let cfg = model_arg(args)?;
            let model = cfg.name.clone();
            // shutdown-only invocations shouldn't have to spell --events 0
            let default_events = if args.has("shutdown") { 0u64 } else { 1000u64 };
            let events = args
                .get_parse("events", default_events)
                .map_err(anyhow::Error::msg)?;
            let rate = args.get_parse("rate", 0u64).map_err(anyhow::Error::msg)?;
            let burst = args.get_parse("burst", 1u64).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(burst >= 1, "--burst must be >= 1");
            let seed = args.get_parse("seed", 0xFEEDu64).map_err(anyhow::Error::msg)?;
            let swap_at: Option<u64> = match args.get("swap-at") {
                Some(v) => Some(
                    v.parse()
                        .map_err(|_| anyhow::anyhow!("--swap-at: cannot parse '{v}'"))?,
                ),
                None => None,
            };
            let swap_precision: Option<String> = match args.get("precision-plan") {
                Some(path) => Some(
                    std::fs::read_to_string(path)
                        .with_context(|| format!("--precision-plan {path}"))?,
                ),
                None => None,
            };
            let swap_reuse: Option<String> = match args.get("reuse-plan") {
                Some(path) => Some(
                    std::fs::read_to_string(path)
                        .with_context(|| format!("--reuse-plan {path}"))?,
                ),
                None => None,
            };
            anyhow::ensure!(
                swap_at.is_none() || swap_precision.is_some() || swap_reuse.is_some(),
                "--swap-at needs --precision-plan and/or --reuse-plan (the new design point)"
            );
            anyhow::ensure!(
                (swap_precision.is_none() && swap_reuse.is_none()) || swap_at.is_some(),
                "--precision-plan/--reuse-plan on send need --swap-at N (when to swap)"
            );
            let mut stream = std::net::TcpStream::connect(to)
                .with_context(|| format!("--to {to}"))?;
            stream.set_nodelay(true).ok();
            let mut gen = generator_for(&model, seed)
                .with_context(|| format!("no event generator for model '{model}'"))?;
            let mut rng = XorShift::new(seed ^ 0xB1157);
            let t_start = std::time::Instant::now();
            let mut burst_left = 0u64;
            let mut burst_due = std::time::Duration::ZERO;
            let mut swapped = false;
            for i in 0..events {
                if swap_at == Some(i) {
                    hls4ml_transformer::coordinator::net::write_frame(
                        &mut stream,
                        &Frame::Swap(PlanSwap {
                            model: model.clone(),
                            precision: swap_precision.clone(),
                            reuse: swap_reuse.clone(),
                        }),
                    )
                    .context("sending swap frame")?;
                    swapped = true;
                }
                if rate > 0 {
                    if burst <= 1 {
                        pace_until(
                            t_start,
                            std::time::Duration::from_nanos(i * 1_000_000_000 / rate),
                        );
                    } else {
                        if burst_left == 0 {
                            burst_left = 1 + rng.next_u64() % (2 * burst - 1);
                            let mean_ns = burst as f64 * 1e9 / rate as f64;
                            burst_due += std::time::Duration::from_nanos(
                                rng.exponential(mean_ns) as u64,
                            );
                            pace_until(t_start, burst_due);
                        }
                        burst_left -= 1;
                    }
                }
                let e = gen.next_event();
                hls4ml_transformer::coordinator::net::write_frame(
                    &mut stream,
                    &Frame::Event(NetEvent {
                        id: i,
                        model: model.clone(),
                        x: e.x,
                        label: Some(e.label),
                        stream_pos: None,
                    }),
                )
                .with_context(|| format!("sending event {i}"))?;
            }
            // a swap point at/past the end still fires (swap-after-drain)
            if let Some(at) = swap_at {
                if !swapped && at >= events {
                    hls4ml_transformer::coordinator::net::write_frame(
                        &mut stream,
                        &Frame::Swap(PlanSwap {
                            model: model.clone(),
                            precision: swap_precision.clone(),
                            reuse: swap_reuse.clone(),
                        }),
                    )
                    .context("sending swap frame")?;
                }
            }
            if args.has("shutdown") {
                hls4ml_transformer::coordinator::net::write_frame(&mut stream, &Frame::Shutdown)
                    .context("sending shutdown frame")?;
            }
            use std::io::Write as _;
            stream.flush().ok();
            let wall = t_start.elapsed().as_secs_f64().max(1e-9);
            println!(
                "sent {events} event(s) for {model} to {to} in {wall:.3}s ({:.0} events/s){}{}",
                events as f64 / wall,
                if swap_at.is_some() { " + 1 plan swap" } else { "" },
                if args.has("shutdown") { " + shutdown" } else { "" },
            );
        }
        "stream" => {
            args.expect_only(&[
                "model", "backend", "samples", "hop", "seed", "mean-gap", "amp-lo",
                "amp-hi", "threshold", "batch", "replicas", "rate", "ring", "no-reuse",
            ])
            .map_err(anyhow::Error::msg)?;
            let cfg = model_arg(args)?;
            let model: &'static str = Box::leak(cfg.name.clone().into_boxed_str());
            let backend: BackendKind = args
                .get_or("backend", "float")
                .parse()
                .map_err(|e: anyhow::Error| e)?;
            anyhow::ensure!(
                backend != BackendKind::Pjrt,
                "stream mode serves float/hls (the PJRT artifacts are exported \
                 for the pre-cut event shapes)"
            );
            let samples = args.get_parse("samples", 100_000u64).map_err(anyhow::Error::msg)?;
            let hop = args
                .get_parse("hop", (cfg.seq_len / 2).max(1))
                .map_err(anyhow::Error::msg)?;
            anyhow::ensure!(hop >= 1, "--hop must be >= 1");
            let seed = args.get_parse("seed", 0xA11CEu64).map_err(anyhow::Error::msg)?;
            let mean_gap = args.get_parse("mean-gap", 1000.0f64).map_err(anyhow::Error::msg)?;
            let amp_lo = args.get_parse("amp-lo", 5.0f64).map_err(anyhow::Error::msg)?;
            let amp_hi = args.get_parse("amp-hi", 9.0f64).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(amp_lo > 0.0 && amp_hi >= amp_lo, "bad --amp-lo/--amp-hi");
            let threshold = args.get_parse("threshold", 3.0f32).map_err(anyhow::Error::msg)?;
            let batch = args.get_parse("batch", 8usize).map_err(anyhow::Error::msg)?;
            let replicas = args.get_parse("replicas", 1usize).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
            let rate = args.get_parse("rate", 0u64).map_err(anyhow::Error::msg)?;
            let ring = args.get_parse("ring", 8192usize).map_err(anyhow::Error::msg)?;
            // incremental cross-window reuse is on by default (bitwise
            // identical to the naive path); --no-reuse forces the full
            // recompute for A/B measurement
            let reuse = !args.has("no-reuse");
            let dir = artifacts_dir();
            let weights = if artifacts_ready(&dir, &cfg.name) {
                WeightsSource::Artifacts
            } else if !cfg.use_layernorm {
                eprintln!(
                    "(note: artifacts missing for {}; analytic excess-power \
                     detector weights)",
                    cfg.name
                );
                WeightsSource::Detector
            } else {
                eprintln!(
                    "(note: artifacts missing for {}; synthetic weights — an \
                     untrained model will recover few injections)",
                    cfg.name
                );
                WeightsSource::Synthetic(7)
            };
            let mut strain = StrainConfig::new(seed, cfg.input_size, cfg.seq_len);
            strain.mean_gap = mean_gap;
            strain.amp = (amp_lo, amp_hi);
            let server = ServerConfig {
                pipelines: vec![PipelineConfig {
                    batch: BatchPolicy { max_batch: batch, ..Default::default() },
                    replicas,
                    ring_capacity: ring,
                    weights,
                    source: SourceMode::Stream(StreamSource { samples, hop, strain, reuse }),
                    ..PipelineConfig::new(model, backend)
                }],
                events_per_source: 0,
                rate_per_source: rate,
                artifacts_dir: dir,
                ..Default::default()
            };
            let report = TriggerServer::run(&server)?;
            print!("{report}");
            let s = &report.per_model[model];
            let truth = report
                .stream_truth
                .get(model)
                .map(|v| v.as_slice())
                .unwrap_or(&[]);
            let mut params = StreamParams::for_windows(cfg.seq_len as u64);
            params.threshold = threshold;
            let sr = analyze(s.windows.clone(), truth, &params);
            print!("{sr}");
            let wall = report.wall.as_secs_f64().max(1e-9);
            let sustained_sps = samples as f64 / wall;
            let windows_per_s = s.windows.len() as f64 / wall;
            println!(
                "sustained: {sustained_sps:.0} samples/s = {windows_per_s:.0} windows/s \
                 at hop {hop} (x{:.1} overlap)",
                cfg.seq_len as f64 / hop as f64
            );
            let ru = s.reuse;
            if reuse {
                println!(
                    "reuse: {}/{} windows incremental | {} prefix rows reused / {} \
                     recomputed ({:.1}%) | {} score entries reused ({:.1}%) | \
                     cache {:.1} KiB high-water",
                    ru.windows_incremental,
                    ru.windows(),
                    ru.rows_reused,
                    ru.rows_recomputed,
                    100.0 * ru.row_reuse_fraction(),
                    ru.score_entries_reused,
                    100.0 * ru.score_reuse_fraction(),
                    ru.cache_bytes as f64 / 1024.0,
                );
            } else {
                println!("reuse: disabled (--no-reuse; naive full recompute per window)");
            }
            benchjson::emit(
                // the parsed enum, not the raw flag: aliases like
                // `--backend fixed` must land on the same perf-series key
                &format!("stream/{model}/{backend:?}/hop{hop}"),
                &[
                    ("samples", samples as f64),
                    ("hop", hop as f64),
                    ("sustained_sps", sustained_sps),
                    ("windows_per_s", windows_per_s),
                    ("windows", s.windows.len() as f64),
                    ("shed", s.shed as f64),
                    ("dropped", s.dropped as f64),
                    ("efficiency", sr.efficiency()),
                    ("injections", sr.injections as f64),
                    ("found", sr.found as f64),
                    ("false_alarms", sr.false_alarms as f64),
                    ("trigger_p99_ns", sr.trigger_latency.quantile_ns(0.99) as f64),
                    ("window_p99_ns", s.latency.quantile_ns(0.99) as f64),
                    ("reuse_enabled", reuse as u64 as f64),
                    ("windows_incremental", ru.windows_incremental as f64),
                    ("row_reuse_fraction", ru.row_reuse_fraction()),
                    ("score_reuse_fraction", ru.score_reuse_fraction()),
                    ("reuse_cache_bytes", ru.cache_bytes as f64),
                ],
            );
        }
        "report" => {
            args.expect_only(&["events", "threads"]).map_err(anyhow::Error::msg)?;
            print!("{}", table1::render());
            println!();
            for m in zoo() {
                let weights = weights_or_synthetic(&m.config)?;
                print!("{}", latency_tables::render(&m.config, &weights));
                println!();
            }
            let dir = artifacts_dir();
            let events = args.get_parse("events", 192usize).map_err(anyhow::Error::msg)?;
            let threads = args
                .get_parse("threads", default_threads())
                .map_err(anyhow::Error::msg)?;
            for m in zoo() {
                if artifacts_ready(&dir, &m.config.name) {
                    let (ptq, qat) = load_checkpoints(&dir, &m.config)?;
                    let eval = EvalSet::load(&dir, &m.config)?.truncate(events);
                    let fracs: Vec<u32> = (2..=11).collect();
                    let results = auc_figures::run_figure(
                        &m.config, &ptq, &qat, &eval, &[6, 8, 10], &fracs, threads,
                    );
                    print!("{}", auc_figures::render(&m.config, &results, &fracs));
                } else {
                    println!(
                        "(skipping figure-auc for {}: artifacts missing)",
                        m.config.name
                    );
                }
                println!();
                let weights = weights_or_synthetic(&m.config)?;
                let fracs: Vec<u32> = (2..=11).collect();
                let pts = resource_figures::sweep(&m.config, &weights, 6, &[1, 2, 4], &fracs);
                print!("{}", resource_figures::render(&m.config, &pts, &fracs));
                println!();
            }
        }
        "" => {
            usage();
            bail!("missing command");
        }
        other => {
            usage();
            bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

/// Artifact weights when available, synthetic otherwise (with a notice —
/// structural experiments don't depend on the training outcome).
fn weights_or_synthetic(
    cfg: &ModelConfig,
) -> Result<hls4ml_transformer::models::Weights> {
    let dir = artifacts_dir();
    if artifacts_ready(&dir, &cfg.name) {
        let (ptq, _) = load_checkpoints(&dir, cfg)?;
        Ok(ptq)
    } else {
        eprintln!("(note: artifacts missing for {}; using synthetic weights)", cfg.name);
        Ok(synthetic_weights(cfg, 0xC0FFEE))
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
