//! Held-out evaluation tensors, loaded from `artifacts/<m>.eval.nnw`.
//!
//! Python exports the exact events its Keras-equivalent model was scored
//! on, plus the float logits from both math paths; scoring the *same*
//! events in Rust is what makes the AUC-ratio plots (Figures 9-11)
//! cross-layer comparable instead of comparing different random data.

use anyhow::{ensure, Context, Result};
use std::path::Path;

use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::nnw::NnwFile;
use crate::nn::tensor::Mat;

/// Eval split with precomputed float-reference scores.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub events: Vec<Mat>,
    pub labels: Vec<u8>,
    /// Exact-float (Keras-semantics) probabilities per event, from the
    /// jax `logits_exact` export.
    pub float_probs: Vec<Vec<f32>>,
    /// LUT-math float probabilities (the PJRT artifact's semantics).
    pub lut_probs: Vec<Vec<f32>>,
    pub num_classes: usize,
}

impl EvalSet {
    /// Load from the artifact directory for one zoo config.
    pub fn load(dir: &Path, cfg: &ModelConfig) -> Result<Self> {
        let path = dir.join(format!("{}.eval.nnw", cfg.name));
        let file = NnwFile::load(&path)?;
        Self::from_nnw(&file, cfg).with_context(|| format!("eval set {}", path.display()))
    }

    pub fn from_nnw(file: &NnwFile, cfg: &ModelConfig) -> Result<Self> {
        let x = file.require("x")?;
        let y = file.require("y")?;
        let exact = file.require("logits_exact")?;
        let lut = file.require("logits_lut")?;
        let n = x.shape[0];
        ensure!(y.shape == vec![n], "label count mismatch");
        ensure!(
            x.shape[1] == cfg.seq_len * cfg.input_size,
            "event width {} != SxF {}",
            x.shape[1],
            cfg.seq_len * cfg.input_size
        );
        ensure!(exact.shape == vec![n, cfg.output_size]);
        let events: Vec<Mat> = (0..n)
            .map(|i| {
                let w = cfg.seq_len * cfg.input_size;
                Mat::from_vec(cfg.seq_len, cfg.input_size, x.data[i * w..(i + 1) * w].to_vec())
            })
            .collect();
        let labels: Vec<u8> = y.data.iter().map(|&v| v as u8).collect();
        let to_probs = |logits: &[f32]| -> Vec<f32> {
            match cfg.final_activation() {
                FinalActivation::Sigmoid => {
                    logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
                }
                FinalActivation::Softmax => {
                    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
                    let s: f32 = e.iter().sum();
                    e.into_iter().map(|v| v / s).collect()
                }
            }
        };
        let o = cfg.output_size;
        let float_probs = (0..n).map(|i| to_probs(&exact.data[i * o..(i + 1) * o])).collect();
        let lut_probs = (0..n).map(|i| to_probs(&lut.data[i * o..(i + 1) * o])).collect();
        Ok(Self {
            events,
            labels,
            float_probs,
            lut_probs,
            num_classes: cfg.output_size.max(2),
        })
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Truncated copy (cheap sweeps / tests).
    pub fn truncate(&self, n: usize) -> EvalSet {
        EvalSet {
            events: self.events.iter().take(n).cloned().collect(),
            labels: self.labels.iter().take(n).copied().collect(),
            float_probs: self.float_probs.iter().take(n).cloned().collect(),
            lut_probs: self.lut_probs.iter().take(n).cloned().collect(),
            num_classes: self.num_classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::zoo_model;

    /// Build a tiny synthetic NNW so the parser is tested without
    /// artifacts (the real round-trip lives in rust/tests/).
    fn fake_nnw(cfg: &ModelConfig, n: usize) -> NnwFile {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"NNW1");
        bytes.extend_from_slice(&4u32.to_le_bytes());
        let mut put = |name: &str, shape: &[usize], data: &[f32]| {
            bytes.extend_from_slice(&(name.len() as u16).to_le_bytes());
            bytes.extend_from_slice(name.as_bytes());
            bytes.push(shape.len() as u8);
            for &d in shape {
                bytes.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        };
        let w = cfg.seq_len * cfg.input_size;
        put("x", &[n, w], &vec![0.25; n * w]);
        put("y", &[n], &(0..n).map(|i| (i % 2) as f32).collect::<Vec<_>>());
        put("logits_exact", &[n, cfg.output_size], &vec![0.5; n * cfg.output_size]);
        put("logits_lut", &[n, cfg.output_size], &vec![0.4; n * cfg.output_size]);
        NnwFile::read(&bytes[..]).unwrap()
    }

    #[test]
    fn parses_and_shapes() {
        let cfg = zoo_model("engine").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 6), &cfg).unwrap();
        assert_eq!(es.len(), 6);
        assert_eq!(es.events[0].rows(), cfg.seq_len);
        assert_eq!(es.float_probs[0].len(), cfg.output_size);
        let s: f32 = es.float_probs[0].iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncate_limits() {
        let cfg = zoo_model("engine").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 6), &cfg).unwrap();
        assert_eq!(es.truncate(2).len(), 2);
        assert_eq!(es.truncate(99).len(), 6);
    }

    #[test]
    fn sigmoid_head_probs() {
        let cfg = zoo_model("gw").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 4), &cfg).unwrap();
        // sigmoid(0.5) ~ 0.622
        assert!((es.float_probs[0][0] - 0.6224593).abs() < 1e-5);
    }
}
