//! Held-out evaluation tensors, loaded from `artifacts/<m>.eval.nnw`.
//!
//! Python exports the exact events its Keras-equivalent model was scored
//! on, plus the float logits from both math paths; scoring the *same*
//! events in Rust is what makes the AUC-ratio plots (Figures 9-11)
//! cross-layer comparable instead of comparing different random data.

use anyhow::{ensure, Context, Result};
use std::path::Path;

use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::nnw::NnwFile;
use crate::nn::tensor::Mat;

/// Eval split with precomputed float-reference scores.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub events: Vec<Mat>,
    pub labels: Vec<u8>,
    /// Exact-float (Keras-semantics) probabilities per event, from the
    /// jax `logits_exact` export.
    pub float_probs: Vec<Vec<f32>>,
    /// LUT-math float probabilities (the PJRT artifact's semantics).
    pub lut_probs: Vec<Vec<f32>>,
    pub num_classes: usize,
}

impl EvalSet {
    /// Load from the artifact directory for one zoo config.
    pub fn load(dir: &Path, cfg: &ModelConfig) -> Result<Self> {
        let path = dir.join(format!("{}.eval.nnw", cfg.name));
        let file = NnwFile::load(&path)?;
        Self::from_nnw(&file, cfg).with_context(|| format!("eval set {}", path.display()))
    }

    pub fn from_nnw(file: &NnwFile, cfg: &ModelConfig) -> Result<Self> {
        let x = file.require("x")?;
        let y = file.require("y")?;
        let exact = file.require("logits_exact")?;
        let lut = file.require("logits_lut")?;
        let n = x.shape[0];
        ensure!(y.shape == vec![n], "label count mismatch");
        ensure!(
            x.shape[1] == cfg.seq_len * cfg.input_size,
            "event width {} != SxF {}",
            x.shape[1],
            cfg.seq_len * cfg.input_size
        );
        ensure!(exact.shape == vec![n, cfg.output_size]);
        let events: Vec<Mat> = (0..n)
            .map(|i| {
                let w = cfg.seq_len * cfg.input_size;
                Mat::from_vec(cfg.seq_len, cfg.input_size, x.data[i * w..(i + 1) * w].to_vec())
            })
            .collect();
        let labels: Vec<u8> = y.data.iter().map(|&v| v as u8).collect();
        let to_probs = |logits: &[f32]| -> Vec<f32> {
            match cfg.final_activation() {
                FinalActivation::Sigmoid => {
                    logits.iter().map(|&z| 1.0 / (1.0 + (-z).exp())).collect()
                }
                FinalActivation::Softmax => {
                    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let e: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
                    let s: f32 = e.iter().sum();
                    e.into_iter().map(|v| v / s).collect()
                }
            }
        };
        let o = cfg.output_size;
        let float_probs = (0..n).map(|i| to_probs(&exact.data[i * o..(i + 1) * o])).collect();
        let lut_probs = (0..n).map(|i| to_probs(&lut.data[i * o..(i + 1) * o])).collect();
        Ok(Self {
            events,
            labels,
            float_probs,
            lut_probs,
            num_classes: cfg.output_size.max(2),
        })
    }

    /// Synthetic eval set scored *and labeled* by the float reference
    /// itself (no artifacts).  Binary / 2-class heads: generate
    /// `n + margin` random events, label by thresholding the float
    /// positive-class score at its median, and drop the `margin` events
    /// nearest the threshold — `auc_float` is then 1.0 by construction,
    /// so a design point's `auc_ratio` measures pure quantization
    /// damage, which is what the mixed-precision search and the
    /// resource benches need from an artifact-free set.  Heads with
    /// more than 2 classes (scored via `macro_auc`) are labeled by the
    /// float argmax instead: the macro-AUC baseline is near-1 (not
    /// exactly 1 — one-vs-rest pairs can invert), but it is the same
    /// fixed baseline for every design point, so ratios stay comparable.
    pub fn synthetic(cfg: &ModelConfig, weights: &crate::models::Weights, n: usize, seed: u64) -> Self {
        use crate::nn::FloatTransformer;
        let float = FloatTransformer::new(cfg.clone(), weights.clone());
        let mut g = crate::testutil::Gen::new(seed);
        let multiclass = cfg.output_size > 2;
        let margin = if multiclass { 0 } else { (n / 3).max(4) };
        let total = n + margin;
        let mut scored: Vec<(f32, Mat, Vec<f32>)> = Vec::with_capacity(total);
        for _ in 0..total {
            let x = Mat::from_vec(
                cfg.seq_len,
                cfg.input_size,
                (0..cfg.seq_len * cfg.input_size).map(|_| g.normal()).collect(),
            );
            let p = float.probs(&float.forward(&x));
            let score = if p.len() == 1 { p[0] } else { p[1.min(p.len() - 1)] };
            scored.push((score, x, p));
        }
        let mut events = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let mut probs = Vec::with_capacity(n);
        if multiclass {
            for (_, x, p) in scored {
                let argmax = p
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                events.push(x);
                labels.push(argmax as u8);
                probs.push(p);
            }
        } else {
            scored.sort_by(|a, b| a.0.total_cmp(&b.0));
            let neg = n / 2;
            let pos = n - neg;
            // the rank-based margin drop only guarantees auc_float = 1
            // if the boundary scores are strictly separated: ties
            // straddling the threshold (e.g. saturated probabilities)
            // would be tie-ranked by binary_auc and break the contract,
            // so widen the drop tie-by-tie, trading kept events for a
            // clean margin (never below 2 per side)
            let (mut lo, mut hi) = (neg, total - pos);
            while lo >= 3 && hi <= total - 3 && scored[lo - 1].0 >= scored[hi].0 {
                lo -= 1;
                hi += 1;
            }
            let keep: Vec<(usize, u8)> = (0..lo)
                .map(|i| (i, 0u8))
                .chain((hi..total).map(|i| (i, 1u8)))
                .collect();
            for (i, label) in keep {
                let (_, x, p) = scored[i].clone();
                events.push(x);
                labels.push(label);
                probs.push(p);
            }
        }
        EvalSet {
            events,
            labels,
            lut_probs: probs.clone(),
            float_probs: probs,
            num_classes: cfg.output_size.max(2),
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Truncated copy (cheap sweeps / tests).
    pub fn truncate(&self, n: usize) -> EvalSet {
        EvalSet {
            events: self.events.iter().take(n).cloned().collect(),
            labels: self.labels.iter().take(n).copied().collect(),
            float_probs: self.float_probs.iter().take(n).cloned().collect(),
            lut_probs: self.lut_probs.iter().take(n).cloned().collect(),
            num_classes: self.num_classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::zoo_model;

    /// Build a tiny synthetic NNW so the parser is tested without
    /// artifacts (the real round-trip lives in rust/tests/).
    fn fake_nnw(cfg: &ModelConfig, n: usize) -> NnwFile {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"NNW1");
        bytes.extend_from_slice(&4u32.to_le_bytes());
        let mut put = |name: &str, shape: &[usize], data: &[f32]| {
            bytes.extend_from_slice(&(name.len() as u16).to_le_bytes());
            bytes.extend_from_slice(name.as_bytes());
            bytes.push(shape.len() as u8);
            for &d in shape {
                bytes.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        };
        let w = cfg.seq_len * cfg.input_size;
        put("x", &[n, w], &vec![0.25; n * w]);
        put("y", &[n], &(0..n).map(|i| (i % 2) as f32).collect::<Vec<_>>());
        put("logits_exact", &[n, cfg.output_size], &vec![0.5; n * cfg.output_size]);
        put("logits_lut", &[n, cfg.output_size], &vec![0.4; n * cfg.output_size]);
        NnwFile::read(&bytes[..]).unwrap()
    }

    #[test]
    fn parses_and_shapes() {
        let cfg = zoo_model("engine").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 6), &cfg).unwrap();
        assert_eq!(es.len(), 6);
        assert_eq!(es.events[0].rows(), cfg.seq_len);
        assert_eq!(es.float_probs[0].len(), cfg.output_size);
        let s: f32 = es.float_probs[0].iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn truncate_limits() {
        let cfg = zoo_model("engine").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 6), &cfg).unwrap();
        assert_eq!(es.truncate(2).len(), 2);
        assert_eq!(es.truncate(99).len(), 6);
    }

    #[test]
    fn synthetic_set_is_margin_labeled_and_separable() {
        use crate::metrics::auc::binary_auc;
        use crate::models::weights::synthetic_weights;
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 17);
        let es = EvalSet::synthetic(&cfg, &w, 16, 3);
        assert_eq!(es.len(), 16);
        assert_eq!(es.labels.iter().filter(|&&l| l == 1).count(), 8);
        // float scores separate the labels perfectly by construction
        let scores: Vec<f32> = es.float_probs.iter().map(|p| p[1]).collect();
        assert_eq!(binary_auc(&scores, &es.labels), 1.0);
    }

    #[test]
    fn synthetic_multiclass_uses_argmax_labels() {
        use crate::models::weights::synthetic_weights;
        let cfg = zoo_model("btag").unwrap().config; // 3 classes -> macro_auc path
        let w = synthetic_weights(&cfg, 19);
        let es = EvalSet::synthetic(&cfg, &w, 12, 4);
        assert_eq!(es.len(), 12);
        for (p, &l) in es.float_probs.iter().zip(&es.labels) {
            assert!((l as usize) < cfg.output_size);
            let am = p.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(l as usize, am, "label must be the float argmax");
        }
    }

    #[test]
    fn sigmoid_head_probs() {
        let cfg = zoo_model("gw").unwrap().config;
        let es = EvalSet::from_nnw(&fake_nnw(&cfg, 4), &cfg).unwrap();
        // sigmoid(0.5) ~ 0.622
        assert!((es.float_probs[0][0] - 0.6224593).abs() < 1e-5);
    }
}
