//! The precision sweep behind Figures 9-11: for each (integer bits,
//! fractional bits, PTQ/QAT) design point, run the full fixed-point model
//! over the eval set and compare against the float reference.
//!
//! The paper's y-axis is the "AUC ratio": AUC of the hls4ml (here:
//! HLS-simulator) model relative to the Keras (here: exact-float jax
//! export) model, both against ground truth.  We also record the mean
//! absolute probability error as a direct output-fidelity measure.

use crate::hls::{FixedTransformer, QuantConfig};
use crate::metrics::auc::{binary_auc, macro_auc};
use crate::models::config::ModelConfig;
use crate::models::weights::Weights;

use super::evalset::EvalSet;

/// One design point of the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepPoint {
    pub integer_bits: u32,
    pub frac_bits: u32,
    /// Scored with the QAT checkpoint instead of the PTQ one.
    pub qat: bool,
}

/// Result at one design point.
#[derive(Clone, Copy, Debug)]
pub struct SweepResult {
    pub point: SweepPoint,
    /// AUC of the fixed-point model against ground truth.
    pub auc_fixed: f64,
    /// AUC of the exact-float reference against ground truth.
    pub auc_float: f64,
    /// The paper's plotted metric: auc_fixed / auc_float.
    pub auc_ratio: f64,
    /// Mean |p_fixed - p_float| over events (output fidelity).
    pub mean_abs_err: f64,
}

/// Score one model at one design point over the eval set.
pub fn score_point(
    cfg: &ModelConfig,
    weights: &Weights,
    eval: &EvalSet,
    point: SweepPoint,
) -> SweepResult {
    let quant = QuantConfig::new(point.integer_bits, point.frac_bits);
    let fixed = FixedTransformer::new(cfg.clone(), weights, quant);

    let mut fixed_probs: Vec<Vec<f32>> = Vec::with_capacity(eval.len());
    for x in &eval.events {
        fixed_probs.push(fixed.forward(x));
    }

    let (auc_fixed, auc_float) = if cfg.output_size > 2 {
        (
            macro_auc(&fixed_probs, &eval.labels, cfg.output_size),
            macro_auc(&eval.float_probs, &eval.labels, cfg.output_size),
        )
    } else {
        let score = |probs: &[Vec<f32>]| -> Vec<f32> {
            probs
                .iter()
                .map(|p| if p.len() == 1 { p[0] } else { p[1] })
                .collect()
        };
        (
            binary_auc(&score(&fixed_probs), &eval.labels),
            binary_auc(&score(&eval.float_probs), &eval.labels),
        )
    };

    let mut err = 0.0f64;
    let mut terms = 0usize;
    for (fp, rp) in fixed_probs.iter().zip(&eval.float_probs) {
        for (a, b) in fp.iter().zip(rp) {
            err += (a - b).abs() as f64;
            terms += 1;
        }
    }

    SweepResult {
        point,
        auc_fixed,
        auc_float,
        auc_ratio: if auc_float > 0.0 { auc_fixed / auc_float } else { 0.0 },
        mean_abs_err: err / terms.max(1) as f64,
    }
}

/// Run many design points, fanned out over OS threads (std::thread::scope
/// — the offline crate set has no rayon).
pub fn run_sweep(
    cfg: &ModelConfig,
    ptq_weights: &Weights,
    qat_weights: &Weights,
    eval: &EvalSet,
    points: &[SweepPoint],
    threads: usize,
) -> Vec<SweepResult> {
    let threads = threads.max(1);
    let mut results: Vec<Option<SweepResult>> = vec![None; points.len()];
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<SweepResult>>> =
        (0..points.len()).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(points.len().max(1)) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let p = points[i];
                let w = if p.qat { qat_weights } else { ptq_weights };
                let r = score_point(cfg, w, eval, p);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        results[i] = slot.into_inner().unwrap();
    }
    results.into_iter().map(|r| r.expect("all points scored")).collect()
}

/// The grid of the paper's Figures 9-11: integer bits 6..=10, fractional
/// bits 2..=11, PTQ and QAT.
pub fn paper_grid() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for qat in [false, true] {
        for integer_bits in 6..=10 {
            for frac_bits in 2..=11 {
                v.push(SweepPoint { integer_bits, frac_bits, qat });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::nn::FloatTransformer;
    use crate::testutil::Gen;

    /// Synthetic eval set scored by the float model itself (no artifacts).
    fn synthetic_eval(cfg: &ModelConfig, w: &Weights, n: usize) -> EvalSet {
        let float = FloatTransformer::new(cfg.clone(), w.clone());
        let mut g = Gen::new(123);
        let mut events = Vec::new();
        let mut labels = Vec::new();
        let mut probs = Vec::new();
        for i in 0..n {
            let x = crate::nn::tensor::Mat::from_vec(
                cfg.seq_len,
                cfg.input_size,
                g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
            );
            let p = float.probs(&float.forward(&x));
            labels.push((i % 2) as u8);
            probs.push(p);
            events.push(x);
        }
        EvalSet {
            events,
            labels,
            lut_probs: probs.clone(),
            float_probs: probs,
            num_classes: cfg.output_size.max(2),
        }
    }

    #[test]
    fn high_precision_point_has_ratio_near_one() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 21);
        let eval = synthetic_eval(&cfg, &w, 24);
        let r = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 8, frac_bits: 12, qat: false });
        assert!((r.auc_ratio - 1.0).abs() < 0.25, "ratio {}", r.auc_ratio);
        assert!(r.mean_abs_err < 0.1, "err {}", r.mean_abs_err);
    }

    #[test]
    fn fidelity_improves_with_precision() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 22);
        let eval = synthetic_eval(&cfg, &w, 16);
        let coarse = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 6, frac_bits: 2, qat: false });
        let fine = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 6, frac_bits: 10, qat: false });
        assert!(fine.mean_abs_err < coarse.mean_abs_err,
            "fine {} vs coarse {}", fine.mean_abs_err, coarse.mean_abs_err);
    }

    #[test]
    fn run_sweep_parallel_matches_serial() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 23);
        let eval = synthetic_eval(&cfg, &w, 8);
        let points = vec![
            SweepPoint { integer_bits: 6, frac_bits: 4, qat: false },
            SweepPoint { integer_bits: 6, frac_bits: 8, qat: true },
            SweepPoint { integer_bits: 8, frac_bits: 6, qat: false },
        ];
        let par = run_sweep(&cfg, &w, &w, &eval, &points, 3);
        let ser = run_sweep(&cfg, &w, &w, &eval, &points, 1);
        assert_eq!(par.len(), 3);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.auc_fixed, b.auc_fixed);
        }
    }

    #[test]
    fn paper_grid_size() {
        // 2 quant types x 5 integer widths x 10 fractional widths
        assert_eq!(paper_grid().len(), 100);
    }
}
