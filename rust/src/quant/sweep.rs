//! The precision sweep behind Figures 9-11: for each (integer bits,
//! fractional bits, PTQ/QAT) design point, run the full fixed-point model
//! over the eval set and compare against the float reference.
//!
//! The paper's y-axis is the "AUC ratio": AUC of the hls4ml (here:
//! HLS-simulator) model relative to the Keras (here: exact-float jax
//! export) model, both against ground truth.  We also record the mean
//! absolute probability error as a direct output-fidelity measure.
//!
//! Beyond the paper's uniform grid, [`bit_shave_search`] walks
//! *per-site* fractional bits down (greedy, subject to an AUC-ratio
//! floor) over a [`PrecisionPlan`] — the mixed-precision design points
//! hls4ml reaches with `granularity="name"`.

use crate::fixed::FixedSpec;
use crate::hls::resources::Resources;
use crate::hls::{FixedTransformer, ParallelismPlan, PrecisionPlan, QuantConfig};
use crate::metrics::auc::{binary_auc, macro_auc};
use crate::models::config::ModelConfig;
use crate::models::weights::Weights;

use super::evalset::EvalSet;

/// One design point of the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepPoint {
    pub integer_bits: u32,
    pub frac_bits: u32,
    /// Scored with the QAT checkpoint instead of the PTQ one.
    pub qat: bool,
}

/// Result at one design point.
#[derive(Clone, Copy, Debug)]
pub struct SweepResult {
    pub point: SweepPoint,
    /// AUC of the fixed-point model against ground truth.
    pub auc_fixed: f64,
    /// AUC of the exact-float reference against ground truth.
    pub auc_float: f64,
    /// The paper's plotted metric: auc_fixed / auc_float.
    pub auc_ratio: f64,
    /// Mean |p_fixed - p_float| over events (output fidelity).
    pub mean_abs_err: f64,
}

/// Fidelity of one precision plan over an eval set.
#[derive(Clone, Copy, Debug)]
pub struct PlanScore {
    pub auc_fixed: f64,
    pub auc_float: f64,
    pub auc_ratio: f64,
    pub mean_abs_err: f64,
}

/// Score one model under one precision plan over the eval set.
///
/// Builds a fresh engine (weights quantized + mantissas lifted into the
/// compiled artifact) per call; search loops that revisit plans should
/// go through a [`PlanCache`] instead.
pub fn score_plan(
    cfg: &ModelConfig,
    weights: &Weights,
    eval: &EvalSet,
    plan: &PrecisionPlan,
) -> PlanScore {
    let fixed = FixedTransformer::with_plan(cfg.clone(), weights, plan.clone());
    score_plan_with(&fixed, cfg, eval)
}

/// [`score_plan`] against an already-built engine: the compile-once
/// entry point — no weight re-quantization, no mantissa re-lift.
pub fn score_plan_with(
    fixed: &FixedTransformer,
    cfg: &ModelConfig,
    eval: &EvalSet,
) -> PlanScore {
    let mut fixed_probs: Vec<Vec<f32>> = Vec::with_capacity(eval.len());
    for x in &eval.events {
        fixed_probs.push(fixed.forward(x));
    }

    let (auc_fixed, auc_float) = if cfg.output_size > 2 {
        (
            macro_auc(&fixed_probs, &eval.labels, cfg.output_size),
            macro_auc(&eval.float_probs, &eval.labels, cfg.output_size),
        )
    } else {
        let score = |probs: &[Vec<f32>]| -> Vec<f32> {
            probs
                .iter()
                .map(|p| if p.len() == 1 { p[0] } else { p[1] })
                .collect()
        };
        (
            binary_auc(&score(&fixed_probs), &eval.labels),
            binary_auc(&score(&eval.float_probs), &eval.labels),
        )
    };

    let mut err = 0.0f64;
    let mut terms = 0usize;
    for (fp, rp) in fixed_probs.iter().zip(&eval.float_probs) {
        for (a, b) in fp.iter().zip(rp) {
            err += (a - b).abs() as f64;
            terms += 1;
        }
    }

    PlanScore {
        auc_fixed,
        auc_float,
        auc_ratio: if auc_float > 0.0 { auc_fixed / auc_float } else { 0.0 },
        mean_abs_err: err / terms.max(1) as f64,
    }
}

/// Score one model at one uniform design point over the eval set.
pub fn score_point(
    cfg: &ModelConfig,
    weights: &Weights,
    eval: &EvalSet,
    point: SweepPoint,
) -> SweepResult {
    let quant = QuantConfig::new(point.integer_bits, point.frac_bits);
    let plan = PrecisionPlan::uniform(cfg.num_blocks, quant);
    let s = score_plan(cfg, weights, eval, &plan);
    SweepResult {
        point,
        auc_fixed: s.auc_fixed,
        auc_float: s.auc_float,
        auc_ratio: s.auc_ratio,
        mean_abs_err: s.mean_abs_err,
    }
}

/// Run many design points, fanned out over OS threads (std::thread::scope
/// — the offline crate set has no rayon).  Workers pull indices off a
/// shared counter and send `(index, result)` down one mpsc channel; the
/// receiver reorders by index, so results come back in `points` order
/// regardless of scheduling.
pub fn run_sweep(
    cfg: &ModelConfig,
    ptq_weights: &Weights,
    qat_weights: &Weights,
    eval: &EvalSet,
    points: &[SweepPoint],
    threads: usize,
) -> Vec<SweepResult> {
    let threads = threads.max(1).min(points.len().max(1));
    let next = std::sync::atomic::AtomicUsize::new(0);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, SweepResult)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let p = points[i];
                let w = if p.qat { qat_weights } else { ptq_weights };
                if tx.send((i, score_point(cfg, w, eval, p))).is_err() {
                    break; // receiver gone — nothing left to report to
                }
            });
        }
        drop(tx); // workers hold the only remaining senders
        let mut results: Vec<Option<SweepResult>> = vec![None; points.len()];
        for (i, r) in rx {
            results[i] = Some(r);
        }
        results.into_iter().map(|r| r.expect("all points scored")).collect()
    })
}

/// The grid of the paper's Figures 9-11: integer bits 6..=10, fractional
/// bits 2..=11, PTQ and QAT.
pub fn paper_grid() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for qat in [false, true] {
        for integer_bits in 6..=10 {
            for frac_bits in 2..=11 {
                v.push(SweepPoint { integer_bits, frac_bits, qat });
            }
        }
    }
    v
}

/// Plan-keyed cache of built engines and their eval-set scores, for the
/// search loops ([`bit_shave_search`], the Pareto front) that visit the
/// same [`PrecisionPlan`] more than once.  The key is the plan's
/// canonical serialization, so two plans that print identically share
/// one engine — one weight quantization, one mantissa lift, one
/// compiled artifact — and one scoring.
pub struct PlanCache<'a> {
    cfg: &'a ModelConfig,
    weights: &'a Weights,
    engines: std::collections::HashMap<String, FixedTransformer>,
    scores: std::collections::HashMap<String, PlanScore>,
    builds: usize,
    scorings: usize,
}

impl<'a> PlanCache<'a> {
    pub fn new(cfg: &'a ModelConfig, weights: &'a Weights) -> Self {
        Self {
            cfg,
            weights,
            engines: Default::default(),
            scores: Default::default(),
            builds: 0,
            scorings: 0,
        }
    }

    /// The engine for `plan`, built on first request and reused after
    /// (same `Arc<CompiledModel>` every time).
    pub fn engine(&mut self, plan: &PrecisionPlan) -> &FixedTransformer {
        let key = plan.serialize();
        if !self.engines.contains_key(&key) {
            self.builds += 1;
            self.engines.insert(
                key.clone(),
                FixedTransformer::with_plan(self.cfg.clone(), self.weights, plan.clone()),
            );
        }
        &self.engines[&key]
    }

    /// Score `plan` over `eval`, running the model only on the first
    /// request per plan.
    pub fn score(&mut self, eval: &EvalSet, plan: &PrecisionPlan) -> PlanScore {
        let key = plan.serialize();
        if let Some(s) = self.scores.get(&key) {
            return *s;
        }
        let cfg = self.cfg;
        let s = score_plan_with(self.engine(plan), cfg, eval);
        self.scorings += 1;
        self.scores.insert(key, s);
        s
    }

    /// Engines actually built (cache misses of [`Self::engine`]).
    pub fn builds(&self) -> usize {
        self.builds
    }

    /// Eval-set scorings actually run (cache misses of [`Self::score`]).
    pub fn scorings(&self) -> usize {
        self.scorings
    }
}

/// Result of one greedy mixed-precision search.
#[derive(Clone, Debug)]
pub struct BitShaveResult {
    /// The heterogeneous plan the search settled on.
    pub plan: PrecisionPlan,
    /// The uniform starting point.
    pub uniform: QuantConfig,
    pub auc_floor: f64,
    pub uniform_score: PlanScore,
    pub plan_score: PlanScore,
    /// Synthesized totals under the search's parallelism plan.
    pub uniform_resources: Resources,
    pub plan_resources: Resources,
    /// Total fractional bits removed across all sites.
    pub bits_shaved: u32,
    /// Eval-set scorings the search actually ran ([`PlanCache`] misses;
    /// revisited plans — the accepted final plan, the uniform baseline —
    /// are free).
    pub points_scored: usize,
    /// Engines built ([`PlanCache`] misses): each one quantized the
    /// weights and lifted the mantissa tiles exactly once.
    pub engines_built: usize,
}

/// Greedy per-site bit shaving: starting from a uniform plan, repeatedly
/// try to remove one fractional bit from each site in turn, keeping a
/// shave only while the plan's `auc_ratio` stays at or above
/// `auc_floor`; a site that refuses a shave is frozen.  Converges when a
/// full pass changes nothing.  Sites the model doesn't instantiate
/// (`ln1`/`ln2` on LN-free models) are skipped.
///
/// This is the mixed-precision analog of the paper's §VI-A sweep: the
/// x-axis walks per site instead of globally, and the payoff is read
/// from the resource model (`uniform_resources` vs `plan_resources`).
pub fn bit_shave_search(
    cfg: &ModelConfig,
    weights: &Weights,
    eval: &EvalSet,
    uniform: QuantConfig,
    auc_floor: f64,
    min_frac: u32,
    par: &ParallelismPlan,
) -> BitShaveResult {
    let mut plan = PrecisionPlan::uniform(cfg.num_blocks, uniform);
    let sites: Vec<String> = plan
        .site_names()
        .into_iter()
        .filter(|s| cfg.use_layernorm || !(s.ends_with(".ln1") || s.ends_with(".ln2")))
        .collect();
    let mut cache = PlanCache::new(cfg, weights);
    let uniform_score = cache.score(eval, &plan);
    let mut frozen: std::collections::HashSet<String> = Default::default();
    loop {
        let mut changed = false;
        for site in &sites {
            if frozen.contains(site) {
                continue;
            }
            let cur = plan.get(site).expect("site_names yields known sites");
            if cur.data.frac() <= min_frac || cur.data.width() <= cur.data.integer() + 1 {
                frozen.insert(site.clone());
                continue;
            }
            let shaved = FixedSpec::new(cur.data.width() - 1, cur.data.integer());
            let mut cand = plan.clone();
            cand.set_data(site, shaved).expect("known site");
            let s = cache.score(eval, &cand);
            if s.auc_ratio >= auc_floor {
                plan = cand;
                changed = true;
            } else {
                frozen.insert(site.clone());
            }
        }
        if !changed {
            break;
        }
    }
    // the final plan was scored the moment its last shave was accepted,
    // and the uniform engine was built for the baseline score — both are
    // pure cache hits here
    let plan_score = cache.score(eval, &plan);
    let uniform_plan = PrecisionPlan::uniform(cfg.num_blocks, uniform);
    let uniform_resources = cache.engine(&uniform_plan).synthesize(par).total;
    let plan_resources = cache.engine(&plan).synthesize(par).total;
    let bits_shaved: u32 = plan
        .site_names()
        .iter()
        .filter_map(|s| plan.get(s))
        .map(|q| uniform.data.frac() - q.data.frac())
        .sum();
    BitShaveResult {
        plan,
        uniform,
        auc_floor,
        uniform_score,
        plan_score,
        uniform_resources,
        plan_resources,
        bits_shaved,
        points_scored: cache.scorings(),
        engines_built: cache.builds(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::resources::VU13P;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::nn::FloatTransformer;
    use crate::testutil::Gen;

    /// Synthetic eval set scored by the float model itself (no artifacts).
    fn synthetic_eval(cfg: &ModelConfig, w: &Weights, n: usize) -> EvalSet {
        let float = FloatTransformer::new(cfg.clone(), w.clone());
        let mut g = Gen::new(123);
        let mut events = Vec::new();
        let mut labels = Vec::new();
        let mut probs = Vec::new();
        for i in 0..n {
            let x = crate::nn::tensor::Mat::from_vec(
                cfg.seq_len,
                cfg.input_size,
                g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
            );
            let p = float.probs(&float.forward(&x));
            labels.push((i % 2) as u8);
            probs.push(p);
            events.push(x);
        }
        EvalSet {
            events,
            labels,
            lut_probs: probs.clone(),
            float_probs: probs,
            num_classes: cfg.output_size.max(2),
        }
    }

    #[test]
    fn high_precision_point_has_ratio_near_one() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 21);
        let eval = synthetic_eval(&cfg, &w, 24);
        let r = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 8, frac_bits: 12, qat: false });
        assert!((r.auc_ratio - 1.0).abs() < 0.25, "ratio {}", r.auc_ratio);
        assert!(r.mean_abs_err < 0.1, "err {}", r.mean_abs_err);
    }

    #[test]
    fn fidelity_improves_with_precision() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 22);
        let eval = synthetic_eval(&cfg, &w, 16);
        let coarse = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 6, frac_bits: 2, qat: false });
        let fine = score_point(&cfg, &w, &eval,
            SweepPoint { integer_bits: 6, frac_bits: 10, qat: false });
        assert!(fine.mean_abs_err < coarse.mean_abs_err,
            "fine {} vs coarse {}", fine.mean_abs_err, coarse.mean_abs_err);
    }

    #[test]
    fn run_sweep_parallel_matches_serial() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 23);
        let eval = synthetic_eval(&cfg, &w, 8);
        let points = vec![
            SweepPoint { integer_bits: 6, frac_bits: 4, qat: false },
            SweepPoint { integer_bits: 6, frac_bits: 8, qat: true },
            SweepPoint { integer_bits: 8, frac_bits: 6, qat: false },
        ];
        let par = run_sweep(&cfg, &w, &w, &eval, &points, 3);
        let ser = run_sweep(&cfg, &w, &w, &eval, &points, 1);
        assert_eq!(par.len(), 3);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.auc_fixed, b.auc_fixed);
        }
    }

    #[test]
    fn run_sweep_with_more_threads_than_points_stays_ordered() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 24);
        let eval = synthetic_eval(&cfg, &w, 6);
        let points = vec![
            SweepPoint { integer_bits: 6, frac_bits: 4, qat: false },
            SweepPoint { integer_bits: 7, frac_bits: 5, qat: false },
        ];
        let r = run_sweep(&cfg, &w, &w, &eval, &points, 16);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].point, points[0]);
        assert_eq!(r[1].point, points[1]);
    }

    #[test]
    fn score_plan_uniform_matches_score_point() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 25);
        let eval = synthetic_eval(&cfg, &w, 8);
        let point = SweepPoint { integer_bits: 6, frac_bits: 8, qat: false };
        let a = score_point(&cfg, &w, &eval, point);
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 8));
        let b = score_plan(&cfg, &w, &eval, &plan);
        assert_eq!(a.auc_fixed, b.auc_fixed);
        assert_eq!(a.mean_abs_err, b.mean_abs_err);
    }

    #[test]
    fn plan_cache_builds_and_scores_each_plan_once() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 41);
        let eval = synthetic_eval(&cfg, &w, 8);
        let mut cache = PlanCache::new(&cfg, &w);
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 8));
        let a = cache.score(&eval, &plan);
        let b = cache.score(&eval, &plan);
        assert_eq!(a.auc_fixed, b.auc_fixed);
        assert_eq!(a.mean_abs_err, b.mean_abs_err);
        assert_eq!(cache.scorings(), 1, "second score is a cache hit");
        assert_eq!(cache.builds(), 1);
        // repeat requests return the SAME compiled artifact, not an
        // equal rebuild
        let first = cache.engine(&plan).compiled().clone();
        assert!(std::sync::Arc::ptr_eq(&first, cache.engine(&plan).compiled()));
        assert_eq!(cache.builds(), 1);
        // a different plan is a genuine miss
        let other = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 9));
        cache.score(&eval, &other);
        assert_eq!(cache.scorings(), 2);
        assert_eq!(cache.builds(), 2);
        // and the cached score matches the uncached entry point exactly
        let direct = score_plan(&cfg, &w, &eval, &plan);
        assert_eq!(a.auc_fixed, direct.auc_fixed);
        assert_eq!(a.mean_abs_err, direct.mean_abs_err);
    }

    #[test]
    fn paper_grid_size() {
        // 2 quant types x 5 integer widths x 10 fractional widths
        assert_eq!(paper_grid().len(), 100);
    }

    /// The tentpole's search acceptance bar: on a synthetic zoo model,
    /// the found plan fits the VU13P with strictly fewer DSPs+FFs than
    /// the uniform design at the same `auc_ratio >= 0.99` floor.
    #[test]
    fn bit_shave_search_beats_uniform_resources_at_iso_auc() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 31);
        // margin-labeled eval: auc_float = 1 by construction, so the
        // ratio floor measures pure quantization damage
        let eval = EvalSet::synthetic(&cfg, &w, 24, 7);
        let uniform = QuantConfig::new(6, 12); // width 18: above the DSP port
        let par = ParallelismPlan::uniform(cfg.num_blocks, crate::hls::ReuseFactor(1));
        let r = bit_shave_search(&cfg, &w, &eval, uniform, 0.99, 2, &par);
        assert!(
            r.plan_score.auc_ratio >= 0.99,
            "found plan violates the floor: {}",
            r.plan_score.auc_ratio
        );
        assert!(r.plan_resources.fits(&VU13P));
        assert!(r.bits_shaved > 0, "search must shave at least one site");
        assert!(
            r.plan_resources.dsp + r.plan_resources.ff
                < r.uniform_resources.dsp + r.uniform_resources.ff,
            "plan {:?} not cheaper than uniform {:?}",
            r.plan_resources,
            r.uniform_resources
        );
        assert!(r.points_scored >= 2);
        // compile-once accounting: the final re-score and both resource
        // syntheses reused cached engines, so builds == scorings
        assert_eq!(r.engines_built, r.points_scored);
    }

    #[test]
    fn bit_shave_search_respects_min_frac() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 32);
        let eval = EvalSet::synthetic(&cfg, &w, 8, 9);
        let uniform = QuantConfig::new(6, 6);
        // floor 0 lets every shave through: all sites must stop at
        // min_frac, never below
        let par = ParallelismPlan::uniform(cfg.num_blocks, crate::hls::ReuseFactor(1));
        let r = bit_shave_search(&cfg, &w, &eval, uniform, 0.0, 4, &par);
        for site in r.plan.site_names() {
            let q = r.plan.get(&site).unwrap();
            if cfg.use_layernorm || !(site.ends_with(".ln1") || site.ends_with(".ln2")) {
                assert_eq!(q.data.frac(), 4, "{site} at {:?}", q.data);
            }
        }
    }
}
