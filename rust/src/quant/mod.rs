//! Post-training-quantization machinery + the precision sweep engine
//! behind Figures 9-11 (S8), plus the joint (precision × parallelism)
//! Pareto explorer behind `repro pareto`.

pub mod evalset;
pub mod pareto;
pub mod sweep;

pub use evalset::EvalSet;
pub use pareto::{pareto_explore, ParetoConfig, ParetoPoint, ParetoResult};
pub use sweep::{
    bit_shave_search, run_sweep, score_plan, score_plan_with, score_point, BitShaveResult,
    PlanCache, PlanScore, SweepPoint, SweepResult,
};
