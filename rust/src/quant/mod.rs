//! Post-training-quantization machinery + the precision sweep engine
//! behind Figures 9-11 (S8).

pub mod evalset;
pub mod sweep;

pub use evalset::EvalSet;
pub use sweep::{run_sweep, score_point, SweepPoint, SweepResult};
