//! Post-training-quantization machinery + the precision sweep engine
//! behind Figures 9-11 (S8).

pub mod evalset;
pub mod sweep;

pub use evalset::EvalSet;
pub use sweep::{
    bit_shave_search, run_sweep, score_plan, score_point, BitShaveResult, PlanScore,
    SweepPoint, SweepResult,
};
