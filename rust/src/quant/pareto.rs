//! Joint (precision plan × parallelism plan) design-space exploration:
//! the latency-vs-resources Pareto frontier under a device budget and an
//! AUC-ratio floor.
//!
//! The paper tunes two knobs per design point — fixed-point precision
//! (§VI-A) and the reuse factor (§VI-B) — but only uniformly and only
//! one at a time.  With both dials *per site* ([`PrecisionPlan`],
//! [`ParallelismPlan`]) the design space is a lattice this module walks
//! with a deterministic greedy phase (seed every uniform reuse choice,
//! then relax non-gating sites while latency holds and cost falls, then
//! shave fractional bits under the AUC floor) followed by a seeded
//! annealing phase that jitters single sites to fill in the frontier.
//!
//! Two structural facts keep the search cheap:
//! * reuse is *schedule-only* — it never changes a probability, so
//!   parallelism moves need no eval-set re-scoring (AUC is cached per
//!   precision plan);
//! * the schedule is monotone in per-site reuse (property-tested in
//!   `hls::transformer`), so latency-free resource savings exist exactly
//!   at the sites that neither gate the drain nor the re-arm interval.

use crate::fixed::FixedSpec;
use crate::hls::resources::{Device, Resources, VU13P};
use crate::hls::{ParallelismPlan, PrecisionPlan, QuantConfig, ReuseFactor, SynthesisReport};
use crate::models::config::ModelConfig;
use crate::models::weights::Weights;
use crate::testutil::XorShift;

use super::evalset::EvalSet;
use super::sweep::PlanCache;

/// Search configuration.
#[derive(Clone, Debug)]
pub struct ParetoConfig {
    /// Feasibility floor on `auc_fixed / auc_float`.
    pub auc_floor: f64,
    /// Fractional bits below which no site is shaved.
    pub min_frac: u32,
    /// Per-site reuse factors the walk may assign (sorted, deduped).
    pub reuse_choices: Vec<u32>,
    /// Annealing iterations after the deterministic greedy phase.
    pub anneal_iters: usize,
    /// RNG seed of the annealing walk (the greedy phase and therefore
    /// the headline dominance result are deterministic regardless).
    pub seed: u64,
    /// Device budget every feasible point must fit.
    pub device: Device,
}

impl Default for ParetoConfig {
    fn default() -> Self {
        Self {
            auc_floor: 0.99,
            min_frac: 2,
            reuse_choices: vec![1, 2, 4, 8],
            anneal_iters: 64,
            seed: 0xF0CA_CC1A,
            device: VU13P,
        }
    }
}

/// One feasible design point on (or offered to) the frontier.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub precision: PrecisionPlan,
    pub parallelism: ParallelismPlan,
    pub latency_cycles: u64,
    pub interval_cycles: u64,
    pub latency_us: f64,
    pub resources: Resources,
    pub auc_ratio: f64,
}

impl ParetoPoint {
    /// The resource objective: DSP + FF (the two axes the paper's
    /// Figures 12-13 track and the mixed-precision work minimizes).
    pub fn cost(&self) -> u64 {
        self.resources.dsp + self.resources.ff
    }

    /// Strict Pareto dominance on (latency cycles, DSP+FF): at least as
    /// good on both axes, strictly better on one.
    pub fn dominates(&self, o: &ParetoPoint) -> bool {
        (self.latency_cycles <= o.latency_cycles && self.cost() < o.cost())
            || (self.latency_cycles < o.latency_cycles && self.cost() <= o.cost())
    }

    /// True iff the reuse map is heterogeneous.
    pub fn is_mixed_reuse(&self) -> bool {
        self.parallelism.is_uniform().is_none()
    }
}

/// Result of one exploration.
#[derive(Clone, Debug)]
pub struct ParetoResult {
    /// Non-dominated feasible points, sorted by latency then cost.
    pub frontier: Vec<ParetoPoint>,
    /// The best (lowest-latency, then cheapest) *feasible uniform-reuse*
    /// design point — the baseline a mixed plan must beat.  `None` when
    /// no uniform seed fits the budget at the AUC floor.
    pub best_uniform: Option<ParetoPoint>,
    /// Schedule/resource evaluations spent (`synthesize` calls).
    pub evals: usize,
    /// Eval-set scorings spent (one per distinct precision plan).
    pub scored: usize,
    /// Engines built — one per distinct precision plan, shared between
    /// the AUC scoring and every `synthesize` of that plan (the
    /// compile-once [`PlanCache`] contract).
    pub engines_built: usize,
    /// Candidates rejected by the static verifier before any schedule or
    /// eval-set work was spent on them.
    pub pruned: usize,
}

impl ParetoResult {
    /// The first frontier point with a heterogeneous reuse map that
    /// strictly dominates [`Self::best_uniform`] — the acceptance
    /// artifact of the explorer.
    pub fn mixed_dominator(&self) -> Option<&ParetoPoint> {
        let bu = self.best_uniform.as_ref()?;
        self.frontier
            .iter()
            .find(|p| p.is_mixed_reuse() && p.dominates(bu))
    }
}

/// Evaluation engine over one shared [`PlanCache`]: the fixed-point
/// engine (weights PTQ'd + mantissas lifted once per distinct plan) is
/// reused by both the schedule synthesis and the AUC scoring, and the
/// AUC itself is scored once per plan (reuse moves are schedule-only
/// and never re-score).
struct Explorer<'a> {
    cfg: &'a ModelConfig,
    eval: &'a EvalSet,
    pcfg: &'a ParetoConfig,
    cache: PlanCache<'a>,
    evals: usize,
    pruned: usize,
}

impl<'a> Explorer<'a> {
    fn new(
        cfg: &'a ModelConfig,
        weights: &'a Weights,
        eval: &'a EvalSet,
        pcfg: &'a ParetoConfig,
    ) -> Self {
        Self {
            cfg,
            eval,
            pcfg,
            cache: PlanCache::new(cfg, weights),
            evals: 0,
            pruned: 0,
        }
    }

    fn synth(&mut self, pp: &PrecisionPlan, par: &ParallelismPlan) -> SynthesisReport {
        self.evals += 1;
        self.cache.engine(pp).synthesize(par)
    }

    fn auc_ratio(&mut self, pp: &PrecisionPlan) -> f64 {
        self.cache.score(self.eval, pp).auc_ratio
    }

    /// Evaluate one candidate, or `None` when the static verifier's
    /// profile-free passes flag it as ERROR — a plan that would saturate
    /// its own accumulator clamp or deadlock its schedule is rejected
    /// before any synthesis or eval-set scoring is spent on it.
    fn point(&mut self, pp: &PrecisionPlan, par: &ParallelismPlan) -> Option<ParetoPoint> {
        if crate::analysis::static_plan_errors(self.cfg, pp, par) > 0 {
            self.pruned += 1;
            return None;
        }
        let rep = self.synth(pp, par);
        Some(ParetoPoint {
            precision: pp.clone(),
            parallelism: par.clone(),
            latency_cycles: rep.latency_cycles,
            interval_cycles: rep.interval_cycles,
            latency_us: rep.latency_us,
            resources: rep.total,
            auc_ratio: self.auc_ratio(pp),
        })
    }

    fn feasible(&self, p: &ParetoPoint) -> bool {
        p.resources.fits(&self.pcfg.device) && p.auc_ratio >= self.pcfg.auc_floor
    }
}

/// Insert `p` into the archive iff no member dominates or duplicates it,
/// evicting anything it dominates.  Returns whether it was kept.
fn offer(frontier: &mut Vec<ParetoPoint>, p: ParetoPoint) -> bool {
    let duplicated = frontier
        .iter()
        .any(|q| q.dominates(&p) || (q.latency_cycles == p.latency_cycles && q.cost() == p.cost()));
    if duplicated {
        return false;
    }
    frontier.retain(|q| !p.dominates(q));
    frontier.push(p);
    true
}

/// Sites the model actually instantiates (LN sites are dead on LN-free
/// configs and must not soak up moves).
fn live_sites(cfg: &ModelConfig, names: Vec<String>) -> Vec<String> {
    names
        .into_iter()
        .filter(|s| cfg.use_layernorm || !(s.ends_with(".ln1") || s.ends_with(".ln2")))
        .collect()
}

/// Explore the joint (precision × parallelism) space from a uniform
/// `base` precision; see the module docs for the phase structure.
pub fn pareto_explore(
    cfg: &ModelConfig,
    weights: &Weights,
    eval: &EvalSet,
    base: QuantConfig,
    pcfg: &ParetoConfig,
) -> ParetoResult {
    let mut ex = Explorer::new(cfg, weights, eval, pcfg);
    let mut choices = pcfg.reuse_choices.clone();
    choices.retain(|&r| r >= 1);
    choices.sort_unstable();
    choices.dedup();
    if choices.is_empty() {
        choices.push(1);
    }
    let base_pp = PrecisionPlan::uniform(cfg.num_blocks, base);
    let mut frontier: Vec<ParetoPoint> = Vec::new();
    let mut best_uniform: Option<ParetoPoint> = None;
    let mut seeds: Vec<ParetoPoint> = Vec::new();

    // ---- phase 1: uniform seeds ---------------------------------------
    for &r in &choices {
        let par = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(r));
        let Some(p) = ex.point(&base_pp, &par) else { continue };
        if !ex.feasible(&p) {
            continue;
        }
        let better = match &best_uniform {
            None => true,
            Some(b) => (p.latency_cycles, p.cost()) < (b.latency_cycles, b.cost()),
        };
        if better {
            best_uniform = Some(p.clone());
        }
        offer(&mut frontier, p.clone());
        seeds.push(p);
    }

    // ---- phase 2: greedy reuse relaxation (deterministic) -------------
    // From a starting point, raise one site's reuse at a time, keeping a
    // move only when it is latency-free and strictly cheaper — the
    // "relax every engine the schedule isn't gated by" walk.  Reuse
    // moves never re-score the eval set, so this is pure schedule work.
    let all_sites = ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(1)).site_names();
    let sites = live_sites(cfg, all_sites);
    let relax = |ex: &mut Explorer, frontier: &mut Vec<ParetoPoint>, seed: ParetoPoint| {
        let mut cur = seed;
        loop {
            let mut improved = false;
            'scan: for site in &sites {
                let r_now = cur.parallelism.get(site).expect("live site").get();
                for &r in choices.iter().filter(|&&c| c > r_now) {
                    let mut par = cur.parallelism.clone();
                    par.set(site, ReuseFactor(r)).expect("live site");
                    let Some(cand) = ex.point(&cur.precision, &par) else { continue };
                    if ex.feasible(&cand)
                        && cand.latency_cycles <= cur.latency_cycles
                        && cand.cost() < cur.cost()
                    {
                        offer(frontier, cand.clone());
                        cur = cand;
                        improved = true;
                        break 'scan;
                    }
                }
            }
            if !improved {
                break;
            }
        }
    };
    for seed in seeds {
        relax(&mut ex, &mut frontier, seed);
    }

    // ---- phase 3: greedy precision shave off the best uniform ---------
    // One pass of per-site fractional-bit shaving under the AUC floor,
    // each kept step offered to the frontier (the joint dial: a shave
    // can unlock a cheaper point at the same latency).
    if let Some(bu) = best_uniform.clone() {
        let mut cur = bu;
        for site in live_sites(cfg, cur.precision.site_names()) {
            let q = cur.precision.get(&site).expect("live site");
            if q.data.frac() <= pcfg.min_frac || q.data.width() <= q.data.integer() + 1 {
                continue;
            }
            let shaved = FixedSpec::new(q.data.width() - 1, q.data.integer());
            let mut pp = cur.precision.clone();
            if pp.set_data(&site, shaved).is_err() {
                continue;
            }
            let Some(cand) = ex.point(&pp, &cur.parallelism) else { continue };
            if ex.feasible(&cand) && cand.cost() <= cur.cost() {
                offer(&mut frontier, cand.clone());
                cur = cand;
            }
        }
    }

    // ---- phase 4: annealing jitter ------------------------------------
    // Single-site random moves (reuse up/down, frac shave/widen) from a
    // walk state that restarts off the archive; worse-but-feasible moves
    // are taken with a cooling probability so the walk can cross valleys.
    let mut rng = XorShift::new(pcfg.seed);
    let psites = live_sites(cfg, base_pp.site_names());
    if let Some(first) = frontier.first().cloned() {
        let mut walk = first;
        for i in 0..pcfg.anneal_iters {
            if !frontier.is_empty() && rng.next_f64() < 0.2 {
                walk = frontier[(rng.next_u64() as usize) % frontier.len()].clone();
            }
            let temp = 1.0 - i as f64 / pcfg.anneal_iters.max(1) as f64;
            let cand = match rng.next_u64() % 3 {
                0 => {
                    // reuse move: one site, one notch up or down
                    let site = &sites[(rng.next_u64() as usize) % sites.len()];
                    let r_now = walk.parallelism.get(site).expect("live site").get();
                    let idx = choices.iter().position(|&c| c >= r_now).unwrap_or(0);
                    let next = if rng.next_u64() & 1 == 1 {
                        choices.get(idx + 1)
                    } else {
                        idx.checked_sub(1).and_then(|j| choices.get(j))
                    };
                    next.and_then(|&r| {
                        let mut par = walk.parallelism.clone();
                        par.set(site, ReuseFactor(r)).expect("live site");
                        ex.point(&walk.precision, &par)
                    })
                }
                1 => {
                    // precision shave
                    let site = &psites[(rng.next_u64() as usize) % psites.len()];
                    let q = walk.precision.get(site).expect("live site");
                    if q.data.frac() > pcfg.min_frac {
                        let mut pp = walk.precision.clone();
                        let shaved = FixedSpec::new(q.data.width() - 1, q.data.integer());
                        match pp.set_data(site, shaved) {
                            Ok(()) => ex.point(&pp, &walk.parallelism),
                            Err(_) => None,
                        }
                    } else {
                        None
                    }
                }
                _ => {
                    // precision widen, bounded by the base width
                    let site = &psites[(rng.next_u64() as usize) % psites.len()];
                    let q = walk.precision.get(site).expect("live site");
                    if q.data.width() < base.data.width() {
                        let mut pp = walk.precision.clone();
                        let widened = FixedSpec::new(q.data.width() + 1, q.data.integer());
                        match pp.set_data(site, widened) {
                            Ok(()) => ex.point(&pp, &walk.parallelism),
                            Err(_) => None,
                        }
                    } else {
                        None
                    }
                }
            };
            if let Some(cand) = cand {
                if ex.feasible(&cand) {
                    offer(&mut frontier, cand.clone());
                    if cand.dominates(&walk) || rng.next_f64() < 0.4 * temp {
                        walk = cand;
                    }
                }
            }
        }
    }

    // ---- phase 5: final reuse relaxation over the frontier ------------
    // Precision shaves (phases 3-4) can mint uniform-reuse points that
    // dominate earlier mixed ones; a last relax pass over a snapshot
    // restores the invariant that every surviving design has had its
    // non-gating engines relaxed — in particular, the lowest-latency
    // point always ends up with (or dominated only by) a latency-free
    // cheaper mixed twin.
    for p in frontier.clone() {
        relax(&mut ex, &mut frontier, p);
    }

    frontier.sort_by(|a, b| {
        (a.latency_cycles, a.cost()).cmp(&(b.latency_cycles, b.cost()))
    });
    ParetoResult {
        frontier,
        best_uniform,
        evals: ex.evals,
        scored: ex.cache.scorings(),
        engines_built: ex.cache.builds(),
        pruned: ex.pruned,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::{zoo, zoo_model};

    fn small_cfg(iters: usize) -> ParetoConfig {
        ParetoConfig { anneal_iters: iters, ..ParetoConfig::default() }
    }

    /// The tentpole's acceptance bar: under the VU13P budget at AUC
    /// floor 0.99, a mixed-reuse plan strictly dominates the best
    /// uniform-reuse design point (lower latency at <= DSP+FF, or fewer
    /// DSP+FF at <= latency) on at least one zoo model.
    #[test]
    fn pareto_mixed_reuse_dominates_best_uniform_on_a_zoo_model() {
        let mut found = None;
        for m in zoo() {
            let w = synthetic_weights(&m.config, 31);
            // margin-labeled eval: auc_float = 1 by construction, so the
            // floor measures pure quantization damage
            let eval = EvalSet::synthetic(&m.config, &w, 16, 7);
            let r = pareto_explore(
                &m.config,
                &w,
                &eval,
                QuantConfig::new(6, 12),
                &small_cfg(24),
            );
            let bu = match r.best_uniform.as_ref() {
                Some(b) => b,
                None => continue,
            };
            assert!(bu.parallelism.is_uniform().is_some());
            if let Some(dom) = r.mixed_dominator() {
                assert!(dom.is_mixed_reuse());
                assert!(dom.dominates(bu), "mixed_dominator must dominate");
                assert!(dom.resources.fits(&VU13P));
                assert!(dom.auc_ratio >= 0.99);
                // dominance spelled out: lower latency at <= resources,
                // or fewer DSPs+FFs at <= latency
                assert!(
                    (dom.latency_cycles < bu.latency_cycles && dom.cost() <= bu.cost())
                        || (dom.latency_cycles <= bu.latency_cycles
                            && dom.cost() < bu.cost())
                );
                found = Some(m.config.name.clone());
                break;
            }
        }
        assert!(
            found.is_some(),
            "no zoo model produced a mixed-reuse plan dominating the best uniform point"
        );
    }

    #[test]
    fn frontier_is_mutually_non_dominated_and_sorted() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 32);
        let eval = EvalSet::synthetic(&m.config, &w, 12, 9);
        let r = pareto_explore(&m.config, &w, &eval, QuantConfig::new(6, 12), &small_cfg(32));
        assert!(!r.frontier.is_empty());
        for (i, a) in r.frontier.iter().enumerate() {
            assert!(a.resources.fits(&VU13P));
            assert!(a.auc_ratio >= 0.99, "infeasible point on the frontier");
            for (j, b) in r.frontier.iter().enumerate() {
                if i != j {
                    assert!(!a.dominates(b), "frontier point {i} dominates {j}");
                }
            }
        }
        for w2 in r.frontier.windows(2) {
            assert!(w2[0].latency_cycles <= w2[1].latency_cycles, "sorted by latency");
            // along a frontier, more latency must buy fewer resources
            assert!(w2[0].cost() > w2[1].cost(), "latency must buy resources");
        }
        assert!(r.evals >= r.frontier.len());
        assert!(r.scored >= 1, "the base precision plan is scored once");
        // compile-once: every distinct precision plan is built exactly
        // once and that one engine serves both its scoring and all of
        // its synthesize calls
        assert_eq!(r.engines_built, r.scored);
        assert!(r.evals > r.engines_built, "reuse moves must not rebuild engines");
    }

    #[test]
    fn reuse_moves_do_not_rescore_the_eval_set() {
        // AUC is a function of precision alone; with annealing biased to
        // reuse moves the scored count stays far below the eval count
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 33);
        let eval = EvalSet::synthetic(&m.config, &w, 8, 11);
        let pcfg = ParetoConfig { anneal_iters: 0, ..ParetoConfig::default() };
        let r = pareto_explore(&m.config, &w, &eval, QuantConfig::new(6, 10), &pcfg);
        // phases 1-2 are reuse-only; phase 3 shaves once per site at most
        let sites = 1 + m.config.num_blocks * 6 + 4;
        assert!(r.scored <= 1 + sites, "{} scorings for {} sites", r.scored, sites);
        assert!(r.evals > 0);
    }

    #[test]
    fn infeasible_floor_yields_empty_frontier() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 34);
        let eval = EvalSet::synthetic(&m.config, &w, 8, 13);
        let pcfg = ParetoConfig { auc_floor: 1.5, anneal_iters: 4, ..ParetoConfig::default() };
        let r = pareto_explore(&m.config, &w, &eval, QuantConfig::new(6, 10), &pcfg);
        assert!(r.frontier.is_empty());
        assert!(r.best_uniform.is_none());
        assert!(r.mixed_dominator().is_none());
    }

    #[test]
    fn structurally_invalid_base_precision_is_pruned_before_scoring() {
        // base int bits 12 > the 10-bit accumulator clamp: every seed the
        // explorer would mint carries the structural ERROR, so the static
        // verifier prunes the whole walk before a single synthesize or
        // eval-set scoring is spent
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 36);
        let eval = EvalSet::synthetic(&m.config, &w, 8, 17);
        let r = pareto_explore(&m.config, &w, &eval, QuantConfig::new(12, 6), &small_cfg(8));
        assert!(r.frontier.is_empty(), "no invalid plan may reach the frontier");
        assert!(r.best_uniform.is_none());
        assert!(r.pruned > 0, "the uniform seeds must be pruned");
        assert_eq!(r.evals, 0, "pruning happens before synthesis");
        assert_eq!(r.scored, 0, "pruning happens before eval-set scoring");
        assert_eq!(r.engines_built, 0, "pruning happens before any engine build");
    }

    #[test]
    fn valid_plans_are_never_pruned() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 37);
        let eval = EvalSet::synthetic(&m.config, &w, 8, 19);
        let r = pareto_explore(&m.config, &w, &eval, QuantConfig::new(6, 10), &small_cfg(8));
        assert_eq!(r.pruned, 0, "well-formed candidates must all be scored");
        assert!(!r.frontier.is_empty());
    }

    #[test]
    fn explorer_is_deterministic_for_a_seed() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 35);
        let eval = EvalSet::synthetic(&m.config, &w, 8, 15);
        let run = || {
            pareto_explore(&m.config, &w, &eval, QuantConfig::new(6, 10), &small_cfg(16))
                .frontier
                .iter()
                .map(|p| (p.latency_cycles, p.cost()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
