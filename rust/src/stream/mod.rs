//! Continuous-stream windowed inference (the stream-to-trigger
//! tentpole): the path from an always-on strain stream to de-duplicated
//! trigger candidates — the actual deployment scenario behind the
//! paper's sub-2 µs "real-time applications" claim (PAPER.md; Duarte et
//! al. 2018 frame the same always-on trigger setting).
//!
//! ```text
//!  StrainStream ──► Windowizer ──► coordinator (router/batcher/backend)
//!  (continuous      ((S,d) hops,        │  per-window scores
//!   samples +        ring buffer)       ▼
//!   injected                     robust z statistic ──► TriggerFinder
//!   chirps)                      (median/MAD, self-     (threshold +
//!                                 calibrating)           peak-over-cluster)
//!                                                            │
//!                              detection efficiency + trigger latency
//! ```
//!
//! * [`crate::data::gw::StrainStream`] — seedable continuous source with
//!   chirps injected at known sample offsets (the ground truth).
//! * [`Windowizer`] — ring-buffered stream -> `(seq_len, channels)`
//!   window slicer, bitwise identical to a naive re-slice, allocation-
//!   free per window once its scratch pool is warm.
//! * [`TriggerFinder`] — threshold + peak-over-cluster de-duplication.
//! * [`analyze`] — robust-z statistic, clustering, efficiency vs the
//!   injection truth, trigger-latency percentiles.
//!
//! The coordinator consumes this as an ingestion mode: a
//! `PipelineConfig` whose `source` is `SourceMode::Stream` runs the
//! windowizer in the source thread, submits windows through the same
//! router/SPSC backpressure path as pre-cut events, and workers record
//! per-window [`WindowScore`]s for the analyzer.  Unlike batch size,
//! hop is a *coverage* dial: throughput at hop S/2 is set by overlap
//! reuse, not batch fill.

pub mod report;
pub mod reuse;
pub mod trigger;
pub mod window;

pub use report::{analyze, StreamParams, StreamReport};
pub use reuse::ReuseCounters;
pub use trigger::{Trigger, TriggerFinder};
pub use window::{StreamWindow, Windowizer};

/// One scored stream window, as recorded by a coordinator worker:
/// stream position in, model score and serving latency out.  The
/// analyzer consumes these (in any order — shards interleave).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowScore {
    /// Absolute sample index of the window's first row.
    pub pos: u64,
    /// The model's positive-class score for this window.
    pub score: f32,
    /// Arrival (last sample) -> scored latency in nanoseconds.
    pub latency_ns: u64,
}
