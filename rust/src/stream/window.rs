//! The windowizer: slices a continuous multi-channel sample stream into
//! overlapping `(seq_len, channels)` model windows with a configurable
//! hop, using a fixed ring buffer — no per-window work proportional to
//! the overlap.  Drivers that score windows in place and hand them back
//! via [`Windowizer::recycle`] allocate nothing per window once the
//! scratch pool is warm; the trigger server's stream source instead
//! *moves* each window into its SPSC ring (ownership leaves with the
//! event), which costs exactly one buffer allocation per window.
//!
//! Contract (property-tested below): every emitted window is **bitwise
//! identical** to the naive re-slice `stream[k*hop .. k*hop + seq_len]`
//! of the recorded stream, for any hop >= 1 — including hop > seq_len,
//! where the windows have gaps between them and the ring simply skips
//! the uncovered samples.

use crate::hls::scratch::Scratch;
use crate::nn::tensor::Mat;

/// One window cut from the stream.
#[derive(Debug)]
pub struct StreamWindow {
    /// Absolute sample index of the window's first row.
    pub start: u64,
    /// `(seq_len, channels)` feature matrix (same layout the router
    /// validates for the model).
    pub x: Mat,
    /// Row lineage: how many trailing rows of `x` are new relative to
    /// the previously emitted window of this stream.  The first window
    /// (and any window at hop >= seq_len) is all fresh; at hop h < S a
    /// steady-state window carries `S - h` rows and grows `h` fresh
    /// ones.  The leading `seq_len - fresh_rows` rows are bitwise
    /// copies of the previous window's trailing rows (property-tested
    /// below) — exactly the rows an incremental executor may reuse.
    pub fresh_rows: usize,
}

impl StreamWindow {
    /// Rows carried over (bitwise) from the previously emitted window.
    pub fn carried_rows(&self) -> usize {
        self.x.rows() - self.fresh_rows
    }
}

/// Ring-buffered stream -> window slicer.
pub struct Windowizer {
    seq_len: usize,
    channels: usize,
    hop: usize,
    /// The last `seq_len` samples, sample-major: slot `t` holds the
    /// sample with absolute index `i` where `i % seq_len == t`.
    ring: Vec<f32>,
    /// Samples pushed so far.
    n: u64,
    /// Start of the previously emitted window (lineage anchor).
    last_start: Option<u64>,
    /// Window buffers are drawn from (and recycled into) this pool, so
    /// a steady-state stream driver allocates nothing per window.
    scratch: Scratch,
}

impl Windowizer {
    pub fn new(seq_len: usize, channels: usize, hop: usize) -> Self {
        assert!(seq_len >= 1 && channels >= 1, "degenerate window shape");
        assert!(hop >= 1, "hop must be >= 1");
        Self {
            seq_len,
            channels,
            hop,
            ring: vec![0.0; seq_len * channels],
            n: 0,
            last_start: None,
            scratch: Scratch::new(),
        }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn hop(&self) -> usize {
        self.hop
    }

    /// Samples pushed so far.
    pub fn pushed(&self) -> u64 {
        self.n
    }

    /// Windows emitted so far: one per hop once the first `seq_len`
    /// samples have arrived.
    pub fn emitted(&self) -> u64 {
        if self.n < self.seq_len as u64 {
            0
        } else {
            (self.n - self.seq_len as u64) / self.hop as u64 + 1
        }
    }

    /// Push one sample (one value per channel).  Returns the completed
    /// window when this sample is the last row of one — at most one
    /// window per push, since windows complete `hop >= 1` samples apart.
    pub fn push(&mut self, sample: &[f32]) -> Option<StreamWindow> {
        assert_eq!(sample.len(), self.channels, "bad channel count");
        let slot = (self.n % self.seq_len as u64) as usize * self.channels;
        self.ring[slot..slot + self.channels].copy_from_slice(sample);
        self.n += 1;
        // window [s, s + seq_len) completes at sample s + seq_len - 1,
        // i.e. when n - seq_len is a window start (a multiple of hop)
        let s = self.seq_len as u64;
        if self.n >= s && (self.n - s) % self.hop as u64 == 0 {
            Some(self.emit())
        } else {
            None
        }
    }

    fn emit(&mut self) -> StreamWindow {
        let start = self.n - self.seq_len as u64;
        let ch = self.channels;
        let mut buf = self.scratch.take_row(self.seq_len * ch);
        for t in 0..self.seq_len {
            // absolute index start + t lives in ring slot (start+t) % S
            let slot = ((start + t as u64) % self.seq_len as u64) as usize * ch;
            buf[t * ch..(t + 1) * ch].copy_from_slice(&self.ring[slot..slot + ch]);
        }
        // lineage: rows [0, S - delta) are bitwise copies of the previous
        // window's rows [delta, S); a first window (or hop >= S) shares
        // nothing and is all fresh
        let fresh_rows = match self.last_start {
            Some(prev) => (start - prev).min(self.seq_len as u64) as usize,
            None => self.seq_len,
        };
        self.last_start = Some(start);
        StreamWindow { start, x: Mat::from_vec(self.seq_len, ch, buf), fresh_rows }
    }

    /// Return a served window's buffer to the pool so the next emission
    /// reuses its allocation.  Optional: windows handed to another owner
    /// (e.g. the trigger server's rings) simply cost one allocation each.
    pub fn recycle(&mut self, w: StreamWindow) {
        self.scratch.put_row(w.x.into_vec());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    /// Naive reference: record the whole stream, then re-slice.
    fn naive_windows(stream: &[f32], ch: usize, s: usize, hop: usize) -> Vec<(u64, Vec<f32>)> {
        let total = stream.len() / ch;
        let mut out = Vec::new();
        let mut start = 0usize;
        while start + s <= total {
            out.push((start as u64, stream[start * ch..(start + s) * ch].to_vec()));
            start += hop;
        }
        out
    }

    fn drive(stream: &[f32], ch: usize, s: usize, hop: usize) -> Vec<(u64, Vec<f32>)> {
        let mut wz = Windowizer::new(s, ch, hop);
        let mut out = Vec::new();
        for sample in stream.chunks(ch) {
            if let Some(w) = wz.push(sample) {
                out.push((w.start, w.x.data().to_vec()));
                wz.recycle(w);
            }
        }
        out
    }

    #[test]
    fn prop_streamed_windows_bitwise_match_naive_reslice() {
        Prop::new("windowizer == naive re-slice").runs(300).check(|g| {
            let ch = g.usize_in(1, 4);
            let s = g.usize_in(1, 24);
            // hop deliberately ranges past s (gapped windows)
            let hop = g.usize_in(1, 2 * s + 4);
            let total = g.usize_in(0, 6 * s + 3);
            let stream: Vec<f32> = (0..total * ch).map(|_| g.normal()).collect();
            let got = drive(&stream, ch, s, hop);
            let want = naive_windows(&stream, ch, s, hop);
            assert_eq!(got.len(), want.len(), "S={s} hop={hop} total={total}");
            for ((gp, gx), (wp, wx)) in got.iter().zip(&want) {
                assert_eq!(gp, wp, "window start");
                assert_eq!(gx, wx, "S={s} hop={hop} start={gp}");
            }
        });
    }

    #[test]
    fn stream_shorter_than_window_emits_nothing() {
        let mut wz = Windowizer::new(10, 2, 3);
        for i in 0..9 {
            assert!(wz.push(&[i as f32, 0.0]).is_none());
        }
        assert_eq!(wz.emitted(), 0);
        // the 10th sample completes the first window
        let w = wz.push(&[9.0, 0.0]).expect("first window at sample 10");
        assert_eq!(w.start, 0);
        assert_eq!(wz.emitted(), 1);
    }

    #[test]
    fn hop_larger_than_window_leaves_gaps() {
        // S=4, hop=6: windows [0,4), [6,10), [12,16) — samples 4,5 and
        // 10,11 belong to no window
        let stream: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let got = drive(&stream, 1, 4, 6);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], (0, vec![0.0, 1.0, 2.0, 3.0]));
        assert_eq!(got[1], (6, vec![6.0, 7.0, 8.0, 9.0]));
        assert_eq!(got[2], (12, vec![12.0, 13.0, 14.0, 15.0]));
    }

    #[test]
    fn exact_multiple_tail_emits_final_window_on_last_sample() {
        // total = S + 2*hop exactly: the last window completes on the
        // very last pushed sample, nothing is left dangling
        let (s, hop) = (8usize, 3usize);
        let total = s + 2 * hop;
        let stream: Vec<f32> = (0..total).map(|v| v as f32).collect();
        let mut wz = Windowizer::new(s, 1, hop);
        let mut last = None;
        for (i, sample) in stream.chunks(1).enumerate() {
            if let Some(w) = wz.push(sample) {
                last = Some((i, w.start));
            }
        }
        assert_eq!(last, Some((total - 1, 2 * hop as u64)));
        assert_eq!(wz.emitted(), 3);
        // one sample short of the next window: still 3
        wz.push(&[99.0]);
        assert_eq!(wz.emitted(), 3);
    }

    #[test]
    fn recycled_buffers_are_reused_not_reallocated() {
        let mut wz = Windowizer::new(6, 2, 2);
        let mut g = Gen::new(5);
        let mut ptr = None;
        for i in 0..40 {
            let s = [g.normal(), g.normal()];
            if let Some(w) = wz.push(&s) {
                let p = w.x.data().as_ptr();
                match ptr {
                    None => ptr = Some(p),
                    // single-buffer steady state: the recycled allocation
                    // is handed back every time
                    Some(prev) => assert_eq!(prev, p, "window {i} reallocated"),
                }
                wz.recycle(w);
            }
        }
        assert!(wz.emitted() > 10);
    }

    #[test]
    #[should_panic(expected = "hop must be >= 1")]
    fn zero_hop_rejected() {
        Windowizer::new(4, 1, 0);
    }

    /// Drive a stream keeping full windows plus their lineage claims.
    fn drive_lineage(
        stream: &[f32],
        ch: usize,
        s: usize,
        hop: usize,
    ) -> Vec<(u64, usize, Vec<f32>)> {
        let mut wz = Windowizer::new(s, ch, hop);
        let mut out = Vec::new();
        for sample in stream.chunks(ch) {
            if let Some(w) = wz.push(sample) {
                out.push((w.start, w.fresh_rows, w.x.data().to_vec()));
                wz.recycle(w);
            }
        }
        out
    }

    #[test]
    fn prop_row_lineage_matches_brute_force_overlap() {
        // The lineage contract an incremental executor leans on: the
        // leading `S - fresh_rows` rows of every window are *bitwise*
        // copies of the previous window's trailing rows, and fresh_rows
        // equals the brute-force start-delta overlap — over random
        // (S, d, hop) including hop >= S (zero reuse) and hop = S
        // (exact tail, zero reuse).
        Prop::new("windowizer lineage == brute-force overlap").runs(300).check(|g| {
            let ch = g.usize_in(1, 4);
            let s = g.usize_in(1, 24);
            let hop = g.usize_in(1, 2 * s + 4); // deliberately past S
            let total = g.usize_in(0, 6 * s + 3);
            let stream: Vec<f32> = (0..total * ch).map(|_| g.normal()).collect();
            let wins = drive_lineage(&stream, ch, s, hop);
            for (i, (start, fresh, x)) in wins.iter().enumerate() {
                if i == 0 {
                    assert_eq!(*fresh, s, "first window is all fresh");
                    continue;
                }
                let (prev_start, _, prev_x) = &wins[i - 1];
                let delta = (*start - *prev_start) as usize;
                let want_fresh = delta.min(s);
                assert_eq!(*fresh, want_fresh, "S={s} hop={hop} start={start}");
                // brute force: every claimed-carried row must be a
                // bitwise copy of the previous window's shifted row
                for t in 0..s - want_fresh {
                    assert_eq!(
                        &x[t * ch..(t + 1) * ch],
                        &prev_x[(t + delta) * ch..(t + delta + 1) * ch],
                        "S={s} hop={hop} window {i} row {t} not carried"
                    );
                }
            }
        });
    }

    #[test]
    fn lineage_at_hop_equal_to_seq_len_is_all_fresh() {
        // hop = S: windows tile the stream exactly, sharing no rows
        let stream: Vec<f32> = (0..32).map(|v| v as f32).collect();
        let wins = drive_lineage(&stream, 1, 8, 8);
        assert_eq!(wins.len(), 4);
        for (_, fresh, _) in &wins {
            assert_eq!(*fresh, 8, "hop == S must claim zero reuse");
        }
    }

    #[test]
    fn lineage_steady_state_fresh_rows_equal_hop() {
        let stream: Vec<f32> = (0..64).map(|v| v as f32).collect();
        let wins = drive_lineage(&stream, 1, 16, 4);
        assert!(wins.len() > 3);
        assert_eq!(wins[0].1, 16);
        for (_, fresh, _) in &wins[1..] {
            assert_eq!(*fresh, 4, "steady state grows exactly hop rows");
        }
    }

    #[test]
    fn stream_restart_resets_lineage_to_all_fresh() {
        // a restarted stream (new Windowizer) must not claim carried
        // rows from the dead stream — downstream caches key on this
        let stream: Vec<f32> = (0..24).map(|v| v as f32).collect();
        let first_run = drive_lineage(&stream, 1, 8, 2);
        assert!(first_run.len() > 1);
        assert_eq!(first_run[1].1, 2, "warm stream reuses");
        let restarted = drive_lineage(&stream[10..], 1, 8, 2);
        assert_eq!(restarted[0].1, 8, "restart claims nothing");
    }
}
