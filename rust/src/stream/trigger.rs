//! Trigger clustering: per-window detection statistics in, de-duplicated
//! trigger candidates out.
//!
//! A window whose statistic crosses the threshold opens (or extends) a
//! cluster; any observation more than `merge_gap` samples past the
//! cluster's last over-threshold window closes it.  Each closed cluster
//! becomes exactly one [`Trigger`] carrying the *peak* window — the
//! standard peak-over-cluster de-duplication of burst searches (one
//! astrophysical event excites every overlapping window; reporting them
//! all would multiply the trigger rate by the overlap factor).
//!
//! Observations must arrive in non-decreasing stream order; the analyzer
//! sorts the scored windows first (a sharded worker pool completes them
//! out of order).

/// One de-duplicated trigger candidate.
#[derive(Clone, Debug, PartialEq)]
pub struct Trigger {
    /// Start sample of the first over-threshold window in the cluster.
    pub onset: u64,
    /// Start sample of the peak (highest-statistic) window.
    pub peak_pos: u64,
    /// The peak window's detection statistic.
    pub peak_stat: f32,
    /// Over-threshold windows folded into this trigger.
    pub windows: usize,
    /// Latency of the peak window (ns, last-sample arrival -> scored) —
    /// the "how stale is this trigger" number a downstream veto cares
    /// about.
    pub latency_ns: u64,
}

struct OpenCluster {
    onset: u64,
    last: u64,
    peak_pos: u64,
    peak_stat: f32,
    peak_latency: u64,
    windows: usize,
}

/// Streaming threshold + peak-over-cluster trigger finder.
pub struct TriggerFinder {
    threshold: f32,
    merge_gap: u64,
    open: Option<OpenCluster>,
    last_pos: Option<u64>,
    triggers: Vec<Trigger>,
}

impl TriggerFinder {
    /// `threshold` on the detection statistic; over-threshold windows
    /// whose starts are within `merge_gap` samples coalesce.
    pub fn new(threshold: f32, merge_gap: u64) -> Self {
        Self {
            threshold,
            merge_gap,
            open: None,
            last_pos: None,
            triggers: Vec::new(),
        }
    }

    fn close(&mut self) {
        if let Some(c) = self.open.take() {
            self.triggers.push(Trigger {
                onset: c.onset,
                peak_pos: c.peak_pos,
                peak_stat: c.peak_stat,
                windows: c.windows,
                latency_ns: c.peak_latency,
            });
        }
    }

    /// Feed one scored window (start sample, statistic, scoring latency).
    /// Panics if windows arrive out of stream order.
    pub fn observe(&mut self, pos: u64, stat: f32, latency_ns: u64) {
        if let Some(p) = self.last_pos {
            assert!(pos >= p, "windows must arrive in stream order ({pos} after {p})");
        }
        self.last_pos = Some(pos);
        if let Some(c) = &self.open {
            if pos - c.last > self.merge_gap {
                self.close();
            }
        }
        if stat >= self.threshold {
            match &mut self.open {
                Some(c) => {
                    c.last = pos;
                    c.windows += 1;
                    if stat > c.peak_stat {
                        c.peak_pos = pos;
                        c.peak_stat = stat;
                        c.peak_latency = latency_ns;
                    }
                }
                None => {
                    self.open = Some(OpenCluster {
                        onset: pos,
                        last: pos,
                        peak_pos: pos,
                        peak_stat: stat,
                        peak_latency: latency_ns,
                        windows: 1,
                    });
                }
            }
        }
    }

    /// Close any open cluster and return every trigger, in stream order.
    pub fn finish(mut self) -> Vec<Trigger> {
        self.close();
        self.triggers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(obs: &[(u64, f32)], thr: f32, gap: u64) -> Vec<Trigger> {
        let mut f = TriggerFinder::new(thr, gap);
        for &(pos, stat) in obs {
            f.observe(pos, stat, 1_000 + pos);
        }
        f.finish()
    }

    #[test]
    fn overlapping_windows_dedup_to_one_trigger_at_the_peak() {
        // one event excites four overlapping windows; one trigger, at
        // the argmax, counting all four
        let t = run(
            &[(0, 0.1), (25, 4.0), (50, 9.0), (75, 6.5), (100, 3.5), (125, 0.2)],
            3.0,
            100,
        );
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].onset, 25);
        assert_eq!(t[0].peak_pos, 50);
        assert_eq!(t[0].peak_stat, 9.0);
        assert_eq!(t[0].windows, 4);
        assert_eq!(t[0].latency_ns, 1_050, "latency is the peak window's");
    }

    #[test]
    fn distant_events_stay_separate_triggers() {
        let t = run(&[(0, 5.0), (500, 0.0), (1000, 7.0)], 3.0, 100);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].peak_pos, 0);
        assert_eq!(t[1].peak_pos, 1000);
    }

    #[test]
    fn sub_threshold_stream_yields_no_triggers() {
        assert!(run(&[(0, 0.5), (25, 2.9), (50, 1.0)], 3.0, 100).is_empty());
    }

    #[test]
    fn gap_exactly_at_merge_gap_still_merges() {
        let t = run(&[(0, 4.0), (100, 5.0)], 3.0, 100);
        assert_eq!(t.len(), 1, "<= merge_gap coalesces");
        let t = run(&[(0, 4.0), (101, 5.0)], 3.0, 100);
        assert_eq!(t.len(), 2, "> merge_gap separates");
    }

    #[test]
    fn open_cluster_flushes_at_finish() {
        let t = run(&[(0, 0.1), (25, 8.0)], 3.0, 100);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].windows, 1);
    }

    #[test]
    #[should_panic(expected = "stream order")]
    fn out_of_order_observation_panics() {
        let mut f = TriggerFinder::new(3.0, 100);
        f.observe(50, 0.0, 0);
        f.observe(25, 0.0, 0);
    }
}
