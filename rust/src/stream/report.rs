//! Detection-efficiency + latency analysis of a scored window stream.
//!
//! The per-window detection statistic is a robust two-sided z-score of
//! the model's positive-class score against the stream's own background:
//! `z = |score - median| / (1.4826 * MAD)`.  Injections are sparse (a
//! few percent of windows), so median/MAD are background-dominated and
//! the statistic is self-calibrating — no separate noise-only pass, and
//! no dependence on where an untrained/quantized model centers its
//! scores.  MAD is floored so a saturated (near-constant) background
//! cannot divide by zero.

use super::trigger::{Trigger, TriggerFinder};
use super::WindowScore;
use crate::data::gw::{Injection, CHIRP_HALF_SPAN};
use crate::metrics::LatencyHistogram;

/// Analysis knobs.
#[derive(Clone, Copy, Debug)]
pub struct StreamParams {
    /// Threshold on the robust z statistic.
    pub threshold: f32,
    /// Cluster merge gap in samples (see [`TriggerFinder`]).
    pub merge_gap: u64,
    /// Model window length in samples.
    pub seq_len: u64,
}

impl StreamParams {
    /// Defaults for a model with `seq_len`-sample windows: z >= 3,
    /// clusters merge within one window length.
    pub fn for_windows(seq_len: u64) -> Self {
        Self { threshold: 3.0, merge_gap: seq_len, seq_len }
    }
}

/// Result of analyzing one scored stream against its injection truth.
#[derive(Clone, Debug)]
pub struct StreamReport {
    /// Scored windows analyzed.
    pub windows: u64,
    /// De-duplicated trigger candidates, in stream order.
    pub triggers: Vec<Trigger>,
    /// Injections whose chirp support the scored stream fully covered.
    pub injections: usize,
    /// Covered injections matched by at least one trigger.
    pub found: usize,
    /// Triggers matching no injection at all (noise triggers).
    pub false_alarms: usize,
    /// Background score center/spread the z statistic used.
    pub bg_median: f32,
    pub bg_mad: f32,
    /// Latency of each trigger's peak window (arrival -> scored).
    pub trigger_latency: LatencyHistogram,
}

impl StreamReport {
    /// Fraction of covered injections recovered (1.0 when none were
    /// injected — a null stream has nothing to miss).
    pub fn efficiency(&self) -> f64 {
        if self.injections == 0 {
            1.0
        } else {
            self.found as f64 / self.injections as f64
        }
    }
}

impl std::fmt::Display for StreamReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "stream analysis: {} windows -> {} triggers | {}/{} injections recovered \
             (efficiency {:.1}%) | {} false alarms",
            self.windows,
            self.triggers.len(),
            self.found,
            self.injections,
            100.0 * self.efficiency(),
            self.false_alarms,
        )?;
        writeln!(
            f,
            "  background score: median {:.4} mad {:.4}",
            self.bg_median, self.bg_mad
        )?;
        writeln!(f, "  trigger latency: {}", self.trigger_latency.summary())
    }
}

/// Does `trig` account for an injection centered at `t0`?  The peak
/// window's center must lie within one window length of the center —
/// tight enough that a noise trigger elsewhere cannot claim it, loose
/// enough that a peak on the chirp's edge still counts.
fn matches(trig: &Trigger, t0: u64, seq_len: u64) -> bool {
    let center = trig.peak_pos + seq_len / 2;
    center.abs_diff(t0) <= seq_len
}

/// Cluster a scored window stream and score it against the injection
/// ground truth.  Windows may arrive in any order (sharded pools
/// interleave); they are sorted by stream position first.
pub fn analyze(
    mut windows: Vec<WindowScore>,
    injections: &[Injection],
    p: &StreamParams,
) -> StreamReport {
    windows.sort_by_key(|w| w.pos);
    let n = windows.len();
    // robust background stats over the whole scored stream
    let mut scores: Vec<f32> = windows.iter().map(|w| w.score).collect();
    let bg_median = median(&mut scores);
    let mut devs: Vec<f32> = windows.iter().map(|w| (w.score - bg_median).abs()).collect();
    let bg_mad = (median(&mut devs) * 1.4826).max(1e-4);
    let mut finder = TriggerFinder::new(p.threshold, p.merge_gap);
    for w in &windows {
        finder.observe(w.pos, (w.score - bg_median).abs() / bg_mad, w.latency_ns);
    }
    let triggers = finder.finish();
    // injections whose chirp support the scored windows fully cover
    let last_end = windows.last().map(|w| w.pos + p.seq_len).unwrap_or(0);
    let half = CHIRP_HALF_SPAN as u64;
    let covered: Vec<&Injection> = injections
        .iter()
        .filter(|i| i.t0 >= half && i.t0 + half <= last_end)
        .collect();
    let found = covered
        .iter()
        .filter(|i| triggers.iter().any(|t| matches(t, i.t0, p.seq_len)))
        .count();
    // a trigger near *any* injection (covered or edge) is not a false
    // alarm — only triggers explained by nothing count
    let false_alarms = triggers
        .iter()
        .filter(|t| !injections.iter().any(|i| matches(t, i.t0, p.seq_len)))
        .count();
    let mut trigger_latency = LatencyHistogram::new();
    for t in &triggers {
        trigger_latency.record(t.latency_ns);
    }
    StreamReport {
        windows: n as u64,
        triggers,
        injections: covered.len(),
        found,
        false_alarms,
        bg_median,
        bg_mad,
        trigger_latency,
    }
}

fn median(v: &mut [f32]) -> f32 {
    if v.is_empty() {
        return 0.0;
    }
    let mid = v.len() / 2;
    v.sort_by(|a, b| a.total_cmp(b));
    if v.len() % 2 == 1 {
        v[mid]
    } else {
        (v[mid - 1] + v[mid]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(pos: u64, score: f32) -> WindowScore {
        WindowScore { pos, score, latency_ns: 5_000 }
    }

    /// Background at 0.5 with tiny spread; outliers where requested.
    fn stream_with(outliers: &[(u64, f32)]) -> Vec<WindowScore> {
        let mut v = Vec::new();
        for k in 0..200u64 {
            let pos = k * 50;
            let base = 0.5 + if k % 2 == 0 { 0.01 } else { -0.01 };
            let score = outliers
                .iter()
                .find(|(p, _)| *p == pos)
                .map(|(_, s)| *s)
                .unwrap_or(base);
            v.push(w(pos, score));
        }
        v
    }

    #[test]
    fn injected_outliers_are_recovered_and_nulls_are_clean() {
        let p = StreamParams::for_windows(100);
        // two injections, each lighting up two overlapping windows
        let windows = stream_with(&[(1000, 0.95), (1050, 0.9), (5000, 0.05)]);
        let inj = [
            Injection { t0: 1050, amp: 6.0 },
            Injection { t0: 5050, amp: 7.0 },
        ];
        let r = analyze(windows, &inj, &p);
        assert_eq!(r.injections, 2);
        assert_eq!(r.found, 2);
        assert_eq!(r.false_alarms, 0);
        assert_eq!(r.triggers.len(), 2);
        assert_eq!(r.efficiency(), 1.0);
        assert!((r.bg_median - 0.5).abs() < 0.02, "{}", r.bg_median);
        assert_eq!(r.trigger_latency.count(), 2);
        // the display renders the headline numbers
        let text = format!("{r}");
        assert!(text.contains("2/2 injections"), "{text}");
        assert!(text.contains("efficiency 100.0%"), "{text}");
    }

    #[test]
    fn unexplained_outlier_is_a_false_alarm() {
        let p = StreamParams::for_windows(100);
        let windows = stream_with(&[(3000, 0.99)]);
        let r = analyze(windows, &[], &p);
        assert_eq!(r.injections, 0);
        assert_eq!(r.false_alarms, 1);
        assert_eq!(r.efficiency(), 1.0, "null stream misses nothing");
    }

    #[test]
    fn missed_injection_lowers_efficiency() {
        let p = StreamParams::for_windows(100);
        let windows = stream_with(&[(1000, 0.95)]);
        let inj = [
            Injection { t0: 1050, amp: 6.0 },
            Injection { t0: 7000, amp: 5.0 }, // nothing lit up here
        ];
        let r = analyze(windows, &inj, &p);
        assert_eq!((r.found, r.injections), (1, 2));
        assert!((r.efficiency() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn injections_outside_the_scored_band_are_not_counted() {
        let p = StreamParams::for_windows(100);
        // windows cover [0, 10_000); an injection at 50_000 was never
        // streamed and must not count against efficiency
        let windows = stream_with(&[(1000, 0.95)]);
        let inj = [
            Injection { t0: 1050, amp: 6.0 },
            Injection { t0: 50_000, amp: 6.0 },
        ];
        let r = analyze(windows, &inj, &p);
        assert_eq!(r.injections, 1);
        assert_eq!(r.efficiency(), 1.0);
    }

    #[test]
    fn out_of_order_windows_are_sorted_before_clustering() {
        let p = StreamParams::for_windows(100);
        let mut windows = stream_with(&[(1000, 0.95), (1050, 0.9)]);
        windows.reverse(); // shard interleaving, adversarially
        let r = analyze(windows, &[Injection { t0: 1050, amp: 6.0 }], &p);
        assert_eq!(r.found, 1);
        assert_eq!(r.triggers.len(), 1, "still one de-duplicated trigger");
    }

    #[test]
    fn saturated_background_does_not_divide_by_zero() {
        let p = StreamParams::for_windows(100);
        let windows: Vec<WindowScore> = (0..100).map(|k| w(k * 50, 1.0)).collect();
        let r = analyze(windows, &[], &p);
        assert!(r.bg_mad >= 1e-4);
        assert!(r.triggers.is_empty());
    }

    #[test]
    fn empty_stream_is_calm() {
        let p = StreamParams::for_windows(100);
        let r = analyze(Vec::new(), &[Injection { t0: 500, amp: 6.0 }], &p);
        assert_eq!(r.windows, 0);
        assert_eq!(r.injections, 0, "nothing was covered");
        assert!(r.triggers.is_empty());
    }

    #[test]
    fn median_of_small_slices() {
        let mut empty: [f32; 0] = [];
        assert_eq!(median(&mut empty), 0.0);
        assert_eq!(median(&mut [3.0f32]), 3.0);
        assert_eq!(median(&mut [1.0f32, 2.0]), 1.5);
        assert_eq!(median(&mut [5.0f32, 1.0, 3.0]), 3.0);
    }
}
