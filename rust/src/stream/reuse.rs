//! Counters for incremental cross-window reuse.
//!
//! At hop `h < S` consecutive stream windows share `S - h` identical
//! token rows (the zoo transformers carry no positional encoding), so
//! the block-0 prefix work for those rows — embed projection, the QKV
//! re-grid cast, the Q/K/V head projections — and the `(S-h) x (S-h)`
//! overlap block of raw block-0 QK^T scores are bitwise identical
//! between windows.  The incremental executors in `nn::transformer` and
//! `hls::transformer` retain exactly that state per stream and account
//! for what they reused here; the coordinator folds per-shard counters
//! into the [`crate::coordinator::ServerReport`] and `repro stream`
//! prints them.
//!
//! Steady-state contract (pinned by tests): once warm, every window at
//! hop `h` recomputes exactly `h` prefix rows (`rows_reused = S - h`)
//! and exactly `heads * (S^2 - (S-h)^2)` fresh block-0 score entries.

/// Reuse accounting for one incremental window cache (or, after server
/// aggregation, one whole worker pool).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReuseCounters {
    /// Windows scored through the full-recompute path (cold cache,
    /// non-overlapping hop, stream restart, or reuse disabled).
    pub windows_full: u64,
    /// Windows scored through the incremental path.
    pub windows_incremental: u64,
    /// Block-0 prefix token rows carried over from the previous window
    /// (embed output / QKV-grid rows; counted once per window, not per
    /// projection site).
    pub rows_reused: u64,
    /// Block-0 prefix token rows recomputed (the whole window on the
    /// full path; exactly the fresh rows on the incremental path).
    pub rows_recomputed: u64,
    /// Per-head overlap score blocks served from the cache (one per
    /// head per incremental window).
    pub score_block_hits: u64,
    /// Raw block-0 QK^T entries actually computed, summed over heads.
    pub score_entries_fresh: u64,
    /// Raw block-0 QK^T entries served from the cached overlap block.
    pub score_entries_reused: u64,
    /// Resident bytes of the window cache (f32 rows + raw score
    /// blocks); a high-water mark across merges.
    pub cache_bytes: u64,
}

impl ReuseCounters {
    /// Fold another cache's (or shard's) counters into this one.
    pub fn merge(&mut self, other: &ReuseCounters) {
        self.windows_full += other.windows_full;
        self.windows_incremental += other.windows_incremental;
        self.rows_reused += other.rows_reused;
        self.rows_recomputed += other.rows_recomputed;
        self.score_block_hits += other.score_block_hits;
        self.score_entries_fresh += other.score_entries_fresh;
        self.score_entries_reused += other.score_entries_reused;
        self.cache_bytes = self.cache_bytes.max(other.cache_bytes);
    }

    /// Total windows scored.
    pub fn windows(&self) -> u64 {
        self.windows_full + self.windows_incremental
    }

    /// Fraction of prefix rows served from the cache, in `[0, 1]`.
    pub fn row_reuse_fraction(&self) -> f64 {
        let total = self.rows_reused + self.rows_recomputed;
        if total == 0 {
            0.0
        } else {
            self.rows_reused as f64 / total as f64
        }
    }

    /// Fraction of block-0 score entries served from the cache.
    pub fn score_reuse_fraction(&self) -> f64 {
        let total = self.score_entries_fresh + self.score_entries_reused;
        if total == 0 {
            0.0
        } else {
            self.score_entries_reused as f64 / total as f64
        }
    }

    pub fn any_reuse(&self) -> bool {
        self.windows_incremental > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_counts_and_high_waters_bytes() {
        let mut a = ReuseCounters {
            windows_full: 1,
            windows_incremental: 3,
            rows_reused: 30,
            rows_recomputed: 14,
            score_block_hits: 3,
            score_entries_fresh: 700,
            score_entries_reused: 300,
            cache_bytes: 4096,
        };
        let b = ReuseCounters {
            windows_full: 2,
            windows_incremental: 5,
            rows_reused: 50,
            rows_recomputed: 26,
            score_block_hits: 5,
            score_entries_fresh: 900,
            score_entries_reused: 500,
            cache_bytes: 2048,
        };
        a.merge(&b);
        assert_eq!(a.windows(), 11);
        assert_eq!(a.rows_reused, 80);
        assert_eq!(a.rows_recomputed, 40);
        assert_eq!(a.score_block_hits, 8);
        assert_eq!(a.score_entries_fresh, 1600);
        assert_eq!(a.score_entries_reused, 800);
        assert_eq!(a.cache_bytes, 4096, "bytes are a high-water mark");
        assert!((a.row_reuse_fraction() - 80.0 / 120.0).abs() < 1e-12);
        assert!((a.score_reuse_fraction() - 800.0 / 2400.0).abs() < 1e-12);
    }

    #[test]
    fn empty_counters_report_zero_fractions() {
        let c = ReuseCounters::default();
        assert_eq!(c.row_reuse_fraction(), 0.0);
        assert_eq!(c.score_reuse_fraction(), 0.0);
        assert!(!c.any_reuse());
    }
}
