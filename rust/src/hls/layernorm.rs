//! Fixed-point 5-stage LayerNorm (paper §IV-C, figure 8):
//! mean -> deviation -> variance -> ROM 1/sqrt(var) -> gamma/beta.

use super::calibration as cal;
use super::compiled::CompiledLn;
use super::hotpath;
use super::pipeline::{adder_tree_depth, Stage};
use super::resources::{bram18_for_bits, dsp_per_mult, Resources};
use super::ReuseFactor;
use crate::fixed::lut::Roms;
use crate::fixed::mantissa;
use crate::fixed::{FixedSpec, MacQuantizer, MantissaConv};

/// Normalize one row in place on the `ap_fixed` grid.
///
/// Dispatch ([`hotpath`]): the mean sum and the variance MAC run on
/// `i64` mantissa lanes ([`layernorm_fixed_row_int`]) when provably
/// bit-identical for this spec/length, else the f64 reference
/// [`layernorm_fixed_row_ref`].
pub fn layernorm_fixed_row(
    row: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    // the mean needs the stage-1 data-grid sum exact too (the variance
    // MAC's accum-grid bound is the int_mac predicate)
    if hotpath::int_path_enabled(data, accum, row.len())
        && mantissa::f64_sum_exact(data, row.len())
    {
        return layernorm_fixed_row_int(row, gamma, beta, roms, data, accum);
    }
    layernorm_fixed_row_ref(row, gamma, beta, roms, data, accum);
}

/// [`layernorm_fixed_row`] through a prebuilt [`CompiledLn`] site: the
/// dispatch verdict comes from the artifact (ANDed with the live
/// reference override) instead of being re-derived per row, and the
/// gamma/beta rows are the artifact's copies.  **Bitwise identical** to
/// the dispatcher at the site's specs.
pub fn layernorm_fixed_row_compiled(row: &mut [f32], site: &CompiledLn, roms: &Roms) {
    if site.use_int() {
        return layernorm_fixed_row_int(
            row, site.gamma(), site.beta(), roms, site.data(), site.accum(),
        );
    }
    layernorm_fixed_row_ref(row, site.gamma(), site.beta(), roms, site.data(), site.accum());
}

/// The f64 reference path of [`layernorm_fixed_row`] — semantic ground
/// truth for the integer variant, still live for wide grids and the
/// `f64-reference` CI legs.
pub fn layernorm_fixed_row_ref(
    row: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    assert_eq!(row.len(), gamma.len());
    assert_eq!(row.len(), beta.len());
    let qa = crate::fixed::Quantizer::new(accum);
    let qd = crate::fixed::Quantizer::new(data);
    let k = row.len() as f64;
    // stage 1: mean
    let mut sum = 0.0f64;
    for v in row.iter() {
        sum += *v as f64;
    }
    let mean = qa.q(sum / k);
    // stage 2: deviations (data grid) + stage 3: variance
    let mut var = 0.0f64;
    for v in row.iter_mut() {
        *v = qd.q32((*v as f64 - mean) as f32);
        var += qa.q(*v as f64 * *v as f64);
    }
    let var = qa.q(var / k) as f32;
    // stage 4: 1/sqrt via ROM
    let inv = qd.q32(roms.invsqrt.lookup(var));
    // stage 5: scale + affine
    for (v, (&g, &b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
        let normalized = qd.q32(*v * inv);
        *v = qd.q32(normalized * g + b);
    }
}

/// Integer-mantissa variant of [`layernorm_fixed_row`]: stage 1 sums
/// data-grid mantissas and stage 3 runs the squared-deviation MAC on
/// `i64` lanes, both 8-wide unrolled.  Stage 2 stays float on purpose —
/// the reference rounds `(v - mean)` to f32 *mid-expression* before the
/// grid projection, and that rounding must be replayed, not integerized.
/// Only bit-identical when the [`layernorm_fixed_row`] gate holds; call
/// through the dispatcher unless you are the hotpath bench.
pub fn layernorm_fixed_row_int(
    row: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    assert_eq!(row.len(), gamma.len());
    assert_eq!(row.len(), beta.len());
    let qa = crate::fixed::Quantizer::new(accum);
    let qd = crate::fixed::Quantizer::new(data);
    let k = row.len() as f64;
    let conv = MantissaConv::new(data);
    let mqv = MacQuantizer::new(data, accum);
    let mut tile = hotpath::tls_take_ints(row.len());
    // stage 1: mean — the f64 reference sum of on-grid values is exact,
    // so the mantissa sum times the grid step reproduces it bit-for-bit
    for (m, &v) in tile.iter_mut().zip(row.iter()) {
        *m = conv.to_m(v);
    }
    let mut sum_m = 0i64;
    let mut c = tile.chunks_exact(8);
    for ch in &mut c {
        let mut lanes = 0i64;
        for l in 0..8 {
            lanes += ch[l];
        }
        sum_m += lanes;
    }
    for &m in c.remainder() {
        sum_m += m;
    }
    let mean = qa.q(sum_m as f64 * data.step() / k);
    // stage 2: deviations, float (see above)
    for (v, m) in row.iter_mut().zip(tile.iter_mut()) {
        *v = qd.q32((*v as f64 - mean) as f32);
        *m = conv.to_m(*v);
    }
    // stage 3: variance MAC on the deviation mantissas
    let mut var_m = 0i64;
    let mut c = tile.chunks_exact(8);
    for ch in &mut c {
        let mut lanes = 0i64;
        for l in 0..8 {
            lanes += mqv.product(ch[l], ch[l]);
        }
        var_m += lanes;
    }
    for &m in c.remainder() {
        var_m += mqv.product(m, m);
    }
    hotpath::tls_put_ints(tile);
    let var = qa.q(var_m as f64 * accum.step() / k) as f32;
    // stage 4: 1/sqrt via ROM
    let inv = qd.q32(roms.invsqrt.lookup(var));
    // stage 5: scale + affine
    for (v, (&g, &b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
        let normalized = qd.q32(*v * inv);
        *v = qd.q32(normalized * g + b);
    }
}

/// Batched LayerNorm: normalize every row of every event in place.
/// Rows are independent and [`layernorm_fixed_row`] allocates nothing,
/// so the batched form is trivially bitwise identical to the per-event
/// loop — it exists so `FixedTransformer::forward_batch` can stay
/// batch-major end to end.
pub fn layernorm_fixed_batch(
    x: &mut crate::nn::tensor::Mat3,
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    for i in 0..x.flat_rows() {
        layernorm_fixed_row(x.flat_row_mut(i), gamma, beta, roms, data, accum);
    }
}

/// Batched twin of [`layernorm_fixed_row_compiled`].
pub fn layernorm_fixed_batch_compiled(
    x: &mut crate::nn::tensor::Mat3,
    site: &CompiledLn,
    roms: &Roms,
) {
    for i in 0..x.flat_rows() {
        layernorm_fixed_row_compiled(x.flat_row_mut(i), site, roms);
    }
}

/// Pipeline stage: the five sub-stages are themselves pipelined, so the
/// layer streams rows at II = R after a fill depth of ~2 adder trees.
/// The stage-3 squares and stage-5 gamma multiplies take one operand
/// from a held register (the deviation / the ROM's 1/sqrt word), so
/// wide grids add no cascade fill — but past the 26-bit port the
/// decomposed multiply still halves the issue rate.
pub fn layernorm_stage(
    name: &str,
    rows: usize,
    d: usize,
    r: ReuseFactor,
    data: FixedSpec,
) -> Stage {
    // one adder tree of fill: the mean and variance trees overlap in the
    // 5-stage pipeline (stage 3 streams behind stage 1)
    Stage::new(
        name,
        cal::LAYERNORM_DEPTH_BASE
            + adder_tree_depth(d as u64)
            + cal::reuse_depth_growth(d, r) / 2,
        r.get() as u64 * cal::dsp_ii_widening(data.width()),
        rows as u64,
    )
}

/// [`layernorm_stage`] that refuses (site-named, one line) a reuse
/// factor that does not evenly divide the `d`-channel row instead of
/// silently rounding the chunk count up.
pub fn layernorm_stage_checked(
    name: &str,
    rows: usize,
    d: usize,
    r: ReuseFactor,
    data: FixedSpec,
) -> Result<Stage, String> {
    super::pipeline::check_reuse_divides(name, r, d)?;
    Ok(layernorm_stage(name, rows, d, r, data))
}

/// Resources: d/R multipliers for stage 3 squares + d/R for the gamma
/// dot-product unit, one invsqrt ROM, adder trees in fabric.
pub fn layernorm_resources(d: usize, data: FixedSpec, r: ReuseFactor) -> Resources {
    let w = data.width() as u64;
    let concurrent = 2 * (d as u64).div_ceil(r.get() as u64);
    let dsp = concurrent * dsp_per_mult(data.width());
    let ff = (concurrent as f64 * w as f64 * cal::FF_PER_MULT_BIT) as u64
        + cal::FF_CTRL_PER_STAGE;
    let lut = (concurrent as f64 * w as f64 * cal::LUT_PER_MULT_BIT) as u64
        + cal::LUT_CTRL_PER_STAGE;
    let rom_bits = crate::fixed::lut::LutKind::InvSqrt.geometry().2 as u64 * w;
    Resources::new(dsp, ff, lut, bram18_for_bits(rom_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    fn setup() -> (Roms, FixedSpec, FixedSpec) {
        let data = FixedSpec::new(18, 8);
        (Roms::new(), data, data.accum())
    }

    #[test]
    fn close_to_exact_layernorm() {
        let (roms, data, accum) = setup();
        let mut g = Gen::new(1);
        let k = 32;
        let gamma = g.normal_vec(k, 1.0);
        let beta = g.normal_vec(k, 0.5);
        let mut row = g.normal_vec(k, 1.5);
        let exact = {
            let m: f32 = row.iter().sum::<f32>() / k as f32;
            let var: f32 = row.iter().map(|v| (v - m).powi(2)).sum::<f32>() / k as f32;
            let inv = 1.0 / var.sqrt();
            row.iter()
                .zip(gamma.iter().zip(&beta))
                .map(|(v, (&g_, &b_))| (v - m) * inv * g_ + b_)
                .collect::<Vec<_>>()
        };
        layernorm_fixed_row(&mut row, &gamma, &beta, &roms, data, accum);
        for (a, b) in row.iter().zip(&exact) {
            assert!((a - b).abs() < 0.08, "{a} vs {b}");
        }
    }

    #[test]
    fn prop_normalizes_unit_gamma() {
        Prop::new("fixed ln mean0 var1").runs(150).check(|g| {
            let (roms, data, accum) = setup();
            let k = g.usize_in(8, 64);
            let mut row = g.normal_vec(k, 1.0);
            layernorm_fixed_row(&mut row, &vec![1.0; k], &vec![0.0; k], &roms, data, accum);
            let mean: f32 = row.iter().sum::<f32>() / k as f32;
            let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / k as f32;
            assert!(mean.abs() < 0.02, "mean {mean}");
            assert!((var - 1.0).abs() < 0.15, "var {var}");
        });
    }

    #[test]
    fn outputs_on_grid() {
        let (roms, data, accum) = setup();
        let mut row = vec![1.0, -0.5, 2.25, 0.125];
        layernorm_fixed_row(&mut row, &[1.0; 4], &[0.0; 4], &roms, data, accum);
        for &v in &row {
            assert_eq!(v, data.quantize(v));
        }
    }

    #[test]
    fn prop_int_layernorm_bitwise_matches_ref() {
        Prop::new("layernorm int == f64 ref").runs(200).check(|g| {
            let roms = Roms::new();
            let data = g.fixed_spec();
            let accum = data.accum();
            let k = g.usize_in(1, 48);
            assert!(crate::fixed::mantissa::int_mac_eligible(data, accum, k), "{data}");
            assert!(crate::fixed::mantissa::f64_sum_exact(data, k), "{data}");
            let gamma: Vec<f32> =
                g.normal_vec(k, 1.0).iter().map(|&v| data.quantize(v)).collect();
            let beta: Vec<f32> =
                g.normal_vec(k, 0.5).iter().map(|&v| data.quantize(v)).collect();
            // on-grid rows, occasionally scaled hard enough to saturate
            // the variance accumulator on narrow grids
            let scale = if g.bool() { 1.5 } else { 50.0 };
            let row: Vec<f32> =
                g.normal_vec(k, scale).iter().map(|&v| data.quantize(v)).collect();
            let mut want = row.clone();
            layernorm_fixed_row_ref(&mut want, &gamma, &beta, &roms, data, accum);
            let mut got = row;
            layernorm_fixed_row_int(&mut got, &gamma, &beta, &roms, data, accum);
            assert_eq!(got, want, "{data} k={k}");
        });
    }

    #[test]
    fn compiled_layernorm_bitwise_matches_dispatcher() {
        use crate::hls::QuantConfig;
        use crate::models::weights::LnWeights;
        let roms = Roms::new();
        let mut g = Gen::new(7);
        let k = 24;
        let gamma = g.normal_vec(k, 1.0);
        let beta = g.normal_vec(k, 0.5);
        let ln = LnWeights { gamma: gamma.clone(), beta: beta.clone() };
        // one int-eligible grid, one wide grid that must fall back
        for data in [FixedSpec::new(14, 6), FixedSpec::new(32, 12)] {
            let accum = data.accum();
            let site = CompiledLn::build(&ln, QuantConfig { data, accum });
            let row: Vec<f32> = g.normal_vec(k, 1.5);
            let mut want = row.clone();
            layernorm_fixed_row(&mut want, &gamma, &beta, &roms, data, accum);
            let mut got = row;
            layernorm_fixed_row_compiled(&mut got, &site, &roms);
            assert_eq!(got, want, "{data}");
        }
    }

    #[test]
    fn stage_depth_grows_with_width() {
        let spec = FixedSpec::new(16, 6);
        let a = layernorm_stage("ln", 10, 16, ReuseFactor(1), spec);
        let b = layernorm_stage("ln", 10, 64, ReuseFactor(1), spec);
        assert!(b.depth > a.depth);
        // past the 26-bit port the LN multiplies' issue rate halves, but
        // the register-fed operands keep the fill depth flat
        let wide = layernorm_stage("ln", 10, 16, ReuseFactor(1), FixedSpec::new(27, 10));
        assert_eq!(wide.depth, a.depth);
        assert!(wide.ii > a.ii);
    }

    #[test]
    fn resources_have_rom_and_scale_down_with_reuse() {
        let r1 = layernorm_resources(64, FixedSpec::new(16, 6), ReuseFactor(1));
        let r4 = layernorm_resources(64, FixedSpec::new(16, 6), ReuseFactor(4));
        assert!(r1.bram18 > 0);
        assert!(r4.dsp < r1.dsp);
    }
}
