//! Fixed-point 5-stage LayerNorm (paper §IV-C, figure 8):
//! mean -> deviation -> variance -> ROM 1/sqrt(var) -> gamma/beta.

use super::calibration as cal;
use super::pipeline::{adder_tree_depth, Stage};
use super::resources::{bram18_for_bits, dsp_per_mult, Resources};
use super::ReuseFactor;
use crate::fixed::lut::Roms;
use crate::fixed::FixedSpec;

/// Normalize one row in place on the `ap_fixed` grid.
pub fn layernorm_fixed_row(
    row: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    assert_eq!(row.len(), gamma.len());
    assert_eq!(row.len(), beta.len());
    let qa = crate::fixed::Quantizer::new(accum);
    let qd = crate::fixed::Quantizer::new(data);
    let k = row.len() as f64;
    // stage 1: mean
    let mut sum = 0.0f64;
    for v in row.iter() {
        sum += *v as f64;
    }
    let mean = qa.q(sum / k);
    // stage 2: deviations (data grid) + stage 3: variance
    let mut var = 0.0f64;
    for v in row.iter_mut() {
        *v = qd.q32((*v as f64 - mean) as f32);
        var += qa.q(*v as f64 * *v as f64);
    }
    let var = qa.q(var / k) as f32;
    // stage 4: 1/sqrt via ROM
    let inv = qd.q32(roms.invsqrt.lookup(var));
    // stage 5: scale + affine
    for (v, (&g, &b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
        let normalized = qd.q32(*v * inv);
        *v = qd.q32(normalized * g + b);
    }
}

/// Batched LayerNorm: normalize every row of every event in place.
/// Rows are independent and [`layernorm_fixed_row`] allocates nothing,
/// so the batched form is trivially bitwise identical to the per-event
/// loop — it exists so `FixedTransformer::forward_batch` can stay
/// batch-major end to end.
pub fn layernorm_fixed_batch(
    x: &mut crate::nn::tensor::Mat3,
    gamma: &[f32],
    beta: &[f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    for i in 0..x.flat_rows() {
        layernorm_fixed_row(x.flat_row_mut(i), gamma, beta, roms, data, accum);
    }
}

/// Pipeline stage: the five sub-stages are themselves pipelined, so the
/// layer streams rows at II = R after a fill depth of ~2 adder trees.
/// The stage-3 squares and stage-5 gamma multiplies take one operand
/// from a held register (the deviation / the ROM's 1/sqrt word), so
/// wide grids add no cascade fill — but past the 26-bit port the
/// decomposed multiply still halves the issue rate.
pub fn layernorm_stage(
    name: &str,
    rows: usize,
    d: usize,
    r: ReuseFactor,
    data: FixedSpec,
) -> Stage {
    // one adder tree of fill: the mean and variance trees overlap in the
    // 5-stage pipeline (stage 3 streams behind stage 1)
    Stage::new(
        name,
        cal::LAYERNORM_DEPTH_BASE
            + adder_tree_depth(d as u64)
            + cal::reuse_depth_growth(d, r) / 2,
        r.get() as u64 * cal::dsp_ii_widening(data.width()),
        rows as u64,
    )
}

/// Resources: d/R multipliers for stage 3 squares + d/R for the gamma
/// dot-product unit, one invsqrt ROM, adder trees in fabric.
pub fn layernorm_resources(d: usize, data: FixedSpec, r: ReuseFactor) -> Resources {
    let w = data.width() as u64;
    let concurrent = 2 * (d as u64).div_ceil(r.get() as u64);
    let dsp = concurrent * dsp_per_mult(data.width());
    let ff = (concurrent as f64 * w as f64 * cal::FF_PER_MULT_BIT) as u64
        + cal::FF_CTRL_PER_STAGE;
    let lut = (concurrent as f64 * w as f64 * cal::LUT_PER_MULT_BIT) as u64
        + cal::LUT_CTRL_PER_STAGE;
    let rom_bits = crate::fixed::lut::LutKind::InvSqrt.geometry().2 as u64 * w;
    Resources::new(dsp, ff, lut, bram18_for_bits(rom_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    fn setup() -> (Roms, FixedSpec, FixedSpec) {
        let data = FixedSpec::new(18, 8);
        (Roms::new(), data, data.accum())
    }

    #[test]
    fn close_to_exact_layernorm() {
        let (roms, data, accum) = setup();
        let mut g = Gen::new(1);
        let k = 32;
        let gamma = g.normal_vec(k, 1.0);
        let beta = g.normal_vec(k, 0.5);
        let mut row = g.normal_vec(k, 1.5);
        let exact = {
            let m: f32 = row.iter().sum::<f32>() / k as f32;
            let var: f32 = row.iter().map(|v| (v - m).powi(2)).sum::<f32>() / k as f32;
            let inv = 1.0 / var.sqrt();
            row.iter()
                .zip(gamma.iter().zip(&beta))
                .map(|(v, (&g_, &b_))| (v - m) * inv * g_ + b_)
                .collect::<Vec<_>>()
        };
        layernorm_fixed_row(&mut row, &gamma, &beta, &roms, data, accum);
        for (a, b) in row.iter().zip(&exact) {
            assert!((a - b).abs() < 0.08, "{a} vs {b}");
        }
    }

    #[test]
    fn prop_normalizes_unit_gamma() {
        Prop::new("fixed ln mean0 var1").runs(150).check(|g| {
            let (roms, data, accum) = setup();
            let k = g.usize_in(8, 64);
            let mut row = g.normal_vec(k, 1.0);
            layernorm_fixed_row(&mut row, &vec![1.0; k], &vec![0.0; k], &roms, data, accum);
            let mean: f32 = row.iter().sum::<f32>() / k as f32;
            let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / k as f32;
            assert!(mean.abs() < 0.02, "mean {mean}");
            assert!((var - 1.0).abs() < 0.15, "var {var}");
        });
    }

    #[test]
    fn outputs_on_grid() {
        let (roms, data, accum) = setup();
        let mut row = vec![1.0, -0.5, 2.25, 0.125];
        layernorm_fixed_row(&mut row, &[1.0; 4], &[0.0; 4], &roms, data, accum);
        for &v in &row {
            assert_eq!(v, data.quantize(v));
        }
    }

    #[test]
    fn stage_depth_grows_with_width() {
        let spec = FixedSpec::new(16, 6);
        let a = layernorm_stage("ln", 10, 16, ReuseFactor(1), spec);
        let b = layernorm_stage("ln", 10, 64, ReuseFactor(1), spec);
        assert!(b.depth > a.depth);
        // past the 26-bit port the LN multiplies' issue rate halves, but
        // the register-fed operands keep the fill depth flat
        let wide = layernorm_stage("ln", 10, 16, ReuseFactor(1), FixedSpec::new(27, 10));
        assert_eq!(wide.depth, a.depth);
        assert!(wide.ii > a.ii);
    }

    #[test]
    fn resources_have_rom_and_scale_down_with_reuse() {
        let r1 = layernorm_resources(64, FixedSpec::new(16, 6), ReuseFactor(1));
        let r4 = layernorm_resources(64, FixedSpec::new(16, 6), ReuseFactor(4));
        assert!(r1.bram18 > 0);
        assert!(r4.dsp < r1.dsp);
    }
}
