//! Fixed-point global-average pooling + the softmax/sigmoid output heads.

use super::pipeline::{adder_tree_depth, Stage};
use super::resources::Resources;
use super::ReuseFactor;
use crate::fixed::lut::Roms;
use crate::fixed::FixedSpec;
use crate::nn::tensor::{Mat, Mat3};

/// Column means, accumulated on the accumulator grid: (S, d) -> (1, d).
pub fn global_average_pool_fixed(x: &Mat, data: FixedSpec, accum: FixedSpec) -> Mat {
    let mut out = Mat::zeros(1, x.cols());
    for c in 0..x.cols() {
        let mut acc = 0.0f64;
        for r in 0..x.rows() {
            acc += x.at(r, c) as f64;
        }
        let mean = accum.quantize_f64(acc / x.rows() as f64);
        *out.at_mut(0, c) = data.quantize(mean as f32);
    }
    out
}

/// Batched column means: (B, S, d) -> (B, 1, d), the same per-column
/// r-ascending accumulation as [`global_average_pool_fixed`] so the two
/// are bitwise identical per event.
pub fn global_average_pool_fixed_batch(x: &Mat3, data: FixedSpec, accum: FixedSpec) -> Mat3 {
    let mut out = Mat3::zeros(x.batch(), 1, x.cols());
    for b in 0..x.batch() {
        for c in 0..x.cols() {
            let mut acc = 0.0f64;
            for r in 0..x.rows() {
                acc += x.event_row(b, r)[c] as f64;
            }
            let mean = accum.quantize_f64(acc / x.rows() as f64);
            out.event_row_mut(b, 0)[c] = data.quantize(mean as f32);
        }
    }
    out
}

/// Sigmoid through the exp ROM: `1 / (1 + e^{-x})` — reuses the softmax
/// exp table plus the inversion table, as hls4ml's activation LUTs do.
pub fn sigmoid_fixed(x: f32, roms: &Roms, data: FixedSpec) -> f32 {
    let e = roms.exp.lookup(-x);
    data.quantize(roms.inv.lookup(1.0 + e))
}

/// Pooling pipeline stage (one adder tree over the sequence).
pub fn pool_stage(name: &str, rows: usize, r: ReuseFactor) -> Stage {
    Stage::new(name, adder_tree_depth(rows as u64) + 2, r.get() as u64, rows as u64)
}

/// Pooling is adder-tree-only: no DSPs (the 1/S multiply is a constant
/// shift-add), modest fabric.
pub fn pool_resources(d: usize, data: FixedSpec, r: ReuseFactor) -> Resources {
    let w = data.width() as u64;
    let adders = (d as u64).div_ceil(r.get() as u64);
    Resources::new(0, adders * w, adders * w * 2, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Gen;

    #[test]
    fn pool_matches_float() {
        let mut g = Gen::new(1);
        let x = Mat::from_vec(10, 4, g.normal_vec(40, 1.0));
        let wide = FixedSpec::new(32, 12);
        let q = global_average_pool_fixed(&x, wide, wide.accum());
        let f = crate::nn::layers::global_average_pool(&x);
        assert!(q.max_abs_diff(&f) < 1e-3);
    }

    #[test]
    fn sigmoid_tracks_float() {
        let roms = Roms::new();
        let data = FixedSpec::new(18, 8);
        for x in [-4.0f32, -1.0, 0.0, 0.5, 3.0] {
            let want = 1.0 / (1.0 + (-x).exp());
            let got = sigmoid_fixed(x, &roms, data);
            assert!((got - want).abs() < 0.03, "x={x}: {got} vs {want}");
        }
    }

    #[test]
    fn sigmoid_saturates_sanely() {
        let roms = Roms::new();
        let data = FixedSpec::new(18, 8);
        assert!(sigmoid_fixed(20.0, &roms, data) > 0.9);
        assert!(sigmoid_fixed(-20.0, &roms, data) < 0.1);
    }

    #[test]
    fn batched_pool_bitwise_matches_per_event() {
        let mut g = Gen::new(4);
        let data = FixedSpec::new(12, 5);
        let events: Vec<Mat> =
            (0..3).map(|_| Mat::from_vec(6, 4, g.normal_vec(24, 1.0))).collect();
        let refs: Vec<&Mat> = events.iter().collect();
        let batched = global_average_pool_fixed_batch(&Mat3::from_events(&refs), data, data.accum());
        for (i, e) in events.iter().enumerate() {
            assert_eq!(batched.event(i), global_average_pool_fixed(e, data, data.accum()));
        }
    }

    #[test]
    fn pool_has_no_dsps() {
        assert_eq!(pool_resources(64, FixedSpec::new(16, 6), ReuseFactor(1)).dsp, 0);
    }
}
