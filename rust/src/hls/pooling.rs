//! Fixed-point global-average pooling + the softmax/sigmoid output heads.

use super::compiled::CompiledPool;
use super::hotpath;
use super::pipeline::{adder_tree_depth, Stage};
use super::resources::Resources;
use super::ReuseFactor;
use crate::fixed::lut::Roms;
use crate::fixed::{FixedSpec, MantissaConv};

use crate::nn::tensor::{Mat, Mat3};

/// Column means, accumulated on the accumulator grid: (S, d) -> (1, d).
///
/// Dispatch ([`hotpath`]): integer-mantissa column sums
/// ([`pool_int_core`]) when the reference's f64 accumulation is provably
/// exact for this grid and sequence length, else the f64 reference
/// [`global_average_pool_fixed_ref`].
pub fn global_average_pool_fixed(x: &Mat, data: FixedSpec, accum: FixedSpec) -> Mat {
    if hotpath::int_sum_enabled(data, x.rows()) {
        let mut out = Mat::zeros(1, x.cols());
        pool_int_core(x.data(), out.data_mut(), x.rows(), x.cols(), data, accum);
        return out;
    }
    global_average_pool_fixed_ref(x, data, accum)
}

/// [`global_average_pool_fixed`] through a prebuilt [`CompiledPool`]
/// site: the sum-exactness verdict (a function of the grid and the
/// sequence length the artifact was compiled for) is read from the
/// artifact instead of re-derived.  **Bitwise identical** to the
/// dispatcher when `x.rows()` matches the compiled sequence length.
pub fn global_average_pool_fixed_compiled(x: &Mat, site: &CompiledPool) -> Mat {
    if site.use_int() {
        let mut out = Mat::zeros(1, x.cols());
        pool_int_core(x.data(), out.data_mut(), x.rows(), x.cols(), site.data(), site.accum());
        return out;
    }
    global_average_pool_fixed_ref(x, site.data(), site.accum())
}

/// The f64 reference path of [`global_average_pool_fixed`].
pub fn global_average_pool_fixed_ref(x: &Mat, data: FixedSpec, accum: FixedSpec) -> Mat {
    let mut out = Mat::zeros(1, x.cols());
    for c in 0..x.cols() {
        let mut acc = 0.0f64;
        for r in 0..x.rows() {
            acc += x.at(r, c) as f64;
        }
        let mean = accum.quantize_f64(acc / x.rows() as f64);
        *out.at_mut(0, c) = data.quantize(mean as f32);
    }
    out
}

/// Integer column sums for one event: row-major traversal (the
/// reference strides column-major; integer addition is order-blind, so
/// the cache-friendly order costs nothing in bits), per-column `i64`
/// accumulators from the TLS pool, then the reference's exact
/// mean-and-project epilogue on the same f64 values.
pub fn pool_int_core(
    x: &[f32],
    out: &mut [f32],
    rows: usize,
    cols: usize,
    data: FixedSpec,
    accum: FixedSpec,
) {
    let conv = MantissaConv::new(data);
    let mut sums = hotpath::tls_take_ints(cols);
    for row in x.chunks_exact(cols) {
        for (s, &v) in sums.iter_mut().zip(row) {
            *s += conv.to_m(v);
        }
    }
    for (o, &s) in out.iter_mut().zip(sums.iter()) {
        let mean = accum.quantize_f64(s as f64 * data.step() / rows as f64);
        *o = data.quantize(mean as f32);
    }
    hotpath::tls_put_ints(sums);
}

/// Batched column means: (B, S, d) -> (B, 1, d), dispatching exactly
/// like [`global_average_pool_fixed`] so the two are bitwise identical
/// per event.
pub fn global_average_pool_fixed_batch(x: &Mat3, data: FixedSpec, accum: FixedSpec) -> Mat3 {
    if hotpath::int_sum_enabled(data, x.rows()) {
        let mut out = Mat3::zeros(x.batch(), 1, x.cols());
        for b in 0..x.batch() {
            pool_int_core(
                x.event_slice(b),
                out.event_row_mut(b, 0),
                x.rows(),
                x.cols(),
                data,
                accum,
            );
        }
        return out;
    }
    global_average_pool_fixed_batch_ref(x, data, accum)
}

/// Batched twin of [`global_average_pool_fixed_compiled`].
pub fn global_average_pool_fixed_batch_compiled(x: &Mat3, site: &CompiledPool) -> Mat3 {
    if site.use_int() {
        let mut out = Mat3::zeros(x.batch(), 1, x.cols());
        for b in 0..x.batch() {
            pool_int_core(
                x.event_slice(b),
                out.event_row_mut(b, 0),
                x.rows(),
                x.cols(),
                site.data(),
                site.accum(),
            );
        }
        return out;
    }
    global_average_pool_fixed_batch_ref(x, site.data(), site.accum())
}

/// The f64 reference path of [`global_average_pool_fixed_batch`].
pub fn global_average_pool_fixed_batch_ref(
    x: &Mat3,
    data: FixedSpec,
    accum: FixedSpec,
) -> Mat3 {
    let mut out = Mat3::zeros(x.batch(), 1, x.cols());
    for b in 0..x.batch() {
        for c in 0..x.cols() {
            let mut acc = 0.0f64;
            for r in 0..x.rows() {
                acc += x.event_row(b, r)[c] as f64;
            }
            let mean = accum.quantize_f64(acc / x.rows() as f64);
            out.event_row_mut(b, 0)[c] = data.quantize(mean as f32);
        }
    }
    out
}

/// Sigmoid through the exp ROM: `1 / (1 + e^{-x})` — reuses the softmax
/// exp table plus the inversion table, as hls4ml's activation LUTs do.
pub fn sigmoid_fixed(x: f32, roms: &Roms, data: FixedSpec) -> f32 {
    let e = roms.exp.lookup(-x);
    data.quantize(roms.inv.lookup(1.0 + e))
}

/// Pooling pipeline stage (one adder tree over the sequence).
pub fn pool_stage(name: &str, rows: usize, r: ReuseFactor) -> Stage {
    Stage::new(name, adder_tree_depth(rows as u64) + 2, r.get() as u64, rows as u64)
}

/// [`pool_stage`] that refuses (site-named, one line) a reuse factor
/// that does not evenly divide the pooled sequence instead of silently
/// rounding the chunk count up.
pub fn pool_stage_checked(
    name: &str,
    rows: usize,
    r: ReuseFactor,
) -> Result<Stage, String> {
    super::pipeline::check_reuse_divides(name, r, rows)?;
    Ok(pool_stage(name, rows, r))
}

/// Pooling is adder-tree-only: no DSPs (the 1/S multiply is a constant
/// shift-add), modest fabric.
pub fn pool_resources(d: usize, data: FixedSpec, r: ReuseFactor) -> Resources {
    let w = data.width() as u64;
    let adders = (d as u64).div_ceil(r.get() as u64);
    Resources::new(0, adders * w, adders * w * 2, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Gen;

    #[test]
    fn pool_matches_float() {
        let mut g = Gen::new(1);
        let x = Mat::from_vec(10, 4, g.normal_vec(40, 1.0));
        let wide = FixedSpec::new(32, 12);
        let q = global_average_pool_fixed(&x, wide, wide.accum());
        let f = crate::nn::layers::global_average_pool(&x);
        assert!(q.max_abs_diff(&f) < 1e-3);
    }

    #[test]
    fn sigmoid_tracks_float() {
        let roms = Roms::new();
        let data = FixedSpec::new(18, 8);
        for x in [-4.0f32, -1.0, 0.0, 0.5, 3.0] {
            let want = 1.0 / (1.0 + (-x).exp());
            let got = sigmoid_fixed(x, &roms, data);
            assert!((got - want).abs() < 0.03, "x={x}: {got} vs {want}");
        }
    }

    #[test]
    fn sigmoid_saturates_sanely() {
        let roms = Roms::new();
        let data = FixedSpec::new(18, 8);
        assert!(sigmoid_fixed(20.0, &roms, data) > 0.9);
        assert!(sigmoid_fixed(-20.0, &roms, data) < 0.1);
    }

    #[test]
    fn prop_int_pool_bitwise_matches_ref() {
        use crate::testutil::Prop;
        Prop::new("pool int == f64 ref").runs(200).check(|g| {
            let data = g.fixed_spec();
            let accum = data.accum();
            let (rows, cols) = (g.usize_in(1, 40), g.usize_in(1, 12));
            assert!(crate::fixed::mantissa::f64_sum_exact(data, rows), "{data}");
            // on-grid inputs, sometimes hot enough to saturate the mean
            let scale = if g.bool() { 1.0 } else { 60.0 };
            let x = Mat::from_vec(rows, cols, g.normal_vec(rows * cols, scale))
                .map(|v| data.quantize(v));
            let want = global_average_pool_fixed_ref(&x, data, accum);
            // the int core directly (not the dispatcher), so the
            // comparison is live in the `f64-reference` build too
            let mut got = Mat::zeros(1, cols);
            pool_int_core(x.data(), got.data_mut(), rows, cols, data, accum);
            assert_eq!(got, want, "{data} {rows}x{cols}");
            let b3 = Mat3::from_events(&[&x, &x]);
            let wantb = global_average_pool_fixed_batch_ref(&b3, data, accum);
            let gotb = global_average_pool_fixed_batch(&b3, data, accum);
            assert_eq!(gotb.data(), wantb.data(), "{data} batch");
        });
    }

    #[test]
    fn batched_pool_bitwise_matches_per_event() {
        let mut g = Gen::new(4);
        let data = FixedSpec::new(12, 5);
        let events: Vec<Mat> =
            (0..3).map(|_| Mat::from_vec(6, 4, g.normal_vec(24, 1.0))).collect();
        let refs: Vec<&Mat> = events.iter().collect();
        let batched = global_average_pool_fixed_batch(&Mat3::from_events(&refs), data, data.accum());
        for (i, e) in events.iter().enumerate() {
            assert_eq!(batched.event(i), global_average_pool_fixed(e, data, data.accum()));
        }
    }

    #[test]
    fn compiled_pool_bitwise_matches_dispatcher() {
        use crate::hls::QuantConfig;
        let mut g = Gen::new(12);
        let rows = 10;
        // one sum-exact grid, one wide grid forcing the reference path
        for data in [FixedSpec::new(12, 5), FixedSpec::new(32, 12)] {
            let accum = data.accum();
            let site = CompiledPool::build(QuantConfig { data, accum }, rows);
            let x = Mat::from_vec(rows, 4, g.normal_vec(rows * 4, 1.0));
            let want = global_average_pool_fixed(&x, data, accum);
            assert_eq!(global_average_pool_fixed_compiled(&x, &site), want, "{data}");
            let b3 = Mat3::from_events(&[&x, &x]);
            let wantb = global_average_pool_fixed_batch(&b3, data, accum);
            let gotb = global_average_pool_fixed_batch_compiled(&b3, &site);
            assert_eq!(gotb.data(), wantb.data(), "{data} batch");
        }
    }

    #[test]
    fn pool_has_no_dsps() {
        assert_eq!(pool_resources(64, FixedSpec::new(16, 6), ReuseFactor(1)).dsp, 0);
    }
}
