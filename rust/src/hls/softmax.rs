//! Fixed-point LUT SoftMax — the paper's restructured O(k) layer (§IV-B).
//!
//! Three stages (figure 7): exp ROM per element, one sum + inversion ROM
//! (held in a register), elementwise multiply.  Compare the old hls4ml
//! formulation which recomputed the exp-sum per output element — O(k²);
//! [`softmax_fixed_legacy`] implements it for the ablation bench.

use super::calibration as cal;
use super::compiled::CompiledSoftmax;
use super::hotpath;
use super::pipeline::{adder_tree_depth, Stage};
use super::resources::{bram18_for_bits, dsp_per_mult, Resources};
use super::ReuseFactor;
use crate::fixed::lut::Roms;
use crate::fixed::{FixedSpec, MacQuantizer, MantissaConv};

/// One row of LUT softmax on the `ap_fixed` grid.
///
/// Includes the hls4ml "stable" stage 0 (row-max subtraction, one
/// comparator tree, still O(k)): our trained checkpoints produce scores
/// far outside any realistic exp-ROM domain, which the paper's raw
/// formulation silently saturates into garbage (see DESIGN.md §2).
/// [`softmax_fixed_legacy`] keeps the raw O(k²) pre-paper baseline and
/// [`softmax_fixed_raw`] the paper's unshifted O(k) version for the
/// ablation bench (both always on the reference arithmetic).
///
/// Dispatch ([`hotpath`]): the stage-2 exp-sum runs on `i64` mantissa
/// lanes ([`softmax_fixed_row_int`]) when provably bit-identical, else
/// the f64 reference [`softmax_fixed_row_ref`].
pub fn softmax_fixed_row(
    row: &mut [f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    if hotpath::int_sum_enabled(data, row.len()) {
        return softmax_fixed_row_int(row, roms, data, accum);
    }
    softmax_fixed_row_ref(row, roms, data, accum);
}

/// [`softmax_fixed_row`] through a prebuilt [`CompiledSoftmax`] site:
/// the grid-exactness half of the dispatch verdict comes from the
/// artifact; only the length-dependent sum bound (and the live
/// reference override) is evaluated per row.  **Bitwise identical** to
/// the dispatcher at the site's specs.
pub fn softmax_fixed_row_compiled(row: &mut [f32], site: &CompiledSoftmax, roms: &Roms) {
    if site.use_int(row.len()) {
        return softmax_fixed_row_int(row, roms, site.data(), site.accum());
    }
    softmax_fixed_row_ref(row, roms, site.data(), site.accum());
}

/// The f64 reference path of [`softmax_fixed_row`].
pub fn softmax_fixed_row_ref(
    row: &mut [f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    let qa = crate::fixed::Quantizer::new(accum);
    let qd = crate::fixed::Quantizer::new(data);
    // stage 0: comparator tree + subtract (values stay on-grid)
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    // stage 1: exp ROM (outputs quantized to the data grid, as the ROM
    // words are data-width fixed-point on the FPGA)
    let mut sum = 0.0f64;
    for v in row.iter_mut() {
        *v = qd.q32(roms.exp.lookup(*v - max));
        sum += *v as f64; // stage 2 accumulates behind stage 1
    }
    let sum = qa.q(sum) as f32;
    if sum == 0.0 {
        // every exp output underflowed the data grid (possible only on
        // degenerate grids whose max representable value is 0, or for an
        // empty row): `inv.lookup(0)` would read the inversion ROM's
        // singularity bin — the defined behavior is the uniform limit
        uniform_row(row, &qd);
        return;
    }
    let inv = qd.q32(roms.inv.lookup(sum));
    // stage 3: elementwise multiply
    for v in row.iter_mut() {
        *v = qd.q32(*v * inv);
    }
}

/// Integer-mantissa variant of [`softmax_fixed_row`]: the ROM lookups
/// and the stage-3 multiply are float exactly as the reference, but the
/// stage-2 exp-sum accumulates data-grid mantissas on an `i64` lane and
/// requantizes with one shift-and-round — the reference's exact f64 sum
/// plus `Quantizer::q`, reproduced bit-for-bit (including the zero-sum
/// comparator: no nonzero mantissa multiple rounds to an f32 zero).
/// Only bit-identical when the [`softmax_fixed_row`] gate holds.
pub fn softmax_fixed_row_int(
    row: &mut [f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    let qd = crate::fixed::Quantizer::new(data);
    let conv = MantissaConv::new(data);
    let mq = MacQuantizer::from_fracs(data.frac(), accum);
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum_m = 0i64;
    for v in row.iter_mut() {
        *v = qd.q32(roms.exp.lookup(*v - max));
        sum_m += conv.to_m(*v);
    }
    let sum = (mq.requant(sum_m) as f64 * accum.step()) as f32;
    if sum == 0.0 {
        uniform_row(row, &qd);
        return;
    }
    let inv = qd.q32(roms.inv.lookup(sum));
    for v in row.iter_mut() {
        *v = qd.q32(*v * inv);
    }
}

/// Zero-exp-sum fallback shared by the softmax variants: a uniform
/// distribution over the row, projected onto the data grid (in
/// hardware, a mux that bypasses the inversion ROM when the sum-is-zero
/// comparator fires).  On grids too coarse to represent `1/len` this
/// degrades to zeros — still well-defined, never the ROM-edge garbage
/// of `inv.lookup(0)`.
fn uniform_row(row: &mut [f32], qd: &crate::fixed::Quantizer) {
    let u = qd.q32(1.0 / row.len().max(1) as f32);
    for v in row.iter_mut() {
        *v = u;
    }
}

/// Masked LUT softmax — the paper's §VII future-work feature ("we could
/// add masking ability to the MHA layer").  In hardware the mask is an
/// AND gate ahead of the exp ROM: masked lanes contribute zero to the
/// sum and output zero probability; the max tree only sees live lanes.
pub fn softmax_fixed_row_masked(
    row: &mut [f32],
    mask: &[bool],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    assert_eq!(row.len(), mask.len());
    let qa = crate::fixed::Quantizer::new(accum);
    let qd = crate::fixed::Quantizer::new(data);
    let max = row
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(v, _)| *v)
        .fold(f32::NEG_INFINITY, f32::max);
    if max == f32::NEG_INFINITY {
        // fully-masked row: hardware outputs all zeros
        row.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let mut sum = 0.0f64;
    for (v, &m) in row.iter_mut().zip(mask) {
        *v = if m { qd.q32(roms.exp.lookup(*v - max)) } else { 0.0 };
        sum += *v as f64;
    }
    let sum = qa.q(sum) as f32;
    if sum == 0.0 {
        // live lanes exist (max was finite) but every exp underflowed:
        // uniform over the live lanes, masked lanes stay zero (the same
        // singularity-bypass mux as in `softmax_fixed_row`)
        let live = mask.iter().filter(|&&m| m).count();
        let u = qd.q32(1.0 / live.max(1) as f32);
        for (v, &m) in row.iter_mut().zip(mask) {
            *v = if m { u } else { 0.0 };
        }
        return;
    }
    let inv = qd.q32(roms.inv.lookup(sum));
    for v in row.iter_mut() {
        *v = qd.q32(*v * inv);
    }
}

/// The paper's raw O(k) formulation (§IV-B, no max subtraction) — exact
/// for in-ROM-range scores, saturates outside.  Ablation only.
pub fn softmax_fixed_raw(
    row: &mut [f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    for v in row.iter_mut() {
        *v = data.quantize(roms.exp.lookup(*v));
    }
    let mut sum = 0.0f64;
    for v in row.iter() {
        sum += *v as f64;
    }
    let sum = accum.quantize_f64(sum) as f32;
    if sum == 0.0 {
        // without the stable shift this is reachable on realistic grids
        // (all scores below the exp ROM's domain saturate to a value
        // that underflows the data grid); same singularity bypass
        uniform_row(row, &crate::fixed::Quantizer::new(data));
        return;
    }
    let inv = data.quantize(roms.inv.lookup(sum));
    for v in row.iter_mut() {
        *v = data.quantize(*v * inv);
    }
}

/// The pre-paper hls4ml softmax: `S_i = (Σ_j e^{z_j - z_i})^{-1}` —
/// k lookups *per element*, hence O(k²) work.  Kept as the ablation
/// baseline for the §IV-B comparison bench.
pub fn softmax_fixed_legacy(
    row: &mut [f32],
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) {
    let orig: Vec<f32> = row.to_vec();
    for (i, out) in row.iter_mut().enumerate() {
        let mut sum = 0.0f64;
        for &zj in &orig {
            sum += data.quantize(roms.exp.lookup(zj - orig[i])) as f64;
        }
        let sum = accum.quantize_f64(sum) as f32;
        *out = if sum == 0.0 {
            // same zero-exp-sum singularity bypass as the other
            // variants, per element here (the legacy form has one
            // exp-sum per output lane)
            data.quantize(1.0 / orig.len().max(1) as f32)
        } else {
            data.quantize(roms.inv.lookup(sum))
        };
    }
}

/// Pipeline stage for the 3-stage softmax over `rows` rows of width `k`,
/// at the site's reuse and the LUT-I/O precision.  The stage-3 multiply
/// takes one ROM-fed operand already held in a register, so wide grids
/// cost no cascade fill here — but past the 26-bit port the decomposed
/// multiply still halves the issue rate ([`cal::dsp_ii_widening`]).
pub fn softmax_stage(
    name: &str,
    rows: usize,
    k: usize,
    r: ReuseFactor,
    data: FixedSpec,
) -> Stage {
    Stage::new(
        name,
        cal::SOFTMAX_DEPTH_BASE
            + adder_tree_depth(k as u64)
            + cal::reuse_depth_growth(k, r) / 2,
        r.get() as u64 * cal::dsp_ii_widening(data.width()),
        rows as u64,
    )
}

/// [`softmax_stage`] that refuses (site-named, one line) a reuse factor
/// that does not evenly divide the `k`-wide row instead of silently
/// rounding the chunk count up.
pub fn softmax_stage_checked(
    name: &str,
    rows: usize,
    k: usize,
    r: ReuseFactor,
    data: FixedSpec,
) -> Result<Stage, String> {
    super::pipeline::check_reuse_divides(name, r, k)?;
    Ok(softmax_stage(name, rows, k, r, data))
}

/// Resources: two ROMs + k/R multipliers (stage 3) + the adder tree.
pub fn softmax_resources(k: usize, data: FixedSpec, r: ReuseFactor) -> Resources {
    let w = data.width() as u64;
    let concurrent = (k as u64).div_ceil(r.get() as u64);
    let dsp = concurrent * dsp_per_mult(data.width());
    let ff = (concurrent as f64 * w as f64 * cal::FF_PER_MULT_BIT) as u64
        + cal::FF_CTRL_PER_STAGE;
    let lut = (concurrent as f64 * w as f64 * cal::LUT_PER_MULT_BIT) as u64
        + cal::LUT_CTRL_PER_STAGE;
    let rom_bits = (roms_len_exp() + roms_len_inv()) * w;
    Resources::new(dsp, ff, lut, bram18_for_bits(rom_bits))
}

fn roms_len_exp() -> u64 {
    crate::fixed::lut::LutKind::Exp.geometry().2 as u64
}

fn roms_len_inv() -> u64 {
    crate::fixed::lut::LutKind::Inv.geometry().2 as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{Gen, Prop};

    fn setup() -> (Roms, FixedSpec, FixedSpec) {
        let data = FixedSpec::new(18, 8);
        (Roms::new(), data, data.accum())
    }

    #[test]
    fn close_to_exact_softmax() {
        // high-precision fixed LUT softmax tracks exact float softmax
        let (roms, data, accum) = setup();
        let mut g = Gen::new(1);
        for _ in 0..50 {
            let mut row = g.normal_vec(16, 1.0);
            let exact = {
                let max = row.iter().cloned().fold(f32::MIN, f32::max);
                let e: Vec<f32> = row.iter().map(|v| (v - max).exp()).collect();
                let s: f32 = e.iter().sum();
                e.into_iter().map(|v| v / s).collect::<Vec<_>>()
            };
            softmax_fixed_row(&mut row, &roms, data, accum);
            for (a, b) in row.iter().zip(&exact) {
                assert!((a - b).abs() < 0.05, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn prop_outputs_nonneg_sum_near_one() {
        Prop::new("fixed softmax sums ~1").runs(200).check(|g| {
            let (roms, data, accum) = setup();
            let k = g.usize_in(8, 64);
            let mut row = g.normal_vec(k, 1.0);
            softmax_fixed_row(&mut row, &roms, data, accum);
            assert!(row.iter().all(|&p| p >= 0.0));
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 0.1, "sum {s} (k={k})");
        });
    }

    #[test]
    fn legacy_agrees_with_new_in_range() {
        // the paper's restructuring is a refactor, not a semantics change:
        // for in-ROM-range inputs the two produce similar probabilities
        let (roms, data, accum) = setup();
        let mut g = Gen::new(5);
        let mut a = g.normal_vec(12, 0.8);
        let mut b = a.clone();
        softmax_fixed_row(&mut a, &roms, data, accum);
        softmax_fixed_legacy(&mut b, &roms, data, accum);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
    }

    #[test]
    fn outputs_on_grid() {
        let (roms, data, accum) = setup();
        let mut row = vec![0.3, -1.2, 2.0, 0.0];
        softmax_fixed_row(&mut row, &roms, data, accum);
        for &v in &row {
            assert_eq!(v, data.quantize(v));
        }
    }

    #[test]
    fn prop_int_softmax_bitwise_matches_ref() {
        Prop::new("softmax int == f64 ref").runs(200).check(|g| {
            let roms = Roms::new();
            let data = g.fixed_spec();
            let accum = data.accum();
            let k = g.usize_in(1, 64);
            assert!(crate::fixed::mantissa::f32_grid_exact(data));
            assert!(crate::fixed::mantissa::f64_sum_exact(data, k), "{data}");
            // scores on the data grid (as the MHA score stage delivers
            // them), spread wide enough to underflow coarse exp grids
            let row: Vec<f32> =
                g.normal_vec(k, 4.0).iter().map(|&v| data.quantize(v)).collect();
            let mut want = row.clone();
            softmax_fixed_row_ref(&mut want, &roms, data, accum);
            let mut got = row;
            softmax_fixed_row_int(&mut got, &roms, data, accum);
            assert_eq!(got, want, "{data} k={k}");
        });
    }

    #[test]
    fn int_softmax_zero_exp_sum_matches_ref_uniform_bypass() {
        // ap_fixed<1,1> forces every exp output to quantize to zero: the
        // integer path's requantized sum must trip the same zero-sum
        // comparator and emit the same uniform fallback as the reference
        let roms = Roms::new();
        let data = FixedSpec::new(1, 1);
        let mut want = vec![0.0f32, -1.0, 0.0];
        softmax_fixed_row_ref(&mut want, &roms, data, data.accum());
        let mut got = vec![0.0f32, -1.0, 0.0];
        softmax_fixed_row_int(&mut got, &roms, data, data.accum());
        assert_eq!(got, want);
        // and the dispatcher takes the integer path on this grid
        let mut via = vec![0.0f32, -1.0, 0.0];
        softmax_fixed_row(&mut via, &roms, data, data.accum());
        assert_eq!(via, want);
    }

    #[test]
    fn masked_softmax_zeroes_masked_lanes() {
        let (roms, data, accum) = setup();
        let mut g = Gen::new(9);
        let k = 20;
        let mut row = g.normal_vec(k, 1.0);
        let mask: Vec<bool> = (0..k).map(|i| i % 3 != 0).collect();
        softmax_fixed_row_masked(&mut row, &mask, &roms, data, accum);
        let mut live_sum = 0.0f32;
        for (v, &m) in row.iter().zip(&mask) {
            if m {
                assert!(*v >= 0.0);
                live_sum += *v;
            } else {
                assert_eq!(*v, 0.0, "masked lane must be zero");
            }
        }
        assert!((live_sum - 1.0).abs() < 0.1, "live mass {live_sum}");
    }

    #[test]
    fn zero_exp_sum_yields_uniform_not_rom_edge_garbage() {
        let roms = Roms::new();
        // raw (unshifted) softmax: scores far below the exp ROM domain
        // saturate to exp(-8)≈3.3e-4, which underflows an 8-frac-bit
        // grid — the sum is exactly 0 and inv.lookup(0) would return the
        // singularity bin (~12.8).  Defined behavior: uniform.
        let data = FixedSpec::new(16, 8);
        let mut row = vec![-20.0f32, -25.0, -30.0, -40.0];
        softmax_fixed_raw(&mut row, &roms, data, data.accum());
        let want = data.quantize(0.25);
        assert_eq!(row, vec![want; 4]);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 0.01, "uniform mass {s}");
    }

    #[test]
    fn zero_sum_stable_softmax_is_defined_on_degenerate_grids() {
        // ap_fixed<1,1> can only represent {-1, 0}: every exp output
        // quantizes to 0, so the stable path hits the zero-sum case too;
        // it must emit the (grid-projected) uniform value, not consult
        // the inversion ROM at its singularity
        let roms = Roms::new();
        let data = FixedSpec::new(1, 1);
        let mut row = vec![0.0f32, -1.0, 0.0];
        softmax_fixed_row(&mut row, &roms, data, data.accum());
        let want = data.quantize(1.0 / 3.0);
        assert_eq!(row, vec![want; 3]);
        // the legacy ablation baseline defines the same bypass, per
        // element (its exp-sums underflow lane-by-lane)
        let mut legacy = vec![0.0f32, -1.0, 0.0];
        softmax_fixed_legacy(&mut legacy, &roms, data, data.accum());
        assert_eq!(legacy, vec![want; 3]);
        // an empty row is a no-op, not a ROM read
        let mut empty: Vec<f32> = vec![];
        softmax_fixed_row(&mut empty, &roms, FixedSpec::new(18, 8), FixedSpec::new(18, 8).accum());
        assert!(empty.is_empty());
    }

    #[test]
    fn zero_sum_masked_softmax_is_uniform_over_live_lanes() {
        let roms = Roms::new();
        let data = FixedSpec::new(1, 1);
        let mut row = vec![0.0f32, -1.0, 0.0, -1.0];
        let mask = [true, false, true, false];
        softmax_fixed_row_masked(&mut row, &mask, &roms, data, data.accum());
        let want = data.quantize(0.5);
        assert_eq!(row, vec![want, 0.0, want, 0.0]);
    }

    #[test]
    fn masked_softmax_all_masked_is_zero() {
        let (roms, data, accum) = setup();
        let mut row = vec![1.0f32, 2.0, 3.0];
        softmax_fixed_row_masked(&mut row, &[false; 3], &roms, data, accum);
        assert_eq!(row, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn masked_softmax_full_mask_matches_unmasked() {
        let (roms, data, accum) = setup();
        let mut g = Gen::new(10);
        let a0 = g.normal_vec(16, 1.0);
        let mut a = a0.clone();
        let mut b = a0;
        softmax_fixed_row(&mut a, &roms, data, accum);
        softmax_fixed_row_masked(&mut b, &[true; 16], &roms, data, accum);
        assert_eq!(a, b);
    }

    #[test]
    fn stage_and_resources_shapes() {
        let s = softmax_stage("sm", 50, 50, ReuseFactor(2), FixedSpec::new(16, 6));
        assert_eq!(s.ii, 2);
        let r1 = softmax_resources(50, FixedSpec::new(16, 6), ReuseFactor(1));
        let r4 = softmax_resources(50, FixedSpec::new(16, 6), ReuseFactor(4));
        assert!(r4.dsp < r1.dsp);
        assert!(r1.bram18 > 0, "ROMs must occupy BRAM");
    }
}
