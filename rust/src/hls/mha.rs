//! Fixed-point multi-head attention — the paper's 4-stage pipeline
//! (§IV-A, figure 4), executed the way the hardware streams it:
//!
//!   stage 1  row-streamed Q/K/V projections; Q rows go into a FIFO
//!            (figure 5), K and V land in fully-partitioned registers
//!            (figure 6) — V is reshaped for row+column access (§IV-A).
//!   stage 2  per Q row: dot with every K row, scale by 1/sqrt(d_k),
//!            3-stage LUT softmax (§IV-B); result rows into a FIFO.
//!   stage 3  per score row: weighted sum of V rows; result into the
//!            output FIFO.
//!   stage 4  drain per-head FIFOs, concat, output projection Wo.
//!
//! The FIFO traffic is real (the functional sim pushes/pops rows), so the
//! BRAM estimate uses observed high-water marks, not guesses.

use super::compiled::CompiledMha;
use super::dense::{
    dense_fixed, dense_fixed_batch, dense_fixed_batch_compiled, dense_fixed_compiled,
    dense_resources, dense_stage,
};
use super::fifo::Fifo;
use super::hotpath;
use super::parallelism::MhaParallelism;
use super::pipeline::{adder_tree_depth, PipelineModel, Stage};
use super::precision::{MhaPrecision, QuantConfig, RangeProfile};
use super::resources::{bram18_for_bits, dsp_per_mult, Resources};
use super::scratch::Scratch;
use super::softmax::{softmax_fixed_row, softmax_resources, softmax_stage};
use super::{calibration as cal, ReuseFactor};
use crate::fixed::lut::Roms;
use crate::fixed::mantissa::{self, F32_EXACT_LIMIT};
use crate::fixed::{FixedSpec, MacQuantizer, MantissaConv};
use crate::models::weights::MhaWeights;
use crate::nn::layers::Activation;
use crate::nn::tensor::{Mat, Mat3};

/// Observed FIFO sizing from one forward pass (feeds the BRAM model).
#[derive(Clone, Copy, Debug, Default)]
pub struct MhaFifoStats {
    pub q_high_water: usize,
    pub score_high_water: usize,
    pub out_high_water: usize,
}

/// Stage 2 core for one Q row: dot against every K row (all K rows
/// readable in parallel on the register partition), scale into the data
/// grid.  `km` is one event's `(S, k)` row-major K block.  Shared by
/// [`mha_fixed`] and [`mha_fixed_batch`] so the bit-exactness contract
/// lives in exactly one place.
fn score_q_row(
    q_row: &[f32],
    km: &[f32],
    score_row: &mut [f32],
    scale: f32,
    qa: &crate::fixed::Quantizer,
    qd: &crate::fixed::Quantizer,
) {
    let k = q_row.len();
    for (j, sc) in score_row.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (qi, ki) in q_row.iter().zip(&km[j * k..(j + 1) * k]) {
            acc += qa.q(*qi as f64 * *ki as f64);
        }
        let acc = qa.q(acc);
        *sc = qd.q32((acc as f32) * scale);
    }
}

/// Stage 3 core for one probability row: weighted sum of V rows into
/// `out_row` (zeroed here), f32 accumulation of accumulator-grid
/// products, then the final accum+data grid projection.  `vm` is one
/// event's `(S, k)` row-major V block.  Shared by both MHA paths.
fn apply_v_row(
    p_row: &[f32],
    vm: &[f32],
    out_row: &mut [f32],
    qa: &crate::fixed::Quantizer,
    qd: &crate::fixed::Quantizer,
) {
    let k = out_row.len();
    out_row.fill(0.0);
    for (j, &p) in p_row.iter().enumerate() {
        // V row access (the §IV-A reshape makes both row and column
        // access legal; row order streams vm cache-local)
        let p = p as f64;
        for (o, &vv) in out_row.iter_mut().zip(&vm[j * k..(j + 1) * k]) {
            *o += qa.q(p * vv as f64) as f32;
        }
    }
    for o in out_row.iter_mut() {
        *o = qd.q32(qa.q(*o as f64) as f32);
    }
}

/// Integer-lane twin of [`score_q_row`]: `km_m` is the head's K block
/// already on the qkv mantissa grid (hoisted once per head, so the
/// per-row cost is one O(k) Q-row conversion), the dot runs on `i64`
/// lanes with 8-wide unrolling, and the epilogue replays the reference's
/// f64 -> f32 -> scale -> grid chain on the same exact f64 value — hence
/// the same output bits (see [`crate::fixed::mantissa`]).
#[allow(clippy::too_many_arguments)]
fn score_q_row_int(
    q_row: &[f32],
    km_m: &[i64],
    score_row: &mut [f32],
    scale: f32,
    conv: &MantissaConv,
    mq: &MacQuantizer,
    step_a: f64,
    qd: &crate::fixed::Quantizer,
) {
    let k = q_row.len();
    let mut qm = hotpath::tls_take_ints(k);
    for (m, &v) in qm.iter_mut().zip(q_row) {
        *m = conv.to_m(v);
    }
    for (j, sc) in score_row.iter_mut().enumerate() {
        let krow = &km_m[j * k..(j + 1) * k];
        let mut acc = 0i64;
        let mut qc = qm.chunks_exact(8);
        let mut kc = krow.chunks_exact(8);
        for (qv, kv) in (&mut qc).zip(&mut kc) {
            let mut lanes = 0i64;
            for l in 0..8 {
                lanes += mq.product(qv[l], kv[l]);
            }
            acc += lanes;
        }
        for (qv, kv) in qc.remainder().iter().zip(kc.remainder()) {
            acc += mq.product(*qv, *kv);
        }
        *sc = qd.q32((mq.clamp(acc) as f64 * step_a) as f32 * scale);
    }
    hotpath::tls_put_ints(qm);
}

/// Integer-lane twin of [`apply_v_row`] with a per-row exactness guard.
///
/// The reference accumulates in *f32*, so the integer rewrite is only
/// bit-identical while every reference partial sum stays inside the
/// f32-exact integer window: the accumulator mantissa is bounded by
/// `Σ|p_m| · max|v_m| · 2^shift + S/2` (each product requantizes with at
/// most a half-step of rounding, and saturation only shrinks it).  Rows
/// whose bound reaches [`F32_EXACT_LIMIT`] fall back to the f32
/// reference — bit-identical either way, and the guard is a pure
/// function of the row's own inputs, so batch and per-event dispatch in
/// lockstep.
#[allow(clippy::too_many_arguments)]
fn apply_v_row_int(
    p_row: &[f32],
    vm_m: &[i64],
    max_abs_vm: i64,
    vm_f: &[f32],
    out_row: &mut [f32],
    conv_sm: &MantissaConv,
    mq: &MacQuantizer,
    step_a: f64,
    qa: &crate::fixed::Quantizer,
    qd: &crate::fixed::Quantizer,
) {
    let s = p_row.len();
    let k = out_row.len();
    let mut pm = hotpath::tls_take_ints(s);
    let mut sum_abs = 0i64;
    for (m, &v) in pm.iter_mut().zip(p_row) {
        *m = conv_sm.to_m(v);
        sum_abs += (*m).abs();
    }
    let bound =
        sum_abs as f64 * max_abs_vm as f64 * (mq.shift() as f64).exp2() + 0.5 * s as f64;
    if bound >= F32_EXACT_LIMIT {
        hotpath::tls_put_ints(pm);
        apply_v_row(p_row, vm_f, out_row, qa, qd);
        return;
    }
    let mut om = hotpath::tls_take_ints(k);
    for (j, &pmj) in pm.iter().enumerate() {
        if pmj == 0 {
            continue; // the reference adds an exact +0.0 here
        }
        let vrow = &vm_m[j * k..(j + 1) * k];
        let mut oc = om.chunks_exact_mut(8);
        let mut vc = vrow.chunks_exact(8);
        for (ov, vv) in (&mut oc).zip(&mut vc) {
            for l in 0..8 {
                ov[l] += mq.product(pmj, vv[l]);
            }
        }
        for (o, &vv) in oc.into_remainder().iter_mut().zip(vc.remainder()) {
            *o += mq.product(pmj, vv);
        }
    }
    for (o, &m) in out_row.iter_mut().zip(om.iter()) {
        *o = qd.q32((mq.clamp(m) as f64 * step_a) as f32);
    }
    hotpath::tls_put_ints(om);
    hotpath::tls_put_ints(pm);
}

/// The per-call hot-path decisions and requantizer set shared by the
/// per-event and batched MHA bodies, so the two can never disagree.
struct MhaHotPath {
    use_int_score: bool,
    use_int_apply: bool,
    conv_qkv: MantissaConv,
    mq_score: MacQuantizer,
    step_qkv_a: f64,
    conv_sm: MantissaConv,
    mq_apply: MacQuantizer,
    step_out_a: f64,
}

impl MhaHotPath {
    fn new(p: &MhaPrecision, k: usize) -> Self {
        // QK^T is a k-term MAC on the qkv grid — the dense predicate;
        // apply-V is guarded per row (f32 reference accumulation), so
        // its static gate only needs both operand grids f32-exact
        Self::with_verdicts(
            p,
            mantissa::int_mac_eligible(p.qkv.data, p.qkv.accum, k),
            mantissa::f32_grid_exact(p.softmax.data)
                && mantissa::f32_grid_exact(p.qkv.data),
        )
    }

    /// Build from precomputed *pure* verdicts (the compiled artifact
    /// stores exactly these), ANDing in the live reference override so
    /// compiled and per-call dispatch can never disagree.
    fn with_verdicts(p: &MhaPrecision, score_eligible: bool, apply_grid_exact: bool) -> Self {
        let forced = hotpath::f64_reference_forced();
        Self {
            use_int_score: score_eligible && !forced,
            use_int_apply: apply_grid_exact && !forced,
            conv_qkv: MantissaConv::new(p.qkv.data),
            mq_score: MacQuantizer::new(p.qkv.data, p.qkv.accum),
            step_qkv_a: p.qkv.accum.step(),
            conv_sm: MantissaConv::new(p.softmax.data),
            mq_apply: MacQuantizer::from_fracs(
                p.softmax.data.frac() + p.qkv.data.frac(),
                p.out.accum,
            ),
            step_out_a: p.out.accum.step(),
        }
    }

    fn from_compiled(cm: &CompiledMha) -> Self {
        Self::with_verdicts(&cm.precision(), cm.score_eligible(), cm.apply_grid_exact())
    }

    /// Convert a K or V block to mantissas into `dst` (sized by the
    /// caller), returning the max |mantissa| for the apply-V row guard.
    fn convert_block(&self, src: &[f32], dst: &mut [i64]) -> i64 {
        let mut max_abs = 0i64;
        for (m, &v) in dst.iter_mut().zip(src) {
            *m = self.conv_qkv.to_m(v);
            max_abs = max_abs.max((*m).abs());
        }
        max_abs
    }
}

/// Fixed-point MHA forward at one uniform precision: x (S, d) -> (S, d).
/// Thin wrapper over [`mha_fixed_sited`] with every site at the same
/// pair — the legacy global-`QuantConfig` signature.
pub fn mha_fixed(
    x: &Mat,
    w: &MhaWeights,
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
) -> (Mat, MhaFifoStats) {
    let q = QuantConfig { data, accum };
    mha_fixed_sited(x, w, roms, &MhaPrecision::uniform(q), None)
}

/// Fixed-point MHA forward with per-site precision (the heterogeneous
/// `PrecisionPlan` path): stage-1 projections and score MACs at
/// `p.qkv`, the score softmax LUT I/O at `p.softmax`, the apply-V /
/// concat / Wo output path at `p.out`.  With a uniform `p` this is
/// bitwise identical to the legacy path (same op order, idempotent
/// re-quantization).
///
/// `rec`, when present, is `(site prefix, profile)` — the calibration
/// hook that records per-site max-|value| ranges (`"block{b}"` prefix;
/// the softmax LUT I/O records under the shared `"softmax"` site).
pub fn mha_fixed_sited(
    x: &Mat,
    w: &MhaWeights,
    roms: &Roms,
    p: &MhaPrecision,
    rec: Option<(&str, &mut RangeProfile)>,
) -> (Mat, MhaFifoStats) {
    mha_fixed_sited_inner(x, w, roms, p, rec, None)
}

/// Per-event MHA through a prebuilt [`CompiledMha`]: all `3*heads + 1`
/// projection sites use their pre-lifted mantissa tiles (activation
/// lifts only per call), dispatch comes from the artifact's stored
/// verdicts.  **Bitwise identical** to [`mha_fixed_sited`] at the
/// artifact's precision.
pub fn mha_fixed_sited_compiled(
    x: &Mat,
    w: &MhaWeights,
    cm: &CompiledMha,
    roms: &Roms,
    rec: Option<(&str, &mut RangeProfile)>,
) -> (Mat, MhaFifoStats) {
    let p = cm.precision();
    mha_fixed_sited_inner(x, w, roms, &p, rec, Some(cm))
}

fn mha_fixed_sited_inner(
    x: &Mat,
    w: &MhaWeights,
    roms: &Roms,
    p: &MhaPrecision,
    mut rec: Option<(&str, &mut RangeProfile)>,
    cm: Option<&CompiledMha>,
) -> (Mat, MhaFifoStats) {
    let s = x.rows();
    let heads = w.wq.len();
    let k = w.wq[0].cols();
    let scale = 1.0 / (k as f32).sqrt();
    let qa_qkv = crate::fixed::Quantizer::new(p.qkv.accum);
    let qd_sm = crate::fixed::Quantizer::new(p.softmax.data);
    let qa_out = crate::fixed::Quantizer::new(p.out.accum);
    let qd_out = crate::fixed::Quantizer::new(p.out.data);
    let hp = match cm {
        Some(c) => MhaHotPath::from_compiled(c),
        None => MhaHotPath::new(p, k),
    };
    let mut stats = MhaFifoStats::default();

    let mut head_outputs: Vec<Fifo<Vec<f32>>> = Vec::with_capacity(heads);
    for h in 0..heads {
        // ---- stage 1: projections --------------------------------------
        // Q rows stream through a FIFO; K/V are register-partitioned.
        let (q, km, vm) = match cm {
            Some(c) => (
                dense_fixed_compiled(x, &w.wq[h], &c.q[h], Activation::Linear),
                dense_fixed_compiled(x, &w.wk[h], &c.k[h], Activation::Linear),
                dense_fixed_compiled(x, &w.wv[h], &c.v[h], Activation::Linear),
            ),
            None => (
                dense_fixed(x, &w.wq[h], &w.bq[h], Activation::Linear, p.qkv.data, p.qkv.accum),
                dense_fixed(x, &w.wk[h], &w.bk[h], Activation::Linear, p.qkv.data, p.qkv.accum),
                dense_fixed(x, &w.wv[h], &w.bv[h], Activation::Linear, p.qkv.data, p.qkv.accum),
            ),
        };
        if let Some((prefix, prof)) = rec.as_mut() {
            let site = format!("{prefix}.mha.qkv");
            prof.record(&site, q.data());
            prof.record(&site, km.data());
            prof.record(&site, vm.data());
        }
        let mut q_fifo = Fifo::new(format!("h{h}.q"), s);
        for r in 0..s {
            q_fifo.push(q.row(r).to_vec()).expect("q fifo sized to S");
        }
        stats.q_high_water = stats.q_high_water.max(q_fifo.high_water());

        // hoist the K/V mantissa conversions once per head — the
        // per-row conversions below are then only O(k) and O(S)
        let mut km_m = hotpath::tls_take_ints(if hp.use_int_score { s * k } else { 0 });
        if hp.use_int_score {
            hp.convert_block(km.data(), &mut km_m);
        }
        let mut vm_m = hotpath::tls_take_ints(if hp.use_int_apply { s * k } else { 0 });
        let max_vm =
            if hp.use_int_apply { hp.convert_block(vm.data(), &mut vm_m) } else { 0 };

        // ---- stage 2: Q.K^T, scale, LUT softmax ------------------------
        let mut score_fifo = Fifo::new(format!("h{h}.score"), s);
        while let Some(q_row) = q_fifo.pop() {
            let mut score_row = vec![0.0f32; s];
            if hp.use_int_score {
                score_q_row_int(&q_row, &km_m, &mut score_row, scale, &hp.conv_qkv,
                                &hp.mq_score, hp.step_qkv_a, &qd_sm);
            } else {
                score_q_row(&q_row, km.data(), &mut score_row, scale, &qa_qkv, &qd_sm);
            }
            if let Some((_, prof)) = rec.as_mut() {
                prof.record("softmax", &score_row); // LUT input
            }
            softmax_fixed_row(&mut score_row, roms, p.softmax.data, p.softmax.accum);
            if let Some((_, prof)) = rec.as_mut() {
                prof.record("softmax", &score_row); // LUT output
            }
            score_fifo.push(score_row).expect("score fifo sized to S");
        }
        stats.score_high_water = stats.score_high_water.max(score_fifo.high_water());

        // ---- stage 3: weighted sum of V --------------------------------
        let mut out_fifo = Fifo::new(format!("h{h}.out"), s);
        while let Some(p_row) = score_fifo.pop() {
            let mut out_row = vec![0.0f32; k];
            if hp.use_int_apply {
                apply_v_row_int(&p_row, &vm_m, max_vm, vm.data(), &mut out_row,
                                &hp.conv_sm, &hp.mq_apply, hp.step_out_a, &qa_out, &qd_out);
            } else {
                apply_v_row(&p_row, vm.data(), &mut out_row, &qa_out, &qd_out);
            }
            out_fifo.push(out_row).expect("out fifo sized to S");
        }
        stats.out_high_water = stats.out_high_water.max(out_fifo.high_water());
        head_outputs.push(out_fifo);
        hotpath::tls_put_ints(vm_m);
        hotpath::tls_put_ints(km_m);
    }

    // ---- stage 4: concat + output projection ---------------------------
    let mut concat = Mat::zeros(s, heads * k);
    for r in 0..s {
        for (h, fifo) in head_outputs.iter_mut().enumerate() {
            let row = fifo.pop().expect("head fifo drained in row order");
            concat.row_mut(r)[h * k..(h + 1) * k].copy_from_slice(&row);
        }
    }
    let out = match cm {
        Some(c) => dense_fixed_compiled(&concat, &w.wo, &c.out, Activation::Linear),
        None => dense_fixed(&concat, &w.wo, &w.bo, Activation::Linear, p.out.data, p.out.accum),
    };
    if let Some((prefix, prof)) = rec.as_mut() {
        let site = format!("{prefix}.mha.out");
        prof.record(&site, concat.data()); // apply-V outputs live here too
        prof.record(&site, out.data());
    }
    (out, stats)
}

/// Batched fixed-point MHA: x (B, S, d) -> (B, S, d).
///
/// Stage 1 and stage 4 go through [`dense_fixed_batch`], so each of the
/// `3*heads + 1` weight matrices streams once for the whole batch; the
/// quadratic score/softmax/apply-V stages run per event with exactly
/// the operation order of [`mha_fixed`] (including the f32 apply-V
/// accumulation), writing straight into the concat tensor.  The score
/// and output row buffers come from the [`Scratch`] arena instead of
/// being allocated per row, and the FIFO traffic is elided: the
/// per-event schedule deterministically fills every FIFO to `S` before
/// draining (asserted by `fifo_high_water_is_full_sequence`), so the
/// batched path reports those high-water marks directly.
///
/// Output is **bitwise identical** to [`mha_fixed`] per event.
pub fn mha_fixed_batch(
    x: &Mat3,
    w: &MhaWeights,
    roms: &Roms,
    data: FixedSpec,
    accum: FixedSpec,
    scratch: &mut Scratch,
) -> (Mat3, MhaFifoStats) {
    let q = QuantConfig { data, accum };
    mha_fixed_batch_sited(x, w, roms, &MhaPrecision::uniform(q), scratch)
}

/// Batched fixed-point MHA with per-site precision — the batch-major
/// twin of [`mha_fixed_sited`], same site mapping, same op order, so it
/// stays **bitwise identical** to the sited per-event path.
pub fn mha_fixed_batch_sited(
    x: &Mat3,
    w: &MhaWeights,
    roms: &Roms,
    p: &MhaPrecision,
    scratch: &mut Scratch,
) -> (Mat3, MhaFifoStats) {
    mha_fixed_batch_sited_inner(x, w, roms, p, scratch, None)
}

/// Batched MHA through a prebuilt [`CompiledMha`] — the batch-major twin
/// of [`mha_fixed_sited_compiled`], **bitwise identical** to
/// [`mha_fixed_batch_sited`] at the artifact's precision.
pub fn mha_fixed_batch_sited_compiled(
    x: &Mat3,
    w: &MhaWeights,
    cm: &CompiledMha,
    roms: &Roms,
    scratch: &mut Scratch,
) -> (Mat3, MhaFifoStats) {
    let p = cm.precision();
    mha_fixed_batch_sited_inner(x, w, roms, &p, scratch, Some(cm))
}

fn mha_fixed_batch_sited_inner(
    x: &Mat3,
    w: &MhaWeights,
    roms: &Roms,
    p: &MhaPrecision,
    scratch: &mut Scratch,
    cm: Option<&CompiledMha>,
) -> (Mat3, MhaFifoStats) {
    let (bsz, s) = (x.batch(), x.rows());
    let heads = w.wq.len();
    let k = w.wq[0].cols();
    let scale = 1.0 / (k as f32).sqrt();
    let qa_qkv = crate::fixed::Quantizer::new(p.qkv.accum);
    let qd_sm = crate::fixed::Quantizer::new(p.softmax.data);
    let qa_out = crate::fixed::Quantizer::new(p.out.accum);
    let qd_out = crate::fixed::Quantizer::new(p.out.data);
    let hp = match cm {
        Some(c) => MhaHotPath::from_compiled(c),
        None => MhaHotPath::new(p, k),
    };

    let mut concat = Mat3::zeros(bsz, s, heads * k);
    let mut score_row = scratch.take_row(s);
    for h in 0..heads {
        // ---- stage 1: projections, one weight pass per matrix --------
        let (q, km, vm) = match cm {
            Some(c) => (
                dense_fixed_batch_compiled(x, &w.wq[h], &c.q[h], Activation::Linear, scratch),
                dense_fixed_batch_compiled(x, &w.wk[h], &c.k[h], Activation::Linear, scratch),
                dense_fixed_batch_compiled(x, &w.wv[h], &c.v[h], Activation::Linear, scratch),
            ),
            None => (
                dense_fixed_batch(x, &w.wq[h], &w.bq[h], Activation::Linear,
                                  p.qkv.data, p.qkv.accum, scratch),
                dense_fixed_batch(x, &w.wk[h], &w.bk[h], Activation::Linear,
                                  p.qkv.data, p.qkv.accum, scratch),
                dense_fixed_batch(x, &w.wv[h], &w.bv[h], Activation::Linear,
                                  p.qkv.data, p.qkv.accum, scratch),
            ),
        };
        // K/V mantissa hoist, one pass per head; max|v_m| is tracked
        // per event so the apply-V row guard sees exactly the values
        // the per-event path would
        let mut km_m = scratch.take_ints(if hp.use_int_score { bsz * s * k } else { 0 });
        if hp.use_int_score {
            for b in 0..bsz {
                hp.convert_block(km.event_slice(b), &mut km_m[b * s * k..(b + 1) * s * k]);
            }
        }
        let mut vm_m = scratch.take_ints(if hp.use_int_apply { bsz * s * k } else { 0 });
        let mut max_vm = scratch.take_ints(bsz);
        if hp.use_int_apply {
            for b in 0..bsz {
                max_vm[b] =
                    hp.convert_block(vm.event_slice(b), &mut vm_m[b * s * k..(b + 1) * s * k]);
            }
        }
        for b in 0..bsz {
            for r in 0..s {
                // ---- stage 2: Q.K^T, scale, LUT softmax --------------
                if hp.use_int_score {
                    score_q_row_int(q.event_row(b, r), &km_m[b * s * k..(b + 1) * s * k],
                                    &mut score_row, scale, &hp.conv_qkv, &hp.mq_score,
                                    hp.step_qkv_a, &qd_sm);
                } else {
                    score_q_row(q.event_row(b, r), km.event_slice(b), &mut score_row,
                                scale, &qa_qkv, &qd_sm);
                }
                softmax_fixed_row(&mut score_row, roms, p.softmax.data, p.softmax.accum);
                // ---- stage 3: weighted sum of V, into the concat slot
                let out_row = &mut concat.event_row_mut(b, r)[h * k..(h + 1) * k];
                if hp.use_int_apply {
                    apply_v_row_int(&score_row, &vm_m[b * s * k..(b + 1) * s * k], max_vm[b],
                                    vm.event_slice(b), out_row, &hp.conv_sm, &hp.mq_apply,
                                    hp.step_out_a, &qa_out, &qd_out);
                } else {
                    apply_v_row(&score_row, vm.event_slice(b), out_row, &qa_out, &qd_out);
                }
            }
        }
        scratch.put_ints(max_vm);
        scratch.put_ints(vm_m);
        scratch.put_ints(km_m);
    }
    scratch.put_row(score_row);

    // ---- stage 4: output projection, one weight pass -----------------
    let out = match cm {
        Some(c) => dense_fixed_batch_compiled(&concat, &w.wo, &c.out, Activation::Linear, scratch),
        None => dense_fixed_batch(&concat, &w.wo, &w.bo, Activation::Linear,
                                  p.out.data, p.out.accum, scratch),
    };
    let stats = MhaFifoStats {
        q_high_water: s,
        score_high_water: s,
        out_high_water: s,
    };
    (out, stats)
}

/// Retained block-0 attention state for one stream's HLS window cache:
/// per-head Q/K/V projections (on the qkv data grid) and the *raw*
/// post-scale, pre-softmax score matrices (on the softmax data grid).
/// Raw scores are cached — not softmaxed rows — because softmax is
/// row-global: the next hop appends fresh columns to every row, so only
/// the pre-softmax overlap block is shareable.
#[derive(Clone, Debug)]
pub struct MhaWindowState {
    pub q: Vec<Mat>,
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub scores: Vec<Mat>,
}

impl MhaWindowState {
    pub fn new(heads: usize, s: usize, k: usize) -> Self {
        Self {
            q: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            v: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            k: (0..heads).map(|_| Mat::zeros(s, k)).collect(),
            scores: (0..heads).map(|_| Mat::zeros(s, s)).collect(),
        }
    }

    /// Resident bytes of the cached state (f32 payloads).
    pub fn bytes(&self) -> u64 {
        let f = |ms: &[Mat]| ms.iter().map(|m| m.data().len() * 4).sum::<usize>() as u64;
        f(&self.q) + f(&self.k) + f(&self.v) + f(&self.scores)
    }
}

/// Window-cached fixed-point MHA: the incremental twin of
/// [`mha_fixed_sited`] / [`mha_fixed_sited_compiled`].
///
/// With `fresh = None` (cold cache, restart, reuse disabled) everything
/// recomputes and `st` is repopulated.  With `fresh = Some(delta)`,
/// `0 < delta < S`, the leading `S - delta` rows of `x` are bitwise
/// carry-overs from the cached window: only the trailing `delta` rows
/// run the Q/K/V projections, and only the fresh score rows/columns run
/// the dot-product kernel — the cached `(S-delta)^2` raw-score overlap
/// block supplies the rest.  Softmax-onward runs full, per row, exactly
/// as the regular path.
///
/// **Bitwise identical** to the non-cached entries either way: the
/// dense kernels and both score cores compute each output row/entry
/// purely from its own input row(s) (see [`score_q_row`]), hot-path
/// dispatch is a pure function of the plan (not of row count), and the
/// apply-V row guard sees the same full-block `max|v_m|` the regular
/// path hoists.  Pinned by `window_mha_bitwise_matches_sited` below and
/// the transformer/coordinator suites.
pub fn mha_fixed_sited_window(
    x: &Mat,
    w: &MhaWeights,
    roms: &Roms,
    p: &MhaPrecision,
    cm: Option<&CompiledMha>,
    st: &mut MhaWindowState,
    fresh: Option<usize>,
) -> (Mat, MhaFifoStats) {
    let s = x.rows();
    let heads = w.wq.len();
    let k = w.wq[0].cols();
    let scale = 1.0 / (k as f32).sqrt();
    let qa_qkv = crate::fixed::Quantizer::new(p.qkv.accum);
    let qd_sm = crate::fixed::Quantizer::new(p.softmax.data);
    let qa_out = crate::fixed::Quantizer::new(p.out.accum);
    let qd_out = crate::fixed::Quantizer::new(p.out.data);
    let hp = match cm {
        Some(c) => MhaHotPath::from_compiled(c),
        None => MhaHotPath::new(p, k),
    };
    let delta = fresh.filter(|&f| f > 0 && f < s);
    let x_fresh = delta.map(|f| crate::nn::layers::rows_tail(x, f));
    let mut concat = Mat::zeros(s, heads * k);
    let mut prob_row = vec![0.0f32; s];
    for h in 0..heads {
        // ---- stage 1 + raw stage 2: projections and fresh raw scores
        let mut km_m = hotpath::tls_take_ints(if hp.use_int_score { s * k } else { 0 });
        match (delta, &x_fresh) {
            (Some(f), Some(xf)) => {
                let keep = s - f;
                crate::nn::layers::shift_rows_up(&mut st.q[h], f);
                crate::nn::layers::shift_rows_up(&mut st.k[h], f);
                crate::nn::layers::shift_rows_up(&mut st.v[h], f);
                crate::nn::layers::shift_score_block(&mut st.scores[h], f);
                let (qf, kf, vf) = match cm {
                    Some(c) => (
                        dense_fixed_compiled(xf, &w.wq[h], &c.q[h], Activation::Linear),
                        dense_fixed_compiled(xf, &w.wk[h], &c.k[h], Activation::Linear),
                        dense_fixed_compiled(xf, &w.wv[h], &c.v[h], Activation::Linear),
                    ),
                    None => (
                        dense_fixed(xf, &w.wq[h], &w.bq[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                        dense_fixed(xf, &w.wk[h], &w.bk[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                        dense_fixed(xf, &w.wv[h], &w.bv[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                    ),
                };
                for i in 0..f {
                    st.q[h].row_mut(keep + i).copy_from_slice(qf.row(i));
                    st.k[h].row_mut(keep + i).copy_from_slice(kf.row(i));
                    st.v[h].row_mut(keep + i).copy_from_slice(vf.row(i));
                }
                if hp.use_int_score {
                    hp.convert_block(st.k[h].data(), &mut km_m);
                }
                // carried rows: only the fresh trailing columns
                for i in 0..keep {
                    let q_row = st.q[h].row(i);
                    let score_row = st.scores[h].row_mut(i);
                    if hp.use_int_score {
                        score_q_row_int(q_row, &km_m[keep * k..], &mut score_row[keep..],
                                        scale, &hp.conv_qkv, &hp.mq_score, hp.step_qkv_a,
                                        &qd_sm);
                    } else {
                        score_q_row(q_row, &st.k[h].data()[keep * k..],
                                    &mut score_row[keep..], scale, &qa_qkv, &qd_sm);
                    }
                }
                // fresh rows: the whole row
                for i in keep..s {
                    let q_row = st.q[h].row(i);
                    let score_row = st.scores[h].row_mut(i);
                    if hp.use_int_score {
                        score_q_row_int(q_row, &km_m, score_row, scale, &hp.conv_qkv,
                                        &hp.mq_score, hp.step_qkv_a, &qd_sm);
                    } else {
                        score_q_row(q_row, st.k[h].data(), score_row, scale, &qa_qkv,
                                    &qd_sm);
                    }
                }
            }
            _ => {
                let (q, km, vm) = match cm {
                    Some(c) => (
                        dense_fixed_compiled(x, &w.wq[h], &c.q[h], Activation::Linear),
                        dense_fixed_compiled(x, &w.wk[h], &c.k[h], Activation::Linear),
                        dense_fixed_compiled(x, &w.wv[h], &c.v[h], Activation::Linear),
                    ),
                    None => (
                        dense_fixed(x, &w.wq[h], &w.bq[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                        dense_fixed(x, &w.wk[h], &w.bk[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                        dense_fixed(x, &w.wv[h], &w.bv[h], Activation::Linear,
                                    p.qkv.data, p.qkv.accum),
                    ),
                };
                st.q[h] = q;
                st.k[h] = km;
                st.v[h] = vm;
                if hp.use_int_score {
                    hp.convert_block(st.k[h].data(), &mut km_m);
                }
                for i in 0..s {
                    let q_row = st.q[h].row(i);
                    let score_row = st.scores[h].row_mut(i);
                    if hp.use_int_score {
                        score_q_row_int(q_row, &km_m, score_row, scale, &hp.conv_qkv,
                                        &hp.mq_score, hp.step_qkv_a, &qd_sm);
                    } else {
                        score_q_row(q_row, st.k[h].data(), score_row, scale, &qa_qkv,
                                    &qd_sm);
                    }
                }
            }
        }
        hotpath::tls_put_ints(km_m);

        // ---- softmax + stage 3: full, per row, on a copy of the raw
        // scores so the cached overlap block survives the next hop
        let mut vm_m = hotpath::tls_take_ints(if hp.use_int_apply { s * k } else { 0 });
        let max_vm =
            if hp.use_int_apply { hp.convert_block(st.v[h].data(), &mut vm_m) } else { 0 };
        for i in 0..s {
            prob_row.copy_from_slice(st.scores[h].row(i));
            softmax_fixed_row(&mut prob_row, roms, p.softmax.data, p.softmax.accum);
            let out_row = &mut concat.row_mut(i)[h * k..(h + 1) * k];
            if hp.use_int_apply {
                apply_v_row_int(&prob_row, &vm_m, max_vm, st.v[h].data(), out_row,
                                &hp.conv_sm, &hp.mq_apply, hp.step_out_a, &qa_out, &qd_out);
            } else {
                apply_v_row(&prob_row, st.v[h].data(), out_row, &qa_out, &qd_out);
            }
        }
        hotpath::tls_put_ints(vm_m);
    }

    // ---- stage 4: concat + output projection ---------------------------
    let out = match cm {
        Some(c) => dense_fixed_compiled(&concat, &w.wo, &c.out, Activation::Linear),
        None => dense_fixed(&concat, &w.wo, &w.bo, Activation::Linear, p.out.data,
                            p.out.accum),
    };
    // the functional schedule fills every FIFO to S before draining, so
    // the window path reports the same high-water marks as the regular
    // per-event path (see `fifo_high_water_is_full_sequence`)
    let stats = MhaFifoStats {
        q_high_water: s,
        score_high_water: s,
        out_high_water: s,
    };
    (out, stats)
}

/// The MHA dataflow pipeline (figure 4) as a composed stage, with the
/// stage-1/2 projection+score path at the `qkv` site's reuse/precision
/// and the stage-3/4 output path at the `out` site's — the two dials a
/// [`super::ParallelismPlan`] exposes per attention engine.
///
/// Stage 2 cannot start scoring until K is fully resident, and the K/V
/// registers are single-buffered, so the engine's occupancy per event is
/// ~2 passes over the sequence — this is what makes the model-level
/// initiation interval ≈ 2·S·R, matching Tables II-IV's intervals.
pub fn mha_pipeline(
    s: usize,
    d: usize,
    k: usize,
    rp: MhaParallelism,
    mp: &MhaPrecision,
) -> PipelineModel {
    let mut p = PipelineModel::default();
    p.push(dense_stage("mha.qkv_proj", s, d, rp.qkv, mp.qkv.data));
    // the score stage carries the softmax LUT I/O (its own site) plus the
    // QK^T MAC tree on the qkv grid: depth adds, II takes the worse of
    // the two widths' DSP widening
    let mut score = softmax_stage("mha.score_softmax", s, s, rp.qkv, mp.softmax.data);
    score.depth += adder_tree_depth(k as u64)
        + cal::DENSE_DEPTH_EXTRA
        + cal::dsp_cascade_depth(mp.qkv.data.width()); // QK^T tree
    score.ii = score
        .ii
        .max(rp.qkv.get() as u64 * cal::dsp_ii_widening(mp.qkv.data.width()));
    p.push(score);
    p.push(Stage::new(
        "mha.apply_v",
        adder_tree_depth(s as u64)
            + cal::DENSE_DEPTH_EXTRA
            + cal::reuse_depth_growth(k, rp.out)
            + cal::dsp_cascade_depth(mp.out.data.width()),
        rp.out.get() as u64 * cal::dsp_ii_widening(mp.out.data.width()),
        s as u64,
    ));
    p.push(dense_stage("mha.concat_wo", s, d, rp.out, mp.out.data));
    p
}

/// The MHA engine as one top-level stage (dataflow-composed, with the
/// single-buffered K/V occupancy doubling described above).
///
/// Fill depth counts only stages 1-2: stages 3 (apply-V) and 4
/// (concat/Wo) drain row-by-row concurrently with the stage-2 stream,
/// so they contribute occupancy, not fill (calibrated against the
/// depth-dominated b-tagging rows of Table III).
pub fn mha_stage(s: usize, d: usize, k: usize, rp: MhaParallelism, mp: &MhaPrecision) -> Stage {
    let p = mha_pipeline(s, d, k, rp, mp);
    let df = p.dataflow().expect("mha pipeline has stages");
    let fill: u64 = p.stages()[..2].iter().map(|st| st.depth).sum();
    Stage { name: "mha".into(), depth: fill, ii: df.ii, rows: 2 * s as u64 }
}

/// Resource estimate for the whole MHA layer at one uniform width and
/// one uniform reuse factor.
pub fn mha_resources(
    s: usize,
    d: usize,
    heads: usize,
    k: usize,
    data: FixedSpec,
    r: ReuseFactor,
    fifo_stats: Option<MhaFifoStats>,
) -> Resources {
    mha_resources_sited(
        s,
        d,
        heads,
        k,
        data,
        data,
        data,
        MhaParallelism::uniform(r),
        fifo_stats,
    )
}

/// Resource estimate with per-site widths *and* per-path reuse:
/// projections / score MACs / K-V registers / Q FIFO at the `qkv` spec
/// and `rp.qkv` reuse, the softmax engines and score FIFO at the
/// `softmax` spec (sequenced by the score path, so `rp.qkv`), apply-V /
/// Wo / output FIFO at the `out` spec and `rp.out` reuse.  With all
/// sites equal this reproduces [`mha_resources`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn mha_resources_sited(
    s: usize,
    d: usize,
    heads: usize,
    k: usize,
    qkv: FixedSpec,
    out: FixedSpec,
    softmax: FixedSpec,
    rp: MhaParallelism,
    fifo_stats: Option<MhaFifoStats>,
) -> Resources {
    let wq = qkv.width() as u64;
    let wo_bits = out.width() as u64;
    let r_qkv = rp.qkv;
    let r_out = rp.out;
    // stage 1: three projections per head
    let proj: Resources = (0..3)
        .map(|_| dense_resources(d, heads * k, qkv, r_qkv))
        .sum();
    // stage 2: per head, S×k MACs per row + softmax
    let score_mults = (heads * s * k) as u64;
    let score_concurrent = score_mults.div_ceil(r_qkv.get() as u64);
    let score = Resources::new(
        score_concurrent * dsp_per_mult(qkv.width()),
        (score_concurrent as f64 * wq as f64 * cal::FF_PER_MULT_BIT) as u64,
        (score_concurrent as f64 * wq as f64 * cal::LUT_PER_MULT_BIT) as u64,
        0,
    );
    let softmax_res: Resources =
        (0..heads).map(|_| softmax_resources(s, softmax, r_qkv)).sum();
    // stage 3: mirror of stage 2 (probs @ V), on the output-path grid
    let apply_concurrent = score_mults.div_ceil(r_out.get() as u64);
    let apply_v = Resources::new(
        apply_concurrent * dsp_per_mult(out.width()),
        (apply_concurrent as f64 * wo_bits as f64 * cal::FF_PER_MULT_BIT) as u64,
        (apply_concurrent as f64 * wo_bits as f64 * cal::LUT_PER_MULT_BIT) as u64,
        0,
    );
    // stage 4: concat + Wo
    let wo = dense_resources(heads * k, d, out, r_out);
    // K/V register partitions: 2 matrices of S×k per head (filled and
    // read by the qkv-path schedule)
    let kv_bits = (2 * heads * s * k) as u64 * wq;
    let kv = if r_qkv.get() > 1 {
        // reuse re-partitions a (1 - 1/R) share into BRAM (§VI-B)
        let bram_share = kv_bits - kv_bits / r_qkv.get() as u64;
        Resources::new(0, kv_bits / r_qkv.get() as u64, 0, bram18_for_bits(bram_share))
    } else {
        Resources::new(0, kv_bits, 0, 0)
    };
    // FIFOs sized by observed high-water (fallback: full depth S), each
    // at the width of the stream it carries
    let hw = fifo_stats.unwrap_or(MhaFifoStats {
        q_high_water: s,
        score_high_water: s,
        out_high_water: s,
    });
    let fifo_bits = heads as u64
        * ((hw.q_high_water * k) as u64 * wq
            + (hw.score_high_water * s) as u64 * softmax.width() as u64
            + (hw.out_high_water * k) as u64 * wo_bits);
    let fifos = Resources::new(0, 0, 0, bram18_for_bits(fifo_bits));
    proj + score + softmax_res + apply_v + wo + kv + fifos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::testutil::Gen;

    fn gw_setup() -> (Mat, MhaWeights, Roms, FixedSpec, FixedSpec) {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 11);
        let mut g = Gen::new(3);
        let x = Mat::from_vec(
            m.config.seq_len,
            m.config.d_model,
            g.normal_vec(m.config.seq_len * m.config.d_model, 0.7),
        );
        let data = FixedSpec::new(20, 8);
        (x, w.blocks[0].mha.clone(), Roms::new(), data, data.accum())
    }

    #[test]
    fn tracks_float_mha_at_high_precision() {
        let (x, w, roms, data, accum) = gw_setup();
        let (q, _) = mha_fixed(&x, &w, &roms, data, accum);
        let f = crate::nn::layers::mha(&x, &w);
        // LUT softmax + quantization vs exact float: close but not equal
        assert!(q.max_abs_diff(&f) < 0.15, "diff {}", q.max_abs_diff(&f));
        assert!(q.max_abs_diff(&f) > 0.0);
    }

    #[test]
    fn fifo_high_water_is_full_sequence() {
        let (x, w, roms, data, accum) = gw_setup();
        let (_, stats) = mha_fixed(&x, &w, &roms, data, accum);
        // the functional schedule fills each FIFO before draining
        assert_eq!(stats.q_high_water, x.rows());
        assert_eq!(stats.score_high_water, x.rows());
    }

    #[test]
    fn outputs_on_grid() {
        let (x, w, roms, data, accum) = gw_setup();
        let (q, _) = mha_fixed(&x, &w, &roms, data, accum);
        for &v in q.data() {
            assert_eq!(v, data.quantize(v));
        }
    }

    #[test]
    fn batched_mha_bitwise_matches_per_event() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 11).blocks[0].mha.clone();
        let roms = Roms::new();
        let mut g = Gen::new(21);
        for data in [FixedSpec::new(20, 8), FixedSpec::new(8, 4)] {
            let accum = data.accum();
            let events: Vec<Mat> = (0..3)
                .map(|_| {
                    Mat::from_vec(
                        m.config.seq_len,
                        m.config.d_model,
                        g.normal_vec(m.config.seq_len * m.config.d_model, 0.7),
                    )
                })
                .collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let mut scratch = Scratch::new();
            let (batched, stats) =
                mha_fixed_batch(&Mat3::from_events(&refs), &w, &roms, data, accum, &mut scratch);
            for (i, e) in events.iter().enumerate() {
                let (per_event, ev_stats) = mha_fixed(e, &w, &roms, data, accum);
                assert_eq!(batched.event(i), per_event, "{data} event {i}");
                // the batched path's synthesized FIFO stats must agree
                // with what the per-event schedule actually observes
                assert_eq!(stats.q_high_water, ev_stats.q_high_water);
                assert_eq!(stats.score_high_water, ev_stats.score_high_water);
                assert_eq!(stats.out_high_water, ev_stats.out_high_water);
            }
        }
    }

    #[test]
    fn sited_mha_with_uniform_sites_matches_legacy() {
        let (x, w, roms, data, accum) = gw_setup();
        let p = MhaPrecision::uniform(QuantConfig { data, accum });
        let (legacy, st_a) = mha_fixed(&x, &w, &roms, data, accum);
        let (sited, st_b) = mha_fixed_sited(&x, &w, &roms, &p, None);
        assert_eq!(legacy, sited);
        assert_eq!(st_a.q_high_water, st_b.q_high_water);
    }

    #[test]
    fn mixed_site_mha_batch_bitwise_matches_per_event() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 11).blocks[0].mha.clone();
        let roms = Roms::new();
        let mut g = Gen::new(33);
        let p = MhaPrecision {
            qkv: QuantConfig::from_spec(FixedSpec::new(14, 5)),
            out: QuantConfig::from_spec(FixedSpec::new(11, 4)),
            softmax: QuantConfig::from_spec(FixedSpec::new(10, 3)),
        };
        let events: Vec<Mat> = (0..3)
            .map(|_| {
                Mat::from_vec(
                    m.config.seq_len,
                    m.config.d_model,
                    g.normal_vec(m.config.seq_len * m.config.d_model, 0.7),
                )
            })
            .collect();
        let refs: Vec<&Mat> = events.iter().collect();
        let mut scratch = Scratch::new();
        let (batched, _) =
            mha_fixed_batch_sited(&Mat3::from_events(&refs), &w, &roms, &p, &mut scratch);
        for (i, e) in events.iter().enumerate() {
            let (per_event, _) = mha_fixed_sited(e, &w, &roms, &p, None);
            assert_eq!(batched.event(i), per_event, "event {i}");
            // every output lands on the out-site grid
            for &v in per_event.data() {
                assert_eq!(v, p.out.data.quantize(v));
            }
        }
    }

    #[test]
    fn compiled_mha_bitwise_matches_per_call_lift() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 11).blocks[0].mha.clone();
        let roms = Roms::new();
        let mut g = Gen::new(44);
        let plans = [
            MhaPrecision::uniform(QuantConfig::from_spec(FixedSpec::new(16, 6))),
            MhaPrecision {
                qkv: QuantConfig::from_spec(FixedSpec::new(14, 5)),
                out: QuantConfig::from_spec(FixedSpec::new(11, 4)),
                softmax: QuantConfig::from_spec(FixedSpec::new(10, 3)),
            },
            // a wide grid that fails the int-MAC predicate: the compiled
            // path must fall back to the reference bit-for-bit too
            MhaPrecision::uniform(QuantConfig::from_spec(FixedSpec::new(32, 12))),
        ];
        for p in &plans {
            let cm = CompiledMha::build(&w, *p);
            let events: Vec<Mat> = (0..3)
                .map(|_| {
                    Mat::from_vec(
                        m.config.seq_len,
                        m.config.d_model,
                        g.normal_vec(m.config.seq_len * m.config.d_model, 0.7),
                    )
                })
                .collect();
            for e in &events {
                let (want, _) = mha_fixed_sited(e, &w, &roms, p, None);
                let (got, _) = mha_fixed_sited_compiled(e, &w, &cm, &roms, None);
                assert_eq!(got, want);
            }
            let refs: Vec<&Mat> = events.iter().collect();
            let x3 = Mat3::from_events(&refs);
            let mut scratch = Scratch::new();
            let (want_b, _) = mha_fixed_batch_sited(&x3, &w, &roms, p, &mut scratch);
            let (got_b, _) =
                mha_fixed_batch_sited_compiled(&x3, &w, &cm, &roms, &mut scratch);
            assert_eq!(got_b, want_b);
        }
    }

    #[test]
    fn window_mha_bitwise_matches_sited_across_hops_and_plans() {
        // simulated stream windows: the cached incremental path must
        // reproduce the from-scratch sited MHA bit for bit — per-call
        // and compiled, uniform and mixed plans, int-eligible and
        // reference-fallback grids, every hop geometry incl. no-reuse
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 11).blocks[0].mha.clone();
        let roms = Roms::new();
        let (s, d) = (m.config.seq_len, m.config.d_model);
        let plans = [
            MhaPrecision::uniform(QuantConfig::from_spec(FixedSpec::new(16, 6))),
            MhaPrecision {
                qkv: QuantConfig::from_spec(FixedSpec::new(14, 5)),
                out: QuantConfig::from_spec(FixedSpec::new(11, 4)),
                softmax: QuantConfig::from_spec(FixedSpec::new(10, 3)),
            },
            // wide grid: int predicate fails, reference fallback path
            MhaPrecision::uniform(QuantConfig::from_spec(FixedSpec::new(32, 12))),
        ];
        let mut g = Gen::new(55);
        for p in &plans {
            let cm = CompiledMha::build(&w, *p);
            for hop in [s / 4, s / 2, s] {
                let hop = hop.max(1);
                let total = s + 2 * hop;
                let stream = Mat::from_vec(total, d, g.normal_vec(total * d, 0.7));
                let heads = w.wq.len();
                let k = w.wq[0].cols();
                let mut st = MhaWindowState::new(heads, s, k);
                let mut st_cm = MhaWindowState::new(heads, s, k);
                let mut prev: Option<usize> = None;
                let mut start = 0usize;
                while start + s <= total {
                    let mut x = Mat::zeros(s, d);
                    for t in 0..s {
                        x.row_mut(t).copy_from_slice(stream.row(start + t));
                    }
                    let fresh = prev.map(|pv| start - pv);
                    let (want, _) = mha_fixed_sited(&x, &w, &roms, p, None);
                    let (got, stats) =
                        mha_fixed_sited_window(&x, &w, &roms, p, None, &mut st, fresh);
                    assert_eq!(got, want, "percall hop {hop} start {start}");
                    assert_eq!(stats.q_high_water, s);
                    let (got_cm, _) = mha_fixed_sited_window(&x, &w, &roms, p, Some(&cm),
                                                             &mut st_cm, fresh);
                    assert_eq!(got_cm, want, "compiled hop {hop} start {start}");
                    prev = Some(start);
                    start += hop;
                }
            }
        }
    }

    #[test]
    fn prop_int_score_row_matches_ref() {
        use crate::testutil::Prop;
        Prop::new("score_q_row int == ref").runs(200).check(|g| {
            let data = g.fixed_spec();
            let accum = data.accum();
            let sm = g.fixed_spec();
            let (s, k) = (g.usize_in(1, 12), g.usize_in(1, 20));
            let qa = crate::fixed::Quantizer::new(accum);
            let qd = crate::fixed::Quantizer::new(sm);
            let scale = 1.0 / (k as f32).sqrt();
            let q_row: Vec<f32> =
                g.normal_vec(k, 2.0).iter().map(|&v| data.quantize(v)).collect();
            let km: Vec<f32> =
                g.normal_vec(s * k, 2.0).iter().map(|&v| data.quantize(v)).collect();
            let conv = MantissaConv::new(data);
            let mq = MacQuantizer::new(data, accum);
            let km_m: Vec<i64> = km.iter().map(|&v| conv.to_m(v)).collect();
            let mut want = vec![0.0f32; s];
            score_q_row(&q_row, &km, &mut want, scale, &qa, &qd);
            let mut got = vec![0.0f32; s];
            score_q_row_int(&q_row, &km_m, &mut got, scale, &conv, &mq, accum.step(), &qd);
            assert_eq!(got, want, "{data} sm {sm}");
        });
    }

    #[test]
    fn prop_int_apply_v_row_matches_ref() {
        use crate::testutil::Prop;
        Prop::new("apply_v_row int == ref").runs(200).check(|g| {
            let qkv = g.fixed_spec();
            let sm = g.fixed_spec();
            let out = g.fixed_spec();
            let accum = out.accum();
            let qa = crate::fixed::Quantizer::new(accum);
            let qd = crate::fixed::Quantizer::new(out);
            let (s, k) = (g.usize_in(1, 16), g.usize_in(1, 12));
            // a mix of softmax-like rows and large off-distribution rows
            // so the per-row exactness guard takes both branches
            let p_scale = if g.bool() { 1.0 } else { 60.0 };
            let p_row: Vec<f32> = g
                .normal_vec(s, p_scale)
                .iter()
                .map(|&v| sm.quantize(v.abs()))
                .collect();
            let vm: Vec<f32> =
                g.normal_vec(s * k, 2.0).iter().map(|&v| qkv.quantize(v)).collect();
            let conv_qkv = MantissaConv::new(qkv);
            let conv_sm = MantissaConv::new(sm);
            let mq = MacQuantizer::from_fracs(sm.frac() + qkv.frac(), accum);
            let mut max_vm = 0i64;
            let vm_m: Vec<i64> = vm
                .iter()
                .map(|&v| {
                    let m = conv_qkv.to_m(v);
                    max_vm = max_vm.max(m.abs());
                    m
                })
                .collect();
            let mut want = vec![0.0f32; k];
            apply_v_row(&p_row, &vm, &mut want, &qa, &qd);
            let mut got = vec![0.0f32; k];
            apply_v_row_int(&p_row, &vm_m, max_vm, &vm, &mut got, &conv_sm, &mq,
                            accum.step(), &qa, &qd);
            assert_eq!(got, want, "qkv {qkv} sm {sm} out {out}");
        });
    }

    #[test]
    fn sited_recording_profiles_qkv_softmax_and_out() {
        let (x, w, roms, data, accum) = gw_setup();
        let p = MhaPrecision::uniform(QuantConfig { data, accum });
        let mut prof = RangeProfile::new();
        let _ = mha_fixed_sited(&x, &w, &roms, &p, Some(("block0", &mut prof)));
        for site in ["block0.mha.qkv", "block0.mha.out", "softmax"] {
            assert!(prof.max_abs(site).is_some(), "missing {site}");
        }
        // probabilities are bounded by 1 (softmax output dominates input
        // only on degenerate rows, and scores here are small)
        assert!(prof.max_abs("block0.mha.qkv").unwrap() > 0.0);
    }

    #[test]
    fn sited_resources_match_legacy_when_uniform_and_scale_per_site() {
        let data = FixedSpec::new(16, 6);
        let r2 = MhaParallelism::uniform(ReuseFactor(2));
        let legacy = mha_resources(50, 16, 2, 4, data, ReuseFactor(2), None);
        let sited = mha_resources_sited(50, 16, 2, 4, data, data, data, r2, None);
        assert_eq!(legacy, sited);
        // shaving only the output path trims FF without touching the
        // projections' DSP story
        let slim = mha_resources_sited(
            50, 16, 2, 4, data, FixedSpec::new(10, 4), data, r2, None,
        );
        assert!(slim.ff < legacy.ff);
        // relaxing only the output path's parallelism trims its DSPs
        // while the qkv-path projections keep theirs
        let relaxed = mha_resources_sited(
            50, 16, 2, 4, data, data, data,
            MhaParallelism { qkv: ReuseFactor(2), out: ReuseFactor(8) },
            None,
        );
        assert!(relaxed.dsp < legacy.dsp);
    }

    fn uniform_stage(s: usize, d: usize, k: usize, r: u32) -> Stage {
        let q = QuantConfig::from_spec(FixedSpec::new(16, 6));
        mha_stage(
            s, d, k,
            MhaParallelism::uniform(ReuseFactor(r)),
            &MhaPrecision::uniform(q),
        )
    }

    #[test]
    fn stage_occupancy_is_two_passes() {
        let s = uniform_stage(50, 16, 4, 1);
        assert_eq!(s.occupancy(), 100);
        let s2 = uniform_stage(50, 16, 4, 2);
        assert_eq!(s2.occupancy(), 200);
    }

    #[test]
    fn mixed_reuse_mha_stage_gates_on_the_slower_path() {
        // a relaxed output path slows the engine's II; the fill depth
        // still belongs to the stage-1/2 qkv path
        let q = QuantConfig::from_spec(FixedSpec::new(16, 6));
        let mp = MhaPrecision::uniform(q);
        let base = mha_stage(50, 16, 4, MhaParallelism::uniform(ReuseFactor(1)), &mp);
        let slow_out = mha_stage(
            50, 16, 4,
            MhaParallelism { qkv: ReuseFactor(1), out: ReuseFactor(4) },
            &mp,
        );
        assert_eq!(slow_out.depth, base.depth, "fill is the qkv path's");
        assert_eq!(slow_out.ii, 4, "II gates on the slowest sub-stage");
    }

    #[test]
    fn resources_scale_down_with_reuse() {
        let data = FixedSpec::new(16, 6);
        let r1 = mha_resources(50, 16, 2, 4, data, ReuseFactor(1), None);
        let r4 = mha_resources(50, 16, 2, 4, data, ReuseFactor(4), None);
        assert!(r4.dsp < r1.dsp, "{} vs {}", r4.dsp, r1.dsp);
        assert!(r4.ff < r1.ff);
        assert!(r4.bram18 > r1.bram18, "reuse must move arrays into BRAM");
    }
}
