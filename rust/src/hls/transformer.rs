//! The full fixed-point transformer — what "running the FPGA" means in
//! this reproduction: bit-accurate `ap_fixed` inference plus the
//! synthesis-style latency/resource report for a (precision, reuse)
//! design point.

use super::dense::{dense_fixed, dense_fixed_batch, dense_resources, dense_stage};
use super::layernorm::{
    layernorm_fixed_batch, layernorm_fixed_row, layernorm_resources, layernorm_stage,
};
use super::mha::{mha_fixed, mha_fixed_batch, mha_resources, mha_stage, MhaFifoStats};
use super::pipeline::{PipelineModel, Stage};
use super::pooling::{
    global_average_pool_fixed, global_average_pool_fixed_batch, pool_resources, pool_stage,
    sigmoid_fixed,
};
use super::report::{LayerReport, SynthesisReport};
use super::resources::Resources;
use super::scratch::Scratch;
use super::softmax::softmax_fixed_row;
use super::{calibration as cal, ReuseFactor};
use crate::fixed::lut::Roms;
use crate::fixed::FixedSpec;
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;
use crate::nn::layers::Activation;
use crate::nn::tensor::{Mat, Mat3};

/// Quantization configuration of one design point (paper §VI-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    /// Data type of weights and activations.
    pub data: FixedSpec,
    /// Accumulator type (10 integer bits, fractional width follows data).
    pub accum: FixedSpec,
}

impl QuantConfig {
    /// Paper convention: `ap_fixed<I + frac, I>` data with the 10-int-bit
    /// accumulator at the same fractional width.
    pub fn new(integer_bits: u32, frac_bits: u32) -> Self {
        let data = FixedSpec::new(integer_bits + frac_bits, integer_bits);
        Self { data, accum: data.accum() }
    }

    pub fn from_spec(data: FixedSpec) -> Self {
        Self { data, accum: data.accum() }
    }
}

/// Fixed-point inference engine for one zoo model at one design point.
#[derive(Clone, Debug)]
pub struct FixedTransformer {
    cfg: ModelConfig,
    /// Weights pre-quantized onto the data grid (PTQ).
    weights: Weights,
    quant: QuantConfig,
    roms: Roms,
    /// FIFO stats observed during forward passes (sizes the BRAM model).
    last_fifo_stats: std::cell::Cell<MhaFifoStats>,
    /// Reusable buffers for the batched kernels — allocated on first use
    /// and reused across every later batch served by this engine.
    scratch: std::cell::RefCell<Scratch>,
}

impl FixedTransformer {
    /// Build from float weights: quantizes them onto the data grid (PTQ).
    pub fn new(cfg: ModelConfig, float_weights: &Weights, quant: QuantConfig) -> Self {
        Self {
            cfg,
            weights: float_weights.quantized(quant.data),
            quant,
            roms: Roms::new(),
            last_fifo_stats: std::cell::Cell::new(MhaFifoStats::default()),
            scratch: std::cell::RefCell::new(Scratch::new()),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn quant(&self) -> QuantConfig {
        self.quant
    }

    /// Forward one event `(seq_len, input_size)` -> probabilities.
    ///
    /// Unlike the float reference (which returns logits), the hardware
    /// design bakes the final softmax/sigmoid in (paper §V: "the final
    /// layer is a SoftMax layer").
    pub fn forward(&self, x: &Mat) -> Vec<f32> {
        let (data, accum) = (self.quant.data, self.quant.accum);
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let w = &self.weights;
        // input quantization (the AXI boundary cast)
        let xq = x.map(|v| data.quantize(v));
        let mut h = dense_fixed(&xq, &w.embed.0, &w.embed.1, Activation::Linear, data, accum);
        let mut fifo_stats = MhaFifoStats::default();
        for b in &w.blocks {
            let (attn, stats) = mha_fixed(&h, &b.mha, &self.roms, data, accum);
            fifo_stats.q_high_water = fifo_stats.q_high_water.max(stats.q_high_water);
            fifo_stats.score_high_water =
                fifo_stats.score_high_water.max(stats.score_high_water);
            fifo_stats.out_high_water = fifo_stats.out_high_water.max(stats.out_high_water);
            h = quantize_mat(&h.add(&attn), data); // residual adder
            if let Some(ln) = &b.ln1 {
                for r in 0..h.rows() {
                    layernorm_fixed_row(h.row_mut(r), &ln.gamma, &ln.beta, &self.roms, data, accum);
                }
            }
            let y = dense_fixed(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu, data, accum);
            let y = dense_fixed(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear, data, accum);
            h = quantize_mat(&h.add(&y), data); // residual adder
            if let Some(ln) = &b.ln2 {
                for r in 0..h.rows() {
                    layernorm_fixed_row(h.row_mut(r), &ln.gamma, &ln.beta, &self.roms, data, accum);
                }
            }
        }
        self.last_fifo_stats.set(fifo_stats);
        let pooled = global_average_pool_fixed(&h, data, accum);
        let hid = dense_fixed(&pooled, &w.head.0, &w.head.1, Activation::Relu, data, accum);
        let logits = dense_fixed(&hid, &w.out.0, &w.out.1, Activation::Linear, data, accum);
        let mut out = logits.row(0).to_vec();
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => {
                out[0] = sigmoid_fixed(out[0], &self.roms, data);
            }
            FinalActivation::Softmax => {
                softmax_fixed_row(&mut out, &self.roms, data, accum);
            }
        }
        out
    }

    /// Forward a whole batch of events -> per-event probabilities.
    ///
    /// Batch-major `ap_fixed` execution: each layer's weight matrix
    /// streams once for the entire batch (weight-stationary loop order),
    /// and all temporaries come from the engine's reusable [`Scratch`]
    /// arena.  Every intermediate still lands on the `FixedSpec` grid in
    /// the same order as [`Self::forward`], so the result is **bitwise
    /// identical** to scoring the events one at a time (property-tested
    /// below) — batching changes throughput, never a probability.
    pub fn forward_batch(&self, xs: &[&Mat]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        let (data, accum) = (self.quant.data, self.quant.accum);
        for x in xs {
            assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
            assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        }
        let w = &self.weights;
        let mut scratch_guard = self.scratch.borrow_mut();
        let scratch = &mut *scratch_guard;
        // input quantization (the AXI boundary cast)
        let mut xq = Mat3::from_events(xs);
        xq.map_in_place(|v| data.quantize(v));
        let mut h = dense_fixed_batch(&xq, &w.embed.0, &w.embed.1, Activation::Linear,
                                      data, accum, scratch);
        let mut fifo_stats = MhaFifoStats::default();
        for b in &w.blocks {
            let (attn, stats) = mha_fixed_batch(&h, &b.mha, &self.roms, data, accum, scratch);
            fifo_stats.q_high_water = fifo_stats.q_high_water.max(stats.q_high_water);
            fifo_stats.score_high_water =
                fifo_stats.score_high_water.max(stats.score_high_water);
            fifo_stats.out_high_water = fifo_stats.out_high_water.max(stats.out_high_water);
            h = h.add(&attn); // residual adder
            h.map_in_place(|v| data.quantize(v));
            if let Some(ln) = &b.ln1 {
                layernorm_fixed_batch(&mut h, &ln.gamma, &ln.beta, &self.roms, data, accum);
            }
            let y = dense_fixed_batch(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu,
                                      data, accum, scratch);
            let y = dense_fixed_batch(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear,
                                      data, accum, scratch);
            h = h.add(&y); // residual adder
            h.map_in_place(|v| data.quantize(v));
            if let Some(ln) = &b.ln2 {
                layernorm_fixed_batch(&mut h, &ln.gamma, &ln.beta, &self.roms, data, accum);
            }
        }
        self.last_fifo_stats.set(fifo_stats);
        let pooled = global_average_pool_fixed_batch(&h, data, accum);
        let hid = dense_fixed_batch(&pooled, &w.head.0, &w.head.1, Activation::Relu,
                                    data, accum, scratch);
        let logits = dense_fixed_batch(&hid, &w.out.0, &w.out.1, Activation::Linear,
                                       data, accum, scratch);
        (0..xs.len())
            .map(|i| {
                let mut out = logits.event_row(i, 0).to_vec();
                match self.cfg.final_activation() {
                    FinalActivation::Sigmoid => {
                        out[0] = sigmoid_fixed(out[0], &self.roms, data);
                    }
                    FinalActivation::Softmax => {
                        softmax_fixed_row(&mut out, &self.roms, data, accum);
                    }
                }
                out
            })
            .collect()
    }

    /// Positive-class score (same convention as `FloatTransformer::score`).
    pub fn score(&self, probs: &[f32]) -> f32 {
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => probs[0],
            FinalActivation::Softmax => probs[1.min(probs.len() - 1)],
        }
    }

    /// Top-level pipeline under the paper's layered strategy: inner
    /// layers at the latency strategy, model top level resource-shared.
    pub fn pipeline(&self, r: ReuseFactor) -> PipelineModel {
        let c = &self.cfg;
        let mut p = PipelineModel::default();
        p.push(dense_stage("embed", c.seq_len, c.input_size.max(2), r));
        for b in 0..c.num_blocks {
            let mut m = mha_stage(c.seq_len, c.d_model, c.head_dim, r);
            m.name = format!("block{b}.mha");
            p.push(m);
            if c.use_layernorm {
                p.push(layernorm_stage(&format!("block{b}.ln1"), c.seq_len, c.d_model, r));
            }
            p.push(dense_stage(&format!("block{b}.ffn1"), c.seq_len, c.d_model, r));
            p.push(dense_stage(&format!("block{b}.ffn2"), c.seq_len, c.ffn_dim, r));
            if c.use_layernorm {
                p.push(layernorm_stage(&format!("block{b}.ln2"), c.seq_len, c.d_model, r));
            }
        }
        p.push(pool_stage("pool", c.seq_len, r));
        p.push(dense_stage("head", 1, c.d_model, r));
        p.push(dense_stage("out", 1, c.head_hidden, r));
        p
    }

    /// Per-layer resource estimates.
    pub fn layer_resources(&self, r: ReuseFactor) -> Vec<(String, Resources)> {
        let c = &self.cfg;
        let d = self.quant.data;
        let fifo = {
            let st = self.last_fifo_stats.get();
            (st.q_high_water > 0).then_some(st)
        };
        let mut v: Vec<(String, Resources)> = Vec::new();
        v.push(("embed".into(), dense_resources(c.input_size, c.d_model, d, r)));
        for b in 0..c.num_blocks {
            v.push((
                format!("block{b}.mha"),
                mha_resources(c.seq_len, c.d_model, c.num_heads, c.head_dim, d, r, fifo),
            ));
            if c.use_layernorm {
                v.push((format!("block{b}.ln1"), layernorm_resources(c.d_model, d, r)));
            }
            v.push((format!("block{b}.ffn1"), dense_resources(c.d_model, c.ffn_dim, d, r)));
            v.push((format!("block{b}.ffn2"), dense_resources(c.ffn_dim, c.d_model, d, r)));
            if c.use_layernorm {
                v.push((format!("block{b}.ln2"), layernorm_resources(c.d_model, d, r)));
            }
        }
        v.push(("pool".into(), pool_resources(c.d_model, d, r)));
        v.push(("head".into(), dense_resources(c.d_model, c.head_hidden, d, r)));
        v.push(("out".into(), dense_resources(c.head_hidden, c.output_size, d, r)));
        v
    }

    /// "Synthesize" the design point: latency, interval, clock, resources
    /// — the stand-in for a Vivado run (Tables II-IV / Figures 12-14).
    ///
    /// The model top level is one dataflow (figure 5: FIFO streams
    /// between layers), so the event latency is the sum of pipeline fill
    /// depths plus the drain of the gating two-pass MHA stream, and the
    /// initiation interval is the re-arm time of the busiest engine —
    /// the closed forms in `calibration.rs` (fit to Tables II-IV).
    pub fn synthesize(&self, r: ReuseFactor) -> SynthesisReport {
        let pipe = self.pipeline(r);
        let s = self.cfg.seq_len as u64;
        let depths: u64 = pipe.stages().iter().map(|st| st.depth).sum();
        // layernorm models pay an extra ~1.5 streaming passes (the two
        // LN instances per block are II-gating but partially overlapped)
        let ln_extra = if self.cfg.use_layernorm { 3 * s * r.get() as u64 / 2 } else { 0 };
        let latency_cycles =
            depths + (2 * s - 1) * r.get() as u64 + ln_extra + cal::LATENCY_BASE;
        let interval_cycles = 2 * s * cal::interval_multiplier(r) + cal::II_BASE;
        let interval_cycles = interval_cycles.min(latency_cycles);
        let clk_ns = cal::clock_ns(r);
        let layers: Vec<LayerReport> = pipe
            .stages()
            .iter()
            .zip(self.layer_resources(r))
            .map(|(s, (name, res))| {
                debug_assert_eq!(s.name, name);
                LayerReport {
                    name,
                    depth: s.depth,
                    ii: s.ii,
                    rows: s.rows,
                    latency: s.latency(),
                    resources: res,
                }
            })
            .collect();
        let total: Resources = layers.iter().map(|l| l.resources).sum();
        SynthesisReport {
            model: self.cfg.name.clone(),
            quant: self.quant,
            reuse: r,
            clk_ns,
            latency_cycles,
            interval_cycles,
            latency_us: latency_cycles as f64 * clk_ns / 1000.0,
            layers,
            total,
        }
    }
}

fn quantize_mat(m: &Mat, spec: FixedSpec) -> Mat {
    m.map(|v| spec.quantize(v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::{zoo, zoo_model};
    use crate::nn::FloatTransformer;
    use crate::testutil::Gen;

    fn event(cfg: &ModelConfig, seed: u64) -> Mat {
        let mut g = Gen::new(seed);
        Mat::from_vec(
            cfg.seq_len,
            cfg.input_size,
            g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
        )
    }

    /// The PR's acceptance bar: batched HLS execution is bitwise
    /// identical to the per-event path — over random design points,
    /// batch sizes and inputs, every probability must be `==`, not
    /// merely close.
    #[test]
    fn prop_forward_batch_bitwise_identical_to_per_event() {
        use crate::testutil::Prop;
        Prop::new("fixed forward_batch == forward per event").runs(12).check(|g| {
            let m = zoo_model("btag").unwrap(); // smallest zoo model
            let quant = QuantConfig::new(
                g.usize_in(4, 11) as u32,
                g.usize_in(2, 13) as u32,
            );
            let w = synthetic_weights(&m.config, g.u64());
            let t = FixedTransformer::new(m.config.clone(), &w, quant);
            let bsz = g.usize_in(1, 6);
            let events: Vec<Mat> = (0..bsz).map(|i| event(&m.config, g.u64() ^ i as u64)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let batched = t.forward_batch(&refs);
            assert_eq!(batched.len(), bsz);
            for (x, got) in events.iter().zip(&batched) {
                assert_eq!(got, &t.forward(x), "{:?} batch {bsz}", t.quant());
            }
        });
    }

    #[test]
    fn forward_batch_across_zoo_models_is_bitwise_identical() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 5);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
            let events: Vec<Mat> = (0..4).map(|s| event(&m.config, s)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            // two batched passes through the same engine must also agree
            // (the scratch arena may not leak state between batches)
            let first = t.forward_batch(&refs);
            let second = t.forward_batch(&refs);
            assert_eq!(first, second, "{}", m.config.name);
            for (x, got) in events.iter().zip(&first) {
                assert_eq!(got, &t.forward(x), "{}", m.config.name);
            }
            // FIFO stats feeding the BRAM model match the per-event path
            let batched_stats = t.last_fifo_stats.get();
            t.forward(&events[0]);
            let ev_stats = t.last_fifo_stats.get();
            assert_eq!(batched_stats.q_high_water, ev_stats.q_high_water);
            assert_eq!(batched_stats.score_high_water, ev_stats.score_high_water);
            assert_eq!(batched_stats.out_high_water, ev_stats.out_high_water);
        }
    }

    #[test]
    fn forward_batch_of_empty_is_empty() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 5);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        assert!(t.forward_batch(&[]).is_empty());
    }

    #[test]
    fn forward_shapes_and_probabilities() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 5);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
            let p = t.forward(&event(&m.config, 1));
            assert_eq!(p.len(), m.config.output_size);
            assert!(p.iter().all(|&v| (0.0..=1.0001).contains(&v)), "{p:?}");
        }
    }

    #[test]
    fn high_precision_tracks_float_reference() {
        // At 26-bit precision the remaining gap is the LUT-math (ROM
        // softmax through 3 attention blocks), not quantization — the
        // same gap the Python test test_lut_math_close_but_not_identical
        // bounds at 0.5.  Here probabilities must stay within 0.2 and
        // *rank* the same way.
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let fixed = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, 16));
        let float = FloatTransformer::new(m.config.clone(), w);
        for seed in 0..8 {
            let x = event(&m.config, seed);
            let pf = float.probs(&float.forward(&x));
            let pq = fixed.forward(&x);
            for (a, b) in pf.iter().zip(&pq) {
                assert!((a - b).abs() < 0.2, "{a} vs {b} (seed {seed})");
            }
            // same argmax
            let am = |p: &[f32]| {
                p.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            assert_eq!(am(&pf), am(&pq), "argmax differs (seed {seed})");
        }
    }

    #[test]
    fn quantization_error_shrinks_with_frac_bits() {
        // isolates quantization from LUT math: compare two fixed designs
        // against the finest one; error must decrease monotonically-ish
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let reference = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, 20));
        let x = event(&m.config, 3);
        let pr = reference.forward(&x);
        let mut prev_err = f32::MAX;
        for frac in [2u32, 6, 12] {
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, frac));
            let p = t.forward(&x);
            let err: f32 = p.iter().zip(&pr).map(|(a, b)| (a - b).abs()).sum();
            assert!(err <= prev_err + 0.02, "frac {frac}: err {err} prev {prev_err}");
            prev_err = err;
        }
        assert!(prev_err < 0.05, "12 frac bits should track 20: {prev_err}");
    }

    #[test]
    fn coarse_precision_diverges_more() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let float = FloatTransformer::new(m.config.clone(), w.clone());
        let fine = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(8, 12));
        let coarse = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(4, 2));
        let mut fine_err = 0.0f32;
        let mut coarse_err = 0.0f32;
        for seed in 0..6 {
            let x = event(&m.config, seed);
            let pf = float.probs(&float.forward(&x));
            fine_err += (fine.forward(&x)[0] - pf[0]).abs();
            coarse_err += (coarse.forward(&x)[0] - pf[0]).abs();
        }
        assert!(coarse_err > fine_err, "{coarse_err} vs {fine_err}");
    }

    #[test]
    fn synthesis_report_trends_match_paper() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 7);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let r1 = t.synthesize(ReuseFactor(1));
        let r2 = t.synthesize(ReuseFactor(2));
        let r4 = t.synthesize(ReuseFactor(4));
        // Tables II-IV trends: latency & interval grow with R, clock shrinks
        assert!(r1.latency_cycles < r2.latency_cycles);
        assert!(r2.latency_cycles < r4.latency_cycles);
        assert!(r1.interval_cycles < r2.interval_cycles);
        assert!(r1.clk_ns > r4.clk_ns);
        // Figures 12-14 trends: DSP/FF shrink with R
        assert!(r1.total.dsp > r2.total.dsp);
        assert!(r2.total.dsp >= r4.total.dsp);
        assert!(r1.total.ff > r4.total.ff);
        // BRAM grows with R (array re-partitioning)
        assert!(r4.total.bram18 >= r1.total.bram18);
    }

    #[test]
    fn interval_formula_matches_tables() {
        // interval = 2*S*ceil(log2(2R)) + II_BASE — exact vs the paper:
        // engine R1 119, btag R1 49, gw R1 212 (II_BASE calibrated)
        for (m, want_r1) in zoo().iter().zip([119u64, 49, 219]) {
            let w = synthetic_weights(&m.config, 8);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
            let rep = t.synthesize(ReuseFactor(1));
            assert_eq!(
                rep.interval_cycles,
                2 * m.config.seq_len as u64 + cal::II_BASE,
                "{}",
                m.config.name
            );
            // paper rows within ~5%
            let paper = [119.0, 49.0, 212.0][match m.config.name.as_str() {
                "engine" => 0,
                "btag" => 1,
                _ => 2,
            }];
            let delta = (rep.interval_cycles as f64 - paper).abs() / paper;
            assert!(delta < 0.06, "{}: {} vs paper {paper}", m.config.name, rep.interval_cycles);
            let _ = want_r1;
        }
    }
}
