//! The full fixed-point transformer — what "running the FPGA" means in
//! this reproduction: bit-accurate `ap_fixed` inference plus the
//! synthesis-style latency/resource report for a (precision, reuse)
//! design point.
//!
//! Quantization authority: a per-site [`PrecisionPlan`] (embed,
//! per-block `mha.qkv`/`mha.out`/`ln1`/`ffn1`/`ffn2`/`ln2`, pool, head,
//! out, shared softmax LUT I/O).  [`FixedTransformer::new`] wraps a
//! legacy global [`QuantConfig`] into a *uniform* plan — bitwise
//! identical to the pre-plan implementation (golden-tested below) —
//! while [`FixedTransformer::with_plan`] takes a heterogeneous plan, the
//! design points that actually minimize DSP/FF at iso-AUC.  At every
//! site boundary the stream is re-grid cast onto the consumer's data
//! grid (a no-op when producer and consumer share a grid).

use super::compiled::CompiledModel;
use super::dense::{
    dense_fixed, dense_fixed_batch, dense_fixed_batch_compiled, dense_fixed_compiled,
};
use super::layernorm::{
    layernorm_fixed_batch, layernorm_fixed_batch_compiled, layernorm_fixed_row,
    layernorm_fixed_row_compiled,
};
use super::mha::{
    mha_fixed_batch_sited, mha_fixed_batch_sited_compiled, mha_fixed_sited,
    mha_fixed_sited_compiled, mha_fixed_sited_window, MhaFifoStats, MhaWindowState,
};
use super::parallelism::ParallelismPlan;
use super::pipeline::PipelineModel;
use super::pooling::{
    global_average_pool_fixed, global_average_pool_fixed_batch,
    global_average_pool_fixed_batch_compiled, global_average_pool_fixed_compiled, sigmoid_fixed,
};
use super::precision::{quantize_weights_sited, PrecisionPlan, RangeProfile};
use super::report::{LayerReport, SynthesisReport};
use super::resources::Resources;
use super::scratch::Scratch;
use super::softmax::{softmax_fixed_row, softmax_fixed_row_compiled};
use super::{calibration as cal, ReuseFactor};
use crate::ir::SiteGraph;
use std::sync::Arc;
use crate::fixed::FixedSpec;
use crate::models::config::{FinalActivation, ModelConfig};
use crate::models::weights::Weights;
use crate::nn::layers::{rows_tail, shift_rows_up, Activation};
use crate::nn::tensor::{Mat, Mat3};
use crate::stream::ReuseCounters;

pub use super::precision::QuantConfig;

/// Fixed-point inference engine for one zoo model at one design point.
///
/// Cloning is cheap and *shares* the heavy state: the site-quantized
/// weights and the build-once [`CompiledModel`] artifact both live
/// behind `Arc`s, so a sharded worker pool holds R handles to one
/// immutable copy instead of R rebuilt copies.
#[derive(Clone, Debug)]
pub struct FixedTransformer {
    cfg: ModelConfig,
    /// Weights pre-quantized onto each site's data grid (PTQ), shared
    /// by every clone of this engine.
    weights: Arc<Weights>,
    plan: PrecisionPlan,
    /// The compiled execution artifact: every site's mantissa tiles,
    /// requantizers, ROMs and dispatch verdicts, lifted once at build
    /// time and shared by every clone.
    compiled: Arc<CompiledModel>,
    /// FIFO stats observed during forward passes (sizes the BRAM model).
    last_fifo_stats: std::cell::Cell<MhaFifoStats>,
    /// Reusable buffers for the batched kernels — allocated on first use
    /// and reused across every later batch served by this engine.
    scratch: std::cell::RefCell<Scratch>,
}

/// Per-stream incremental state for [`FixedTransformer::forward_incremental`]:
/// the block-0 prefix rows (embed output already cast onto the block-0
/// QKV grid) plus the block-0 MHA window state (Q/K/V rows and raw
/// pre-softmax scores), keyed by the absolute sample position of the
/// last window served.  One cache per (engine, stream) pair — a sharded
/// worker pool holds one per shard, since the router hands each shard a
/// strided sub-stream whose own deltas key the reuse.
///
/// All cached values are the canonical on-grid `f32` representation the
/// kernels exchange, so replaying them is bitwise identical to
/// recomputing them; integer-mantissa hoists are re-derived per window
/// (a deterministic conversion).
#[derive(Clone, Debug)]
pub struct WindowCache {
    pos: Option<u64>,
    /// Embed output rows, cast onto the block-0 QKV data grid (the
    /// representation entering the block-0 attention engine).
    h_qkv: Mat,
    mha: MhaWindowState,
    counters: ReuseCounters,
}

impl WindowCache {
    /// Reuse/recompute accounting accumulated by every
    /// [`FixedTransformer::forward_incremental`] call through this cache.
    pub fn counters(&self) -> ReuseCounters {
        self.counters
    }

    /// Drop the retained window: the next call recomputes everything
    /// (and repopulates), regardless of position delta.
    pub fn invalidate(&mut self) {
        self.pos = None;
    }
}

impl FixedTransformer {
    /// Build from float weights at one uniform precision (the legacy
    /// global-`QuantConfig` design point): every site gets the same
    /// data/accum pair.
    pub fn new(cfg: ModelConfig, float_weights: &Weights, quant: QuantConfig) -> Self {
        let plan = PrecisionPlan::uniform(cfg.num_blocks, quant);
        Self::with_plan(cfg, float_weights, plan)
    }

    /// Build from float weights under a per-site precision plan:
    /// quantizes each weight tensor onto its own site's grid (PTQ).
    pub fn with_plan(cfg: ModelConfig, float_weights: &Weights, plan: PrecisionPlan) -> Self {
        assert_eq!(
            plan.num_blocks(),
            cfg.num_blocks,
            "plan has {} blocks, model '{}' has {}",
            plan.num_blocks(),
            cfg.name,
            cfg.num_blocks
        );
        let weights = Arc::new(quantize_weights_sited(float_weights, &plan));
        let compiled = Arc::new(CompiledModel::build(&cfg, &weights, &plan));
        Self {
            weights,
            compiled,
            cfg,
            plan,
            last_fifo_stats: std::cell::Cell::new(MhaFifoStats::default()),
            scratch: std::cell::RefCell::new(Scratch::new()),
        }
    }

    /// The build-once compiled artifact (mantissa tiles, requantizers,
    /// ROMs, dispatch verdicts).  Clones of this engine return the same
    /// `Arc` — replica shards can be checked for sharing with
    /// [`Arc::ptr_eq`].
    pub fn compiled(&self) -> &Arc<CompiledModel> {
        &self.compiled
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The embed-site pair — identical to the legacy global config when
    /// the plan is uniform (use [`Self::plan`] for the full map).
    pub fn quant(&self) -> QuantConfig {
        self.plan.embed()
    }

    pub fn plan(&self) -> &PrecisionPlan {
        &self.plan
    }

    /// Forward one event `(seq_len, input_size)` -> probabilities.
    ///
    /// Unlike the float reference (which returns logits), the hardware
    /// design bakes the final softmax/sigmoid in (paper §V: "the final
    /// layer is a SoftMax layer").
    ///
    /// Arithmetic: executed through the build-once [`CompiledModel`]
    /// artifact — weight-side mantissa lifts were paid at construction,
    /// only activations are lifted per call.  Every kernel still
    /// honors the [`super::hotpath`] reference override, and the result
    /// is **bitwise identical** to the retained per-call-lift path
    /// ([`Self::forward_percall`]) — same bits either way, sealed by
    /// the golden corpus.
    pub fn forward(&self, x: &Mat) -> Vec<f32> {
        self.forward_recorded(x, None)
    }

    /// The retained per-call-lift forward: every kernel re-lifts its
    /// weight tiles onto the mantissa grid inside the call, exactly as
    /// before the compiled artifact existed.  Kept as the bitwise
    /// baseline for the property suite and the `hotpath compiled`
    /// bench lane — serving code should use [`Self::forward`].
    pub fn forward_percall(&self, x: &Mat) -> Vec<f32> {
        self.forward_inner(x, None, false)
    }

    /// [`Self::forward`] with an optional per-site range recorder — the
    /// calibration hook: when `rec` is present, the max-|value| of every
    /// site's stream is folded into the profile (used by
    /// [`super::precision::calibrate_plan`] to auto-assign integer bits).
    pub fn forward_recorded(
        &self,
        x: &Mat,
        rec: Option<&mut RangeProfile>,
    ) -> Vec<f32> {
        self.forward_inner(x, rec, true)
    }

    /// One body for the compiled and per-call-lift paths, so the op
    /// order (and therefore the bits) can never drift between them: the
    /// `use_compiled` flag only selects which kernel entry executes the
    /// same arithmetic.
    fn forward_inner(
        &self,
        x: &Mat,
        mut rec: Option<&mut RangeProfile>,
        use_compiled: bool,
    ) -> Vec<f32> {
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let p = &self.plan;
        let w = &*self.weights;
        let c = &*self.compiled;
        let roms = &c.roms;
        if let Some(r) = rec.as_deref_mut() {
            r.record("embed", x.data());
        }
        // input quantization (the AXI boundary cast, on the embed grid)
        let xq = x.map(|v| p.embed().data.quantize(v));
        let mut h = if use_compiled {
            dense_fixed_compiled(&xq, &w.embed.0, &c.embed, Activation::Linear)
        } else {
            dense_fixed(
                &xq,
                &w.embed.0,
                &w.embed.1,
                Activation::Linear,
                p.embed().data,
                p.embed().accum,
            )
        };
        if let Some(r) = rec.as_deref_mut() {
            r.record("embed", h.data());
        }
        let mut fifo_stats = MhaFifoStats::default();
        for (b, blk) in w.blocks.iter().enumerate() {
            let bp = *p.block(b);
            let prefix = format!("block{b}");
            // re-grid cast: the stream enters the attention engine (and
            // its residual bypass) on the QKV grid.  The *input* is
            // recorded into the consumer site before each cast — the
            // site's grid clamps exactly these values, so calibration
            // must size the integer bits for them, not just the outputs.
            if let Some(r) = rec.as_deref_mut() {
                r.record(&format!("{prefix}.mha.qkv"), h.data());
            }
            h = quantize_mat(&h, bp.qkv.data);
            let (attn, stats) = if use_compiled {
                mha_fixed_sited_compiled(
                    &h,
                    &blk.mha,
                    &c.blocks[b].mha,
                    roms,
                    rec.as_deref_mut().map(|r| (prefix.as_str(), r)),
                )
            } else {
                mha_fixed_sited(
                    &h,
                    &blk.mha,
                    roms,
                    &bp.mha(p.softmax()),
                    rec.as_deref_mut().map(|r| (prefix.as_str(), r)),
                )
            };
            fifo_stats.q_high_water = fifo_stats.q_high_water.max(stats.q_high_water);
            fifo_stats.score_high_water =
                fifo_stats.score_high_water.max(stats.score_high_water);
            fifo_stats.out_high_water = fifo_stats.out_high_water.max(stats.out_high_water);
            let sum = h.add(&attn); // residual adder
            if let Some(r) = rec.as_deref_mut() {
                r.record(&format!("{prefix}.mha.out"), sum.data()); // pre-cast sum
            }
            h = quantize_mat(&sum, bp.mha_out.data);
            if let Some(ln) = &blk.ln1 {
                if let Some(r) = rec.as_deref_mut() {
                    r.record(&format!("{prefix}.ln1"), h.data()); // cast input
                }
                h = quantize_mat(&h, bp.ln1.data); // re-grid cast
                if use_compiled {
                    let site = c.blocks[b].ln1.as_ref().expect("compiled LN follows weights");
                    for r in 0..h.rows() {
                        layernorm_fixed_row_compiled(h.row_mut(r), site, roms);
                    }
                } else {
                    for r in 0..h.rows() {
                        layernorm_fixed_row(
                            h.row_mut(r),
                            &ln.gamma,
                            &ln.beta,
                            roms,
                            bp.ln1.data,
                            bp.ln1.accum,
                        );
                    }
                }
                if let Some(r) = rec.as_deref_mut() {
                    r.record(&format!("{prefix}.ln1"), h.data());
                }
            }
            if let Some(r) = rec.as_deref_mut() {
                r.record(&format!("{prefix}.ffn1"), h.data()); // cast input
            }
            h = quantize_mat(&h, bp.ffn1.data); // re-grid cast
            let y = if use_compiled {
                dense_fixed_compiled(&h, &blk.ffn1.0, &c.blocks[b].ffn1, Activation::Relu)
            } else {
                dense_fixed(
                    &h,
                    &blk.ffn1.0,
                    &blk.ffn1.1,
                    Activation::Relu,
                    bp.ffn1.data,
                    bp.ffn1.accum,
                )
            };
            if let Some(r) = rec.as_deref_mut() {
                r.record(&format!("{prefix}.ffn1"), y.data());
                r.record(&format!("{prefix}.ffn2"), y.data()); // cast input
            }
            let y2_in = quantize_mat(&y, bp.ffn2.data); // re-grid cast
            let y = if use_compiled {
                dense_fixed_compiled(&y2_in, &blk.ffn2.0, &c.blocks[b].ffn2, Activation::Linear)
            } else {
                dense_fixed(
                    &y2_in,
                    &blk.ffn2.0,
                    &blk.ffn2.1,
                    Activation::Linear,
                    bp.ffn2.data,
                    bp.ffn2.accum,
                )
            };
            let sum = h.add(&y); // residual adder
            if let Some(r) = rec.as_deref_mut() {
                r.record(&format!("{prefix}.ffn2"), sum.data()); // pre-cast sum
            }
            h = quantize_mat(&sum, bp.ffn2.data);
            if let Some(ln) = &blk.ln2 {
                if let Some(r) = rec.as_deref_mut() {
                    r.record(&format!("{prefix}.ln2"), h.data()); // cast input
                }
                h = quantize_mat(&h, bp.ln2.data); // re-grid cast
                if use_compiled {
                    let site = c.blocks[b].ln2.as_ref().expect("compiled LN follows weights");
                    for r in 0..h.rows() {
                        layernorm_fixed_row_compiled(h.row_mut(r), site, roms);
                    }
                } else {
                    for r in 0..h.rows() {
                        layernorm_fixed_row(
                            h.row_mut(r),
                            &ln.gamma,
                            &ln.beta,
                            roms,
                            bp.ln2.data,
                            bp.ln2.accum,
                        );
                    }
                }
                if let Some(r) = rec.as_deref_mut() {
                    r.record(&format!("{prefix}.ln2"), h.data());
                }
            }
        }
        self.last_fifo_stats.set(fifo_stats);
        if let Some(r) = rec.as_deref_mut() {
            r.record("pool", h.data()); // cast input
        }
        let pool_in = quantize_mat(&h, p.pool().data);
        let pooled = if use_compiled {
            global_average_pool_fixed_compiled(&pool_in, &c.pool)
        } else {
            global_average_pool_fixed(&pool_in, p.pool().data, p.pool().accum)
        };
        if let Some(r) = rec.as_deref_mut() {
            r.record("pool", pooled.data());
            r.record("head", pooled.data()); // cast input
        }
        let head_in = quantize_mat(&pooled, p.head().data);
        let hid = if use_compiled {
            dense_fixed_compiled(&head_in, &w.head.0, &c.head, Activation::Relu)
        } else {
            dense_fixed(
                &head_in,
                &w.head.0,
                &w.head.1,
                Activation::Relu,
                p.head().data,
                p.head().accum,
            )
        };
        if let Some(r) = rec.as_deref_mut() {
            r.record("head", hid.data());
            r.record("out", hid.data()); // cast input
        }
        let out_in = quantize_mat(&hid, p.out().data);
        let logits = if use_compiled {
            dense_fixed_compiled(&out_in, &w.out.0, &c.out, Activation::Linear)
        } else {
            dense_fixed(
                &out_in,
                &w.out.0,
                &w.out.1,
                Activation::Linear,
                p.out().data,
                p.out().accum,
            )
        };
        if let Some(r) = rec.as_deref_mut() {
            r.record("out", logits.data());
        }
        let mut out = logits.row(0).to_vec();
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => {
                out[0] = sigmoid_fixed(out[0], roms, p.softmax().data);
            }
            FinalActivation::Softmax if use_compiled => {
                softmax_fixed_row_compiled(&mut out, &c.softmax, roms);
            }
            FinalActivation::Softmax => {
                softmax_fixed_row(&mut out, roms, p.softmax().data, p.softmax().accum);
            }
        }
        if let Some(r) = rec.as_deref_mut() {
            r.record("softmax", &out);
        }
        out
    }

    /// Forward a whole batch of events -> per-event probabilities.
    ///
    /// Batch-major `ap_fixed` execution: each layer's weight matrix
    /// streams once for the entire batch (weight-stationary loop order),
    /// and all temporaries come from the engine's reusable [`Scratch`]
    /// arena.  Every intermediate still lands on its site's `FixedSpec`
    /// grid in the same order as [`Self::forward`] (including the
    /// inter-site re-grid casts), so the result is **bitwise identical**
    /// to scoring the events one at a time (property-tested below) —
    /// batching changes throughput, never a probability.  The batched
    /// kernels dispatch through [`super::hotpath`] exactly like
    /// [`Self::forward`], so per-event and batched execution take the
    /// integer path (or the f64 reference) in lockstep.
    pub fn forward_batch(&self, xs: &[&Mat]) -> Vec<Vec<f32>> {
        self.forward_batch_inner(xs, true)
    }

    /// The retained per-call-lift batch forward — the bitwise baseline
    /// for [`Self::forward_batch`] (see [`Self::forward_percall`]).
    pub fn forward_batch_percall(&self, xs: &[&Mat]) -> Vec<Vec<f32>> {
        self.forward_batch_inner(xs, false)
    }

    fn forward_batch_inner(&self, xs: &[&Mat], use_compiled: bool) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        for x in xs {
            assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
            assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        }
        let p = &self.plan;
        let w = &*self.weights;
        let c = &*self.compiled;
        let roms = &c.roms;
        let mut scratch_guard = self.scratch.borrow_mut();
        let scratch = &mut *scratch_guard;
        // input quantization (the AXI boundary cast, on the embed grid)
        let mut xq = Mat3::from_events(xs);
        let embed = p.embed();
        xq.map_in_place(|v| embed.data.quantize(v));
        let mut h = if use_compiled {
            dense_fixed_batch_compiled(&xq, &w.embed.0, &c.embed, Activation::Linear, scratch)
        } else {
            dense_fixed_batch(
                &xq, &w.embed.0, &w.embed.1, Activation::Linear, embed.data, embed.accum, scratch,
            )
        };
        let mut fifo_stats = MhaFifoStats::default();
        for (b, blk) in w.blocks.iter().enumerate() {
            let bp = *p.block(b);
            // re-grid cast into the attention engine
            h.map_in_place(|v| bp.qkv.data.quantize(v));
            let (attn, stats) = if use_compiled {
                mha_fixed_batch_sited_compiled(&h, &blk.mha, &c.blocks[b].mha, roms, scratch)
            } else {
                mha_fixed_batch_sited(&h, &blk.mha, roms, &bp.mha(p.softmax()), scratch)
            };
            fifo_stats.q_high_water = fifo_stats.q_high_water.max(stats.q_high_water);
            fifo_stats.score_high_water =
                fifo_stats.score_high_water.max(stats.score_high_water);
            fifo_stats.out_high_water = fifo_stats.out_high_water.max(stats.out_high_water);
            h = h.add(&attn); // residual adder
            h.map_in_place(|v| bp.mha_out.data.quantize(v));
            if let Some(ln) = &blk.ln1 {
                h.map_in_place(|v| bp.ln1.data.quantize(v)); // re-grid cast
                if use_compiled {
                    let site = c.blocks[b].ln1.as_ref().expect("compiled LN follows weights");
                    layernorm_fixed_batch_compiled(&mut h, site, roms);
                } else {
                    layernorm_fixed_batch(
                        &mut h, &ln.gamma, &ln.beta, roms, bp.ln1.data, bp.ln1.accum,
                    );
                }
            }
            h.map_in_place(|v| bp.ffn1.data.quantize(v)); // re-grid cast
            let y = if use_compiled {
                dense_fixed_batch_compiled(&h, &blk.ffn1.0, &c.blocks[b].ffn1,
                                           Activation::Relu, scratch)
            } else {
                dense_fixed_batch(
                    &h, &blk.ffn1.0, &blk.ffn1.1, Activation::Relu,
                    bp.ffn1.data, bp.ffn1.accum, scratch,
                )
            };
            let mut y2_in = y;
            y2_in.map_in_place(|v| bp.ffn2.data.quantize(v)); // re-grid cast
            let y = if use_compiled {
                dense_fixed_batch_compiled(&y2_in, &blk.ffn2.0, &c.blocks[b].ffn2,
                                           Activation::Linear, scratch)
            } else {
                dense_fixed_batch(
                    &y2_in, &blk.ffn2.0, &blk.ffn2.1, Activation::Linear,
                    bp.ffn2.data, bp.ffn2.accum, scratch,
                )
            };
            h = h.add(&y); // residual adder
            h.map_in_place(|v| bp.ffn2.data.quantize(v));
            if let Some(ln) = &blk.ln2 {
                h.map_in_place(|v| bp.ln2.data.quantize(v)); // re-grid cast
                if use_compiled {
                    let site = c.blocks[b].ln2.as_ref().expect("compiled LN follows weights");
                    layernorm_fixed_batch_compiled(&mut h, site, roms);
                } else {
                    layernorm_fixed_batch(
                        &mut h, &ln.gamma, &ln.beta, roms, bp.ln2.data, bp.ln2.accum,
                    );
                }
            }
        }
        self.last_fifo_stats.set(fifo_stats);
        let pool = p.pool();
        h.map_in_place(|v| pool.data.quantize(v)); // re-grid cast
        let mut pooled = if use_compiled {
            global_average_pool_fixed_batch_compiled(&h, &c.pool)
        } else {
            global_average_pool_fixed_batch(&h, pool.data, pool.accum)
        };
        let head = p.head();
        pooled.map_in_place(|v| head.data.quantize(v)); // re-grid cast
        let mut hid = if use_compiled {
            dense_fixed_batch_compiled(&pooled, &w.head.0, &c.head, Activation::Relu, scratch)
        } else {
            dense_fixed_batch(
                &pooled, &w.head.0, &w.head.1, Activation::Relu, head.data, head.accum, scratch,
            )
        };
        let outq = p.out();
        hid.map_in_place(|v| outq.data.quantize(v)); // re-grid cast
        let logits = if use_compiled {
            dense_fixed_batch_compiled(&hid, &w.out.0, &c.out, Activation::Linear, scratch)
        } else {
            dense_fixed_batch(
                &hid, &w.out.0, &w.out.1, Activation::Linear, outq.data, outq.accum, scratch,
            )
        };
        let sm = p.softmax();
        (0..xs.len())
            .map(|i| {
                let mut out = logits.event_row(i, 0).to_vec();
                match self.cfg.final_activation() {
                    FinalActivation::Sigmoid => {
                        out[0] = sigmoid_fixed(out[0], roms, sm.data);
                    }
                    FinalActivation::Softmax if use_compiled => {
                        softmax_fixed_row_compiled(&mut out, &c.softmax, roms);
                    }
                    FinalActivation::Softmax => {
                        softmax_fixed_row(&mut out, roms, sm.data, sm.accum);
                    }
                }
                out
            })
            .collect()
    }

    /// Positive-class score (same convention as `FloatTransformer::score`).
    pub fn score(&self, probs: &[f32]) -> f32 {
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => probs[0],
            FinalActivation::Softmax => probs[1.min(probs.len() - 1)],
        }
    }

    /// A fresh per-stream cache for [`Self::forward_incremental`], sized
    /// from this engine's dimensions.
    pub fn window_cache(&self) -> WindowCache {
        let s = self.cfg.seq_len;
        let (heads, k) = match self.weights.blocks.first() {
            Some(b) => (b.mha.wq.len(), b.mha.wq[0].cols()),
            None => (0, 0),
        };
        WindowCache {
            pos: None,
            h_qkv: Mat::zeros(s, self.weights.embed.0.cols()),
            mha: MhaWindowState::new(heads, s, k),
            counters: ReuseCounters::default(),
        }
    }

    /// [`Self::forward`] for consecutive stream windows: reuses the
    /// block-0 prefix rows (embed -> QKV-grid cast -> Q/K/V projections)
    /// and the raw QK^T overlap block that the previous window at
    /// position `cache.pos` already computed.  `pos` is the absolute
    /// sample index of the window's first row; reuse engages iff the
    /// delta to the cached window is positive and smaller than
    /// `seq_len` (same stream, overlapping rows).  Anything else — first
    /// window, hop >= S, a stream restart (backwards position), a
    /// duplicate position — falls back to a full recompute and
    /// repopulates the cache.
    ///
    /// **Bitwise identical** to [`Self::forward`] on the same window:
    /// the zoo models carry no positional encoding, every reused kernel
    /// computes each output row/entry purely from its own input rows,
    /// and softmax-onward always recomputes (softmax is row-global —
    /// fresh columns land in every row).  Pinned below across zoo
    /// models, uniform and mixed plans, and by the coordinator's
    /// streamed-vs-naive suite.
    pub fn forward_incremental(
        &self,
        x: &Mat,
        pos: u64,
        cache: &mut WindowCache,
    ) -> Vec<f32> {
        assert_eq!(x.rows(), self.cfg.seq_len, "bad seq len");
        assert_eq!(x.cols(), self.cfg.input_size, "bad input size");
        let p = &self.plan;
        let w = &*self.weights;
        let c = &*self.compiled;
        let roms = &c.roms;
        let s = self.cfg.seq_len;
        let delta = match cache.pos {
            Some(prev)
                if pos > prev && pos - prev < s as u64 && !w.blocks.is_empty() =>
            {
                (pos - prev) as usize
            }
            _ => 0,
        };
        cache.pos = Some(pos);
        if w.blocks.is_empty() {
            // degenerate (not in the zoo): nothing is cacheable
            cache.counters.windows_full += 1;
            cache.counters.rows_recomputed += s as u64;
            return self.forward(x);
        }
        let heads = w.blocks[0].mha.wq.len() as u64;
        let su = s as u64;
        let bp0 = *p.block(0);
        if delta > 0 {
            // carried rows shift up; only the `delta` fresh tail rows pay
            // the embed dense + QKV-grid cast
            let keep = s - delta;
            shift_rows_up(&mut cache.h_qkv, delta);
            let xf = rows_tail(x, delta);
            let xq = xf.map(|v| p.embed().data.quantize(v));
            let ef = dense_fixed_compiled(&xq, &w.embed.0, &c.embed, Activation::Linear);
            let ef = quantize_mat(&ef, bp0.qkv.data);
            for i in 0..delta {
                cache.h_qkv.row_mut(keep + i).copy_from_slice(ef.row(i));
            }
            let d = delta as u64;
            cache.counters.windows_incremental += 1;
            cache.counters.rows_recomputed += d;
            cache.counters.rows_reused += su - d;
            cache.counters.score_block_hits += heads;
            cache.counters.score_entries_fresh += heads * (su * su - (su - d) * (su - d));
            cache.counters.score_entries_reused += heads * (su - d) * (su - d);
        } else {
            let xq = x.map(|v| p.embed().data.quantize(v));
            let e = dense_fixed_compiled(&xq, &w.embed.0, &c.embed, Activation::Linear);
            cache.h_qkv = quantize_mat(&e, bp0.qkv.data);
            cache.counters.windows_full += 1;
            cache.counters.rows_recomputed += su;
            cache.counters.score_entries_fresh += heads * su * su;
        }
        let resident = (cache.h_qkv.data().len() * 4) as u64 + cache.mha.bytes();
        cache.counters.cache_bytes = cache.counters.cache_bytes.max(resident);
        let mut h = cache.h_qkv.clone();
        let mut fifo_stats = MhaFifoStats::default();
        for (b, blk) in w.blocks.iter().enumerate() {
            let bp = *p.block(b);
            // re-grid cast onto the QKV grid — idempotent for block 0,
            // whose cached rows already live there
            h = quantize_mat(&h, bp.qkv.data);
            let (attn, stats) = if b == 0 {
                let cm = &c.blocks[b].mha;
                let pm = cm.precision();
                mha_fixed_sited_window(
                    &h,
                    &blk.mha,
                    roms,
                    &pm,
                    Some(cm),
                    &mut cache.mha,
                    (delta > 0).then_some(delta),
                )
            } else {
                mha_fixed_sited_compiled(&h, &blk.mha, &c.blocks[b].mha, roms, None)
            };
            fifo_stats.q_high_water = fifo_stats.q_high_water.max(stats.q_high_water);
            fifo_stats.score_high_water =
                fifo_stats.score_high_water.max(stats.score_high_water);
            fifo_stats.out_high_water = fifo_stats.out_high_water.max(stats.out_high_water);
            let sum = h.add(&attn); // residual adder
            h = quantize_mat(&sum, bp.mha_out.data);
            if blk.ln1.is_some() {
                h = quantize_mat(&h, bp.ln1.data); // re-grid cast
                let site = c.blocks[b].ln1.as_ref().expect("compiled LN follows weights");
                for r in 0..h.rows() {
                    layernorm_fixed_row_compiled(h.row_mut(r), site, roms);
                }
            }
            h = quantize_mat(&h, bp.ffn1.data); // re-grid cast
            let y = dense_fixed_compiled(&h, &blk.ffn1.0, &c.blocks[b].ffn1, Activation::Relu);
            let y2_in = quantize_mat(&y, bp.ffn2.data); // re-grid cast
            let y =
                dense_fixed_compiled(&y2_in, &blk.ffn2.0, &c.blocks[b].ffn2, Activation::Linear);
            let sum = h.add(&y); // residual adder
            h = quantize_mat(&sum, bp.ffn2.data);
            if blk.ln2.is_some() {
                h = quantize_mat(&h, bp.ln2.data); // re-grid cast
                let site = c.blocks[b].ln2.as_ref().expect("compiled LN follows weights");
                for r in 0..h.rows() {
                    layernorm_fixed_row_compiled(h.row_mut(r), site, roms);
                }
            }
        }
        self.last_fifo_stats.set(fifo_stats);
        let pool_in = quantize_mat(&h, p.pool().data);
        let pooled = global_average_pool_fixed_compiled(&pool_in, &c.pool);
        let head_in = quantize_mat(&pooled, p.head().data);
        let hid = dense_fixed_compiled(&head_in, &w.head.0, &c.head, Activation::Relu);
        let out_in = quantize_mat(&hid, p.out().data);
        let logits = dense_fixed_compiled(&out_in, &w.out.0, &c.out, Activation::Linear);
        let mut out = logits.row(0).to_vec();
        match self.cfg.final_activation() {
            FinalActivation::Sigmoid => {
                out[0] = sigmoid_fixed(out[0], roms, p.softmax().data);
            }
            FinalActivation::Softmax => {
                softmax_fixed_row_compiled(&mut out, &c.softmax, roms);
            }
        }
        out
    }

    /// The site-graph IR of this engine under `par`: one typed node per
    /// layer site carrying its `FixedSpec` pair, reuse factor, stage
    /// schedule and resource estimate; edges carry the inter-stage
    /// stream shapes.  Built once per design point — [`Self::pipeline`],
    /// [`Self::layer_resources`] and [`Self::synthesize`] are all views
    /// of this graph, as are the static-verifier passes
    /// ([`crate::analysis`]).
    pub fn site_graph(&self, par: &ParallelismPlan) -> SiteGraph {
        self.assert_par(par);
        let fifo = {
            let st = self.last_fifo_stats.get();
            (st.q_high_water > 0).then_some(st)
        };
        SiteGraph::build(&self.cfg, &self.plan, par, fifo)
    }

    /// Top-level pipeline under the paper's layered strategy: inner
    /// layers at the latency strategy, model top level resource-shared.
    /// Every stage is built at its own site's reuse factor (the
    /// [`ParallelismPlan`]) and its own site's precision (the engine's
    /// [`PrecisionPlan`]), so both dials shape the schedule.  This is
    /// the schedule view of [`Self::site_graph`].
    pub fn pipeline(&self, par: &ParallelismPlan) -> PipelineModel {
        self.site_graph(par).pipeline_model()
    }

    /// Per-layer (name, data spec, reuse, resources) estimates — each
    /// layer at its own site's width and its own site's reuse.  The MHA
    /// row reports the QKV spec/reuse (its score/softmax/output
    /// sub-engines are folded into the resource number).  This is the
    /// resource view of [`Self::site_graph`].
    pub fn layer_resources(
        &self,
        par: &ParallelismPlan,
    ) -> Vec<(String, FixedSpec, ReuseFactor, Resources)> {
        self.site_graph(par)
            .nodes
            .into_iter()
            .map(|n| (n.name, n.data, n.reuse, n.resources))
            .collect()
    }

    /// "Synthesize" the design point: latency, interval, clock, resources
    /// — the stand-in for a Vivado run (Tables II-IV / Figures 12-14).
    ///
    /// Latency and interval are *composed from the per-site schedule*
    /// rather than a closed form in the global reuse factor:
    ///
    /// * latency = Σ stage fill depths + the worst per-stage drain
    ///   `(rows-1)·II` (the gating stream — the two-pass MHA drain on
    ///   every zoo model) + the LN overlap penalty set by the slowest LN
    ///   engine + `LATENCY_BASE`;
    /// * interval = the worst per-stage re-arm occupancy
    ///   `rows · ceil(log2(2·II))` + `II_BASE`, capped at the latency.
    ///
    /// Per-stage depth/II are functions of that site's reuse *and*
    /// precision (`dense_stage` et al.), and inter-stage FIFOs are sized
    /// from producer/consumer II mismatch ([`fifo_depth`]).  A uniform
    /// plan at a sub-DSP-port width reproduces the retired global-reuse
    /// closed form *exactly* (golden-tested below), so the calibrated
    /// Tables II-IV fit carries over.
    pub fn synthesize(&self, par: &ParallelismPlan) -> SynthesisReport {
        let graph = self.site_graph(par);
        let s = self.cfg.seq_len as u64;
        let depths: u64 = graph.nodes.iter().map(|n| n.stage.depth).sum();
        // drain of the gating stream: the worst per-stage (rows-1)·II
        let drain = graph
            .nodes
            .iter()
            .map(|n| (n.stage.rows - 1) * n.stage.ii)
            .max()
            .unwrap_or(0);
        // layernorm models pay an extra ~1.5 streaming passes (the two
        // LN instances per block are II-gating but partially overlapped);
        // the penalty is set by the slowest LN engine in the plan
        let ln_extra = if self.cfg.use_layernorm {
            let max_ln = (0..par.num_blocks())
                .map(|b| par.block(b).ln1.get().max(par.block(b).ln2.get()) as u64)
                .max()
                .unwrap_or(0);
            3 * s * max_ln / 2
        } else {
            0
        };
        let latency_cycles = depths + drain + ln_extra + cal::LATENCY_BASE;
        let interval_cycles = graph
            .nodes
            .iter()
            .map(|n| n.stage.rows * cal::interval_multiplier_ii(n.stage.ii))
            .max()
            .unwrap_or(0)
            + cal::II_BASE;
        let interval_cycles = interval_cycles.min(latency_cycles);
        // the most-serialized engine sets achievable clock
        let reuse = par.max_reuse();
        let clk_ns = cal::clock_ns(reuse);
        let fifo = graph.fifo_resources();
        let layers: Vec<LayerReport> = graph
            .nodes
            .into_iter()
            .map(|n| LayerReport {
                latency: n.stage.latency(),
                name: n.name,
                depth: n.stage.depth,
                ii: n.stage.ii,
                rows: n.stage.rows,
                precision: n.data,
                reuse: n.reuse,
                resources: n.resources,
            })
            .collect();
        let total: Resources =
            layers.iter().map(|l| l.resources).sum::<Resources>() + fifo;
        SynthesisReport {
            model: self.cfg.name.clone(),
            quant: self.plan.embed(),
            plan: self.plan.clone(),
            parallelism: par.clone(),
            reuse,
            clk_ns,
            latency_cycles,
            interval_cycles,
            latency_us: latency_cycles as f64 * clk_ns / 1000.0,
            layers,
            fifo,
            total,
        }
    }

    fn assert_par(&self, par: &ParallelismPlan) {
        assert_eq!(
            par.num_blocks(),
            self.cfg.num_blocks,
            "parallelism plan has {} blocks, model '{}' has {}",
            par.num_blocks(),
            self.cfg.name,
            self.cfg.num_blocks
        );
    }
}

fn quantize_mat(m: &Mat, spec: FixedSpec) -> Mat {
    m.map(|v| spec.quantize(v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::lut::Roms;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::{zoo, zoo_model};
    use crate::nn::FloatTransformer;
    use crate::testutil::Gen;

    fn event(cfg: &ModelConfig, seed: u64) -> Mat {
        let mut g = Gen::new(seed);
        Mat::from_vec(
            cfg.seq_len,
            cfg.input_size,
            g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
        )
    }

    /// The pre-plan `FixedTransformer::forward` body, verbatim (PR 2):
    /// the golden reference for the uniform-plan bitwise contract.
    /// Takes weights already uniformly quantized via
    /// `Weights::quantized(quant.data)` — the legacy PTQ step.
    fn legacy_forward(
        cfg: &ModelConfig,
        w: &Weights,
        roms: &Roms,
        quant: QuantConfig,
        x: &Mat,
    ) -> Vec<f32> {
        use super::super::mha::mha_fixed;
        let (data, accum) = (quant.data, quant.accum);
        let xq = x.map(|v| data.quantize(v));
        let mut h = dense_fixed(&xq, &w.embed.0, &w.embed.1, Activation::Linear, data, accum);
        for b in &w.blocks {
            let (attn, _) = mha_fixed(&h, &b.mha, roms, data, accum);
            h = quantize_mat(&h.add(&attn), data);
            if let Some(ln) = &b.ln1 {
                for r in 0..h.rows() {
                    layernorm_fixed_row(h.row_mut(r), &ln.gamma, &ln.beta, roms, data, accum);
                }
            }
            let y = dense_fixed(&h, &b.ffn1.0, &b.ffn1.1, Activation::Relu, data, accum);
            let y = dense_fixed(&y, &b.ffn2.0, &b.ffn2.1, Activation::Linear, data, accum);
            h = quantize_mat(&h.add(&y), data);
            if let Some(ln) = &b.ln2 {
                for r in 0..h.rows() {
                    layernorm_fixed_row(h.row_mut(r), &ln.gamma, &ln.beta, roms, data, accum);
                }
            }
        }
        let pooled = global_average_pool_fixed(&h, data, accum);
        let hid = dense_fixed(&pooled, &w.head.0, &w.head.1, Activation::Relu, data, accum);
        let logits = dense_fixed(&hid, &w.out.0, &w.out.1, Activation::Linear, data, accum);
        let mut out = logits.row(0).to_vec();
        match cfg.final_activation() {
            FinalActivation::Sigmoid => {
                out[0] = sigmoid_fixed(out[0], roms, data);
            }
            FinalActivation::Softmax => {
                softmax_fixed_row(&mut out, roms, data, accum);
            }
        }
        out
    }

    /// The tentpole's golden contract: a *uniform* `PrecisionPlan`
    /// reproduces the legacy global-`QuantConfig` outputs bitwise —
    /// per-event AND batched — across all three zoo models and random
    /// `FixedSpec`s.
    #[test]
    fn prop_uniform_plan_bitwise_matches_legacy_quantconfig_path() {
        use crate::testutil::Prop;
        Prop::new("uniform plan == legacy QuantConfig path").runs(3).check(|g| {
            let roms = Roms::new();
            for m in zoo() {
                let quant = QuantConfig::from_spec(g.fixed_spec_max_width(22));
                let w = synthetic_weights(&m.config, g.u64());
                let legacy_w = w.quantized(quant.data);
                let t = FixedTransformer::with_plan(
                    m.config.clone(),
                    &w,
                    PrecisionPlan::uniform(m.config.num_blocks, quant),
                );
                let events: Vec<Mat> =
                    (0..2).map(|i| event(&m.config, g.u64() ^ i)).collect();
                for x in &events {
                    assert_eq!(
                        t.forward(x),
                        legacy_forward(&m.config, &legacy_w, &roms, quant, x),
                        "{} {quant:?} per-event",
                        m.config.name
                    );
                }
                let refs: Vec<&Mat> = events.iter().collect();
                for (x, got) in events.iter().zip(&t.forward_batch(&refs)) {
                    assert_eq!(
                        got,
                        &legacy_forward(&m.config, &legacy_w, &roms, quant, x),
                        "{} {quant:?} batched",
                        m.config.name
                    );
                }
            }
        });
    }

    /// The PR's acceptance bar: batched HLS execution is bitwise
    /// identical to the per-event path — over random design points,
    /// batch sizes and inputs, every probability must be `==`, not
    /// merely close.
    #[test]
    fn prop_forward_batch_bitwise_identical_to_per_event() {
        use crate::testutil::Prop;
        Prop::new("fixed forward_batch == forward per event").runs(12).check(|g| {
            let m = zoo_model("btag").unwrap(); // smallest zoo model
            let quant = QuantConfig::new(
                g.usize_in(4, 11) as u32,
                g.usize_in(2, 13) as u32,
            );
            let w = synthetic_weights(&m.config, g.u64());
            let t = FixedTransformer::new(m.config.clone(), &w, quant);
            let bsz = g.usize_in(1, 6);
            let events: Vec<Mat> = (0..bsz).map(|i| event(&m.config, g.u64() ^ i as u64)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let batched = t.forward_batch(&refs);
            assert_eq!(batched.len(), bsz);
            for (x, got) in events.iter().zip(&batched) {
                assert_eq!(got, &t.forward(x), "{:?} batch {bsz}", t.quant());
            }
        });
    }

    /// Same bit-exactness bar for *heterogeneous* plans: a mixed plan's
    /// batched path must equal its per-event path exactly.
    #[test]
    fn mixed_plan_forward_batch_bitwise_identical_to_per_event() {
        let mut g = Gen::new(77);
        for m in zoo() {
            let mut plan =
                PrecisionPlan::uniform(m.config.num_blocks, QuantConfig::new(6, 10));
            for (i, site) in plan.site_names().into_iter().enumerate() {
                // vary widths site-by-site, keeping enough int bits to
                // stay numerically alive
                let frac = 6 + (i as u32 % 5);
                let int = 4 + (i as u32 % 3);
                plan.set_data(&site, FixedSpec::new(int + frac, int)).unwrap();
            }
            let w = synthetic_weights(&m.config, 51);
            let t = FixedTransformer::with_plan(m.config.clone(), &w, plan);
            let events: Vec<Mat> = (0..3).map(|_| event(&m.config, g.u64())).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            let batched = t.forward_batch(&refs);
            for (x, got) in events.iter().zip(&batched) {
                assert_eq!(got, &t.forward(x), "{} mixed plan", m.config.name);
            }
        }
    }

    /// The compiled-artifact contract: executing through the prebuilt
    /// mantissa tiles is bitwise identical to re-lifting per call —
    /// over random eligible (and ineligible, wide-grid) specs, all zoo
    /// models, per-event and batched.
    #[test]
    fn prop_compiled_forward_bitwise_matches_per_call_lift() {
        use crate::testutil::Prop;
        Prop::new("compiled forward == per-call lift").runs(4).check(|g| {
            for m in zoo() {
                let quant = QuantConfig::from_spec(g.fixed_spec_max_width(22));
                let w = synthetic_weights(&m.config, g.u64());
                let t = FixedTransformer::new(m.config.clone(), &w, quant);
                let events: Vec<Mat> =
                    (0..2).map(|i| event(&m.config, g.u64() ^ i)).collect();
                for x in &events {
                    assert_eq!(
                        t.forward(x),
                        t.forward_percall(x),
                        "{} {quant:?} per-event",
                        m.config.name
                    );
                }
                let refs: Vec<&Mat> = events.iter().collect();
                assert_eq!(
                    t.forward_batch(&refs),
                    t.forward_batch_percall(&refs),
                    "{} {quant:?} batched",
                    m.config.name
                );
            }
        });
    }

    /// Same contract for heterogeneous plans — every site on its own
    /// grid, compiled vs per-call-lift, per-event and batched.
    #[test]
    fn mixed_plan_compiled_bitwise_matches_per_call_lift() {
        let mut g = Gen::new(91);
        for m in zoo() {
            let mut plan =
                PrecisionPlan::uniform(m.config.num_blocks, QuantConfig::new(6, 10));
            for (i, site) in plan.site_names().into_iter().enumerate() {
                let frac = 6 + (i as u32 % 5);
                let int = 4 + (i as u32 % 3);
                plan.set_data(&site, FixedSpec::new(int + frac, int)).unwrap();
            }
            let w = synthetic_weights(&m.config, 51);
            let t = FixedTransformer::with_plan(m.config.clone(), &w, plan);
            let events: Vec<Mat> = (0..3).map(|_| event(&m.config, g.u64())).collect();
            for x in &events {
                assert_eq!(t.forward(x), t.forward_percall(x), "{}", m.config.name);
            }
            let refs: Vec<&Mat> = events.iter().collect();
            assert_eq!(
                t.forward_batch(&refs),
                t.forward_batch_percall(&refs),
                "{} batched",
                m.config.name
            );
        }
    }

    /// Continuous stream of `n` samples at one model's input width; a
    /// window at absolute sample position `pos` is the naive re-slice.
    fn stream_buf(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<f32> {
        let mut g = Gen::new(seed);
        g.normal_vec(n * cfg.input_size, 1.0)
    }

    fn window_at(cfg: &ModelConfig, buf: &[f32], pos: usize) -> Mat {
        let d = cfg.input_size;
        Mat::from_vec(
            cfg.seq_len,
            d,
            buf[pos * d..(pos + cfg.seq_len) * d].to_vec(),
        )
    }

    /// The incremental tentpole's hard contract: streamed windows served
    /// through [`FixedTransformer::forward_incremental`] are bitwise
    /// identical to a naive full recompute of every window — across all
    /// zoo models, uniform AND mixed plans, and hops S/4, S/2, S and
    /// beyond-S (the no-overlap fallback).
    #[test]
    fn incremental_forward_bitwise_matches_full_across_zoo_plans_and_hops() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 11);
            let uniform = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
            let mut plan =
                PrecisionPlan::uniform(m.config.num_blocks, QuantConfig::new(6, 10));
            for (i, site) in plan.site_names().into_iter().enumerate() {
                let frac = 6 + (i as u32 % 5);
                let int = 4 + (i as u32 % 3);
                plan.set_data(&site, FixedSpec::new(int + frac, int)).unwrap();
            }
            let mixed = FixedTransformer::with_plan(m.config.clone(), &w, plan);
            let s = m.config.seq_len;
            let hops =
                [s.div_ceil(4).max(1), s.div_ceil(2).max(1), s, s + 3];
            for t in [&uniform, &mixed] {
                for hop in hops {
                    let n_win = 4;
                    let buf = stream_buf(&m.config, s + hop * n_win, 9 ^ hop as u64);
                    let mut cache = t.window_cache();
                    for wi in 0..n_win {
                        let pos = wi * hop;
                        let x = window_at(&m.config, &buf, pos);
                        assert_eq!(
                            t.forward_incremental(&x, pos as u64, &mut cache),
                            t.forward(&x),
                            "{} hop {hop} window {wi}",
                            m.config.name
                        );
                    }
                    if hop < s {
                        assert!(cache.counters().any_reuse(), "{} hop {hop}", m.config.name);
                    } else {
                        assert_eq!(cache.counters().windows_incremental, 0);
                    }
                }
            }
        }
    }

    /// Steady-state accounting is *exact*: after the cold window, every
    /// warm window recomputes precisely `hop` prefix rows and
    /// `heads * (S^2 - (S-hop)^2)` fresh score entries per block-0 head.
    #[test]
    fn incremental_steady_state_counters_are_exact() {
        let m = zoo_model("gw").unwrap();
        let w = synthetic_weights(&m.config, 13);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        let s = m.config.seq_len;
        let heads = m.config.num_heads as u64;
        let hop = (s / 4).max(1);
        let warm = 4u64;
        let buf = stream_buf(&m.config, s + hop * warm as usize, 21);
        let mut cache = t.window_cache();
        for wi in 0..=warm as usize {
            let pos = wi * hop;
            t.forward_incremental(&window_at(&m.config, &buf, pos), pos as u64, &mut cache);
        }
        let (su, h, d) = (s as u64, hop as u64, cache.counters());
        assert_eq!(d.windows_full, 1);
        assert_eq!(d.windows_incremental, warm);
        assert_eq!(d.rows_recomputed, su + warm * h);
        assert_eq!(d.rows_reused, warm * (su - h));
        assert_eq!(d.score_block_hits, warm * heads);
        assert_eq!(
            d.score_entries_fresh,
            heads * su * su + warm * heads * (su * su - (su - h) * (su - h))
        );
        assert_eq!(d.score_entries_reused, warm * heads * (su - h) * (su - h));
        // the resident footprint matches the artifact's sizing estimate
        assert_eq!(d.cache_bytes, t.compiled().window_cache_bytes(s));
        assert!(d.cache_bytes > 0);
    }

    /// Restarts and non-monotonic positions fall back to a full
    /// recompute — still bitwise right, never a stale carry.
    #[test]
    fn incremental_stream_restart_falls_back_to_full_recompute() {
        let m = zoo_model("btag").unwrap();
        let w = synthetic_weights(&m.config, 17);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        let mut cache = t.window_cache();
        // unrelated windows at adversarial positions: backwards, equal
        for (seed, pos) in [(1u64, 1000u64), (2, 0), (3, 0), (4, 5000)] {
            let x = event(&m.config, seed);
            let inc = t.forward_incremental(&x, pos, &mut cache);
            assert_eq!(inc, t.forward(&x), "pos {pos}");
        }
        // pos 0 -> 5000 is a forward jump past S: also a full recompute
        assert_eq!(cache.counters().windows_full, 4);
        assert_eq!(cache.counters().windows_incremental, 0);
        // invalidate() forces the fallback even on a friendly delta
        let x = event(&m.config, 9);
        t.forward_incremental(&x, 5001, &mut cache);
        cache.invalidate();
        let y = event(&m.config, 10);
        assert_eq!(t.forward_incremental(&y, 5002, &mut cache), t.forward(&y));
        assert_eq!(cache.counters().windows_incremental, 1); // only the 5000->5001 hop
    }

    /// Clones share the artifact by pointer — the property the
    /// coordinator's replica shards rely on.
    #[test]
    fn engine_clones_share_one_compiled_artifact() {
        let m = zoo_model("gw").unwrap();
        let w = synthetic_weights(&m.config, 5);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        let clones: Vec<FixedTransformer> = (0..3).map(|_| t.clone()).collect();
        for c in &clones {
            assert!(Arc::ptr_eq(t.compiled(), c.compiled()));
        }
        // the artifact records a real footprint and a build time
        assert!(t.compiled().bytes() > 0);
        // an independently built engine does NOT share (build-per-model,
        // not a global cache)
        let t2 = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        assert!(!Arc::ptr_eq(t.compiled(), t2.compiled()));
    }

    #[test]
    fn with_plan_rejects_wrong_block_count() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 5);
        let plan = PrecisionPlan::uniform(m.config.num_blocks + 1, QuantConfig::new(6, 10));
        let res = std::panic::catch_unwind(|| {
            FixedTransformer::with_plan(m.config.clone(), &w, plan)
        });
        assert!(res.is_err());
    }

    #[test]
    fn forward_batch_across_zoo_models_is_bitwise_identical() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 5);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
            let events: Vec<Mat> = (0..4).map(|s| event(&m.config, s)).collect();
            let refs: Vec<&Mat> = events.iter().collect();
            // two batched passes through the same engine must also agree
            // (the scratch arena may not leak state between batches)
            let first = t.forward_batch(&refs);
            let second = t.forward_batch(&refs);
            assert_eq!(first, second, "{}", m.config.name);
            for (x, got) in events.iter().zip(&first) {
                assert_eq!(got, &t.forward(x), "{}", m.config.name);
            }
            // FIFO stats feeding the BRAM model match the per-event path
            let batched_stats = t.last_fifo_stats.get();
            t.forward(&events[0]);
            let ev_stats = t.last_fifo_stats.get();
            assert_eq!(batched_stats.q_high_water, ev_stats.q_high_water);
            assert_eq!(batched_stats.score_high_water, ev_stats.score_high_water);
            assert_eq!(batched_stats.out_high_water, ev_stats.out_high_water);
        }
    }

    #[test]
    fn forward_batch_of_empty_is_empty() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 5);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
        assert!(t.forward_batch(&[]).is_empty());
    }

    #[test]
    fn forward_shapes_and_probabilities() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 5);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 10));
            let p = t.forward(&event(&m.config, 1));
            assert_eq!(p.len(), m.config.output_size);
            assert!(p.iter().all(|&v| (0.0..=1.0001).contains(&v)), "{p:?}");
        }
    }

    #[test]
    fn high_precision_tracks_float_reference() {
        // At 26-bit precision the remaining gap is the LUT-math (ROM
        // softmax through 3 attention blocks), not quantization — the
        // same gap the Python test test_lut_math_close_but_not_identical
        // bounds at 0.5.  Here probabilities must stay within 0.2 and
        // *rank* the same way.
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let fixed = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, 16));
        let float = FloatTransformer::new(m.config.clone(), w);
        for seed in 0..8 {
            let x = event(&m.config, seed);
            let pf = float.probs(&float.forward(&x));
            let pq = fixed.forward(&x);
            for (a, b) in pf.iter().zip(&pq) {
                assert!((a - b).abs() < 0.2, "{a} vs {b} (seed {seed})");
            }
            // same argmax
            let am = |p: &[f32]| {
                p.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            assert_eq!(am(&pf), am(&pq), "argmax differs (seed {seed})");
        }
    }

    #[test]
    fn quantization_error_shrinks_with_frac_bits() {
        // isolates quantization from LUT math: compare two fixed designs
        // against the finest one; error must decrease monotonically-ish
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let reference = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, 20));
        let x = event(&m.config, 3);
        let pr = reference.forward(&x);
        let mut prev_err = f32::MAX;
        for frac in [2u32, 6, 12] {
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(10, frac));
            let p = t.forward(&x);
            let err: f32 = p.iter().zip(&pr).map(|(a, b)| (a - b).abs()).sum();
            assert!(err <= prev_err + 0.02, "frac {frac}: err {err} prev {prev_err}");
            prev_err = err;
        }
        assert!(prev_err < 0.05, "12 frac bits should track 20: {prev_err}");
    }

    #[test]
    fn coarse_precision_diverges_more() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 6);
        let float = FloatTransformer::new(m.config.clone(), w.clone());
        let fine = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(8, 12));
        let coarse = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(4, 2));
        let mut fine_err = 0.0f32;
        let mut coarse_err = 0.0f32;
        for seed in 0..6 {
            let x = event(&m.config, seed);
            let pf = float.probs(&float.forward(&x));
            fine_err += (fine.forward(&x)[0] - pf[0]).abs();
            coarse_err += (coarse.forward(&x)[0] - pf[0]).abs();
        }
        assert!(coarse_err > fine_err, "{coarse_err} vs {fine_err}");
    }

    #[test]
    fn coarsening_one_site_only_perturbs_less_than_coarsening_all() {
        // heterogeneity is a real dial: shaving a single FFN site hurts
        // fidelity less than shaving every site to the same width
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 8);
        let fine = QuantConfig::new(8, 12);
        let reference = FixedTransformer::new(m.config.clone(), &w, fine);
        let mut one_site = PrecisionPlan::uniform(m.config.num_blocks, fine);
        one_site.set_data("block1.ffn1", FixedSpec::new(8, 4)).unwrap();
        let t_one = FixedTransformer::with_plan(m.config.clone(), &w, one_site);
        let t_all = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(4, 4));
        let mut err_one = 0.0f32;
        let mut err_all = 0.0f32;
        for seed in 0..6 {
            let x = event(&m.config, seed);
            let pr = reference.forward(&x);
            err_one += (t_one.forward(&x)[0] - pr[0]).abs();
            err_all += (t_all.forward(&x)[0] - pr[0]).abs();
        }
        assert!(err_one < err_all, "one-site {err_one} vs all-sites {err_all}");
    }

    /// Shorthand: a uniform plan for one model.
    fn upar(cfg: &ModelConfig, r: u32) -> ParallelismPlan {
        ParallelismPlan::uniform(cfg.num_blocks, ReuseFactor(r))
    }

    #[test]
    fn synthesis_report_trends_match_paper() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 7);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let r1 = t.synthesize(&upar(&m.config, 1));
        let r2 = t.synthesize(&upar(&m.config, 2));
        let r4 = t.synthesize(&upar(&m.config, 4));
        // Tables II-IV trends: latency & interval grow with R, clock shrinks
        assert!(r1.latency_cycles < r2.latency_cycles);
        assert!(r2.latency_cycles < r4.latency_cycles);
        assert!(r1.interval_cycles < r2.interval_cycles);
        assert!(r1.clk_ns > r4.clk_ns);
        // Figures 12-14 trends: DSP/FF shrink with R
        assert!(r1.total.dsp > r2.total.dsp);
        assert!(r2.total.dsp >= r4.total.dsp);
        assert!(r1.total.ff > r4.total.ff);
        // BRAM grows with R (array re-partitioning)
        assert!(r4.total.bram18 >= r1.total.bram18);
    }

    #[test]
    fn mixed_plan_synthesis_reports_per_layer_precision_and_saves_resources() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 9);
        let uniform = QuantConfig::new(6, 12); // width 18: above the DSP port
        let t_uni = FixedTransformer::new(m.config.clone(), &w, uniform);
        let mut plan = PrecisionPlan::uniform(m.config.num_blocks, uniform);
        plan.set_data("block0.ffn1", FixedSpec::new(12, 5)).unwrap();
        plan.set_data("block2.mha.qkv", FixedSpec::new(14, 6)).unwrap();
        let t_mix = FixedTransformer::with_plan(m.config.clone(), &w, plan);
        let rep_uni = t_uni.synthesize(&upar(&m.config, 1));
        let rep_mix = t_mix.synthesize(&upar(&m.config, 1));
        // shaved sites show their own spec in the per-layer column
        let spec_of = |rep: &SynthesisReport, name: &str| {
            rep.layers.iter().find(|l| l.name == name).unwrap().precision
        };
        assert_eq!(spec_of(&rep_mix, "block0.ffn1"), FixedSpec::new(12, 5));
        assert_eq!(spec_of(&rep_mix, "block2.mha"), FixedSpec::new(14, 6));
        assert_eq!(spec_of(&rep_mix, "embed"), uniform.data);
        // crossing back under the DSP port width halves that layer's DSPs
        let uni_ffn1 = rep_uni.layers.iter().find(|l| l.name == "block0.ffn1").unwrap();
        let mix_ffn1 = rep_mix.layers.iter().find(|l| l.name == "block0.ffn1").unwrap();
        assert!(mix_ffn1.resources.dsp < uni_ffn1.resources.dsp);
        assert!(rep_mix.total.dsp + rep_mix.total.ff < rep_uni.total.dsp + rep_uni.total.ff);
        // the rendered report carries the precision column
        let text = format!("{rep_mix}");
        assert!(text.contains("ap_fixed<12,5>"), "{text}");
        assert!(text.contains("precision"), "{text}");
    }

    #[test]
    fn interval_formula_matches_tables() {
        // interval = 2*S*ceil(log2(2R)) + II_BASE — exact vs the paper:
        // engine R1 119, btag R1 49, gw R1 212 (II_BASE calibrated)
        for (m, want_r1) in zoo().iter().zip([119u64, 49, 219]) {
            let w = synthetic_weights(&m.config, 8);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
            let rep = t.synthesize(&upar(&m.config, 1));
            assert_eq!(
                rep.interval_cycles,
                2 * m.config.seq_len as u64 + cal::II_BASE,
                "{}",
                m.config.name
            );
            // paper rows within ~5%
            let paper = [119.0, 49.0, 212.0][match m.config.name.as_str() {
                "engine" => 0,
                "btag" => 1,
                _ => 2,
            }];
            let delta = (rep.interval_cycles as f64 - paper).abs() / paper;
            assert!(delta < 0.06, "{}: {} vs paper {paper}", m.config.name, rep.interval_cycles);
            let _ = want_r1;
        }
    }

    /// The retired closed-form `synthesize(ReuseFactor)` of the
    /// pre-ParallelismPlan code, verbatim: stage depths from the old
    /// (precision-blind) builders, latency/interval from the fitted
    /// global-R formulas of `calibration.rs`.  The golden reference for
    /// the schedule-derived path.
    fn legacy_closed_form(cfg: &ModelConfig, r: ReuseFactor) -> (u64, u64) {
        use super::super::pipeline::adder_tree_depth;
        let rg = r.get() as u64;
        let rds = |inner: usize| cal::reuse_depth_growth(inner, r);
        let dense_depth =
            |n_in: usize| adder_tree_depth(n_in as u64) + cal::DENSE_DEPTH_EXTRA + rds(n_in);
        let ln_depth = || {
            cal::LAYERNORM_DEPTH_BASE
                + adder_tree_depth(cfg.d_model as u64)
                + rds(cfg.d_model) / 2
        };
        let s = cfg.seq_len as u64;
        let mut depths = dense_depth(cfg.input_size.max(2)); // embed
        for _ in 0..cfg.num_blocks {
            // MHA fill = qkv_proj + score stages (apply-V/concat drain
            // concurrently: occupancy, not fill)
            depths += dense_depth(cfg.d_model);
            depths += cal::SOFTMAX_DEPTH_BASE
                + adder_tree_depth(s)
                + rds(cfg.seq_len) / 2
                + adder_tree_depth(cfg.head_dim as u64)
                + cal::DENSE_DEPTH_EXTRA;
            if cfg.use_layernorm {
                depths += ln_depth();
            }
            depths += dense_depth(cfg.d_model); // ffn1
            depths += dense_depth(cfg.ffn_dim); // ffn2
            if cfg.use_layernorm {
                depths += ln_depth();
            }
        }
        depths += adder_tree_depth(s) + 2; // pool
        depths += dense_depth(cfg.d_model); // head
        depths += dense_depth(cfg.head_hidden); // out
        let ln_extra = if cfg.use_layernorm { 3 * s * rg / 2 } else { 0 };
        let latency = depths + (2 * s - 1) * rg + ln_extra + cal::LATENCY_BASE;
        let interval = (2 * s * cal::interval_multiplier(r) + cal::II_BASE).min(latency);
        (latency, interval)
    }

    /// The tentpole's golden contract: a *uniform* `ParallelismPlan(R)`
    /// reproduces the retired `synthesize(ReuseFactor(R))` numbers for
    /// all three zoo models — exactly at sub-DSP-port widths, and within
    /// the existing calibration tolerance where the schedule now charges
    /// the DSP-port cascade registers the closed form ignored (width-18
    /// b-tagging); interval, clock and resources stay exact everywhere.
    #[test]
    fn golden_uniform_plan_reproduces_retired_closed_form() {
        for m in zoo() {
            let w = synthetic_weights(&m.config, 7);
            for (quant, exact) in [
                (QuantConfig::new(6, 8), true),   // width 14: below the port
                (QuantConfig::new(10, 8), false), // width 18: pays cascade fill
            ] {
                let t = FixedTransformer::new(m.config.clone(), &w, quant);
                for r in [1u32, 2, 4, 8] {
                    let rf = ReuseFactor(r);
                    let rep = t.synthesize(&upar(&m.config, r));
                    let (legacy_lat, legacy_ii) = legacy_closed_form(&m.config, rf);
                    let tag = format!("{} {:?} R{r}", m.config.name, quant.data);
                    assert_eq!(rep.interval_cycles, legacy_ii, "{tag} interval");
                    assert_eq!(rep.clk_ns, cal::clock_ns(rf), "{tag} clock");
                    // uniform plans: no II mismatch, no stream FIFOs —
                    // resource totals are exactly the per-layer sums of
                    // the unchanged resource model
                    assert_eq!(rep.fifo, Resources::ZERO, "{tag} fifo");
                    assert_eq!(
                        rep.total,
                        rep.layers.iter().map(|l| l.resources).sum::<Resources>(),
                        "{tag} totals"
                    );
                    if exact {
                        assert_eq!(rep.latency_cycles, legacy_lat, "{tag} latency");
                    } else {
                        assert!(
                            rep.latency_cycles >= legacy_lat,
                            "{tag}: cascade registers only ever add fill"
                        );
                        let delta = (rep.latency_cycles - legacy_lat) as f64
                            / legacy_lat as f64;
                        assert!(
                            delta < 0.10,
                            "{tag}: {} vs retired {legacy_lat} (+{:.1}%)",
                            rep.latency_cycles,
                            100.0 * delta
                        );
                    }
                }
            }
        }
    }

    /// Schedule monotonicity in per-site reuse (the satellite property):
    /// raising any single site's reuse factor never reduces modeled
    /// latency or interval cycles.
    #[test]
    fn prop_schedule_monotone_in_per_site_reuse() {
        use crate::testutil::Prop;
        Prop::new("schedule monotone in per-site reuse").runs(60).check(|g| {
            let zoo = zoo();
            let m = &zoo[g.usize_in(0, zoo.len())];
            let w = synthetic_weights(&m.config, 5);
            let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
            let mut par = upar(&m.config, [1u32, 2, 4][g.usize_in(0, 3)]);
            // randomize a few sites first so monotonicity holds from
            // heterogeneous starting points too
            let sites = par.site_names();
            for _ in 0..g.usize_in(0, 4) {
                let site = &sites[g.usize_in(0, sites.len())];
                par.set(site, ReuseFactor([1u32, 2, 4, 8][g.usize_in(0, 4)])).unwrap();
            }
            let base = t.synthesize(&par);
            let site = &sites[g.usize_in(0, sites.len())];
            let cur = par.get(site).unwrap().get();
            let mut bumped = par.clone();
            bumped.set(site, ReuseFactor(cur * 2)).unwrap();
            let after = t.synthesize(&bumped);
            assert!(
                after.latency_cycles >= base.latency_cycles,
                "{site} x2: latency {} -> {}",
                base.latency_cycles,
                after.latency_cycles
            );
            assert!(
                after.interval_cycles >= base.interval_cycles,
                "{site} x2: interval {} -> {}",
                base.interval_cycles,
                after.interval_cycles
            );
        });
    }

    /// Heterogeneous reuse has schedule-visible structure: relaxing a
    /// non-gating site (the adder-only pool engine to R2) is latency-
    /// free, while relaxing the gating MHA path is not.
    #[test]
    fn relaxing_pool_is_latency_free_but_relaxing_mha_is_not() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 9);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let base = t.synthesize(&upar(&m.config, 1));
        let mut pool2 = upar(&m.config, 1);
        pool2.set("pool", ReuseFactor(2)).unwrap();
        let rep_pool = t.synthesize(&pool2);
        assert_eq!(rep_pool.latency_cycles, base.latency_cycles);
        assert_eq!(rep_pool.interval_cycles, base.interval_cycles);
        // pool halves its adders: strictly cheaper at the same schedule
        assert!(rep_pool.total.ff < base.total.ff);
        assert_eq!(rep_pool.total.dsp, base.total.dsp);
        let mut mha2 = upar(&m.config, 1);
        mha2.set("block0.mha.qkv", ReuseFactor(2)).unwrap();
        let rep_mha = t.synthesize(&mha2);
        assert!(rep_mha.latency_cycles > base.latency_cycles, "MHA gates the drain");
        assert!(rep_mha.interval_cycles > base.interval_cycles);
    }

    /// Heterogeneous reuse also *pays* where it converts rates: a slow
    /// consumer behind a fast producer needs a real FIFO, surfaced in
    /// the report's `fifo` term.
    #[test]
    fn ii_mismatch_charges_stream_fifo_bram() {
        let m = zoo_model("btag").unwrap();
        let w = synthetic_weights(&m.config, 9);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let mut par = upar(&m.config, 1);
        // ffn1 runs at R8 behind an R1 mha/ln chain: its input stream
        // backs up and must buffer
        par.set("block0.ffn1", ReuseFactor(8)).unwrap();
        let rep = t.synthesize(&par);
        assert!(rep.fifo.bram18 > 0, "II mismatch must charge FIFO BRAM");
        assert_eq!(
            rep.total.bram18,
            rep.layers.iter().map(|l| l.resources.bram18).sum::<u64>() + rep.fifo.bram18
        );
    }

    /// The dataflow-totality satellite, end to end: a degenerate
    /// zero-block config must synthesize (no panic) with a sane report.
    #[test]
    fn zero_block_degenerate_config_synthesizes() {
        let mut cfg = zoo_model("engine").unwrap().config;
        cfg.name = "degenerate".into();
        cfg.num_blocks = 0;
        let w = synthetic_weights(&cfg, 3);
        let t = FixedTransformer::new(cfg.clone(), &w, QuantConfig::new(6, 8));
        let rep = t.synthesize(&ParallelismPlan::uniform(0, ReuseFactor(2)));
        // embed, pool, head, out — no blocks
        assert_eq!(rep.layers.len(), 4);
        assert!(rep.latency_cycles > 0);
        assert!(rep.interval_cycles <= rep.latency_cycles);
        assert!(rep.total.dsp > 0);
        // the zero-block forward also still runs
        let p = t.forward(&event(&cfg, 1));
        assert_eq!(p.len(), cfg.output_size);
    }

    #[test]
    fn synthesize_rejects_wrong_block_count_plan() {
        let m = zoo_model("engine").unwrap();
        let w = synthetic_weights(&m.config, 5);
        let t = FixedTransformer::new(m.config.clone(), &w, QuantConfig::new(6, 8));
        let bad = ParallelismPlan::uniform(m.config.num_blocks + 1, ReuseFactor(1));
        assert!(std::panic::catch_unwind(|| t.synthesize(&bad)).is_err());
    }
}
