//! Per-layer heterogeneous precision: the [`PrecisionPlan`] that replaces
//! the single global `QuantConfig` as the quantization authority of
//! [`super::FixedTransformer`].
//!
//! The paper sweeps one uniform `ap_fixed<W,I>` across the whole model
//! (§VI-A), but hls4ml itself configures precision **per layer**
//! (`granularity="name"`), and the follow-up work (Laatu et al.,
//! sub-µs jet tagging; Duarte et al. 1804.06913) gets its resource wins
//! from per-layer bitwidths.  A plan maps every layer *site* of the
//! model — `embed`, per-block `mha.qkv` / `mha.out` / `ln1` / `ln2` /
//! `ffn1` / `ffn2`, `pool`, `head`, `out`, and the shared `softmax` LUT
//! I/O — to its own data/accumulator [`FixedSpec`] pair.
//!
//! Contract: a *uniform* plan (every site at the same pair) is bitwise
//! identical to the legacy global-`QuantConfig` path, per event and
//! batched — pinned by the golden tests in `transformer.rs`.
//!
//! Plans serialize to a line-oriented text format (one `site
//! ap_fixed<W,I>` per line, `#` comments) loadable via
//! `--precision-plan` on `repro serve` / `repro synth` /
//! `repro mixed-precision`; see README "Precision plans".

use std::collections::BTreeMap;

use super::calibration::int_bits_for_range;
use super::planfile::apply_plan_lines;
use crate::fixed::spec::ACCUM_INT_BITS;
use crate::fixed::FixedSpec;
use crate::models::config::ModelConfig;
use crate::models::weights::{BlockWeights, LnWeights, MhaWeights, Weights};
use crate::nn::tensor::Mat;

/// Data/accumulator pair of one design point or one plan site
/// (paper §VI-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    /// Data type of weights and activations.
    pub data: FixedSpec,
    /// Accumulator type (10 integer bits, fractional width follows data).
    pub accum: FixedSpec,
}

impl QuantConfig {
    /// Paper convention: `ap_fixed<I + frac, I>` data with the 10-int-bit
    /// accumulator at the same fractional width.
    pub fn new(integer_bits: u32, frac_bits: u32) -> Self {
        let data = FixedSpec::new(integer_bits + frac_bits, integer_bits);
        Self { data, accum: data.accum() }
    }

    pub fn from_spec(data: FixedSpec) -> Self {
        Self { data, accum: data.accum() }
    }
}

/// Per-site pairs of one transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPrecision {
    /// Stage-1 Q/K/V projections (weights, activations, score MACs).
    pub qkv: QuantConfig,
    /// Stage-3/4 output path: apply-V, concat, Wo, the residual adder.
    pub mha_out: QuantConfig,
    pub ln1: QuantConfig,
    pub ln2: QuantConfig,
    pub ffn1: QuantConfig,
    pub ffn2: QuantConfig,
}

impl BlockPrecision {
    pub fn uniform(q: QuantConfig) -> Self {
        Self { qkv: q, mha_out: q, ln1: q, ln2: q, ffn1: q, ffn2: q }
    }

    /// The site triple one MHA engine consumes.
    pub fn mha(&self, softmax: QuantConfig) -> MhaPrecision {
        MhaPrecision { qkv: self.qkv, out: self.mha_out, softmax }
    }
}

/// Site specs threaded through one MHA engine: stage-1 projections,
/// the score-softmax LUT I/O, and the stage-3/4 output path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MhaPrecision {
    pub qkv: QuantConfig,
    pub out: QuantConfig,
    pub softmax: QuantConfig,
}

impl MhaPrecision {
    pub fn uniform(q: QuantConfig) -> Self {
        Self { qkv: q, out: q, softmax: q }
    }
}

/// Resolved site address: which field of the plan a site name denotes.
#[derive(Clone, Copy)]
enum SiteRef {
    Embed,
    Pool,
    Head,
    Out,
    Softmax,
    Block(usize, BlockField),
}

#[derive(Clone, Copy)]
enum BlockField {
    Qkv,
    MhaOut,
    Ln1,
    Ln2,
    Ffn1,
    Ffn2,
}

/// Typed map from layer site to its `FixedSpec` data/accum pair — the
/// quantization authority of a [`super::FixedTransformer`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrecisionPlan {
    embed: QuantConfig,
    blocks: Vec<BlockPrecision>,
    pool: QuantConfig,
    head: QuantConfig,
    out: QuantConfig,
    /// Softmax/sigmoid LUT I/O: MHA score rows in, probabilities out,
    /// plus the final classifier nonlinearity.  One shared site (the
    /// ROMs are shared hardware).
    softmax: QuantConfig,
}

impl PrecisionPlan {
    /// Every site at the same pair — the legacy `QuantConfig` behavior.
    pub fn uniform(num_blocks: usize, q: QuantConfig) -> Self {
        Self {
            embed: q,
            blocks: vec![BlockPrecision::uniform(q); num_blocks],
            pool: q,
            head: q,
            out: q,
            softmax: q,
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn embed(&self) -> QuantConfig {
        self.embed
    }

    pub fn pool(&self) -> QuantConfig {
        self.pool
    }

    pub fn head(&self) -> QuantConfig {
        self.head
    }

    pub fn out(&self) -> QuantConfig {
        self.out
    }

    pub fn softmax(&self) -> QuantConfig {
        self.softmax
    }

    pub fn block(&self, b: usize) -> &BlockPrecision {
        &self.blocks[b]
    }

    /// Canonical site order (execution order; also the serialization and
    /// search order).
    pub fn site_names(&self) -> Vec<String> {
        crate::ir::canonical_site_names(self.blocks.len())
    }

    /// The one place site names are parsed: both [`Self::get`] and the
    /// mutable slot lookup resolve through here, so the name grammar
    /// cannot diverge between the two.
    fn resolve(&self, site: &str) -> Option<SiteRef> {
        match site {
            "embed" => Some(SiteRef::Embed),
            "pool" => Some(SiteRef::Pool),
            "head" => Some(SiteRef::Head),
            "out" => Some(SiteRef::Out),
            "softmax" => Some(SiteRef::Softmax),
            _ => {
                let rest = site.strip_prefix("block")?;
                let (idx, field) = rest.split_once('.')?;
                let b: usize = idx.parse().ok()?;
                if b >= self.blocks.len() {
                    return None;
                }
                let field = match field {
                    "mha.qkv" => BlockField::Qkv,
                    "mha.out" => BlockField::MhaOut,
                    "ln1" => BlockField::Ln1,
                    "ln2" => BlockField::Ln2,
                    "ffn1" => BlockField::Ffn1,
                    "ffn2" => BlockField::Ffn2,
                    _ => return None,
                };
                Some(SiteRef::Block(b, field))
            }
        }
    }

    fn slot_mut(&mut self, site: &str) -> Option<&mut QuantConfig> {
        Some(match self.resolve(site)? {
            SiteRef::Embed => &mut self.embed,
            SiteRef::Pool => &mut self.pool,
            SiteRef::Head => &mut self.head,
            SiteRef::Out => &mut self.out,
            SiteRef::Softmax => &mut self.softmax,
            SiteRef::Block(b, f) => {
                let bp = &mut self.blocks[b];
                match f {
                    BlockField::Qkv => &mut bp.qkv,
                    BlockField::MhaOut => &mut bp.mha_out,
                    BlockField::Ln1 => &mut bp.ln1,
                    BlockField::Ln2 => &mut bp.ln2,
                    BlockField::Ffn1 => &mut bp.ffn1,
                    BlockField::Ffn2 => &mut bp.ffn2,
                }
            }
        })
    }

    pub fn get(&self, site: &str) -> Option<QuantConfig> {
        Some(match self.resolve(site)? {
            SiteRef::Embed => self.embed,
            SiteRef::Pool => self.pool,
            SiteRef::Head => self.head,
            SiteRef::Out => self.out,
            SiteRef::Softmax => self.softmax,
            SiteRef::Block(b, f) => {
                let bp = &self.blocks[b];
                match f {
                    BlockField::Qkv => bp.qkv,
                    BlockField::MhaOut => bp.mha_out,
                    BlockField::Ln1 => bp.ln1,
                    BlockField::Ln2 => bp.ln2,
                    BlockField::Ffn1 => bp.ffn1,
                    BlockField::Ffn2 => bp.ffn2,
                }
            }
        })
    }

    /// Assign one site; `Err` names the unknown site (the CLI contract:
    /// one line, naming the offending entry).
    pub fn set(&mut self, site: &str, q: QuantConfig) -> Result<(), String> {
        let n = self.blocks.len();
        match self.slot_mut(site) {
            Some(slot) => {
                *slot = q;
                Ok(())
            }
            None => Err(format!(
                "unknown site '{site}' (model has {n} blocks; sites: embed, \
                 blockN.mha.qkv, blockN.mha.out, blockN.ln1, blockN.ffn1, \
                 blockN.ffn2, blockN.ln2, pool, head, out, softmax)"
            )),
        }
    }

    /// Assign a data spec, deriving the accumulator by the paper's
    /// convention (`FixedSpec::accum`).  Fallible end to end: a data
    /// spec whose fractional width pushes the derived accumulator past
    /// 48 bits is a one-line `Err`, never a panic (plan-file input
    /// reaches here).
    pub fn set_data(&mut self, site: &str, data: FixedSpec) -> Result<(), String> {
        let accum = derive_accum(data)?;
        self.set(site, QuantConfig { data, accum })
    }

    /// `Some(pair)` iff every site carries the same pair.
    pub fn is_uniform(&self) -> Option<QuantConfig> {
        let q = self.embed;
        let all = self
            .site_names()
            .iter()
            .all(|s| self.get(s) == Some(q));
        all.then_some(q)
    }

    /// One-line description for reports: the single spec when uniform,
    /// a site count otherwise.
    pub fn summary(&self) -> String {
        match self.is_uniform() {
            Some(q) => format!("{}", q.data),
            None => {
                let (lo, hi) = self
                    .site_names()
                    .iter()
                    .filter_map(|s| self.get(s))
                    .fold((u32::MAX, 0u32), |(lo, hi), q| {
                        (lo.min(q.data.width()), hi.max(q.data.width()))
                    });
                format!("mixed<{lo}..{hi}b,{} sites>", self.site_names().len())
            }
        }
    }

    /// Serialize to the plan text format: one `site ap_fixed<W,I>` line
    /// per site (plus ` accum=ap_fixed<W,I>` when the accumulator is not
    /// the derived `FixedSpec::accum` pair), `#` starting a comment.
    pub fn serialize(&self) -> String {
        let mut s = String::from("# precision plan: site -> ap_fixed<W,I> data spec\n");
        for site in self.site_names() {
            let q = self.get(&site).expect("site_names yields known sites");
            s.push_str(&format!("{site} {}", q.data));
            // write the accumulator only when it is not the derived
            // default (derive_accum, not FixedSpec::accum: the latter
            // panics on wide data specs carrying an explicit accum)
            if derive_accum(q.data) != Ok(q.accum) {
                s.push_str(&format!(" accum={}", q.accum));
            }
            s.push('\n');
        }
        s
    }

    /// Apply plan-text overrides onto this plan.  Unknown sites and
    /// malformed specs produce a one-line error naming the offending
    /// entry and its line number.  Line handling (comments, blanks, the
    /// `plan line N:` prefix) is the shared [`apply_plan_lines`]
    /// skeleton, so this grammar and the `ParallelismPlan` grammar
    /// cannot drift apart.
    pub fn apply_overrides(&mut self, text: &str) -> Result<(), String> {
        apply_plan_lines(text, |site, rest| {
            let (spec_tok, accum_tok) = match rest {
                [] => {
                    return Err(format!(
                        "site '{site}' is missing its ap_fixed<W,I> spec"
                    ));
                }
                [spec] => (*spec, None),
                [spec, accum] => (*spec, Some(*accum)),
                [_, _, tr, ..] => {
                    return Err(format!("site '{site}': trailing token '{tr}'"));
                }
            };
            let data: FixedSpec = spec_tok
                .parse()
                .map_err(|e| format!("site '{site}': {e}"))?;
            let accum = match accum_tok {
                Some(extra) => {
                    let a = extra.strip_prefix("accum=").ok_or_else(|| {
                        format!(
                            "site '{site}': unexpected token '{extra}' \
                             (expected accum=ap_fixed<W,I>)"
                        )
                    })?;
                    a.parse().map_err(|e| format!("site '{site}': {e}"))?
                }
                None => derive_accum(data).map_err(|e| format!("site '{site}': {e}"))?,
            };
            self.set(site, QuantConfig { data, accum })
        })
    }
}

/// The paper-convention accumulator for a data spec, as a `Result`
/// instead of `FixedSpec::accum`'s panic: `ACCUM_INT_BITS + frac` must
/// stay within the 48-bit `ap_fixed` ceiling, and untrusted plan-file
/// specs can violate that (e.g. `ap_fixed<48,2>`).
fn derive_accum(data: FixedSpec) -> Result<FixedSpec, String> {
    FixedSpec::try_new(ACCUM_INT_BITS + data.frac(), ACCUM_INT_BITS).ok_or_else(|| {
        format!(
            "{data} has too many fractional bits for the {ACCUM_INT_BITS}-int-bit \
             accumulator (max {} fractional bits; or give accum=ap_fixed<W,I> explicitly)",
            48 - ACCUM_INT_BITS
        )
    })
}

/// Read + apply a `--precision-plan` file over a uniform base plan.
/// Errors are one line naming the file and the offending entry.
pub fn load_plan_file(
    path: &str,
    num_blocks: usize,
    base: QuantConfig,
) -> Result<PrecisionPlan, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("--precision-plan {path}: {e}"))?;
    let mut plan = PrecisionPlan::uniform(num_blocks, base);
    plan.apply_overrides(&text)
        .map_err(|e| format!("--precision-plan {path}: {e}"))?;
    Ok(plan)
}

/// PTQ onto heterogeneous grids: every tensor quantized at its own
/// site's data spec (the per-site twin of [`Weights::quantized`] — with
/// a uniform plan the two agree exactly).
pub fn quantize_weights_sited(w: &Weights, plan: &PrecisionPlan) -> Weights {
    assert_eq!(w.blocks.len(), plan.num_blocks(), "plan/block count mismatch");
    let qm = |m: &Mat, s: FixedSpec| m.map(|x| s.quantize(x));
    let qv = |v: &[f32], s: FixedSpec| v.iter().map(|&x| s.quantize(x)).collect::<Vec<f32>>();
    Weights {
        embed: (qm(&w.embed.0, plan.embed().data), qv(&w.embed.1, plan.embed().data)),
        blocks: w
            .blocks
            .iter()
            .enumerate()
            .map(|(b, blk)| {
                let bp = *plan.block(b);
                BlockWeights {
                    mha: MhaWeights {
                        wq: blk.mha.wq.iter().map(|m| qm(m, bp.qkv.data)).collect(),
                        bq: blk.mha.bq.iter().map(|v| qv(v, bp.qkv.data)).collect(),
                        wk: blk.mha.wk.iter().map(|m| qm(m, bp.qkv.data)).collect(),
                        bk: blk.mha.bk.iter().map(|v| qv(v, bp.qkv.data)).collect(),
                        wv: blk.mha.wv.iter().map(|m| qm(m, bp.qkv.data)).collect(),
                        bv: blk.mha.bv.iter().map(|v| qv(v, bp.qkv.data)).collect(),
                        wo: qm(&blk.mha.wo, bp.mha_out.data),
                        bo: qv(&blk.mha.bo, bp.mha_out.data),
                    },
                    ln1: blk.ln1.as_ref().map(|l| LnWeights {
                        gamma: qv(&l.gamma, bp.ln1.data),
                        beta: qv(&l.beta, bp.ln1.data),
                    }),
                    ffn1: (qm(&blk.ffn1.0, bp.ffn1.data), qv(&blk.ffn1.1, bp.ffn1.data)),
                    ffn2: (qm(&blk.ffn2.0, bp.ffn2.data), qv(&blk.ffn2.1, bp.ffn2.data)),
                    ln2: blk.ln2.as_ref().map(|l| LnWeights {
                        gamma: qv(&l.gamma, bp.ln2.data),
                        beta: qv(&l.beta, bp.ln2.data),
                    }),
                }
            })
            .collect(),
        head: (qm(&w.head.0, plan.head().data), qv(&w.head.1, plan.head().data)),
        out: (qm(&w.out.0, plan.out().data), qv(&w.out.1, plan.out().data)),
    }
}

/// Max-|value| profile per site, filled by
/// [`super::FixedTransformer::forward_recorded`] during calibration.
#[derive(Clone, Debug, Default)]
pub struct RangeProfile {
    max_abs: BTreeMap<String, f64>,
}

impl RangeProfile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, site: &str, values: &[f32]) {
        let mut m = self.max_abs.get(site).copied().unwrap_or(0.0);
        for &v in values {
            let a = (v as f64).abs();
            if a.is_finite() && a > m {
                m = a;
            }
        }
        self.max_abs.insert(site.to_string(), m);
    }

    pub fn max_abs(&self, site: &str) -> Option<f64> {
        self.max_abs.get(site).copied()
    }

    pub fn sites(&self) -> impl Iterator<Item = (&str, f64)> {
        self.max_abs.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

/// Fold per-site *weight* magnitudes into a profile: weights live on the
/// same data grid as the activations they feed, so the grid's integer
/// width must cover both.
pub fn record_weight_ranges(prof: &mut RangeProfile, w: &Weights) {
    let mats = |p: &mut RangeProfile, site: &str, m: &Mat, b: &[f32]| {
        p.record(site, m.data());
        p.record(site, b);
    };
    mats(prof, "embed", &w.embed.0, &w.embed.1);
    for (b, blk) in w.blocks.iter().enumerate() {
        let qkv = format!("block{b}.mha.qkv");
        for h in 0..blk.mha.wq.len() {
            mats(prof, &qkv, &blk.mha.wq[h], &blk.mha.bq[h]);
            mats(prof, &qkv, &blk.mha.wk[h], &blk.mha.bk[h]);
            mats(prof, &qkv, &blk.mha.wv[h], &blk.mha.bv[h]);
        }
        mats(prof, &format!("block{b}.mha.out"), &blk.mha.wo, &blk.mha.bo);
        if let Some(l) = &blk.ln1 {
            prof.record(&format!("block{b}.ln1"), &l.gamma);
            prof.record(&format!("block{b}.ln1"), &l.beta);
        }
        mats(prof, &format!("block{b}.ffn1"), &blk.ffn1.0, &blk.ffn1.1);
        mats(prof, &format!("block{b}.ffn2"), &blk.ffn2.0, &blk.ffn2.1);
        if let Some(l) = &blk.ln2 {
            prof.record(&format!("block{b}.ln2"), &l.gamma);
            prof.record(&format!("block{b}.ln2"), &l.beta);
        }
    }
    mats(prof, "head", &w.head.0, &w.head.1);
    mats(prof, "out", &w.out.0, &w.out.1);
}

/// Calibrate a per-site plan from observed ranges: run the profiling
/// forward at a wide reference precision over `events`, fold in the
/// weight magnitudes, then give every site the smallest integer width
/// covering its range (`calibration::int_bits_for_range`) at
/// `frac_bits` fractional bits.
pub fn calibrate_plan(
    cfg: &ModelConfig,
    float_weights: &Weights,
    events: &[Mat],
    frac_bits: u32,
) -> PrecisionPlan {
    assert!(frac_bits <= 24, "frac_bits {frac_bits} out of range");
    let wide = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(12, 18));
    let t = super::FixedTransformer::with_plan(cfg.clone(), float_weights, wide);
    let mut prof = RangeProfile::new();
    for x in events {
        t.forward_recorded(x, Some(&mut prof));
    }
    record_weight_ranges(&mut prof, float_weights);
    let mut plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, frac_bits));
    for site in plan.site_names() {
        let max_abs = prof.max_abs(&site).unwrap_or(1.0);
        let int_bits = int_bits_for_range(max_abs);
        plan.set_data(&site, FixedSpec::new(int_bits + frac_bits, int_bits))
            .expect("site_names yields known sites");
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo_model;
    use crate::testutil::Gen;

    #[test]
    fn uniform_plan_reports_uniform() {
        let q = QuantConfig::new(6, 10);
        let p = PrecisionPlan::uniform(3, q);
        assert_eq!(p.is_uniform(), Some(q));
        assert_eq!(p.summary(), "ap_fixed<16,6>");
        assert_eq!(p.site_names().len(), 1 + 3 * 6 + 4);
    }

    #[test]
    fn set_and_get_every_site() {
        let mut p = PrecisionPlan::uniform(2, QuantConfig::new(6, 10));
        for (i, site) in p.site_names().into_iter().enumerate() {
            let spec = FixedSpec::new(8 + (i as u32 % 4), 4);
            p.set_data(&site, spec).unwrap();
            assert_eq!(p.get(&site).unwrap().data, spec, "{site}");
            assert_eq!(p.get(&site).unwrap().accum, spec.accum(), "{site}");
        }
        assert!(p.is_uniform().is_none());
        assert!(p.summary().starts_with("mixed<"));
    }

    #[test]
    fn unknown_sites_rejected_with_named_entry() {
        let mut p = PrecisionPlan::uniform(2, QuantConfig::new(6, 10));
        for bad in ["block2.mha.qkv", "block0.mha.wat", "blurb", "blocknope.ln1"] {
            let err = p.set_data(bad, FixedSpec::new(8, 4)).unwrap_err();
            assert!(err.contains(bad), "{err}");
            assert!(!err.contains('\n'), "one line: {err}");
        }
    }

    #[test]
    fn serialize_round_trips_through_overrides() {
        let mut g = Gen::new(42);
        for _ in 0..20 {
            let mut plan = PrecisionPlan::uniform(3, QuantConfig::new(6, 10));
            for site in plan.site_names() {
                plan.set_data(&site, g.fixed_spec_max_width(20)).unwrap();
            }
            let text = plan.serialize();
            let mut rt = PrecisionPlan::uniform(3, QuantConfig::new(4, 4));
            rt.apply_overrides(&text).unwrap();
            assert_eq!(rt, plan, "round trip failed for:\n{text}");
        }
    }

    #[test]
    fn overrides_accept_comments_and_explicit_accum() {
        let mut p = PrecisionPlan::uniform(1, QuantConfig::new(6, 10));
        let text = "# heterogeneous working point\n\
                    embed ap_fixed<12,4>   # tight input\n\
                    \n\
                    block0.ffn1 ap_fixed<10,3> accum=ap_fixed<20,12>\n";
        p.apply_overrides(text).unwrap();
        assert_eq!(p.embed().data, FixedSpec::new(12, 4));
        assert_eq!(p.get("block0.ffn1").unwrap().accum, FixedSpec::new(20, 12));
        assert!(p.serialize().contains("accum=ap_fixed<20,12>"));
    }

    #[test]
    fn wide_frac_spec_is_error_not_panic() {
        // ap_fixed<48,2> parses as a valid data spec but its derived
        // accumulator would be ap_fixed<56,10> — beyond the 48-bit
        // ceiling.  Must be a one-line Err, never a FixedSpec panic.
        let mut p = PrecisionPlan::uniform(1, QuantConfig::new(6, 10));
        let err = p.apply_overrides("embed ap_fixed<48,2>").unwrap_err();
        assert!(err.contains("embed"), "{err}");
        assert!(err.contains("fractional"), "{err}");
        assert!(!err.contains('\n'), "one line: {err}");
        // an explicit in-range accumulator makes the same data spec legal
        p.apply_overrides("embed ap_fixed<48,2> accum=ap_fixed<48,10>").unwrap();
        assert_eq!(p.embed().accum, FixedSpec::new(48, 10));
        // and such a plan serializes (writing the accum) and round-trips
        let text = p.serialize();
        assert!(text.contains("accum=ap_fixed<48,10>"), "{text}");
        let mut rt = PrecisionPlan::uniform(1, QuantConfig::new(6, 10));
        rt.apply_overrides(&text).unwrap();
        assert_eq!(rt, p);
        // set_data is guarded the same way
        let err = p.set_data("embed", FixedSpec::new(46, 2)).unwrap_err();
        assert!(err.contains("fractional"), "{err}");
    }

    #[test]
    fn malformed_spec_is_one_line_error_naming_the_entry() {
        let mut p = PrecisionPlan::uniform(1, QuantConfig::new(6, 10));
        for (text, needle) in [
            ("embed ap_fixed<4>", "ap_fixed<4>"),
            ("embed fixed<8,3>", "fixed<8,3>"),
            ("embed ap_fixed<3,9>", "ap_fixed<3,9>"),
            ("embed", "missing"),
            ("embed ap_fixed<8,3> wat", "wat"),
            ("block9.ffn1 ap_fixed<8,3>", "block9.ffn1"),
        ] {
            let err = p.clone().apply_overrides(text).unwrap_err();
            assert!(err.contains(needle), "'{text}' -> {err}");
            assert!(!err.contains('\n'), "one line: {err}");
            assert!(err.contains("line 1"), "{err}");
        }
    }

    /// The CLI contract driven the way `repro` drives it: flag parsed by
    /// `Args`, file loaded over a uniform base, offending entry named.
    #[test]
    fn plan_flag_through_args_names_offending_entry() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("plan_test_{}.txt", std::process::id()));
        std::fs::write(&path, "embed ap_fixed<12,4>\nblock7.ln1 ap_fixed<8,3>\n").unwrap();
        let args = Args::parse(
            ["serve", "--precision-plan", path.to_str().unwrap()].map(String::from),
        )
        .unwrap();
        let flag = args.get("precision-plan").unwrap();
        let err = load_plan_file(flag, 3, QuantConfig::new(6, 10)).unwrap_err();
        assert!(err.contains("block7.ln1"), "{err}");
        assert!(!err.contains('\n'), "one line: {err}");
        // a well-formed file round-trips
        std::fs::write(&path, PrecisionPlan::uniform(3, QuantConfig::new(8, 6)).serialize())
            .unwrap();
        let plan = load_plan_file(flag, 3, QuantConfig::new(6, 10)).unwrap();
        assert_eq!(plan, PrecisionPlan::uniform(3, QuantConfig::new(8, 6)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_plan_file_is_clean_error() {
        let err = load_plan_file("/nonexistent/plan.txt", 2, QuantConfig::new(6, 10));
        assert!(err.unwrap_err().contains("/nonexistent/plan.txt"));
    }

    #[test]
    fn sited_weight_quantization_matches_uniform_legacy() {
        let cfg = zoo_model("btag").unwrap().config;
        let w = synthetic_weights(&cfg, 9);
        let q = QuantConfig::new(6, 7);
        let plan = PrecisionPlan::uniform(cfg.num_blocks, q);
        let a = quantize_weights_sited(&w, &plan);
        let b = w.quantized(q.data);
        assert_eq!(a.embed.0.data(), b.embed.0.data());
        assert_eq!(a.blocks[1].mha.wo.data(), b.blocks[1].mha.wo.data());
        assert_eq!(a.blocks[2].ffn1.0.data(), b.blocks[2].ffn1.0.data());
        assert_eq!(
            a.blocks[0].ln1.as_ref().unwrap().gamma,
            b.blocks[0].ln1.as_ref().unwrap().gamma
        );
        assert_eq!(a.out.0.data(), b.out.0.data());
    }

    #[test]
    fn sited_weight_quantization_uses_each_sites_grid() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 10);
        let mut plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 12));
        let coarse = FixedSpec::new(5, 2);
        plan.set_data("block0.ffn1", coarse).unwrap();
        let q = quantize_weights_sited(&w, &plan);
        for &v in q.blocks[0].ffn1.0.data() {
            assert_eq!(v, coarse.quantize(v), "ffn1 weight off its site grid");
        }
        // a different site keeps the fine grid (some value moves if
        // re-projected onto the coarse one)
        let fine = q.blocks[0].ffn2.0.clone();
        assert!(fine.map(|v| coarse.quantize(v)).max_abs_diff(&fine) > 0.0);
    }

    #[test]
    fn calibrated_plan_covers_observed_ranges() {
        let cfg = zoo_model("engine").unwrap().config;
        let w = synthetic_weights(&cfg, 11);
        let mut g = Gen::new(5);
        let events: Vec<Mat> = (0..4)
            .map(|_| {
                Mat::from_vec(
                    cfg.seq_len,
                    cfg.input_size,
                    g.normal_vec(cfg.seq_len * cfg.input_size, 1.0),
                )
            })
            .collect();
        let plan = calibrate_plan(&cfg, &w, &events, 8);
        assert_eq!(plan.num_blocks(), cfg.num_blocks);
        // re-profile and check every site's range fits its assigned grid
        let wide = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(12, 18));
        let t = super::super::FixedTransformer::with_plan(cfg.clone(), &w, wide);
        let mut prof = RangeProfile::new();
        for x in &events {
            t.forward_recorded(x, Some(&mut prof));
        }
        record_weight_ranges(&mut prof, &w);
        for (site, max_abs) in prof.sites() {
            let q = plan.get(site).expect("profiled site is a plan site");
            // the rule's guarantee: 2^(I-1) strictly covers the range
            assert!(
                (q.data.integer() as f64 - 1.0).exp2() > max_abs,
                "{site}: range {max_abs} exceeds {:?}",
                q.data
            );
            assert_eq!(q.data.frac(), 8, "{site} keeps the requested frac bits");
        }
    }
}
