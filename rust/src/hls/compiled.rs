//! Compile-once execution artifact for one (weights, [`PrecisionPlan`])
//! pair.
//!
//! The FPGA keeps weights *stationary* — only activations stream — yet
//! the integer hot path used to re-lift every weight matrix, bias and
//! LN affine onto the mantissa grid on every `forward` call, and every
//! replica backend rebuilt its own copy.  A [`CompiledModel`] hoists all
//! of that out of the per-call path: per site it owns the pre-lifted
//! row-major **and** pre-transposed `i64` weight mantissa tiles, the
//! quantized bias rows, the LN gamma/beta vectors, the prebuilt
//! [`MantissaConv`]/[`MacQuantizer`] pairs, the shared exp/inv/invsqrt
//! ROMs, and the *pure* part of the hotpath dispatch verdict.  The whole
//! artifact is immutable plain data (`Send + Sync`), so the coordinator
//! shares one copy across R replica shards behind an `Arc` instead of
//! building R clones.
//!
//! Dispatch verdicts are stored as pure eligibility predicates
//! (functions of specs and shapes only) and are ANDed with
//! [`super::hotpath::f64_reference_forced`] at *call* time, so flipping
//! the reference override still reroutes a compiled engine exactly like
//! the per-call path.
//!
//! Bit-exactness contract: every compiled kernel consumes these tiles
//! through the same requantizers and the same accumulation order as the
//! per-call-lift kernels (or an order-only permutation of exact `i64`
//! sums), so compiled `forward`/`forward_batch` are bitwise identical to
//! the per-call path — property-tested in `transformer.rs` and pinned by
//! the sealed golden corpus.

use super::hotpath;
use super::precision::{MhaPrecision, PrecisionPlan, QuantConfig};
use crate::fixed::lut::{LutKind, Roms};
use crate::fixed::mantissa::{f32_grid_exact, f64_sum_exact, int_mac_eligible};
use crate::fixed::{FixedSpec, MacQuantizer, MantissaConv};
use crate::models::config::ModelConfig;
use crate::models::weights::{LnWeights, Weights};
use crate::nn::tensor::Mat;

/// One dense site, fully lifted: both tile layouts plus the site's
/// conversion/requantization constants and its pure dispatch verdict.
#[derive(Clone, Debug)]
pub struct CompiledDense {
    /// Row-major mantissa tile — same element order as `Mat::data()`,
    /// consumed by the weight-stationary batched core.
    wm: Vec<i64>,
    /// Transposed tile (`wm_t[j * n_in + i] == wm[i * n_out + j]`):
    /// output column `j` is contiguous, consumed by the single-event
    /// dot-product core (register accumulation, no activation scatter).
    wm_t: Vec<i64>,
    /// Bias row on the site's data grid (already site-quantized).
    bias: Vec<f32>,
    n_in: usize,
    n_out: usize,
    conv: MantissaConv,
    mq: MacQuantizer,
    data: FixedSpec,
    accum: FixedSpec,
    /// Pure `int_mac_eligible(data, accum, n_in)` — AND with
    /// `!f64_reference_forced()` per call.
    int_eligible: bool,
}

impl CompiledDense {
    /// Lift one site-quantized `(w, b)` onto the mantissa grid of `q`.
    pub fn build(w: &Mat, b: &[f32], q: QuantConfig) -> Self {
        assert_eq!(w.cols(), b.len());
        let (n_in, n_out) = (w.rows(), w.cols());
        let conv = MantissaConv::new(q.data);
        let mut wm = vec![0i64; n_in * n_out];
        for (dst, &src) in wm.iter_mut().zip(w.data()) {
            *dst = conv.to_m(src);
        }
        let mut wm_t = vec![0i64; n_in * n_out];
        for i in 0..n_in {
            for j in 0..n_out {
                wm_t[j * n_in + i] = wm[i * n_out + j];
            }
        }
        Self {
            wm,
            wm_t,
            bias: b.to_vec(),
            n_in,
            n_out,
            conv,
            mq: MacQuantizer::new(q.data, q.accum),
            data: q.data,
            accum: q.accum,
            int_eligible: int_mac_eligible(q.data, q.accum, n_in),
        }
    }

    /// Live dispatch verdict: the compiled pure predicate gated by the
    /// process-wide reference override, exactly like
    /// [`hotpath::int_path_enabled`] on the per-call path.
    #[inline(always)]
    pub fn use_int(&self) -> bool {
        self.int_eligible && !hotpath::f64_reference_forced()
    }

    pub fn wm(&self) -> &[i64] {
        &self.wm
    }

    pub fn wm_t(&self) -> &[i64] {
        &self.wm_t
    }

    pub fn bias(&self) -> &[f32] {
        &self.bias
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    pub fn conv(&self) -> MantissaConv {
        self.conv
    }

    pub fn mq(&self) -> MacQuantizer {
        self.mq
    }

    pub fn data(&self) -> FixedSpec {
        self.data
    }

    pub fn accum(&self) -> FixedSpec {
        self.accum
    }

    /// Artifact bytes of this site (both tiles + the bias row).
    pub fn bytes(&self) -> usize {
        (self.wm.len() + self.wm_t.len()) * std::mem::size_of::<i64>()
            + self.bias.len() * std::mem::size_of::<f32>()
    }
}

/// One LayerNorm site: the affine vectors plus the compiled verdict for
/// the mean-sum/variance-MAC integer stages.
#[derive(Clone, Debug)]
pub struct CompiledLn {
    gamma: Vec<f32>,
    beta: Vec<f32>,
    data: FixedSpec,
    accum: FixedSpec,
    /// Pure: variance MAC eligibility AND stage-1 mean-sum exactness for
    /// the `d`-channel row.
    int_eligible: bool,
}

impl CompiledLn {
    pub fn build(ln: &LnWeights, q: QuantConfig) -> Self {
        let d = ln.gamma.len();
        Self {
            gamma: ln.gamma.clone(),
            beta: ln.beta.clone(),
            data: q.data,
            accum: q.accum,
            int_eligible: int_mac_eligible(q.data, q.accum, d) && f64_sum_exact(q.data, d),
        }
    }

    #[inline(always)]
    pub fn use_int(&self) -> bool {
        self.int_eligible && !hotpath::f64_reference_forced()
    }

    pub fn gamma(&self) -> &[f32] {
        &self.gamma
    }

    pub fn beta(&self) -> &[f32] {
        &self.beta
    }

    pub fn data(&self) -> FixedSpec {
        self.data
    }

    pub fn accum(&self) -> FixedSpec {
        self.accum
    }

    pub fn bytes(&self) -> usize {
        (self.gamma.len() + self.beta.len()) * std::mem::size_of::<f32>()
    }
}

/// The shared softmax/sigmoid LUT-I/O site.  Softmax rows come in two
/// lengths (MHA score rows and the final classifier), so the compiled
/// verdict bakes the length-independent half (`f32_grid_exact`) and
/// evaluates the trivial length check per call.
#[derive(Clone, Copy, Debug)]
pub struct CompiledSoftmax {
    data: FixedSpec,
    accum: FixedSpec,
    grid_exact: bool,
}

impl CompiledSoftmax {
    pub fn build(q: QuantConfig) -> Self {
        Self { data: q.data, accum: q.accum, grid_exact: f32_grid_exact(q.data) }
    }

    /// Live verdict for a `len`-wide row — identical to
    /// [`hotpath::int_sum_enabled`] on the per-call path.
    #[inline(always)]
    pub fn use_int(&self, len: usize) -> bool {
        self.grid_exact
            && f64_sum_exact(self.data, len)
            && !hotpath::f64_reference_forced()
    }

    pub fn data(&self) -> FixedSpec {
        self.data
    }

    pub fn accum(&self) -> FixedSpec {
        self.accum
    }
}

/// The global-average-pool site: sequence length is fixed per model, so
/// the sum-exactness verdict is fully baked.
#[derive(Clone, Copy, Debug)]
pub struct CompiledPool {
    data: FixedSpec,
    accum: FixedSpec,
    sum_eligible: bool,
}

impl CompiledPool {
    pub fn build(q: QuantConfig, seq_len: usize) -> Self {
        Self {
            data: q.data,
            accum: q.accum,
            sum_eligible: f32_grid_exact(q.data) && f64_sum_exact(q.data, seq_len),
        }
    }

    #[inline(always)]
    pub fn use_int(&self) -> bool {
        self.sum_eligible && !hotpath::f64_reference_forced()
    }

    pub fn data(&self) -> FixedSpec {
        self.data
    }

    pub fn accum(&self) -> FixedSpec {
        self.accum
    }
}

/// One MHA engine: per-head Q/K/V projection tiles, the output
/// projection tile, and the pure score/apply dispatch verdicts
/// ([`super::mha::MhaHotPath`] re-derives its live verdicts from these).
#[derive(Clone, Debug)]
pub struct CompiledMha {
    pub q: Vec<CompiledDense>,
    pub k: Vec<CompiledDense>,
    pub v: Vec<CompiledDense>,
    pub out: CompiledDense,
    p: MhaPrecision,
    head_dim: usize,
    /// Pure `int_mac_eligible(qkv.data, qkv.accum, head_dim)`.
    score_eligible: bool,
    /// Pure `f32_grid_exact(softmax.data) && f32_grid_exact(qkv.data)`.
    apply_grid_exact: bool,
}

impl CompiledMha {
    pub fn build(w: &crate::models::weights::MhaWeights, p: MhaPrecision) -> Self {
        let k = w.wq[0].cols();
        let lift = |ws: &[Mat], bs: &[Vec<f32>]| -> Vec<CompiledDense> {
            ws.iter()
                .zip(bs)
                .map(|(wm, bm)| CompiledDense::build(wm, bm, p.qkv))
                .collect()
        };
        Self {
            q: lift(&w.wq, &w.bq),
            k: lift(&w.wk, &w.bk),
            v: lift(&w.wv, &w.bv),
            out: CompiledDense::build(&w.wo, &w.bo, p.out),
            p,
            head_dim: k,
            score_eligible: int_mac_eligible(p.qkv.data, p.qkv.accum, k),
            apply_grid_exact: f32_grid_exact(p.softmax.data) && f32_grid_exact(p.qkv.data),
        }
    }

    pub fn precision(&self) -> MhaPrecision {
        self.p
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn score_eligible(&self) -> bool {
        self.score_eligible
    }

    pub fn apply_grid_exact(&self) -> bool {
        self.apply_grid_exact
    }

    pub fn bytes(&self) -> usize {
        self.q.iter().map(CompiledDense::bytes).sum::<usize>()
            + self.k.iter().map(CompiledDense::bytes).sum::<usize>()
            + self.v.iter().map(CompiledDense::bytes).sum::<usize>()
            + self.out.bytes()
    }
}

/// One transformer block of compiled sites.
#[derive(Clone, Debug)]
pub struct CompiledBlock {
    pub mha: CompiledMha,
    pub ln1: Option<CompiledLn>,
    pub ffn1: CompiledDense,
    pub ffn2: CompiledDense,
    pub ln2: Option<CompiledLn>,
}

impl CompiledBlock {
    pub fn bytes(&self) -> usize {
        self.mha.bytes()
            + self.ln1.as_ref().map_or(0, CompiledLn::bytes)
            + self.ffn1.bytes()
            + self.ffn2.bytes()
            + self.ln2.as_ref().map_or(0, CompiledLn::bytes)
    }
}

/// The full build-once artifact: every site lifted, the ROMs
/// materialized, build cost and footprint recorded for the serving
/// report.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub embed: CompiledDense,
    pub blocks: Vec<CompiledBlock>,
    pub head: CompiledDense,
    pub out: CompiledDense,
    pub pool: CompiledPool,
    pub softmax: CompiledSoftmax,
    pub roms: Roms,
    build_micros: u64,
    bytes: usize,
}

impl CompiledModel {
    /// Lift every site of an already *site-quantized* weight set (the
    /// output of [`super::precision::quantize_weights_sited`]) under
    /// `plan`.  Built once per (weights, plan); `FixedTransformer`
    /// clones share it behind an `Arc`.
    pub fn build(cfg: &ModelConfig, qw: &Weights, plan: &PrecisionPlan) -> Self {
        let t0 = std::time::Instant::now();
        let blocks: Vec<CompiledBlock> = qw
            .blocks
            .iter()
            .enumerate()
            .map(|(b, blk)| {
                let bp = plan.block(b);
                CompiledBlock {
                    mha: CompiledMha::build(&blk.mha, bp.mha(plan.softmax())),
                    ln1: blk.ln1.as_ref().map(|l| CompiledLn::build(l, bp.ln1)),
                    ffn1: CompiledDense::build(&blk.ffn1.0, &blk.ffn1.1, bp.ffn1),
                    ffn2: CompiledDense::build(&blk.ffn2.0, &blk.ffn2.1, bp.ffn2),
                    ln2: blk.ln2.as_ref().map(|l| CompiledLn::build(l, bp.ln2)),
                }
            })
            .collect();
        let embed = CompiledDense::build(&qw.embed.0, &qw.embed.1, plan.embed());
        let head = CompiledDense::build(&qw.head.0, &qw.head.1, plan.head());
        let out = CompiledDense::build(&qw.out.0, &qw.out.1, plan.out());
        let rom_words: usize = [LutKind::Exp, LutKind::Inv, LutKind::InvSqrt]
            .iter()
            .map(|k| k.geometry().2)
            .sum();
        let bytes = embed.bytes()
            + blocks.iter().map(CompiledBlock::bytes).sum::<usize>()
            + head.bytes()
            + out.bytes()
            + rom_words * std::mem::size_of::<f32>();
        Self {
            embed,
            blocks,
            head,
            out,
            pool: CompiledPool::build(plan.pool(), cfg.seq_len),
            softmax: CompiledSoftmax::build(plan.softmax()),
            roms: Roms::new(),
            build_micros: t0.elapsed().as_micros() as u64,
            bytes,
        }
    }

    /// Wall-clock microseconds the lift took (the cost `forward` used to
    /// re-pay per call, now paid once).
    pub fn build_micros(&self) -> u64 {
        self.build_micros
    }

    /// Artifact footprint: mantissa tiles (both layouts), bias/affine
    /// rows, and the ROM words.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Resident f32 bytes one incremental stream window cache
    /// (`FixedTransformer::forward_incremental`) holds against this
    /// artifact: the `(S, d_model)` block-0 prefix rows plus, per
    /// block-0 head, the `(S, k)` Q/K/V rows and the `(S, S)` raw score
    /// block.  Sizing input for the serving report — matches the
    /// cache's own `cache_bytes` high-water exactly (pinned in the
    /// transformer suite).
    pub fn window_cache_bytes(&self, seq_len: usize) -> u64 {
        let s = seq_len as u64;
        let prefix = s * self.embed.n_out() as u64;
        let mha = self.blocks.first().map_or(0, |b| {
            let heads = b.mha.q.len() as u64;
            let k = b.mha.head_dim() as u64;
            heads * (3 * s * k + s * s)
        });
        (prefix + mha) * std::mem::size_of::<f32>() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::weights::synthetic_weights;
    use crate::models::zoo::zoo;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn compiled_model_is_shareable_across_threads() {
        // the whole point: one Arc<CompiledModel> serves R replica shards
        assert_send_sync::<CompiledModel>();
        assert_send_sync::<std::sync::Arc<CompiledModel>>();
    }

    #[test]
    fn transposed_tile_is_the_row_major_tile_permuted() {
        let models = zoo();
        let cfg = &models[0].config;
        let w = synthetic_weights(cfg, 11);
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let qw = super::super::precision::quantize_weights_sited(&w, &plan);
        let cm = CompiledModel::build(cfg, &qw, &plan);
        let d = &cm.embed;
        assert_eq!(d.wm().len(), d.n_in() * d.n_out());
        assert_eq!(d.wm_t().len(), d.wm().len());
        for i in 0..d.n_in() {
            for j in 0..d.n_out() {
                assert_eq!(d.wm_t()[j * d.n_in() + i], d.wm()[i * d.n_out() + j]);
            }
        }
        // and the row-major tile is the per-call lift of the same site
        let conv = MantissaConv::new(plan.embed().data);
        for (m, &src) in d.wm().iter().zip(qw.embed.0.data()) {
            assert_eq!(*m, conv.to_m(src));
        }
    }

    #[test]
    fn verdicts_are_pure_and_match_the_hotpath_predicates() {
        for m in zoo() {
            let cfg = &m.config;
            let w = synthetic_weights(cfg, 5);
            let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
            let qw = super::super::precision::quantize_weights_sited(&w, &plan);
            let cm = CompiledModel::build(cfg, &qw, &plan);
            let q = plan.embed();
            assert_eq!(
                cm.embed.int_eligible,
                int_mac_eligible(q.data, q.accum, cfg.input_size),
                "{}",
                cfg.name
            );
            for blk in &cm.blocks {
                assert_eq!(
                    blk.mha.score_eligible(),
                    int_mac_eligible(q.data, q.accum, cfg.head_dim)
                );
            }
            assert_eq!(
                cm.pool.use_int() || hotpath::f64_reference_forced(),
                hotpath::int_sum_enabled(q.data, cfg.seq_len)
                    || hotpath::f64_reference_forced()
            );
        }
    }

    #[test]
    fn artifact_reports_nonzero_footprint() {
        let models = zoo();
        let cfg = &models[0].config;
        let w = synthetic_weights(cfg, 8);
        let plan = PrecisionPlan::uniform(cfg.num_blocks, QuantConfig::new(6, 10));
        let qw = super::super::precision::quantize_weights_sited(&w, &plan);
        let cm = CompiledModel::build(cfg, &qw, &plan);
        // at minimum: both embed tiles + ROMs
        assert!(cm.bytes() > 2 * cfg.input_size * cfg.d_model * 8);
        // bytes is a sum over all sites, so every block contributes
        let per_block: usize = cm.blocks.iter().map(CompiledBlock::bytes).sum();
        assert!(per_block > 0);
    }
}
