//! Reusable scratch arena for the batch-major fixed-point kernels.
//!
//! The per-event HLS forward allocates on every call: the f64 `acc`
//! vector in `dense_fixed`, the per-row score/output `Vec`s and the FIFO
//! `VecDeque`s in `mha_fixed`.  At serving rates those allocations are a
//! measurable slice of the hot loop.  The batched kernels instead draw
//! every temporary from one [`Scratch`] owned by the transformer, so a
//! buffer is allocated the first time a layer shape is seen and then
//! reused for every later batch.
//!
//! The arena only hands out *cleared* buffers (accumulators zeroed, rows
//! zero-filled), so reuse can never leak state between layers or events
//! — which is what keeps the bit-exactness contract (see [`crate::nn`])
//! trivially safe.

/// Growable pool of accumulator, row, and integer-mantissa buffers.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    acc: Vec<f64>,
    rows: Vec<Vec<f32>>,
    ints: Vec<Vec<i64>>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed f64 accumulator tile of exactly `n` elements.  The
    /// backing allocation grows monotonically and is reused across
    /// calls; only one tile is live at a time (layers run sequentially).
    pub fn acc_zeroed(&mut self, n: usize) -> &mut [f64] {
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
        }
        let tile = &mut self.acc[..n];
        tile.fill(0.0);
        tile
    }

    /// Take a zero-filled f32 row buffer of length `n` from the pool
    /// (allocating only when the pool is empty).  Return it with
    /// [`Scratch::put_row`] so the next taker reuses the allocation.
    pub fn take_row(&mut self, n: usize) -> Vec<f32> {
        let mut row = self.rows.pop().unwrap_or_default();
        row.clear();
        row.resize(n, 0.0);
        row
    }

    pub fn put_row(&mut self, row: Vec<f32>) {
        self.rows.push(row);
    }

    /// Take a zero-filled `i64` mantissa tile of length `n` from the
    /// pool — the integer hot path's weight/activation/accumulator
    /// tiles ([`crate::hls::hotpath`]).  Owned `Vec`s (like
    /// [`Scratch::take_row`]) so several tiles can be live at once;
    /// return with [`Scratch::put_ints`].
    pub fn take_ints(&mut self, n: usize) -> Vec<i64> {
        let mut tile = self.ints.pop().unwrap_or_default();
        tile.clear();
        tile.resize(n, 0);
        tile
    }

    pub fn put_ints(&mut self, tile: Vec<i64>) {
        self.ints.push(tile);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_tile_is_always_zeroed() {
        let mut s = Scratch::new();
        s.acc_zeroed(4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!(s.acc_zeroed(3).iter().all(|&v| v == 0.0));
        // growing past the old capacity stays zeroed too
        assert!(s.acc_zeroed(8).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int_tiles_are_zeroed_on_reuse() {
        let mut s = Scratch::new();
        let mut t = s.take_ints(4);
        t.copy_from_slice(&[1, 2, 3, 4]);
        s.put_ints(t);
        assert_eq!(s.take_ints(3), vec![0i64; 3]);
        // several tiles live simultaneously, each its own allocation
        let a = s.take_ints(2);
        let b = s.take_ints(5);
        assert_eq!((a.len(), b.len()), (2, 5));
        s.put_ints(a);
        s.put_ints(b);
    }

    #[test]
    fn row_pool_reuses_and_clears() {
        let mut s = Scratch::new();
        let mut r = s.take_row(5);
        r[0] = 9.0;
        let cap = r.capacity();
        s.put_row(r);
        let r2 = s.take_row(3);
        assert_eq!(r2, vec![0.0; 3]);
        assert!(r2.capacity() >= 3.min(cap));
    }
}
