//! Per-site parallelism: the [`ParallelismPlan`] that replaces the single
//! global `ReuseFactor` argument of `FixedTransformer::{pipeline,
//! layer_resources, synthesize}`.
//!
//! The original hls4ml paper (Duarte et al., 1804.06913) defines the
//! reuse factor as a *per-layer* throughput/resource dial — how many
//! multiplications are time-multiplexed onto each DSP of that layer's
//! engine — and "Ultra Fast Transformers on FPGAs" (2402.01047) tunes
//! different parallelism per transformer engine.  This plan is the reuse
//! twin of [`super::PrecisionPlan`]: the same typed site map (embed,
//! per-block `mha.qkv` / `mha.out` / `ln1` / `ffn1` / `ffn2` / `ln2`,
//! pool, head, out) assigning each site its own [`ReuseFactor`].  There
//! is no `softmax` site: the softmax ROMs are shared lookup hardware
//! whose schedule rides the score engine's reuse.
//!
//! Contract: a *uniform* plan (every site at the same R) reproduces the
//! retired global-`ReuseFactor` schedule — pinned by the golden tests in
//! `transformer.rs` against a verbatim copy of the closed form it
//! replaced.
//!
//! Plans serialize to the same line-oriented skeleton as precision plans
//! ([`super::planfile`]): one `site R` assignment per line (`R4` or bare
//! `4`), `#` comments, loadable via `--reuse-plan` on `repro synth` /
//! `repro serve`; see README "Parallelism plans".

use super::planfile::apply_plan_lines;
use super::ReuseFactor;

/// Largest accepted per-site reuse factor.  Beyond this the schedule
/// model is meaningless (every paper design point is R <= 8).
pub const MAX_REUSE: u32 = 1024;

/// Per-site reuse factors of one transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockParallelism {
    /// Stage-1 Q/K/V projections + the score MAC engine.
    pub qkv: ReuseFactor,
    /// Stage-3/4 output path: apply-V, concat, Wo.
    pub mha_out: ReuseFactor,
    pub ln1: ReuseFactor,
    pub ln2: ReuseFactor,
    pub ffn1: ReuseFactor,
    pub ffn2: ReuseFactor,
}

impl BlockParallelism {
    pub fn uniform(r: ReuseFactor) -> Self {
        Self { qkv: r, mha_out: r, ln1: r, ln2: r, ffn1: r, ffn2: r }
    }

    /// The reuse pair one MHA engine consumes.
    pub fn mha(&self) -> MhaParallelism {
        MhaParallelism { qkv: self.qkv, out: self.mha_out }
    }
}

/// Reuse factors threaded through one MHA engine: the stage-1/2
/// projection+score path and the stage-3/4 output path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MhaParallelism {
    pub qkv: ReuseFactor,
    pub out: ReuseFactor,
}

impl MhaParallelism {
    pub fn uniform(r: ReuseFactor) -> Self {
        Self { qkv: r, out: r }
    }
}

/// Resolved site address (the grammar is shared with `PrecisionPlan`
/// minus the `softmax` site).
#[derive(Clone, Copy)]
enum SiteRef {
    Embed,
    Pool,
    Head,
    Out,
    Block(usize, BlockField),
}

#[derive(Clone, Copy)]
enum BlockField {
    Qkv,
    MhaOut,
    Ln1,
    Ln2,
    Ffn1,
    Ffn2,
}

/// Typed map from layer site to its reuse factor — the parallelism
/// authority of a synthesized design point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParallelismPlan {
    embed: ReuseFactor,
    blocks: Vec<BlockParallelism>,
    pool: ReuseFactor,
    head: ReuseFactor,
    out: ReuseFactor,
}

impl ParallelismPlan {
    /// Every site at the same reuse — the legacy global-`ReuseFactor`
    /// behavior.
    pub fn uniform(num_blocks: usize, r: ReuseFactor) -> Self {
        Self {
            embed: r,
            blocks: vec![BlockParallelism::uniform(r); num_blocks],
            pool: r,
            head: r,
            out: r,
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn embed(&self) -> ReuseFactor {
        self.embed
    }

    pub fn pool(&self) -> ReuseFactor {
        self.pool
    }

    pub fn head(&self) -> ReuseFactor {
        self.head
    }

    pub fn out(&self) -> ReuseFactor {
        self.out
    }

    pub fn block(&self, b: usize) -> &BlockParallelism {
        &self.blocks[b]
    }

    /// Canonical site order (execution order; also the serialization and
    /// search order) — the precision-plan order minus `softmax`.
    pub fn site_names(&self) -> Vec<String> {
        crate::ir::schedule_site_names(self.blocks.len())
    }

    /// The one place site names are parsed (same rule as
    /// `PrecisionPlan::resolve`): both `get` and the mutable slot lookup
    /// resolve through here.
    fn resolve(&self, site: &str) -> Option<SiteRef> {
        match site {
            "embed" => Some(SiteRef::Embed),
            "pool" => Some(SiteRef::Pool),
            "head" => Some(SiteRef::Head),
            "out" => Some(SiteRef::Out),
            _ => {
                let rest = site.strip_prefix("block")?;
                let (idx, field) = rest.split_once('.')?;
                let b: usize = idx.parse().ok()?;
                if b >= self.blocks.len() {
                    return None;
                }
                let field = match field {
                    "mha.qkv" => BlockField::Qkv,
                    "mha.out" => BlockField::MhaOut,
                    "ln1" => BlockField::Ln1,
                    "ln2" => BlockField::Ln2,
                    "ffn1" => BlockField::Ffn1,
                    "ffn2" => BlockField::Ffn2,
                    _ => return None,
                };
                Some(SiteRef::Block(b, field))
            }
        }
    }

    fn slot_mut(&mut self, site: &str) -> Option<&mut ReuseFactor> {
        Some(match self.resolve(site)? {
            SiteRef::Embed => &mut self.embed,
            SiteRef::Pool => &mut self.pool,
            SiteRef::Head => &mut self.head,
            SiteRef::Out => &mut self.out,
            SiteRef::Block(b, f) => {
                let bp = &mut self.blocks[b];
                match f {
                    BlockField::Qkv => &mut bp.qkv,
                    BlockField::MhaOut => &mut bp.mha_out,
                    BlockField::Ln1 => &mut bp.ln1,
                    BlockField::Ln2 => &mut bp.ln2,
                    BlockField::Ffn1 => &mut bp.ffn1,
                    BlockField::Ffn2 => &mut bp.ffn2,
                }
            }
        })
    }

    pub fn get(&self, site: &str) -> Option<ReuseFactor> {
        Some(match self.resolve(site)? {
            SiteRef::Embed => self.embed,
            SiteRef::Pool => self.pool,
            SiteRef::Head => self.head,
            SiteRef::Out => self.out,
            SiteRef::Block(b, f) => {
                let bp = &self.blocks[b];
                match f {
                    BlockField::Qkv => bp.qkv,
                    BlockField::MhaOut => bp.mha_out,
                    BlockField::Ln1 => bp.ln1,
                    BlockField::Ln2 => bp.ln2,
                    BlockField::Ffn1 => bp.ffn1,
                    BlockField::Ffn2 => bp.ffn2,
                }
            }
        })
    }

    /// Assign one site; `Err` names the unknown site (one line, the CLI
    /// contract shared with `PrecisionPlan::set`).
    pub fn set(&mut self, site: &str, r: ReuseFactor) -> Result<(), String> {
        let n = self.blocks.len();
        match self.slot_mut(site) {
            Some(slot) => {
                *slot = r;
                Ok(())
            }
            None => Err(format!(
                "unknown site '{site}' (model has {n} blocks; sites: embed, \
                 blockN.mha.qkv, blockN.mha.out, blockN.ln1, blockN.ffn1, \
                 blockN.ffn2, blockN.ln2, pool, head, out)"
            )),
        }
    }

    /// Every site's reuse in canonical order by direct field access —
    /// the allocation-free twin of [`Self::site_names`] for the hot
    /// paths (`synthesize` consults `max_reuse` on every design point
    /// the Pareto explorer evaluates).
    fn site_values(&self) -> impl Iterator<Item = ReuseFactor> + '_ {
        std::iter::once(self.embed)
            .chain(
                self.blocks
                    .iter()
                    .flat_map(|b| [b.qkv, b.mha_out, b.ln1, b.ffn1, b.ffn2, b.ln2]),
            )
            .chain([self.pool, self.head, self.out])
    }

    /// `Some(r)` iff every site carries the same reuse factor.
    pub fn is_uniform(&self) -> Option<ReuseFactor> {
        let r = self.embed;
        self.site_values().all(|v| v == r).then_some(r)
    }

    /// The largest reuse of any site — the most-serialized engine, which
    /// is what sets achievable clock in the calibration model.
    pub fn max_reuse(&self) -> ReuseFactor {
        self.site_values()
            .max_by_key(|r| r.get())
            .unwrap_or(ReuseFactor(1))
    }

    /// One-line description for reports: the single `R` when uniform, a
    /// range otherwise.
    pub fn summary(&self) -> String {
        match self.is_uniform() {
            Some(r) => r.to_string(),
            None => {
                let (lo, hi) = self
                    .site_values()
                    .fold((u32::MAX, 0u32), |(lo, hi), r| (lo.min(r.get()), hi.max(r.get())));
                format!("Rmixed<{lo}..{hi}>")
            }
        }
    }

    /// Serialize to the plan text format: one `site R<k>` line per site,
    /// `#` starting a comment.  Round-trips through
    /// [`Self::apply_overrides`].
    pub fn serialize(&self) -> String {
        let mut s = String::from("# parallelism plan: site -> reuse factor\n");
        for site in self.site_names() {
            let r = self.get(&site).expect("site_names yields known sites");
            s.push_str(&format!("{site} {r}\n"));
        }
        s
    }

    /// Apply plan-text overrides onto this plan.  Unknown sites and
    /// malformed reuse values produce a one-line error naming the
    /// offending entry and its line number.
    pub fn apply_overrides(&mut self, text: &str) -> Result<(), String> {
        apply_plan_lines(text, |site, rest| {
            let tok = match rest {
                [] => {
                    return Err(format!("site '{site}' is missing its reuse factor"));
                }
                [tok] => *tok,
                [_, tr, ..] => {
                    return Err(format!("site '{site}': trailing token '{tr}'"));
                }
            };
            let r = parse_reuse(tok).map_err(|e| format!("site '{site}': {e}"))?;
            self.set(site, r)
        })
    }
}

/// Parse one reuse token: `4` or `R4`, in `1..=MAX_REUSE`.
pub fn parse_reuse(tok: &str) -> Result<ReuseFactor, String> {
    let digits = tok.strip_prefix('R').unwrap_or(tok);
    let r: u32 = digits
        .parse()
        .map_err(|_| format!("cannot parse reuse '{tok}' (expected an integer like 4 or R4)"))?;
    if r == 0 || r > MAX_REUSE {
        return Err(format!("reuse '{tok}' out of range (1..={MAX_REUSE})"));
    }
    Ok(ReuseFactor(r))
}

/// Read + apply a `--reuse-plan` file over a uniform base plan.  Errors
/// are one line naming the file and the offending entry.
pub fn load_reuse_plan_file(
    path: &str,
    num_blocks: usize,
    base: ReuseFactor,
) -> Result<ParallelismPlan, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("--reuse-plan {path}: {e}"))?;
    let mut plan = ParallelismPlan::uniform(num_blocks, base);
    plan.apply_overrides(&text)
        .map_err(|e| format!("--reuse-plan {path}: {e}"))?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_plan_reports_uniform() {
        let p = ParallelismPlan::uniform(3, ReuseFactor(4));
        assert_eq!(p.is_uniform(), Some(ReuseFactor(4)));
        assert_eq!(p.summary(), "R4");
        assert_eq!(p.max_reuse(), ReuseFactor(4));
        // precision sites minus softmax
        assert_eq!(p.site_names().len(), 1 + 3 * 6 + 3);
    }

    #[test]
    fn set_and_get_every_site() {
        let mut p = ParallelismPlan::uniform(2, ReuseFactor(1));
        for (i, site) in p.site_names().into_iter().enumerate() {
            let r = ReuseFactor(1 + (i as u32 % 4));
            p.set(&site, r).unwrap();
            assert_eq!(p.get(&site), Some(r), "{site}");
        }
        assert!(p.is_uniform().is_none());
        assert!(p.summary().starts_with("Rmixed<"));
        assert_eq!(p.max_reuse(), ReuseFactor(4));
    }

    #[test]
    fn unknown_sites_rejected_with_named_entry() {
        let mut p = ParallelismPlan::uniform(2, ReuseFactor(1));
        for bad in ["block2.mha.qkv", "block0.mha.wat", "softmax", "blurb"] {
            let err = p.set(bad, ReuseFactor(2)).unwrap_err();
            assert!(err.contains(bad), "{err}");
            assert!(!err.contains('\n'), "one line: {err}");
        }
    }

    #[test]
    fn serialize_round_trips_through_overrides() {
        let mut g = crate::testutil::Gen::new(17);
        for _ in 0..20 {
            let mut plan = ParallelismPlan::uniform(3, ReuseFactor(1));
            for site in plan.site_names() {
                plan.set(&site, ReuseFactor([1, 2, 4, 8][g.usize_in(0, 4)])).unwrap();
            }
            let text = plan.serialize();
            let mut rt = ParallelismPlan::uniform(3, ReuseFactor(7));
            rt.apply_overrides(&text).unwrap();
            assert_eq!(rt, plan, "round trip failed for:\n{text}");
        }
    }

    #[test]
    fn overrides_accept_bare_integers_comments_and_r_prefix() {
        let mut p = ParallelismPlan::uniform(1, ReuseFactor(1));
        let text = "# engine working point\n\
                    embed R2   # reuse the input engine\n\
                    \n\
                    block0.ffn1 4\n\
                    pool R8\n";
        p.apply_overrides(text).unwrap();
        assert_eq!(p.embed(), ReuseFactor(2));
        assert_eq!(p.get("block0.ffn1"), Some(ReuseFactor(4)));
        assert_eq!(p.pool(), ReuseFactor(8));
    }

    #[test]
    fn malformed_reuse_is_one_line_error_naming_the_entry() {
        let p = ParallelismPlan::uniform(1, ReuseFactor(1));
        for (text, needle) in [
            ("embed", "missing"),
            ("embed wat", "wat"),
            ("embed R0", "out of range"),
            ("embed 0", "out of range"),
            ("embed 4 4", "trailing"),
            ("embed 99999", "out of range"),
            ("block9.ffn1 4", "block9.ffn1"),
        ] {
            let err = p.clone().apply_overrides(text).unwrap_err();
            assert!(err.contains(needle), "'{text}' -> {err}");
            assert!(!err.contains('\n'), "one line: {err}");
            assert!(err.contains("line 1"), "{err}");
        }
    }

    #[test]
    fn parse_reuse_accepts_both_forms() {
        assert_eq!(parse_reuse("4").unwrap(), ReuseFactor(4));
        assert_eq!(parse_reuse("R16").unwrap(), ReuseFactor(16));
        assert!(parse_reuse("R").is_err());
        assert!(parse_reuse("-1").is_err());
        assert!(parse_reuse("4.5").is_err());
    }

    #[test]
    fn load_reuse_plan_file_round_trip_and_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("reuse_plan_test_{}.txt", std::process::id()));
        std::fs::write(&path, "embed R2\nblock7.ln1 4\n").unwrap();
        let err =
            load_reuse_plan_file(path.to_str().unwrap(), 3, ReuseFactor(1)).unwrap_err();
        assert!(err.contains("block7.ln1"), "{err}");
        assert!(!err.contains('\n'), "one line: {err}");
        let good = ParallelismPlan::uniform(3, ReuseFactor(2)).serialize();
        std::fs::write(&path, good).unwrap();
        let plan = load_reuse_plan_file(path.to_str().unwrap(), 3, ReuseFactor(1)).unwrap();
        assert_eq!(plan, ParallelismPlan::uniform(3, ReuseFactor(2)));
        std::fs::remove_file(&path).ok();
        let missing = load_reuse_plan_file("/nonexistent/reuse.txt", 2, ReuseFactor(1));
        assert!(missing.unwrap_err().contains("/nonexistent/reuse.txt"));
    }

    #[test]
    fn mha_pair_extraction() {
        let mut p = ParallelismPlan::uniform(1, ReuseFactor(1));
        p.set("block0.mha.qkv", ReuseFactor(4)).unwrap();
        let m = p.block(0).mha();
        assert_eq!(m.qkv, ReuseFactor(4));
        assert_eq!(m.out, ReuseFactor(1));
        assert_eq!(MhaParallelism::uniform(ReuseFactor(2)).out, ReuseFactor(2));
    }
}
