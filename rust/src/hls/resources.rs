//! FPGA resource accounting: DSP slices, flip-flops, LUTs, BRAM.
//!
//! The absolute numbers are an analytic model (we have no Vivado); what
//! the reproduction commits to is the *trends* of Figures 12-14, encoded
//! in `calibration.rs` and asserted by `experiments::resource_figures`
//! tests:
//!   * FF/LUT ≈ linear in bit width and in 1/R,
//!   * DSP flat in precision until the multiplier operand exceeds the
//!     DSP48E2 port width, then doubled,
//!   * BRAM grows with R (register arrays re-partitioned into BRAM).

use std::iter::Sum;
use std::ops::{Add, AddAssign};

/// Resource vector for one layer / one design.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u64,
    pub ff: u64,
    pub lut: u64,
    /// BRAM in 18Kb halves (Vivado reports RAMB18 units).
    pub bram18: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources { dsp: 0, ff: 0, lut: 0, bram18: 0 };

    pub fn new(dsp: u64, ff: u64, lut: u64, bram18: u64) -> Self {
        Self { dsp, ff, lut, bram18 }
    }

    /// Utilization fractions against a device budget.
    pub fn utilization(&self, device: &Device) -> [(&'static str, f64); 4] {
        [
            ("DSP", self.dsp as f64 / device.dsp as f64),
            ("FF", self.ff as f64 / device.ff as f64),
            ("LUT", self.lut as f64 / device.lut as f64),
            ("BRAM18", self.bram18 as f64 / device.bram18 as f64),
        ]
    }

    /// True if the design fits the device.
    pub fn fits(&self, device: &Device) -> bool {
        self.dsp <= device.dsp
            && self.ff <= device.ff
            && self.lut <= device.lut
            && self.bram18 <= device.bram18
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            dsp: self.dsp + o.dsp,
            ff: self.ff + o.ff,
            lut: self.lut + o.lut,
            bram18: self.bram18 + o.bram18,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl Sum for Resources {
    fn sum<I: Iterator<Item = Resources>>(iter: I) -> Resources {
        iter.fold(Resources::ZERO, |a, b| a + b)
    }
}

/// Device budget. The paper's part is the Xilinx VU13P.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub dsp: u64,
    pub ff: u64,
    pub lut: u64,
    pub bram18: u64,
}

/// Virtex UltraScale+ VU13P (the paper's evaluation part).
pub const VU13P: Device = Device {
    name: "xcvu13p",
    dsp: 12_288,
    ff: 3_456_000,
    lut: 1_728_000,
    bram18: 5_376,
};

/// DSP48E2 slices needed for one W x W multiply: the 27x18 signed port
/// accommodates one operand up to 26 bits and one up to 17; past the
/// smaller port the multiply is decomposed into two slices (the paper:
/// "an additional DSP is employed" once precision exceeds the DSP input
/// width).
pub fn dsp_per_mult(width_bits: u32) -> u64 {
    if width_bits <= 17 {
        1
    } else if width_bits <= 26 {
        2
    } else {
        4
    }
}

/// BRAM18 blocks to hold `bits` of ROM/FIFO storage (18Kb each).
pub fn bram18_for_bits(bits: u64) -> u64 {
    bits.div_ceil(18 * 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_sum() {
        let a = Resources::new(1, 10, 100, 2);
        let b = Resources::new(2, 20, 200, 3);
        assert_eq!(a + b, Resources::new(3, 30, 300, 5));
        let s: Resources = [a, b, a].into_iter().sum();
        assert_eq!(s, Resources::new(4, 40, 400, 7));
    }

    #[test]
    fn dsp_threshold_matches_paper_claim() {
        // flat until the input width is crossed, then doubles
        assert_eq!(dsp_per_mult(8), 1);
        assert_eq!(dsp_per_mult(17), 1);
        assert_eq!(dsp_per_mult(18), 2);
        assert_eq!(dsp_per_mult(26), 2);
        assert_eq!(dsp_per_mult(27), 4);
    }

    #[test]
    fn bram_rounding() {
        assert_eq!(bram18_for_bits(0), 0);
        assert_eq!(bram18_for_bits(1), 1);
        assert_eq!(bram18_for_bits(18 * 1024), 1);
        assert_eq!(bram18_for_bits(18 * 1024 + 1), 2);
    }

    #[test]
    fn vu13p_fits_check() {
        assert!(Resources::new(100, 1000, 1000, 10).fits(&VU13P));
        assert!(!Resources::new(20_000, 0, 0, 0).fits(&VU13P));
        let u = Resources::new(6144, 0, 0, 0).utilization(&VU13P);
        assert!((u[0].1 - 0.5).abs() < 1e-9);
    }
}
