//! Synthesis-style reporting — the stand-in for Vivado's utilization and
//! timing reports, formatted per design point for the experiment harness.

use super::parallelism::ParallelismPlan;
use super::precision::{PrecisionPlan, QuantConfig};
use super::resources::{Device, Resources};
use super::ReuseFactor;
use crate::fixed::FixedSpec;
use std::fmt;

/// Per-layer line of the report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub depth: u64,
    pub ii: u64,
    pub rows: u64,
    pub latency: u64,
    /// The layer site's data spec (heterogeneous plans differ per row;
    /// the MHA row reports its QKV spec).
    pub precision: FixedSpec,
    /// The layer site's reuse factor (heterogeneous parallelism plans
    /// differ per row; the MHA row reports its QKV-path reuse).
    pub reuse: ReuseFactor,
    pub resources: Resources,
}

/// One "synthesized" design point (model x precision plan x parallelism
/// plan).
#[derive(Clone, Debug)]
pub struct SynthesisReport {
    pub model: String,
    /// The embed-site pair — the whole design's pair when the plan is
    /// uniform (kept for the legacy single-`QuantConfig` consumers).
    pub quant: QuantConfig,
    /// The full per-site precision map of this design point.
    pub plan: PrecisionPlan,
    /// The full per-site reuse map of this design point.
    pub parallelism: ParallelismPlan,
    /// The worst (largest) site reuse — what gates the achievable clock;
    /// equal to the single global factor when the plan is uniform.
    pub reuse: ReuseFactor,
    pub clk_ns: f64,
    pub latency_cycles: u64,
    pub interval_cycles: u64,
    pub latency_us: f64,
    pub layers: Vec<LayerReport>,
    /// Inter-stage stream FIFOs sized from producer/consumer II mismatch
    /// (zero on every uniform parallelism plan); included in `total`.
    pub fifo: Resources,
    pub total: Resources,
}

impl SynthesisReport {
    /// One row in the format of the paper's Tables II-IV.
    pub fn table_row(&self) -> String {
        format!(
            "| {:6} | {:5.3} | {:8} | {:8} | {:6.3} |",
            self.reuse.to_string(),
            self.clk_ns,
            self.interval_cycles,
            self.latency_cycles,
            self.latency_us
        )
    }

    /// Utilization summary against a device.
    pub fn utilization_summary(&self, device: &Device) -> String {
        let mut s = String::new();
        for (name, frac) in self.total.utilization(device) {
            s.push_str(&format!("{name}: {:.2}%  ", frac * 100.0));
        }
        s
    }
}

impl fmt::Display for SynthesisReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "== {} @ {} {} | clk {:.3} ns | II {} cyc | latency {} cyc = {:.3} us",
            self.model,
            self.plan.summary(),
            self.parallelism.summary(),
            self.clk_ns,
            self.interval_cycles,
            self.latency_cycles,
            self.latency_us
        )?;
        writeln!(
            f,
            "   total: DSP {} FF {} LUT {} BRAM18 {}",
            self.total.dsp, self.total.ff, self.total.lut, self.total.bram18
        )?;
        if self.fifo.bram18 > 0 {
            writeln!(
                f,
                "   (includes {} BRAM18 of II-mismatch stream FIFOs)",
                self.fifo.bram18
            )?;
        }
        writeln!(
            f,
            "   {:<16} {:>16} {:>6} {:>6} {:>4} {:>5} {:>8} {:>7} {:>9} {:>9} {:>7}",
            "layer", "precision", "reuse", "depth", "II", "rows", "latency", "DSP", "FF",
            "LUT", "BRAM18"
        )?;
        for l in &self.layers {
            writeln!(
                f,
                "   {:<16} {:>16} {:>6} {:>6} {:>4} {:>5} {:>8} {:>7} {:>9} {:>9} {:>7}",
                l.name,
                l.precision.to_string(),
                l.reuse.to_string(),
                l.depth,
                l.ii,
                l.rows,
                l.latency,
                l.resources.dsp,
                l.resources.ff,
                l.resources.lut,
                l.resources.bram18
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::resources::VU13P;

    fn sample() -> SynthesisReport {
        let quant = QuantConfig::new(6, 8);
        SynthesisReport {
            model: "engine".into(),
            quant,
            plan: PrecisionPlan::uniform(3, quant),
            parallelism: ParallelismPlan::uniform(3, ReuseFactor(1)),
            reuse: ReuseFactor(1),
            clk_ns: 6.86,
            latency_cycles: 257,
            interval_cycles: 119,
            latency_us: 1.9,
            layers: vec![LayerReport {
                name: "embed".into(),
                depth: 4,
                ii: 1,
                rows: 50,
                latency: 53,
                precision: quant.data,
                reuse: ReuseFactor(1),
                resources: Resources::new(16, 100, 200, 0),
            }],
            fifo: Resources::ZERO,
            total: Resources::new(16, 100, 200, 0),
        }
    }

    #[test]
    fn table_row_contains_key_numbers() {
        let row = sample().table_row();
        assert!(row.contains("R1"));
        assert!(row.contains("257"));
        assert!(row.contains("119"));
    }

    #[test]
    fn display_renders_layers_with_precision_and_reuse_columns() {
        let s = format!("{}", sample());
        assert!(s.contains("embed"));
        assert!(s.contains("ap_fixed<14,6>"));
        assert!(s.contains("precision"));
        assert!(s.contains("reuse"));
        assert!(s.contains("R1"));
    }

    #[test]
    fn mixed_plan_header_says_mixed() {
        let mut rep = sample();
        rep.plan
            .set_data("block0.ffn1", crate::fixed::FixedSpec::new(8, 3))
            .unwrap();
        let s = format!("{rep}");
        assert!(s.contains("mixed<"), "{s}");
    }

    #[test]
    fn mixed_parallelism_header_and_fifo_note() {
        let mut rep = sample();
        rep.parallelism.set("block0.ffn1", ReuseFactor(4)).unwrap();
        rep.fifo = Resources::new(0, 0, 0, 3);
        let s = format!("{rep}");
        assert!(s.contains("Rmixed<1..4>"), "{s}");
        assert!(s.contains("3 BRAM18 of II-mismatch stream FIFOs"), "{s}");
    }

    #[test]
    fn utilization_summary_has_all_resources() {
        let s = sample().utilization_summary(&VU13P);
        for k in ["DSP", "FF", "LUT", "BRAM18"] {
            assert!(s.contains(k));
        }
    }
}
